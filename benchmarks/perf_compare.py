"""Before/after comparison: artifacts/dryrun_baseline vs artifacts/dryrun.

Generates the §Perf delta table for EXPERIMENTS.md (per cell: roofline terms,
peak memory, collective bytes, dominant bottleneck).
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent / "artifacts"


def load(d: str, arch: str, shape: str, mesh: str = "16x16"):
    p = ROOT / d / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def delta_row(arch: str, shape: str, mesh: str = "16x16") -> str | None:
    b = load("dryrun_baseline", arch, shape, mesh)
    o = load("dryrun", arch, shape, mesh)
    if not b or not o or not b.get("compile_ok") or not o.get("compile_ok"):
        return None

    def terms(r):
        t = r["roofline"]
        return (t["compute_s"], t["memory_s"], t["collective_s"],
                r["memory"]["peak_bytes_est"] / 1e9,
                max(t["compute_s"], t["memory_s"], t["collective_s"]))

    cb, mb, lb, pb, boundb = terms(b)
    co, mo, lo, po, boundo = terms(o)
    speedup = boundb / boundo if boundo > 0 else float("inf")
    return (f"| {arch} | {shape} | {cb:.2f}/{mb:.2f}/{lb:.2f} | "
            f"{co:.2f}/{mo:.2f}/{lo:.2f} | {pb:.1f} -> {po:.1f} | "
            f"{speedup:.2f}x |")


def main():
    print("| arch | shape | baseline C/M/N (s) | optimized C/M/N (s) | "
          "peak GB | bound speedup |")
    print("|---|---|---|---|---|---|")
    cells = []
    for p in sorted((ROOT / "dryrun").glob("*__16x16.json")):
        arch, shape, _ = p.stem.split("__")
        cells.append((arch, shape))
    for arch, shape in cells:
        r = delta_row(arch, shape)
        if r:
            print(r)


if __name__ == "__main__":
    main()
