"""Core stage-graph benchmark: fused vs. unfused phase A (BENCH_core.json).

Times PixHomology **steps 1-4** (phase A pointers/flags -> phase B label
resolution -> candidate generation) on astro frames, for three stage
pipelines:

* ``seed``   — the pre-stage-graph baseline: pooled ``arg-maxpool2d``, the
  whole-image ``m[m]`` doubling loop with its cond/body double gather, and
  rank-based exact candidates (which pull in the full-image
  ``total_order_rank`` argsort they depend on);
* ``pooled`` — the unfused path after the single-gather fix (same data
  flow, half the doubling gathers);
* ``fused``  — the fused phase-A kernel path (pointer+mask sweep, in-strip
  snap, compacted-frontier resolution, bitmask candidates — no argsort
  dependency in steps 1-4 at all).

Also reports end-to-end ``pixhomology`` wall time (where the argsort is
shared with phase C on every path, so the gap narrows — reported so the
stage numbers cannot oversell), frontier sizes, doubling-iteration counts,
and phase-B gather volumes (the O(n·log depth) -> O(frontier·log depth)
reduction from src/repro/ph/DESIGN.md §2).

  PYTHONPATH=src python -m benchmarks.core_bench --sizes 512 1024 \
      --out BENCH_core.json

CI runs a small-size smoke of this every push and uploads the artifact so
the core-stage perf trajectory accumulates next to the tiled/pipeline
benches.
"""
from __future__ import annotations

import argparse
import functools
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts"


def _timeit(fn, *args, repeats: int = 3):
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def _stage_fns(shape: tuple[int, int], strip_rows: int):
    """Jitted steps-1-4 programs for the three stage pipelines."""
    from repro.core.pixhomology import (
        exact_candidates,
        exact_candidates_masked,
        resolve_labels,
        resolve_labels_frontier,
        steepest_neighbors,
        total_order_rank,
    )
    from repro.kernels.ph_phase_a import ref as phase_a_ref
    h, w = shape

    @jax.jit
    def seed(im):
        ptr = steepest_neighbors(im)

        def cond(m):          # the pre-PR double gather: cond recomputes m[m]
            return jnp.any(m[m] != m)

        def body(m):
            return m[m]

        labels = jax.lax.while_loop(cond, body, ptr)
        rank = total_order_rank(im.reshape(-1))
        cand = exact_candidates(rank.reshape(h, w), labels.reshape(h, w))
        return jnp.sum(cand, dtype=jnp.int32)

    @jax.jit
    def pooled(im):
        ptr = steepest_neighbors(im)
        labels, iters = resolve_labels(ptr, with_count=True)
        rank = total_order_rank(im.reshape(-1))
        cand = exact_candidates(rank.reshape(h, w), labels.reshape(h, w))
        return jnp.sum(cand, dtype=jnp.int32), iters

    @jax.jit
    def fused(im):
        ptr, mask, snap_iters = phase_a_ref.phase_a(
            im, strip_rows=strip_rows, with_stats=True)
        labels, table_iters = resolve_labels_frontier(
            ptr, (h, w), strip_rows, with_count=True)
        cand = exact_candidates_masked(mask.reshape(h, w),
                                       labels.reshape(h, w))
        return jnp.sum(cand, dtype=jnp.int32), snap_iters, table_iters

    return seed, pooled, fused


def bench_size(size: int, *, strip_rows: int, repeats: int,
               end_to_end: bool, deep_sky: bool) -> dict:
    from repro.data import astro
    from repro.kernels.ph_phase_a.ops import boundary_rows

    img_np = astro.generate_image(0, size)
    if deep_sky:
        # Strong radial sky gradient (nebulosity): basins span the frame,
        # the regime where chain depth dwarfs the strip height.
        yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
        img_np = img_np - 4e-2 * ((yy - size / 2) ** 2
                                  + (xx - size / 2) ** 2) / size
    img = jnp.asarray(img_np)
    n = size * size
    frontier = int(len(boundary_rows(size, strip_rows))) * size

    seed, pooled, fused = _stage_fns((size, size), strip_rows)
    t_seed, n_cand = _timeit(seed, img, repeats=repeats)
    t_pool, (n_cand_p, dense_iters) = _timeit(pooled, img, repeats=repeats)
    t_fuse, (n_cand_f, snap_iters, table_iters) = _timeit(
        fused, img, repeats=repeats)
    assert int(n_cand) == int(n_cand_p) == int(n_cand_f), \
        "stage pipelines disagree on the candidate set"

    row = {
        "name": f"core_{size}{'_deep' if deep_sky else ''}",
        "size": size,
        "deep_sky": deep_sky,
        "strip_rows": strip_rows,
        "n_candidates": int(n_cand),
        # steps 1-4 stage times (each pipeline computes what it depends on:
        # the rank argsort for the rank-based candidate generators, nothing
        # but the image for the fused bitmask path)
        "stage_seed_s": t_seed,
        "stage_unfused_s": t_pool,
        "stage_fused_s": t_fuse,
        "fused_speedup_vs_unfused": t_pool / t_fuse,
        "fused_beats_unfused": t_fuse < t_pool,
        # resolution structure
        "dense_iters": int(dense_iters),
        "snap_iters": int(snap_iters),
        "table_iters": int(table_iters),
        "frontier": frontier,
        "frontier_frac": frontier / n,
        # phase-B gather volume (elements gathered by the doubling loops):
        # dense = iters * n (seed pays 2x: cond re-gathers); frontier =
        # iters * frontier + one final dense composition gather.
        "phase_b_gather_seed": 2 * int(dense_iters) * n,
        "phase_b_gather_unfused": int(dense_iters) * n,
        "phase_b_gather_fused": int(table_iters) * frontier + n,
    }

    if end_to_end:
        from repro.core.pixhomology import pixhomology
        kw = dict(max_features=min(4096, n), max_candidates=min(16384, n),
                  merge_impl="boruvka")
        run_f = functools.partial(pixhomology, phase_a_impl="fused",
                                  strip_rows=strip_rows, **kw)
        run_p = functools.partial(pixhomology, phase_a_impl="pooled", **kw)
        t_ef, d_f = _timeit(run_f, img, repeats=repeats)
        t_ep, d_p = _timeit(run_p, img, repeats=repeats)
        np.testing.assert_array_equal(np.asarray(d_f.birth),
                                      np.asarray(d_p.birth))
        row["e2e_fused_s"] = t_ef
        row["e2e_unfused_s"] = t_ep
        row["e2e_count"] = int(d_f.count)
        row["e2e_overflow"] = bool(d_f.overflow)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="*", default=[512, 1024])
    ap.add_argument("--strip-rows", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--deep-sky", action="store_true",
                    help="add a deep-sky-gradient variant per size (basins "
                         "spanning the frame: the deep-chain regime)")
    ap.add_argument("--no-e2e", action="store_true",
                    help="skip the end-to-end pixhomology timings")
    ap.add_argument("--out", default=None,
                    help="output path (default artifacts/BENCH_core.json)")
    args = ap.parse_args()

    rows = []
    for size in args.sizes:
        variants = [False, True] if args.deep_sky else [False]
        for deep in variants:
            row = bench_size(size, strip_rows=args.strip_rows,
                             repeats=args.repeats,
                             end_to_end=not args.no_e2e, deep_sky=deep)
            rows.append(row)
            print(f"{row['name']}: seed={row['stage_seed_s'] * 1e3:.1f}ms "
                  f"unfused={row['stage_unfused_s'] * 1e3:.1f}ms "
                  f"fused={row['stage_fused_s'] * 1e3:.1f}ms "
                  f"({row['fused_speedup_vs_unfused']:.1f}x, "
                  f"frontier {row['frontier_frac']:.1%}, "
                  f"gathers {row['phase_b_gather_unfused']:.2e}->"
                  f"{row['phase_b_gather_fused']:.2e})")

    out_path = Path(args.out) if args.out else ARTIFACTS / "BENCH_core.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rows, indent=2, sort_keys=True))
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
