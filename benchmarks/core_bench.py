"""Core stage-graph benchmark: fused vs. unfused phase A (BENCH_core.json).

Times PixHomology **steps 1-4** (phase A pointers/flags -> phase B label
resolution -> candidate generation) on astro frames, for three stage
pipelines:

* ``seed``   — the pre-stage-graph baseline: pooled ``arg-maxpool2d``, the
  whole-image ``m[m]`` doubling loop with its cond/body double gather, and
  rank-based exact candidates (which pull in the full-image
  ``total_order_rank`` argsort they depend on);
* ``pooled`` — the unfused path after the single-gather fix (same data
  flow, half the doubling gathers);
* ``fused``  — the fused phase-A kernel path (pointer+mask sweep, in-strip
  snap, compacted-frontier resolution, bitmask candidates — no argsort
  dependency in steps 1-4 at all).

Also reports end-to-end ``pixhomology`` wall time (where the argsort is
shared with phase C on every path, so the gap narrows — reported so the
stage numbers cannot oversell), frontier sizes, doubling-iteration counts,
and phase-B gather volumes (the O(n·log depth) -> O(frontier·log depth)
reduction from src/repro/ph/DESIGN.md §2).

Phase C (rank-free merge keys): every row additionally times the phase-C
stage and the key materialization under ``merge_keys="packed"`` (bit-cast
int64 keys, candidate compaction) vs ``"rank"`` (the full-image stable
argsort), and audits the compiled HLO of both phase-C programs for sort
ops — ``full_image_sorts_packed`` must be 0: the packed path contains no
sort whose operand spans all n pixels (its only sorts order the compact
candidate/root buffers).  CI asserts exactly that on the smoke artifact.

  PYTHONPATH=src python -m benchmarks.core_bench --sizes 512 1024 \
      --out BENCH_core.json

CI runs a small-size smoke of this every push and uploads the artifact so
the core-stage perf trajectory accumulates next to the tiled/pipeline
benches.
"""
from __future__ import annotations

import argparse
import functools
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts"

_SHAPE_DIMS = re.compile(r"\[(\d+(?:,\d+)*)\]")


def _sort_audit(hlo_text: str, n: int) -> tuple[int, int]:
    """(total sort ops, full-image sorts) in one compiled HLO module.

    XLA sorts along the trailing dimension (that is where jax lowers
    ``argsort``/``top_k``), so a sort whose trailing extent reaches the
    pixel count n orders the whole image — the rank path's argsorts and
    its full-array top_k selections.  The packed path's tournament
    selections sort 2k-wide blocks and must report zero of them.
    """
    total = full = 0
    for line in hlo_text.splitlines():
        if " sort(" not in line:
            continue
        total += 1
        trailing = [int(m.split(",")[-1]) for m in _SHAPE_DIMS.findall(line)]
        if trailing and max(trailing) >= n:
            full += 1
    return total, full


def _timeit(fn, *args, repeats: int = 3):
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def _stage_fns(shape: tuple[int, int], strip_rows: int):
    """Jitted steps-1-4 programs for the three stage pipelines."""
    from repro.core.pixhomology import (
        exact_candidates,
        exact_candidates_masked,
        resolve_labels,
        resolve_labels_frontier,
        steepest_neighbors,
        total_order_rank,
    )
    from repro.kernels.ph_phase_a import ref as phase_a_ref
    h, w = shape

    @jax.jit
    def seed(im):
        ptr = steepest_neighbors(im)

        def cond(m):          # the pre-PR double gather: cond recomputes m[m]
            return jnp.any(m[m] != m)

        def body(m):
            return m[m]

        labels = jax.lax.while_loop(cond, body, ptr)
        rank = total_order_rank(im.reshape(-1))
        cand = exact_candidates(rank.reshape(h, w), labels.reshape(h, w))
        return jnp.sum(cand, dtype=jnp.int32)

    @jax.jit
    def pooled(im):
        ptr = steepest_neighbors(im)
        labels, iters = resolve_labels(ptr, with_count=True)
        rank = total_order_rank(im.reshape(-1))
        cand = exact_candidates(rank.reshape(h, w), labels.reshape(h, w))
        return jnp.sum(cand, dtype=jnp.int32), iters

    @jax.jit
    def fused(im):
        ptr, mask, snap_iters = phase_a_ref.phase_a(
            im, strip_rows=strip_rows, with_stats=True)
        labels, table_iters = resolve_labels_frontier(
            ptr, (h, w), strip_rows, with_count=True)
        cand = exact_candidates_masked(mask.reshape(h, w),
                                       labels.reshape(h, w))
        return jnp.sum(cand, dtype=jnp.int32), snap_iters, table_iters

    return seed, pooled, fused


def _phase_c_fns(shape: tuple[int, int], mf: int, mc: int, *,
                 phase_c_block: int = 1024, tournament_width: int = 2):
    """Jitted phase-C programs (key materialization + merge + diagram),
    taking precomputed labels/candidates so the timing isolates exactly
    the stage under comparison: the two key encodings (both on the plain
    XLA merge, the historical packed-vs-rank comparison) plus the fused
    compact-instance impl on packed keys (the phase_c_impl comparison)."""
    from repro.core.pixhomology import phase_c, total_order_keys
    h, w = shape

    def run(vals, labels, cand, tv, merge_keys, phase_c_impl):
        key = total_order_keys(vals, merge_keys)
        return phase_c(vals, key, labels, cand, (h, w), tv,
                       max_features=mf, max_candidates=mc,
                       merge_impl="boruvka", phase_c_impl=phase_c_impl,
                       phase_c_block=phase_c_block,
                       tournament_width=tournament_width)

    return (jax.jit(functools.partial(run, merge_keys="rank",
                                      phase_c_impl="xla")),
            jax.jit(functools.partial(run, merge_keys="packed",
                                      phase_c_impl="xla")),
            jax.jit(functools.partial(run, merge_keys="packed",
                                      phase_c_impl="fused")))


def bench_merge_keys(img, *, strip_rows: int, repeats: int,
                     end_to_end: bool, phase_c_block: int = 1024,
                     tournament_width: int = 2) -> dict:
    """Packed-vs-rank phase C: stage + e2e times and the HLO sort audit.

    Runs under the Variant-2 ``filter_std`` threshold — the pipeline's
    production regime, where candidates/roots are a small fraction of n
    and capacity buffers are genuinely sub-image-sized (unfiltered astro
    noise makes ~0.6n pixels candidates, at which point a k-candidate
    selection is a full-image sort for any encoding)."""
    from repro.core import packed_keys
    from repro.core.pixhomology import (
        exact_candidates_masked,
        phase_a,
        phase_b,
        pixhomology,
    )
    from repro.data import astro
    h, w = img.shape
    n = h * w
    tval, _ = astro.filter_threshold(np.asarray(img), "filter_std")
    tv = jnp.asarray(tval, jnp.float32)

    @jax.jit
    def stages_ab(im):
        pa = phase_a(im, strip_rows=strip_rows)
        labels = phase_b(pa, (h, w), strip_rows=strip_rows)
        cand = exact_candidates_masked(pa.hi_mask.reshape(h, w),
                                       labels.reshape(h, w)).reshape(-1)
        return labels, cand

    labels, cand = jax.block_until_ready(stages_ab(img))
    vals = img.reshape(-1)
    # Size the buffers to the measured filtered workload so neither path
    # overflows and the bit-equality below covers full diagrams; both
    # paths share the same capacities.
    n_cand = int(np.asarray(cand & (vals >= tv)).sum())
    n_roots = int(np.asarray(
        (labels == jnp.arange(n, dtype=jnp.int32)) & (vals >= tv)).sum())
    mf, mc = max(n_roots, 1), max(n_cand, 1)
    fn_rank, fn_packed, fn_fused = _phase_c_fns(
        (h, w), mf, mc, phase_c_block=phase_c_block,
        tournament_width=tournament_width)

    # Compile each program once: the compiled executable serves both the
    # HLO sort audit and the timing loop.
    comp_rank = fn_rank.lower(vals, labels, cand, tv).compile()
    with packed_keys.key_scope("packed"):
        comp_packed = fn_packed.lower(vals, labels, cand, tv).compile()
        comp_fused = fn_fused.lower(vals, labels, cand, tv).compile()

    t_rank, d_rank = _timeit(comp_rank, vals, labels, cand, tv,
                             repeats=repeats)
    t_packed, d_packed = _timeit(comp_packed, vals, labels, cand, tv,
                                 repeats=repeats)
    t_fused, d_fused = _timeit(comp_fused, vals, labels, cand, tv,
                               repeats=repeats)
    assert not bool(d_rank.overflow), \
        "bench capacities overflowed; raise mf/mc in bench_merge_keys"
    np.testing.assert_array_equal(np.asarray(d_rank.birth),
                                  np.asarray(d_packed.birth))
    np.testing.assert_array_equal(np.asarray(d_rank.p_death),
                                  np.asarray(d_packed.p_death))
    # phase_c_impl bit-identity on full diagrams (fused vs plain XLA).
    for field in ("birth", "death", "p_birth", "p_death", "count"):
        np.testing.assert_array_equal(
            np.asarray(getattr(d_packed, field)),
            np.asarray(getattr(d_fused, field)),
            err_msg=f"fused phase C diverged from xla on {field}")

    # Boruvka round counts (one untimed call per impl): the fused compact
    # instance must never need *more* rounds than the full-image merge,
    # and the merge-budget early exit keeps both at O(log C).
    from repro.core.parallel_merge import boruvka_merge
    from repro.kernels.ph_phase_c.ops import fused_merge

    @jax.jit
    def rounds_of(vals, labels, cand):
        from repro.core.pixhomology import total_order_keys
        key = total_order_keys(vals, "packed")
        root_mask = (labels == jnp.arange(n, dtype=jnp.int32)) & (vals >= tv)
        cand_b = cand & (vals >= tv)
        *_, r_xla = boruvka_merge(
            vals, key, labels, cand_b, (h, w), mc,
            n_live=jnp.sum(root_mask, dtype=jnp.int32),
            tournament_width=tournament_width)
        *_, r_fused = fused_merge(
            vals, key, labels, cand_b, root_mask, (h, w),
            max_candidates=mc, max_features=mf,
            phase_c_block=phase_c_block, tournament_width=tournament_width)
        return r_xla, r_fused

    with packed_keys.key_scope("packed"):
        r_xla, r_fused = jax.block_until_ready(rounds_of(vals, labels, cand))

    sorts_rank, full_rank = _sort_audit(comp_rank.as_text(), n)
    sorts_packed, full_packed = _sort_audit(comp_packed.as_text(), n)
    sorts_fused, full_fused = _sort_audit(comp_fused.as_text(), n)
    assert full_packed == 0, \
        f"packed phase C still contains {full_packed} full-image sort(s)"
    assert full_fused == 0, \
        f"fused phase C still contains {full_fused} full-image sort(s)"

    row = {
        "merge_keys_mf": mf,
        "merge_keys_mc": mc,
        "merge_keys_threshold": float(tval),
        "phase_c_rank_s": t_rank,
        "phase_c_packed_s": t_packed,
        "phase_c_packed_speedup": t_rank / t_packed,
        # phase_c_impl comparison: both on packed keys, same capacities.
        "phase_c_xla_s": t_packed,
        "phase_c_fused_s": t_fused,
        "phase_c_fused_speedup": t_packed / t_fused,
        "boruvka_rounds_xla": int(r_xla),
        "boruvka_rounds_fused": int(r_fused),
        "hlo_sorts_rank": sorts_rank,
        "hlo_sorts_packed": sorts_packed,
        "hlo_sorts_fused": sorts_fused,
        "full_image_sorts_rank": full_rank,
        "full_image_sorts_packed": full_packed,
        "full_image_sorts_fused": full_fused,
    }

    if end_to_end:
        kw = dict(max_features=mf, max_candidates=mc, merge_impl="boruvka",
                  strip_rows=strip_rows)
        run_p = functools.partial(pixhomology, merge_keys="packed", **kw)
        run_r = functools.partial(pixhomology, merge_keys="rank", **kw)
        t_ep, d_p = _timeit(run_p, img, tv, repeats=repeats)
        t_er, d_r = _timeit(run_r, img, tv, repeats=repeats)
        np.testing.assert_array_equal(np.asarray(d_p.birth),
                                      np.asarray(d_r.birth))
        row["e2e_packed_s"] = t_ep
        row["e2e_rank_s"] = t_er
        row["e2e_packed_speedup"] = t_er / t_ep
    return row


def bench_size(size: int, *, strip_rows: int, repeats: int,
               end_to_end: bool, deep_sky: bool,
               phase_c_block: int = 1024,
               tournament_width: int = 2,
               autotuned: dict | None = None) -> dict:
    from repro.data import astro
    from repro.kernels.ph_phase_a.ops import boundary_rows

    img_np = astro.generate_image(0, size)
    if deep_sky:
        # Strong radial sky gradient (nebulosity): basins span the frame,
        # the regime where chain depth dwarfs the strip height.
        yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
        img_np = img_np - 4e-2 * ((yy - size / 2) ** 2
                                  + (xx - size / 2) ** 2) / size
    img = jnp.asarray(img_np)
    n = size * size
    frontier = int(len(boundary_rows(size, strip_rows))) * size

    seed, pooled, fused = _stage_fns((size, size), strip_rows)
    t_seed, n_cand = _timeit(seed, img, repeats=repeats)
    t_pool, (n_cand_p, dense_iters) = _timeit(pooled, img, repeats=repeats)
    t_fuse, (n_cand_f, snap_iters, table_iters) = _timeit(
        fused, img, repeats=repeats)
    assert int(n_cand) == int(n_cand_p) == int(n_cand_f), \
        "stage pipelines disagree on the candidate set"

    row = {
        "name": f"core_{size}{'_deep' if deep_sky else ''}",
        "size": size,
        "deep_sky": deep_sky,
        "strip_rows": strip_rows,
        "n_candidates": int(n_cand),
        # steps 1-4 stage times (each pipeline computes what it depends on:
        # the rank argsort for the rank-based candidate generators, nothing
        # but the image for the fused bitmask path)
        "stage_seed_s": t_seed,
        "stage_unfused_s": t_pool,
        "stage_fused_s": t_fuse,
        "fused_speedup_vs_unfused": t_pool / t_fuse,
        "fused_beats_unfused": t_fuse < t_pool,
        # resolution structure
        "dense_iters": int(dense_iters),
        "snap_iters": int(snap_iters),
        "table_iters": int(table_iters),
        "frontier": frontier,
        "frontier_frac": frontier / n,
        # phase-B gather volume (elements gathered by the doubling loops):
        # dense = iters * n (seed pays 2x: cond re-gathers); frontier =
        # iters * frontier + one final dense composition gather.
        "phase_b_gather_seed": 2 * int(dense_iters) * n,
        "phase_b_gather_unfused": int(dense_iters) * n,
        "phase_b_gather_fused": int(table_iters) * frontier + n,
    }

    if end_to_end:
        from repro.core.pixhomology import pixhomology
        # Historical fused-vs-pooled comparison stays on rank keys so the
        # trend is comparable across artifacts; the packed-vs-rank rows
        # below carry the key-encoding comparison.
        kw = dict(max_features=min(4096, n), max_candidates=min(16384, n),
                  merge_impl="boruvka", merge_keys="rank")
        run_f = functools.partial(pixhomology, phase_a_impl="fused",
                                  strip_rows=strip_rows, **kw)
        run_p = functools.partial(pixhomology, phase_a_impl="pooled", **kw)
        t_ef, d_f = _timeit(run_f, img, repeats=repeats)
        t_ep, d_p = _timeit(run_p, img, repeats=repeats)
        np.testing.assert_array_equal(np.asarray(d_f.birth),
                                      np.asarray(d_p.birth))
        row["e2e_fused_s"] = t_ef
        row["e2e_unfused_s"] = t_ep
        row["e2e_count"] = int(d_f.count)
        row["e2e_overflow"] = bool(d_f.overflow)

    row.update(bench_merge_keys(img, strip_rows=strip_rows,
                                repeats=repeats, end_to_end=end_to_end,
                                phase_c_block=phase_c_block,
                                tournament_width=tournament_width))
    if autotuned:
        row.update(autotuned)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="*", default=[512, 1024])
    ap.add_argument("--strip-rows", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--deep-sky", action="store_true",
                    help="add a deep-sky-gradient variant per size (basins "
                         "spanning the frame: the deep-chain regime)")
    ap.add_argument("--no-e2e", action="store_true",
                    help="skip the end-to-end pixhomology timings")
    ap.add_argument("--autotune", action="store_true",
                    help="run the roofline autotuner per size first (tiny "
                         "measurement budget), fold the tuned strip_rows / "
                         "phase_c_block / tournament_width into the bench, "
                         "and persist the cache")
    ap.add_argument("--autotune-cache", default=None,
                    help="autotune cache path (default "
                         "artifacts/autotune_cache.json)")
    ap.add_argument("--out", default=None,
                    help="output path (default artifacts/BENCH_core.json)")
    args = ap.parse_args()

    rows = []
    for size in args.sizes:
        strip_rows, pc_block, t_width = args.strip_rows, 1024, 2
        autotuned = None
        if args.autotune:
            from repro.roofline import autotune as at
            tp = at.autotune((size, size), "float32",
                             path=args.autotune_cache,
                             measure_top=2, trials=2)
            autotuned = {"autotune_strip_rows": tp.strip_rows,
                         "autotune_phase_c_block": tp.phase_c_block,
                         "autotune_tournament_width": tp.tournament_width,
                         "autotune_source": tp.source}
            if tp.source != "default":
                strip_rows, pc_block, t_width = (
                    tp.strip_rows, tp.phase_c_block, tp.tournament_width)
            print(f"autotune {size}x{size}: {autotuned}")
        variants = [False, True] if args.deep_sky else [False]
        for deep in variants:
            row = bench_size(size, strip_rows=strip_rows,
                             repeats=args.repeats,
                             end_to_end=not args.no_e2e, deep_sky=deep,
                             phase_c_block=pc_block,
                             tournament_width=t_width,
                             autotuned=autotuned)
            rows.append(row)
            print(f"{row['name']}: seed={row['stage_seed_s'] * 1e3:.1f}ms "
                  f"unfused={row['stage_unfused_s'] * 1e3:.1f}ms "
                  f"fused={row['stage_fused_s'] * 1e3:.1f}ms "
                  f"({row['fused_speedup_vs_unfused']:.1f}x, "
                  f"frontier {row['frontier_frac']:.1%}, "
                  f"gathers {row['phase_b_gather_unfused']:.2e}->"
                  f"{row['phase_b_gather_fused']:.2e})")
            print(f"  phase C rank={row['phase_c_rank_s'] * 1e3:.1f}ms "
                  f"packed={row['phase_c_packed_s'] * 1e3:.1f}ms "
                  f"({row['phase_c_packed_speedup']:.1f}x; full-image "
                  f"sorts {row['full_image_sorts_rank']}->"
                  f"{row['full_image_sorts_packed']})")
            print(f"  phase C impl xla={row['phase_c_xla_s'] * 1e3:.1f}ms "
                  f"fused={row['phase_c_fused_s'] * 1e3:.1f}ms "
                  f"({row['phase_c_fused_speedup']:.1f}x; rounds "
                  f"{row['boruvka_rounds_xla']}->"
                  f"{row['boruvka_rounds_fused']})")

    out_path = Path(args.out) if args.out else ARTIFACTS / "BENCH_core.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rows, indent=2, sort_keys=True))
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
