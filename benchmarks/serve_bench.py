"""Serving benchmark: warmed daemon SLOs + saturation (BENCH_serve.json).

Two measured sections over :mod:`repro.serving`:

``steady``
    Boot a :class:`~repro.serving.PHServer`, ``warmup()`` the plan pool
    (the warmup dummy pre-walks the capacity regrow chain, so its cost —
    reported as ``warmup.seconds`` — buys a trace-free steady state),
    then drive a sustained mixed-shape stream from ``--clients``
    submitter threads.  Reports per-bucket p50/p95/p99 queue-wait and
    end-to-end latency, batch occupancy, throughput, plan-cache stats,
    and ``steady_state_traces`` — the engine's own trace counters
    measured across the stream, asserted **zero** here and again by
    ``benchmarks.perf_gate`` on the artifact.

``saturation``
    A second server with a tiny admission bound (``--sat-queue``) hit
    with an instantaneous burst: proves backpressure engages (rejections
    counted, every rejection carrying a ``retry_after_s`` hint) and the
    accepted requests still all resolve.

``cache``
    A delta-enabled server (exact-hash cache tier + per-request
    ``run_delta`` dispatch) driven with a survey-style request mix: a
    miss pass of distinct frames, a steady-state **repeat** pass of
    exact duplicates (must short-circuit on the submit thread —
    ``steady_state_hits``), and a near-duplicate pass that rides the
    delta frame store (partial hits).  Gated by ``perf_gate``:
    ``steady_state_hits > 0``.

  PYTHONPATH=src python -m benchmarks.serve_bench --buckets 64 128 \
      --clients 4 --requests 32 --out BENCH_serve.json

CI runs a small-bucket smoke per push, uploads the artifact, and gates
on it via ``python -m benchmarks.perf_gate --serve BENCH_serve.json``.
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path

import numpy as np

from repro.ph import PHConfig, PHEngine, ServeSpec
from repro.serving import AdmissionError, PHServer


def mixed_shapes(buckets, rng, count):
    """Shapes cycling the bucket set, 60-100%% of each side: every
    dispatch exercises pad + repair, none escapes its bucket."""
    out = []
    for i in range(count):
        hb, wb = buckets[i % len(buckets)]
        out.append((int(rng.integers(max(2, int(hb * 0.6)), hb + 1)),
                    int(rng.integers(max(2, int(wb * 0.6)), wb + 1))))
    return out


def steady_section(config, args) -> dict:
    engine = PHEngine(config)
    server = PHServer(engine)
    warm = server.warmup()
    results = {"ok": 0}
    lock = threading.Lock()

    def client(cid):
        rng = np.random.default_rng(args.seed + 100 + cid)
        futs = []
        for shape in mixed_shapes(config.serve.buckets, rng,
                                  args.requests):
            futs.append(server.submit(
                rng.normal(size=shape).astype(np.float32)))
        for f in futs:
            f.result(timeout=600)
        with lock:
            results["ok"] += len(futs)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(i,), daemon=True)
               for i in range(args.clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert server.drain(60), "steady stream failed to drain"
    elapsed = time.perf_counter() - t0
    stats = server.stats()
    server.shutdown()
    sst = stats["steady_state_traces"]
    assert sst == 0, \
        f"steady state re-traced {sst} plans: {stats['engine']}"
    assert stats["failed"] == 0 and stats["rejected"] == 0
    assert stats["completed"] == results["ok"] \
        == args.clients * args.requests
    return {"warmup": warm,
            "clients": args.clients,
            "requests": results["ok"],
            "elapsed_s": round(elapsed, 4),
            "throughput_rps": round(results["ok"] / elapsed, 2),
            **stats}


def saturation_section(config, args) -> dict:
    """Burst a tiny-queue server: backpressure must reject, survivors
    must resolve."""
    sat_spec = ServeSpec(buckets=config.serve.buckets,
                         batch_cap=config.serve.batch_cap,
                         max_queue=args.sat_queue,
                         # slow tick: the burst outruns the drain
                         tick_interval_s=0.05,
                         admission="reject")
    engine = PHEngine(config.replace(serve=sat_spec))
    server = PHServer(engine)
    server.warmup()
    rng = np.random.default_rng(args.seed + 999)
    burst = args.sat_burst
    hb, wb = sat_spec.buckets[0]
    futs, rejected, retry_hints = [], 0, []
    for _ in range(burst):
        img = rng.normal(size=(hb, wb)).astype(np.float32)
        try:
            futs.append(server.submit(img))
        except AdmissionError as e:
            rejected += 1
            retry_hints.append(e.retry_after_s)
    for f in futs:
        f.result(timeout=600)
    assert server.drain(60)
    stats = server.stats()
    server.shutdown()
    assert rejected > 0, \
        f"burst of {burst} never saturated max_queue={args.sat_queue}"
    assert stats["rejected"] == rejected
    assert stats["completed"] == len(futs) == burst - rejected
    return {"burst": burst,
            "max_queue": args.sat_queue,
            "accepted": len(futs),
            "rejected": rejected,
            "retry_after_s_mean": round(float(np.mean(retry_hints)), 6),
            **stats}


def cache_section(config, args) -> dict:
    """Survey mix against the delta-enabled cache tier: distinct frames
    miss, exact repeats hit on the submit thread, near-duplicates ride
    the frame store."""
    from repro.ph import DeltaSpec, TileSpec

    hb, wb = config.serve.buckets[0]
    engine = PHEngine(config.replace(
        delta=DeltaSpec(cache_entries=max(8, args.cache_uniques)),
        tile=TileSpec(grid=(2, 2))))
    server = PHServer(engine)
    rng = np.random.default_rng(args.seed + 7)
    frames = [rng.normal(size=(hb, wb)).astype(np.float32)
              for _ in range(args.cache_uniques)]

    for f in [server.submit(im) for im in frames]:        # miss pass
        f.result(timeout=600)
    t0 = time.perf_counter()
    repeats = frames * args.cache_repeats                 # repeat pass
    for f in [server.submit(im) for im in repeats]:
        f.result(timeout=600)
    repeat_s = time.perf_counter() - t0
    near = []                                             # near-dup pass
    for im in frames:
        im2 = im.copy()
        im2[hb // 4, wb // 4] += 3.0    # interior of tile (0, 0)
        near.append(im2)
    for f in [server.submit(im) for im in near]:
        f.result(timeout=600)
    assert server.drain(60), "cache stream failed to drain"
    stats = server.cache_stats()
    server.shutdown()

    hits = stats["hits"]
    assert hits >= len(repeats), \
        f"exact repeats only hit {hits}/{len(repeats)} times"
    assert stats["delta_store"]["partial_hits"] >= len(near), \
        f"near-duplicates did not ride the delta store: {stats}"
    return {"uniques": args.cache_uniques,
            "repeats": len(repeats),
            "near_dups": len(near),
            "steady_state_hits": hits,
            "misses": stats["misses"],
            "repeat_pass_s": round(repeat_s, 4),
            "hit_rps": round(len(repeats) / max(repeat_s, 1e-9), 1),
            **{k: v for k, v in stats.items() if k != "hits"}}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--buckets", type=int, nargs="+", default=[64, 128])
    ap.add_argument("--batch-cap", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--tick-ms", type=float, default=2.0)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=32,
                    help="requests per client in the steady section")
    ap.add_argument("--sat-queue", type=int, default=4,
                    help="admission bound for the saturation burst")
    ap.add_argument("--sat-burst", type=int, default=48)
    ap.add_argument("--filter", dest="filter_level", default=None,
                    choices=["vanilla", "filter_std", "filter_database"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-saturation", action="store_true")
    ap.add_argument("--no-cache", action="store_true",
                    help="skip the delta cache-tier section")
    ap.add_argument("--cache-uniques", type=int, default=4,
                    help="distinct frames in the cache section")
    ap.add_argument("--cache-repeats", type=int, default=3,
                    help="exact-duplicate passes over the frames")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    kw = {}
    if args.filter_level:
        from repro.ph import FilterLevel
        kw["filter_level"] = FilterLevel(args.filter_level)
    config = PHConfig(serve=ServeSpec(
        buckets=tuple(args.buckets), batch_cap=args.batch_cap,
        max_queue=args.max_queue,
        tick_interval_s=args.tick_ms / 1e3), **kw)

    out = {"config": json.loads(config.to_json()),
           "steady": steady_section(config, args)}
    if not args.no_saturation:
        out["saturation"] = saturation_section(config, args)
    if not args.no_cache:
        out["cache"] = cache_section(config, args)
    Path(args.out).write_text(json.dumps(out, indent=1))
    brief = {"steady_state_traces": out["steady"]["steady_state_traces"],
             "throughput_rps": out["steady"]["throughput_rps"],
             "occupancy": {k: v["occupancy"] for k, v in
                           out["steady"]["buckets"].items()},
             "p95_e2e_s": {k: v["e2e_s"].get("p95") for k, v in
                           out["steady"]["buckets"].items()},
             "saturation_rejected":
                 out.get("saturation", {}).get("rejected"),
             "cache_steady_state_hits":
                 out.get("cache", {}).get("steady_state_hits")}
    print(json.dumps(brief, indent=1))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
