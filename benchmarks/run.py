"""Benchmark harness: one function per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (per the repo skeleton contract)
and a readable JSON dump to artifacts/bench_results.json.
"""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts"


def main() -> None:
    from benchmarks import paper_tables

    rows: list[dict] = []
    print("# PixHomology paper benchmarks (reduced sizes, same methodology)")
    paper_tables.table1_filtering(rows=rows)
    paper_tables.fig6_partitioning(rows=rows)
    paper_tables.fig7_equality(rows=rows)
    paper_tables.fig9_10_scaling(rows=rows)
    paper_tables.fig11_dipha(rows=rows)
    paper_tables.perf_merge_impl(rows=rows)
    paper_tables.tiled_vs_whole(rows=rows)

    paper_tables.print_rows(rows)

    # Engine plan-cache summary: every table above shares compiled plans
    # through repro.ph.PHEngine, so traces << calls.
    cache = paper_tables.plan_cache_summary()
    print("# plan cache: " + ";".join(f"{k}={v}" for k, v in cache.items()))

    # Roofline summary (from dry-run artifacts, if present)
    try:
        from benchmarks import roofline_report
        recs = roofline_report.load_records("16x16")
        for r in recs:
            d = roofline_report.row(r)
            if d and "compute_s" in d:
                print(f"roofline/{d['arch']}/{d['shape']},"
                      f"{d['compute_s'] * 1e6:.1f},"
                      f"bottleneck={d['bottleneck']};"
                      f"fraction={d['roofline_fraction']:.3f};"
                      f"fits={d['fits_hbm']}")
    except Exception as e:  # noqa: BLE001
        print(f"# roofline summary unavailable: {e}")

    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "bench_results.json").write_text(
        json.dumps(rows, indent=1, default=float))


if __name__ == "__main__":
    main()
