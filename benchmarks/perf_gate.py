"""Declarative perf/sanity gate over BENCH_*.json artifacts (CI's teeth).

One rule table per artifact; each rule is ``(name, check)`` where
``check(doc)`` returns an error string or ``None``.  Every rule runs
(failures accumulate — one broken field doesn't mask the rest) and a
non-empty failure list exits 1.  This replaces ad-hoc inline asserts in
the workflow file: the gate is code-reviewed, versioned next to the
benchmarks it guards, and runnable locally::

  PYTHONPATH=src python -m benchmarks.perf_gate \
      --core BENCH_core.json --serve BENCH_serve.json

Gated invariants:

* ``BENCH_core.json`` — the packed merge-key fields exist on every row
  and the packed phase-C path compiles with **zero full-image sorts**
  (the PR 5 rank-free guarantee must not quietly regress).
* ``BENCH_serve.json`` — a warmed server re-traces **nothing** over the
  sustained mixed-shape stream (``steady_state_traces == 0``), every
  bucket reports ordered p50<=p95<=p99 latency summaries and nonzero
  occupancy, nothing failed or was rejected in steady state, and the
  saturation burst actually engaged backpressure (rejections > 0).
  When the artifact carries a ``cache`` section (delta-enabled serve
  bench), the steady-state repeat pass must have produced exact-hash
  tier hits (``steady_state_hits > 0``).
* ``BENCH_pipeline.json`` — every delta frame-sequence row is
  **bit-identical** to its cold ``run_tiled`` counterpart and the
  identical-frame resubmission full-hits; rows at >= 256 px must show a
  real speedup, and a full-scale row (>= 1024 px, <= 10% dirty tiles)
  must hold the paper-motivated >= 5x incremental speedup.  Streaming
  rows additionally carry overlap-engine counters: the steady-state
  dispatch path must perform **zero** blocking device readbacks
  (``steady_state_dispatch_syncs == 0`` — the serve-gate
  ``steady_state_traces`` pattern), staging must stay fused (at most one
  ``jax.device_put`` per whole round), and at gate scale
  (``max_size >= 256``, on a host with ``host_parallelism >= 2``) the
  heterogeneous and tiled mixes must show ``overlap_speedup >= 1.2``
  over the serial loop.

* ``BENCH_distance.json`` — the diagram-distance rows hold their
  structural invariants: Pallas/XLA backend **bit-parity**, the
  dual-filtration contract (sublevel distances == superlevel distances
  of the negated frames, bit-for-bit), capacity-pad inertness
  (bottleneck exactly, sliced Wasserstein to float rounding), and zero
  steady-state re-traces of the cached distance plan.

**Trajectory gating**: with ``--baseline-core``/``--baseline-serve`` the
gate additionally compares the current artifact against a *committed
baseline snapshot* (``benchmarks/baselines/BENCH_*.json``), so perf
regressions fail CI instead of silently accumulating in artifacts.
Trajectory rules are declarative tolerances over fields matched by row
``name`` (rows present only on one side are skipped — adding a size or a
field never breaks the gate):

* ``exact``     — the value must equal the baseline (structural
  invariants: full-image sort counts);
* ``le``        — the value must not exceed the baseline (monotone
  counters: Boruvka round counts — the early exit must not regress);
* ``min_ratio`` — the speedup field must stay above ``ratio x baseline``
  (timing-derived but machine-normalized: both numerator and denominator
  move with the machine, so a big drop means a real regression, while
  absolute seconds are deliberately *not* gated across machines).
"""
from __future__ import annotations

import argparse
import json
import sys

CORE_FIELDS = ("phase_c_packed_s", "phase_c_rank_s",
               "phase_c_packed_speedup", "hlo_sorts_packed",
               "full_image_sorts_packed", "full_image_sorts_rank",
               "phase_c_fused_s", "phase_c_xla_s",
               "phase_c_fused_speedup", "full_image_sorts_fused",
               "boruvka_rounds_fused", "boruvka_rounds_xla")


def _core_fields(doc):
    if not doc:
        return "empty artifact"
    for row in doc:
        for field in CORE_FIELDS:
            if field not in row:
                return f"{row.get('name', '?')}: missing {field}"
    return None


def _core_no_full_sorts(doc):
    for row in doc:
        for field in ("full_image_sorts_packed", "full_image_sorts_fused"):
            if row.get(field) != 0:
                return (f"{row.get('name', '?')}: phase C compiled "
                        f"{row[field]} full-image sorts ({field})")
    return None


# -- baseline-trajectory rules ----------------------------------------------
# field -> (mode, arg).  Modes: "exact" (must equal the baseline), "le"
# (must not exceed it), "min_ratio" (must stay >= arg * baseline).  Only
# machine-normalized fields appear here — never absolute seconds.

CORE_TRAJECTORY = {
    "full_image_sorts_packed": ("exact", None),
    "full_image_sorts_fused": ("exact", None),
    "boruvka_rounds_fused": ("le", None),
    "boruvka_rounds_xla": ("le", None),
    "phase_c_packed_speedup": ("min_ratio", 0.5),
    "phase_c_fused_speedup": ("min_ratio", 0.5),
}

SERVE_TRAJECTORY = {
    "steady.steady_state_traces": ("exact", None),
    "steady.failed": ("exact", None),
    "steady.rejected": ("exact", None),
}

DISTANCE_TRAJECTORY = {
    "distance_bit_identical": ("exact", None),
    "sublevel_bit_identical": ("exact", None),
    "pad_inert_bn": ("exact", None),
    "steady_traces": ("le", None),
}

PIPELINE_TRAJECTORY = {
    "delta_bit_identical": ("exact", None),
    "delta_full_hit_ok": ("exact", None),
    "delta_speedup_10pct": ("min_ratio", 0.5),
    "speedup_vs_serial": ("min_ratio", 0.5),
    "overlap_speedup": ("min_ratio", 0.5),
    "steady_state_dispatch_syncs": ("exact", None),
}


def _check_value(label, mode, arg, cur, ref):
    if mode == "exact" and cur != ref:
        return f"{label}: {cur!r} != baseline {ref!r}"
    if mode == "le" and cur > ref:
        return f"{label}: {cur!r} > baseline {ref!r}"
    if mode == "min_ratio" and cur < arg * ref:
        return (f"{label}: {cur:.3g} < {arg} x baseline {ref:.3g} "
                f"(regressed)")
    return None


def _core_trajectory(baseline):
    """Row-matched (by ``name``) tolerance check against the committed
    core baseline; rows/fields present on only one side are skipped."""
    base_rows = {r.get("name"): r for r in baseline if isinstance(r, dict)}

    def check(doc):
        errs, matched = [], 0
        for row in doc:
            b = base_rows.get(row.get("name"))
            if b is None:
                continue
            matched += 1
            for field, (mode, arg) in CORE_TRAJECTORY.items():
                if field not in row or field not in b:
                    continue
                err = _check_value(f"{row['name']}.{field}", mode, arg,
                                   row[field], b[field])
                if err:
                    errs.append(err)
        if not matched:
            errs.append("no rows matched the baseline by name")
        return "; ".join(errs) or None

    return check


def _dotted(doc, path):
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _serve_trajectory(baseline):
    def check(doc):
        errs = []
        for path, (mode, arg) in SERVE_TRAJECTORY.items():
            cur, ref = _dotted(doc, path), _dotted(baseline, path)
            if cur is None or ref is None:
                continue
            err = _check_value(path, mode, arg, cur, ref)
            if err:
                errs.append(err)
        return "; ".join(errs) or None

    return check


def _serve_zero_traces(doc):
    sst = doc.get("steady", {}).get("steady_state_traces")
    if sst != 0:
        return f"steady_state_traces == {sst!r}, want 0 (warm pool leak)"
    return None


def _serve_clean_steady(doc):
    s = doc.get("steady", {})
    for k in ("failed", "rejected"):
        if s.get(k, -1) != 0:
            return f"steady.{k} == {s.get(k)!r}, want 0"
    if s.get("completed", 0) <= 0 or s.get("completed") != s.get(
            "submitted"):
        return (f"steady completed {s.get('completed')!r} != "
                f"submitted {s.get('submitted')!r}")
    return None


def _serve_latency_summaries(doc):
    buckets = doc.get("steady", {}).get("buckets", {})
    if not buckets:
        return "steady section has no buckets"
    for label, b in buckets.items():
        occ = b.get("occupancy")
        if not occ or occ <= 0:
            return f"bucket {label}: occupancy {occ!r}"
        for series in ("queue_wait_s", "e2e_s"):
            s = b.get(series, {})
            if s.get("count", 0) < 2:
                # Zero samples summarize all-zero and one sample pins
                # every percentile to that sample — "ordered" would hold
                # vacuously, so the rule says nothing there; skip rather
                # than read meaning into a degenerate window.
                continue
            ps = [s.get("p50"), s.get("p95"), s.get("p99")]
            if any(p is None for p in ps):
                return f"bucket {label}: {series} missing percentiles"
            if not ps[0] <= ps[1] <= ps[2]:
                return f"bucket {label}: {series} percentiles unordered"
    return None


def _serve_cache_tier(doc):
    sec = doc.get("cache")
    if sec is None:
        return None     # pre-delta artifact / cache section disabled
    if sec.get("steady_state_hits", 0) <= 0:
        return "repeat pass produced no exact-hash cache hits"
    if sec.get("misses", 0) <= 0:
        return "cache section reports no misses (first pass not counted?)"
    return None


def _pipeline_rows(doc):
    rows = doc.get("rows", []) if isinstance(doc, dict) else doc
    return rows, [r for r in rows if isinstance(r, dict)
                  and str(r.get("name", "")).startswith(
                      "pipeline/delta_frame_seq")]


def _pipeline_delta_identity(doc):
    _, delta = _pipeline_rows(doc)
    if not delta:
        return "no delta frame-sequence rows in the artifact"
    for r in delta:
        if r.get("delta_bit_identical") is not True:
            return f"{r['name']}: delta diagrams diverged from cold runs"
        if r.get("delta_full_hit_ok") is not True:
            return f"{r['name']}: identical frame did not full-hit"
        if r.get("cache", {}).get("partial_hits", 0) <= 0:
            return f"{r['name']}: no partial hits (delta path never ran)"
    return None


def _pipeline_delta_speedup(doc):
    """Incremental recompute must actually pay: a real speedup at bench
    scale, and the paper-motivated >= 5x at full scale (>= 1k^2 frames,
    <= 10% dirty tiles).  Tiny smoke frames (< 256 px) are exempt — the
    host-side hash+dispatch floor dominates sub-millisecond tiles."""
    _, delta = _pipeline_rows(doc)
    errs = []
    for r in delta:
        size, ratio = r.get("size", 0), r.get("delta_speedup_10pct", 0)
        if size >= 1024:
            if ratio < 5.0:
                errs.append(f"{r['name']}: {ratio} < 5x at full scale")
            if r.get("mean_dirty_frac", 1.0) > 0.101:
                errs.append(f"{r['name']}: dirty frac "
                            f"{r.get('mean_dirty_frac')} > 10%")
        elif size >= 256 and ratio < 1.0:
            errs.append(f"{r['name']}: {ratio} < 1x (delta slower than "
                        f"cold)")
    return "; ".join(errs) or None


def _pipeline_overlap(doc):
    """The overlap engine's contract: in steady state the dispatch path
    performs **zero** blocking device readbacks (they all move to the
    harvest thread), staging stays fused (one ``jax.device_put`` per
    whole round — tile-grid rounds stage through the tile provider and
    count zero), and at gate scale (``max_size >= 256``) the
    heterogeneous and tiled mixes beat the serial loop by >= 1.2x.
    The speedup floor is scoped twice, the structural invariants never:
    smoke scales (< 256 px) are exempt like the delta gate's size
    floor, and so are hosts without parallelism
    (``host_parallelism < 2`` — on a single-core CPU host the "device"
    *is* the host, so staging/compute/harvest threads time-slice one
    core and overlap cannot buy wall-clock time by construction)."""
    rows, _ = _pipeline_rows(doc)
    streaming = [r for r in rows if isinstance(r, dict)
                 and "steady_state_dispatch_syncs" in r]
    if not streaming:
        return "no overlap-instrumented streaming rows in the artifact"
    errs = []
    for r in streaming:
        name = str(r.get("name", "?"))
        syncs = r.get("steady_state_dispatch_syncs")
        if syncs != 0:
            errs.append(f"{name}: {syncs!r} blocking dispatch-path "
                        f"syncs in steady state, want 0")
        h2d = r.get("h2d_transfers_per_round", -1.0)
        if not 0.0 < h2d <= 1.0:
            errs.append(f"{name}: {h2d!r} H2D transfers per round "
                        f"(fused batch+thresholds staging broken)")
        elif "tiled" not in name and h2d != 1.0:
            errs.append(f"{name}: {h2d!r} H2D transfers per whole "
                        f"round, want exactly 1 (fused)")
        scenario = name.split("/")[-1].rsplit("_", 1)[0]
        if (r.get("max_size", 0) >= 256
                and r.get("host_parallelism", 1) >= 2
                and scenario in ("heterogeneous", "tiled_mix")):
            ratio = r.get("overlap_speedup", 0)
            if ratio < 1.2:
                errs.append(f"{name}: overlap_speedup {ratio} < 1.2x "
                            f"at gate scale")
    return "; ".join(errs) or None


def _pipeline_trajectory(baseline):
    base_rows = {r.get("name"): r
                 for r in _pipeline_rows(baseline)[0]
                 if isinstance(r, dict)}

    def check(doc):
        errs, matched = [], 0
        for row in _pipeline_rows(doc)[0]:
            b = base_rows.get(row.get("name"))
            if b is None:
                continue
            matched += 1
            for field, (mode, arg) in PIPELINE_TRAJECTORY.items():
                if field not in row or field not in b:
                    continue
                err = _check_value(f"{row['name']}.{field}", mode, arg,
                                   row[field], b[field])
                if err:
                    errs.append(err)
        if not matched:
            errs.append("no rows matched the baseline by name")
        return "; ".join(errs) or None

    return check


DISTANCE_FIELDS = ("distance_bit_identical", "sublevel_bit_identical",
                   "pad_inert_bn", "pad_inert_sw_rel", "steady_traces")


def _distance_invariants(doc):
    """Every row: backend bit-parity, the dual-filtration contract, pad
    inertness (bottleneck exactly, SW to float rounding), and zero
    steady-state re-traces of the cached distance plan."""
    if not doc:
        return "empty artifact"
    errs = []
    for row in doc:
        name = row.get("name", "?")
        for field in DISTANCE_FIELDS:
            if field not in row:
                errs.append(f"{name}: missing {field}")
        if row.get("distance_bit_identical") is not True:
            errs.append(f"{name}: Pallas kernel diverged from the XLA "
                        f"reference")
        if row.get("sublevel_bit_identical") is not True:
            errs.append(f"{name}: sublevel run != superlevel(-image) "
                        f"distances")
        if row.get("pad_inert_bn") is not True:
            errs.append(f"{name}: bottleneck bound moved under capacity "
                        f"padding")
        if row.get("pad_inert_sw_rel", 1.0) > 1e-5:
            errs.append(f"{name}: SW moved {row.get('pad_inert_sw_rel')} "
                        f"rel under capacity padding (> 1e-5)")
        if row.get("steady_traces", -1) != 0:
            errs.append(f"{name}: {row.get('steady_traces')!r} "
                        f"steady-state distance-plan traces, want 0")
    return "; ".join(errs) or None


def _distance_trajectory(baseline):
    base_rows = {r.get("name"): r for r in baseline if isinstance(r, dict)}

    def check(doc):
        errs, matched = [], 0
        for row in doc:
            b = base_rows.get(row.get("name"))
            if b is None:
                continue
            matched += 1
            for field, (mode, arg) in DISTANCE_TRAJECTORY.items():
                if field not in row or field not in b:
                    continue
                err = _check_value(f"{row['name']}.{field}", mode, arg,
                                   row[field], b[field])
                if err:
                    errs.append(err)
        if not matched:
            errs.append("no rows matched the baseline by name")
        return "; ".join(errs) or None

    return check


def _serve_backpressure(doc):
    sat = doc.get("saturation")
    if sat is None:
        return None     # smoke may run --no-saturation
    if sat.get("rejected", 0) <= 0:
        return "saturation burst produced no rejections"
    if sat.get("retry_after_s_mean", 0) <= 0:
        return "rejections carried no retry_after_s hint"
    if sat.get("failed", -1) != 0:
        return f"saturation failed {sat.get('failed')!r} requests"
    return None


RULES = {
    "core": [("packed merge-key fields present", _core_fields),
             ("packed phase C has zero full-image sorts",
              _core_no_full_sorts)],
    "serve": [("zero steady-state traces", _serve_zero_traces),
              ("steady stream clean", _serve_clean_steady),
              ("per-bucket SLO summaries", _serve_latency_summaries),
              ("saturation engages backpressure", _serve_backpressure),
              ("cache tier hits in steady state", _serve_cache_tier)],
    "pipeline": [("delta rows bit-identical + full-hit",
                  _pipeline_delta_identity),
                 ("delta recompute pays its way",
                  _pipeline_delta_speedup),
                 ("overlap engine holds its contract",
                  _pipeline_overlap)],
    "distance": [("distance invariants hold", _distance_invariants)],
}


def run_gate(kind: str, path: str,
             baseline_path: str | None = None) -> list[str]:
    try:
        doc = json.load(open(path))
    except (OSError, json.JSONDecodeError) as e:
        return [f"[{kind}] {path}: unreadable ({e})"]
    rules = list(RULES[kind])
    if baseline_path:
        try:
            baseline = json.load(open(baseline_path))
        except (OSError, json.JSONDecodeError) as e:
            return [f"[{kind}] baseline {baseline_path}: unreadable ({e})"]
        make = {"core": _core_trajectory, "serve": _serve_trajectory,
                "pipeline": _pipeline_trajectory,
                "distance": _distance_trajectory}[kind]
        rules.append((f"trajectory vs {baseline_path}", make(baseline)))
    failures = []
    for name, check in rules:
        err = check(doc)
        status = "ok" if err is None else f"FAIL: {err}"
        print(f"[{kind}] {name}: {status}")
        if err is not None:
            failures.append(f"[{kind}] {name}: {err}")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--core", help="BENCH_core.json path")
    ap.add_argument("--serve", help="BENCH_serve.json path")
    ap.add_argument("--pipeline", help="BENCH_pipeline.json path")
    ap.add_argument("--distance", help="BENCH_distance.json path")
    ap.add_argument("--baseline-core",
                    help="committed core baseline to gate the trajectory "
                         "against (benchmarks/baselines/BENCH_core.json)")
    ap.add_argument("--baseline-serve",
                    help="committed serve baseline to gate the trajectory "
                         "against (benchmarks/baselines/BENCH_serve.json)")
    ap.add_argument("--baseline-pipeline",
                    help="committed pipeline baseline to gate the "
                         "trajectory against "
                         "(benchmarks/baselines/BENCH_pipeline.json)")
    ap.add_argument("--baseline-distance",
                    help="committed distance baseline to gate the "
                         "trajectory against "
                         "(benchmarks/baselines/BENCH_distance.json)")
    args = ap.parse_args()
    if not (args.core or args.serve or args.pipeline or args.distance):
        ap.error("nothing to gate: pass --core, --serve, --pipeline "
                 "and/or --distance")
    failures = []
    for kind in ("core", "serve", "pipeline", "distance"):
        path = getattr(args, kind)
        if path:
            failures += run_gate(kind, path,
                                 getattr(args, f"baseline_{kind}"))
    if failures:
        print(f"\nperf gate: {len(failures)} failure(s)")
        sys.exit(1)
    print("\nperf gate: all checks passed")


if __name__ == "__main__":
    main()
