"""Roofline report: artifacts/dryrun/*.json -> markdown table + CSV.

Per (arch x shape x mesh): the three roofline terms (seconds/step/chip),
dominant bottleneck, MODEL_FLOPS ratio, memory fit check vs 16 GB HBM.
Used to pick the hillclimb cells (worst roofline fraction, most
collective-bound, most paper-representative) and to fill EXPERIMENTS.md.
"""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"
HBM_BYTES = 16e9          # v5e


def load_records(mesh: str | None = "16x16"):
    recs = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def row(r) -> dict | None:
    if r.get("skipped"):
        return {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "skipped": r["skipped"]}
    if not r.get("compile_ok"):
        return {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "error": r.get("error", "?")}
    t = r["roofline"]
    mem = r["memory"]
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "compute_s": t["compute_s"], "memory_s": t["memory_s"],
        "collective_s": t["collective_s"], "bottleneck": t["bottleneck"],
        "roofline_fraction": t["roofline_fraction"],
        "useful_ratio": r.get("useful_flops_ratio"),
        "peak_gb": mem["peak_bytes_est"] / 1e9,
        "fits_hbm": mem["peak_bytes_est"] < HBM_BYTES,
        "coll_gb": r["hlo"]["collective_bytes"] / 1e9,
    }


def fmt(x, nd=3):
    if x is None:
        return "-"
    if isinstance(x, bool):
        return "yes" if x else "NO"
    if isinstance(x, float):
        if x != 0 and (abs(x) < 1e-3 or abs(x) >= 1e5):
            return f"{x:.2e}"
        return f"{x:.{nd}f}"
    return str(x)


def markdown_table(mesh="16x16") -> str:
    cols = ["arch", "shape", "compute_s", "memory_s", "collective_s",
            "bottleneck", "roofline_fraction", "useful_ratio", "peak_gb",
            "fits_hbm"]
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join(["---"] * len(cols)) + "|"]
    for r in load_records(mesh):
        d = row(r)
        if d is None:
            continue
        if "skipped" in d:
            lines.append(f"| {d['arch']} | {d['shape']} | skipped: "
                         f"{d['skipped'][:60]}... |" + " |" * (len(cols) - 3))
            continue
        if "error" in d:
            lines.append(f"| {d['arch']} | {d['shape']} | ERROR |"
                         + " |" * (len(cols) - 3))
            continue
        lines.append("| " + " | ".join(fmt(d.get(c)) for c in cols) + " |")
    return "\n".join(lines)


def main():
    for mesh in ("16x16", "2x16x16"):
        recs = load_records(mesh)
        ok = sum(1 for r in recs if r.get("compile_ok"))
        sk = sum(1 for r in recs if r.get("skipped"))
        print(f"\n## mesh {mesh}: {ok} compiled, {sk} skipped, "
              f"{len(recs) - ok - sk} errors\n")
        print(markdown_table(mesh))
    # CSV for downstream tooling
    out = ARTIFACTS.parent / "roofline.csv"
    cols = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
            "bottleneck", "roofline_fraction", "useful_ratio", "peak_gb",
            "coll_gb"]
    with out.open("w") as f:
        f.write(",".join(cols) + "\n")
        for mesh in ("16x16", "2x16x16"):
            for r in load_records(mesh):
                d = row(r)
                if d and "compute_s" in d:
                    f.write(",".join(str(d.get(c, "")) for c in cols) + "\n")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
