"""Streaming-pipeline benchmark: bucketed + prefetched rounds vs serial.

Three dataset mixes, each through ``PHEngine.run_distributed`` with the
loader thread off (``prefetch0``), on (``prefetch1``), and with the full
overlap engine (``overlap``: prefetch + async staging ring + donated
device buffers + non-blocking regrow + harvest-thread D2H), against the
serial per-image loop baseline (generate -> run, one image at a time, no
rounds, no overlap — the pre-streaming pipeline's behavior):

* homogeneous — every image at ``--size``;
* heterogeneous — sizes cycled from ``--sizes`` (shape-bucketed rounds);
* tiled_mix — heterogeneous plus ``--oversize`` images above
  ``max_tile_pixels``, streamed through the halo-tiled tile-provider path.

Plus the **delta frame-sequence** scenario (``--frames > 0``): a
:class:`repro.data.astro.FrameSequence` survey stream — one base star
field, each frame re-imaging it with transients confined to
``--dirty-frac`` of the tiles — run cold (``PHEngine.run_tiled`` per
frame) and incrementally (``PHEngine.run_delta`` against the
content-hashed frame store).  The row records the warm-path speedup
(``delta_speedup_10pct``), whether every delta diagram was bit-identical
to its cold counterpart (``delta_bit_identical``), the full-hit
short-circuit, and the frame-store counters — all gated by
``benchmarks.perf_gate --pipeline``.

Each scenario runs twice; the cold pass pays compiles, the warm pass is
the steady-state number the speedup fields compare (CI trend artifact).
A final counted rep of the overlap engine snapshots its
:class:`repro.ph.overlap.OverlapCounters` to record
``steady_state_dispatch_syncs`` (the gate requires **zero** blocking
device readbacks on the dispatch path) and the fused
``h2d_transfers_per_round`` (batch + thresholds ride one
``jax.device_put``).

  PYTHONPATH=src python -m benchmarks.pipeline_bench --images 6 \
      --sizes 64 96 --oversize 128 --out BENCH_pipeline.json
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def _scenarios(images: int, size: int, sizes: list[int], oversize: int):
    homo = [(i, size) for i in range(images)]
    hetero = [(i, sizes[i % len(sizes)]) for i in range(images)]
    tiled = hetero[:-1] + [(images - 1, oversize)]
    return {"homogeneous": homo, "heterogeneous": hetero,
            "tiled_mix": tiled}


def _serial_loop(engine, images) -> float:
    """The baseline: one image at a time, synchronous load -> compute."""
    import jax
    from repro.data import astro
    t0 = time.perf_counter()
    for img_id, s in images:
        img = astro.generate_image(img_id, s)
        t, _ = astro.filter_threshold(img, engine.config.filter_level)
        if engine.should_tile(s * s):
            res = engine.run_tiled(img, t)
        else:
            res = engine.run(img, t)
        jax.block_until_ready(res.diagram)
    return time.perf_counter() - t0


def _pipeline(engine, images) -> float:
    t0 = time.perf_counter()
    engine.run_distributed(images)
    return time.perf_counter() - t0


def _frame_stamp(size: int, grid: int) -> int:
    """Largest odd transient stamp that keeps its halo margin inside one
    tile (FrameSequence's placement invariant)."""
    tile = size // grid
    return min(15, max(3, (tile - 5) // 2 * 2 - 1))


def delta_row(frames: int, size: int, grid: int, dirty_frac: float,
              reps: int) -> dict:
    """The delta frame-sequence scenario: cold ``run_tiled`` per frame vs
    ``run_delta`` against the frame store, bit-identity asserted on every
    timed frame."""
    import jax
    import numpy as np

    from repro.data import astro
    from repro.data.astro import FrameSequence
    from repro.ph import DeltaSpec, PHConfig, PHEngine, TileSpec

    g = (grid, grid)
    stamp = _frame_stamp(size, grid)
    # Survey-style detection threshold + right-sized per-tile capacities:
    # the compiled programs are shape-static, so oversized capacity pads
    # dominate the seam merge long before live features do.  auto_regrow
    # still covers an unexpectedly busy frame.
    engine = PHEngine(PHConfig(
        max_features=2048, max_candidates=32768,
        delta=DeltaSpec(cache_entries=8),
        tile=TileSpec(grid=g, max_tile_pixels=(size // grid) ** 2,
                      max_features_per_tile=256,
                      max_candidates_per_tile=512)))

    def block(res):
        jax.block_until_ready(res.diagram)
        return res

    # Warm every compiled program off the clock: the tiled plan, the
    # miss-path scatter (bucket == n_tiles), and the partial-hit bucket.
    fs0 = FrameSequence(999, size, grid=g, dirty_frac=dirty_frac,
                        stamp=stamp)
    tv0, _ = astro.filter_threshold(fs0.base(), "filter_heavy")
    block(engine.run_tiled(fs0.frame(0), tv0))
    block(engine.run_delta(fs0.frame(0), tv0))
    block(engine.run_delta(fs0.frame(1), tv0))

    cold_s, delta_s, dirty, identical, full_ok = [], [], [], True, True
    for rep in range(reps):
        fs = FrameSequence(rep, size, grid=g, dirty_frac=dirty_frac,
                           stamp=stamp)
        # One fixed survey detection threshold per sequence (a per-frame
        # threshold would re-key the frame store by design).
        tv, _ = astro.filter_threshold(fs.base(), "filter_heavy")
        seq = [fs.frame(i) for i in range(frames + 1)]
        t0 = time.perf_counter()
        cold = [block(engine.run_tiled(f, tv)) for f in seq[1:]]
        cold_s.append(time.perf_counter() - t0)
        block(engine.run_delta(seq[0], tv))     # prime the store
        t0 = time.perf_counter()
        warm = [block(engine.run_delta(f, tv)) for f in seq[1:]]
        delta_s.append(time.perf_counter() - t0)
        for c, d in zip(cold, warm):
            for f in c.diagram._fields:
                if not np.array_equal(np.asarray(getattr(c.diagram, f)),
                                      np.asarray(getattr(d.diagram, f))):
                    identical = False
            dirty.append(d.delta.dirty_frac)
        # an identical resubmission short-circuits without the device
        full_ok &= engine.run_delta(seq[-1], tv).delta.hit == "full"

    cold_w, delta_w = min(cold_s), min(delta_s)
    return {
        "name": f"pipeline/delta_frame_seq_{size}",
        "value": round(delta_w, 4),
        "frames": frames, "size": size, "grid": [grid, grid],
        "dirty_frac": dirty_frac,
        "mean_dirty_frac": round(sum(dirty) / max(len(dirty), 1), 4),
        "cold_tiled_s": round(cold_w, 4),
        "delta_s": round(delta_w, 4),
        "delta_speedup_10pct": round(cold_w / max(delta_w, 1e-9), 3),
        "delta_bit_identical": bool(identical),
        "delta_full_hit_ok": bool(full_ok),
        "cache": engine.delta_cache_stats(),
    }


def run(images: int, size: int, sizes: list[int], oversize: int,
        out_path: str | None, *, frames: int = 0, frame_size: int = 256,
        frame_grid: int = 4, dirty_frac: float = 0.05,
        delta_reps: int = 2, only_delta: bool = False):
    from benchmarks.paper_tables import ARTIFACTS, print_rows
    from repro.ph import OverlapSpec, PHConfig, TileSpec

    tile_bound = max(max(sizes), size)
    config = PHConfig(
        max_features=8192, max_candidates=32768,
        filter_level="filter_std",
        tile=TileSpec(max_tile_pixels=tile_bound * tile_bound))

    from repro.ph import PHEngine
    rows = []
    scenarios = {} if only_delta else _scenarios(images, size, sizes,
                                                 oversize)
    for name, dataset in scenarios.items():
        # One engine per cell, reused across the cold and warm pass: the
        # cold number pays the compiles, the warm number is steady state.
        engines = {
            "serial": PHEngine(config),
            "prefetch0": PHEngine(config.replace(prefetch_rounds=0)),
            "prefetch1": PHEngine(config.replace(prefetch_rounds=1)),
            "overlap": PHEngine(config.replace(prefetch_rounds=1,
                                               overlap=OverlapSpec())),
        }
        fns = {label: ((lambda e=eng: _serial_loop(e, dataset))
                       if label == "serial"
                       else (lambda e=eng: _pipeline(e, dataset)))
               for label, eng in engines.items()}
        cell = {label: {"cold_s": round(fn(), 4), "warm": []}
                for label, fn in fns.items()}
        for _ in range(3):              # interleaved warm reps: less noise
            for label, fn in fns.items():
                cell[label]["warm"].append(fn())
        for label in cell:
            cell[label]["warm_s"] = round(
                sorted(cell[label].pop("warm"))[1], 4)
        warm = {k: v["warm_s"] for k, v in cell.items()}
        # One extra counted rep of the overlap engine: snapshot the
        # transfer/sync counters around a steady-state run so the gate
        # can assert zero blocking dispatch-path syncs and the fused
        # single H2D transfer per whole round.
        eng_ov = engines["overlap"]
        before = eng_ov.overlap_counters.snapshot()
        res_ov = eng_ov.run_distributed(dataset)
        after = eng_ov.overlap_counters.snapshot()
        delta_c = {k: after[k] - before[k] for k in after}
        n_rounds = max(res_ov.rounds, 1)
        # Row names carry the scenario's largest image side (like the
        # delta rows) so a committed full-scale baseline row and the CI
        # smoke row never collide in the trajectory comparison.
        # host_parallelism lets the gate scope the speedup floor to
        # machines that can overlap at all: on a single-core CPU host
        # the "device" is the host, so transfer/compute overlap cannot
        # buy wall-clock time — only the structural zero-sync and
        # fused-transfer invariants are machine-independent there.
        import os

        import jax
        max_size = max(s for _, s in dataset)
        rows.append({
            "name": f"pipeline/{name}_{max_size}",
            "value": warm["overlap"],
            "max_size": max_size,
            "host_parallelism": max(os.cpu_count() or 1,
                                    len(jax.devices())),
            "serial_s": warm["serial"],
            "prefetch0_s": warm["prefetch0"],
            "prefetch1_s": warm["prefetch1"],
            "overlap_s": warm["overlap"],
            "speedup_vs_serial": round(
                warm["serial"] / max(warm["prefetch1"], 1e-9), 3),
            "speedup_prefetch": round(
                warm["prefetch0"] / max(warm["prefetch1"], 1e-9), 3),
            "overlap_speedup": round(
                warm["serial"] / max(warm["overlap"], 1e-9), 3),
            "cold_prefetch1_s": cell["prefetch1"]["cold_s"],
            "cold_overlap_s": cell["overlap"]["cold_s"],
            "steady_state_dispatch_syncs": delta_c["dispatch_syncs"],
            "h2d_transfers_per_round": round(
                delta_c["h2d_transfers"] / n_rounds, 3),
            "d2h_streams_per_round": round(
                delta_c["d2h_streams"] / n_rounds, 3),
            "donation_replays": delta_c["donation_replays"],
        })

    if frames > 0:
        rows.append(delta_row(frames, frame_size, frame_grid, dirty_frac,
                              delta_reps))

    out = Path(out_path) if out_path else ARTIFACTS / "BENCH_pipeline.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "images": images, "size": size, "sizes": sizes,
        "oversize": oversize, "frames": frames, "frame_size": frame_size,
        "dirty_frac": dirty_frac, "rows": rows}, indent=1))
    print_rows(rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--images", type=int, default=8)
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--sizes", type=int, nargs="+", default=[32, 64, 128],
                    help="heterogeneous mix, cycled over --images ids "
                         "(pow2-aligned sizes land exactly on their "
                         "buckets; ragged sizes additionally pay the "
                         "pad-to-bucket pixels)")
    ap.add_argument("--oversize", type=int, default=192,
                    help="size of the oversized image in tiled_mix (must "
                         "exceed every --sizes entry)")
    ap.add_argument("--frames", type=int, default=6,
                    help="frames in the delta survey-stream scenario "
                         "(0 disables it)")
    ap.add_argument("--frame-size", type=int, default=256,
                    help="frame side length for the delta scenario")
    ap.add_argument("--frame-grid", type=int, default=4,
                    help="tile grid (NxN) for the delta scenario")
    ap.add_argument("--dirty-frac", type=float, default=0.05,
                    help="fraction of tiles each frame's transients "
                         "touch (>= 1 tile)")
    ap.add_argument("--delta-reps", type=int, default=2,
                    help="timed repetitions of the delta scenario "
                         "(best-of)")
    ap.add_argument("--only-delta", action="store_true",
                    help="skip the streaming scenarios, run only the "
                         "delta frame-sequence row")
    ap.add_argument("--out", default=None,
                    help="output path (default artifacts/BENCH_pipeline.json)")
    args = ap.parse_args()
    run(args.images, args.size, args.sizes, args.oversize, args.out,
        frames=args.frames, frame_size=args.frame_size,
        frame_grid=args.frame_grid, dirty_frac=args.dirty_frac,
        delta_reps=args.delta_reps, only_delta=args.only_delta)


if __name__ == "__main__":
    main()
