"""Streaming-pipeline benchmark: bucketed + prefetched rounds vs serial.

Three dataset mixes, each through ``PHEngine.run_distributed`` with the
loader thread off (``prefetch0``) and on (``prefetch1``), against the
serial per-image loop baseline (generate -> run, one image at a time, no
rounds, no overlap — the pre-streaming pipeline's behavior):

* homogeneous — every image at ``--size``;
* heterogeneous — sizes cycled from ``--sizes`` (shape-bucketed rounds);
* tiled_mix — heterogeneous plus ``--oversize`` images above
  ``max_tile_pixels``, streamed through the halo-tiled tile-provider path.

Each scenario runs twice; the cold pass pays compiles, the warm pass is
the steady-state number the speedup fields compare (CI trend artifact).

  PYTHONPATH=src python -m benchmarks.pipeline_bench --images 6 \
      --sizes 64 96 --oversize 128 --out BENCH_pipeline.json
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def _scenarios(images: int, size: int, sizes: list[int], oversize: int):
    homo = [(i, size) for i in range(images)]
    hetero = [(i, sizes[i % len(sizes)]) for i in range(images)]
    tiled = hetero[:-1] + [(images - 1, oversize)]
    return {"homogeneous": homo, "heterogeneous": hetero,
            "tiled_mix": tiled}


def _serial_loop(engine, images) -> float:
    """The baseline: one image at a time, synchronous load -> compute."""
    import jax
    from repro.data import astro
    t0 = time.perf_counter()
    for img_id, s in images:
        img = astro.generate_image(img_id, s)
        t, _ = astro.filter_threshold(img, engine.config.filter_level)
        if engine.should_tile(s * s):
            res = engine.run_tiled(img, t)
        else:
            res = engine.run(img, t)
        jax.block_until_ready(res.diagram)
    return time.perf_counter() - t0


def _pipeline(engine, images) -> float:
    t0 = time.perf_counter()
    engine.run_distributed(images)
    return time.perf_counter() - t0


def run(images: int, size: int, sizes: list[int], oversize: int,
        out_path: str | None):
    from benchmarks.paper_tables import ARTIFACTS, print_rows
    from repro.ph import PHConfig, TileSpec

    tile_bound = max(max(sizes), size)
    config = PHConfig(
        max_features=8192, max_candidates=32768,
        filter_level="filter_std",
        tile=TileSpec(max_tile_pixels=tile_bound * tile_bound))

    from repro.ph import PHEngine
    rows = []
    for name, dataset in _scenarios(images, size, sizes, oversize).items():
        # One engine per cell, reused across the cold and warm pass: the
        # cold number pays the compiles, the warm number is steady state.
        engines = {
            "serial": PHEngine(config),
            "prefetch0": PHEngine(config.replace(prefetch_rounds=0)),
            "prefetch1": PHEngine(config.replace(prefetch_rounds=1)),
        }
        fns = {label: ((lambda e=eng: _serial_loop(e, dataset))
                       if label == "serial"
                       else (lambda e=eng: _pipeline(e, dataset)))
               for label, eng in engines.items()}
        cell = {label: {"cold_s": round(fn(), 4), "warm": []}
                for label, fn in fns.items()}
        for _ in range(3):              # interleaved warm reps: less noise
            for label, fn in fns.items():
                cell[label]["warm"].append(fn())
        for label in cell:
            cell[label]["warm_s"] = round(
                sorted(cell[label].pop("warm"))[1], 4)
        warm = {k: v["warm_s"] for k, v in cell.items()}
        rows.append({
            "name": f"pipeline/{name}",
            "value": warm["prefetch1"],
            "serial_s": warm["serial"],
            "prefetch0_s": warm["prefetch0"],
            "prefetch1_s": warm["prefetch1"],
            "speedup_vs_serial": round(
                warm["serial"] / max(warm["prefetch1"], 1e-9), 3),
            "speedup_prefetch": round(
                warm["prefetch0"] / max(warm["prefetch1"], 1e-9), 3),
            "cold_prefetch1_s": cell["prefetch1"]["cold_s"],
        })

    out = Path(out_path) if out_path else ARTIFACTS / "BENCH_pipeline.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "images": images, "size": size, "sizes": sizes,
        "oversize": oversize, "rows": rows}, indent=1))
    print_rows(rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--images", type=int, default=8)
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--sizes", type=int, nargs="+", default=[32, 64, 128],
                    help="heterogeneous mix, cycled over --images ids "
                         "(pow2-aligned sizes land exactly on their "
                         "buckets; ragged sizes additionally pay the "
                         "pad-to-bucket pixels)")
    ap.add_argument("--oversize", type=int, default=192,
                    help="size of the oversized image in tiled_mix (must "
                         "exceed every --sizes entry)")
    ap.add_argument("--out", default=None,
                    help="output path (default artifacts/BENCH_pipeline.json)")
    args = ap.parse_args()
    run(args.images, args.size, args.sizes, args.oversize, args.out)


if __name__ == "__main__":
    main()
