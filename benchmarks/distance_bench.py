"""Diagram-distance benchmark: batched SW + bottleneck (BENCH_distance.json).

For each ``(batch, size)`` row this computes the persistence diagrams of
a batch of synthetic astro-like frames through :class:`repro.ph.PHEngine`
and times the pairwise distance-matrix stage, reporting the correctness
invariants the perf gate asserts:

* ``distance_bit_identical`` — the Pallas kernel (interpret mode off-TPU:
  CI's parity path) and the XLA reference produce **bit-equal** (B, B)
  matrices for both distances;
* ``sublevel_bit_identical`` — a ``filtration="sublevel"`` engine run on
  the frames and a superlevel run on the negated frames yield bit-equal
  distance matrices (the dual-filtration contract, end to end through
  the diagram computation);
* ``pad_inert_bn`` / ``pad_inert_sw_rel`` — recomputing at doubled
  capacity (pure pad rows appended) leaves the bottleneck bound
  bit-identical and moves sliced Wasserstein by at most float-rounding
  (the sum over the augmented sorted vectors reassociates; the *value*
  is provably unchanged — see ``repro/kernels/ph_distance/ref.py``);
* ``steady_traces`` — repeated matrix calls at one shape reuse a single
  cached "distance" plan (trace exactly once).

Timings (``xla_s``, ``pallas_interpret_s``, ``prep_s``) are reported for
the trajectory record but deliberately not gated across machines.

  PYTHONPATH=src python -m benchmarks.distance_bench \
      --batches 8 --sizes 64 --out BENCH_distance.json

CI runs a smoke of this every push, uploads the artifact, and gates it
against ``benchmarks/baselines/BENCH_distance.json``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.kernels.ph_distance import ops as dist_ops
from repro.kernels.ph_distance import ref as dist_ref
from repro.ph import PHConfig, PHEngine

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts"


def _frames(batch: int, size: int, seed: int = 7) -> np.ndarray:
    """Synthetic astro-like frames: smooth background + point sources."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    out = np.empty((batch, size, size), np.float32)
    for b in range(batch):
        img = rng.normal(0.0, 0.05, (size, size)).astype(np.float32)
        for _ in range(max(3, size // 16)):
            cy, cx = rng.uniform(0, size, 2)
            amp = rng.uniform(0.5, 3.0)
            sig = rng.uniform(1.0, size / 16)
            img += amp * np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2)
                                / (2 * sig * sig)).astype(np.float32)
        out[b] = img
    return out


def bench_row(batch: int, size: int, n_dirs: int, repeats: int) -> dict:
    frames = _frames(batch, size)
    eng = PHEngine(PHConfig())
    res = eng.run_batch(frames)
    birth, death, p_birth = eng._stack_diagrams(res)

    # Backend parity (the structural invariant CI gates).
    t0 = time.perf_counter()
    prep = (dist_ref.diagram_projections(birth, death, p_birth,
                                         n_dirs=n_dirs)
            + (dist_ref.persistence_profiles(birth, death, p_birth),))
    pts, diag, prof = [np.asarray(a) for a in prep]
    prep_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    sw_x, bn_x = [np.asarray(a) for a in
                  dist_ops.pairwise_distances(pts, diag, prof,
                                              use_pallas=False)]
    xla_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    sw_p, bn_p = [np.asarray(a) for a in
                  dist_ops.pairwise_distances(pts, diag, prof,
                                              use_pallas=True)]
    pallas_s = time.perf_counter() - t0
    bit_identical = (np.array_equal(sw_x, sw_p)
                     and np.array_equal(bn_x, bn_p))

    # Dual-filtration contract, end to end.
    sub = PHEngine(PHConfig(filtration="sublevel"))
    ssw, sbn = [np.asarray(a) for a in
                sub.distance_matrix(sub.run_batch(frames))]
    xsw, xbn = [np.asarray(a) for a in
                eng.distance_matrix(eng.run_batch(-frames))]
    sublevel_ok = (np.array_equal(ssw, xsw) and np.array_equal(sbn, xbn))

    # Capacity-pad inertness at doubled F.
    f = birth.shape[1]
    grow = lambda a, fill: np.concatenate(  # noqa: E731
        [a, np.full_like(a, fill)], axis=1)
    sw2, bn2 = [np.asarray(a) for a in dist_ops.diagram_distances(
        grow(birth, -np.inf), grow(death, -np.inf),
        grow(p_birth, -1), n_dirs=n_dirs)]
    sw1, bn1 = [np.asarray(a) for a in dist_ops.diagram_distances(
        birth, death, p_birth, n_dirs=n_dirs)]
    pad_inert_bn = np.array_equal(bn1, bn2)
    denom = max(float(np.abs(sw1).max()), 1e-30)
    pad_inert_sw_rel = float(np.abs(sw1 - sw2).max()) / denom

    # Plan-cache behavior: after one warm call, repeats at the same
    # shape re-trace nothing (the "distance" plan kind is cached).
    eng.distance_matrix(res)
    before = eng.plan_stats()["traces"]
    for _ in range(repeats):
        eng.distance_matrix(res)
    steady_traces = eng.plan_stats()["traces"] - before

    return {"name": f"distance/b{batch}_s{size}",
            "batch": batch, "size": size, "capacity": f,
            "n_dirs": n_dirs,
            "prep_s": round(prep_s, 6),
            "xla_s": round(xla_s, 6),
            "pallas_interpret_s": round(pallas_s, 6),
            "distance_bit_identical": bool(bit_identical),
            "sublevel_bit_identical": bool(sublevel_ok),
            "pad_inert_bn": bool(pad_inert_bn),
            "pad_inert_sw_rel": pad_inert_sw_rel,
            "steady_traces": int(steady_traces)}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--batches", type=int, nargs="+", default=[8])
    ap.add_argument("--sizes", type=int, nargs="+", default=[64])
    ap.add_argument("--n-dirs", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="output path (default artifacts/BENCH_distance"
                         ".json)")
    args = ap.parse_args()

    rows = []
    for batch in args.batches:
        for size in args.sizes:
            row = bench_row(batch, size, args.n_dirs, args.repeats)
            print(json.dumps(row))
            rows.append(row)

    out = Path(args.out) if args.out else ARTIFACTS / "BENCH_distance.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
