"""CLI for the tiled-vs-whole benchmark (CI smoke + ad-hoc runs).

Runs :func:`benchmarks.paper_tables.tiled_vs_whole` at a configurable size
and writes ``BENCH_tiled.json`` — CI runs this on a small image every push
and uploads the artifact so the tiled-path perf trajectory accumulates.

  PYTHONPATH=src python -m benchmarks.tiled_bench --size 96 --grids 1x1 2x2 \
      --out BENCH_tiled.json
"""
from __future__ import annotations

import argparse


def main() -> None:
    from benchmarks import paper_tables
    from repro.ph.config import parse_grid

    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--grids", nargs="*", default=["1x1", "2x2", "4x4"],
                    help="tile grids as RxC (must divide --size)")
    ap.add_argument("--out", default=None,
                    help="output path (default artifacts/BENCH_tiled.json)")
    args = ap.parse_args()

    rows = paper_tables.tiled_vs_whole(
        size=args.size, grids=[parse_grid(g) for g in args.grids],
        out_path=args.out)
    paper_tables.print_rows(rows)


if __name__ == "__main__":
    main()
