"""Benchmarks mirroring each table/figure of the paper (run on this CPU
container at reduced image sizes; the methodology matches the paper's).

All PH computation goes through the ``repro.ph`` facade: one ``PHEngine``
per configuration (cached in ``ENGINES``), so repeated same-shape calls hit
the compiled-plan cache instead of re-tracing — ``benchmarks/run.py``
prints the aggregate cache statistics at the end.

table1  — Variant 2 filtering levels: dropped %, PixHomology time, oracle
          ("Ripser-role") time.                         (paper Table 1)
fig6    — partitioning strategies vs executor count: lockstep-round makespan
          on measured per-image costs.                  (paper Figure 6)
fig7    — PD equality: bottleneck distance PixHomology vs oracle on a crop.
                                                        (paper Figure 7/8)
fig9_10 — time + peak memory vs crop size, PixHomology vs oracle.
                                                        (paper Figures 9/10)
fig11   — DIPHA-style comparison: whole-image-per-executor (ours) vs
          patch-split-with-halo-merge (DIPHA's strategy) at equal executor
          counts.                                       (paper Figure 11)
"""
from __future__ import annotations

import json
import time
import tracemalloc
from pathlib import Path

import jax
import numpy as np

from repro.core import persistence_oracle
from repro.data import astro
from repro.ph import PHConfig, PHEngine, TileSpec
from repro.pipeline.scheduler import make_schedule

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts"

# One engine per distinct config — the plan cache lives as long as the
# benchmark process, so every same-(shape, config) call reuses a plan.
ENGINES: dict[PHConfig, PHEngine] = {}


def _engine(**kw) -> PHEngine:
    # auto_regrow off: the tables time exactly one dispatch at the stated
    # capacities (the pre-engine methodology); overflow is still flagged.
    kw.setdefault("auto_regrow", False)
    cfg = PHConfig(**kw)
    eng = ENGINES.get(cfg)
    if eng is None:
        eng = ENGINES[cfg] = PHEngine(cfg)
    return eng


def print_rows(rows) -> None:
    """The repo skeleton's ``name,us_per_call,derived`` CSV contract —
    shared by ``benchmarks/run.py`` and the tiled smoke CLI so the CI
    artifact and the full-run output can never diverge."""
    print("name,us_per_call,derived")
    for r in rows:
        r = dict(r)
        name = r.pop("name")
        t_s = (r.get("pixhomology_s") or r.get("round_makespan_s")
               or r.get("ours_batch_s") or r.get("value") or 0.0)
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{t_s * 1e6:.1f},{derived}")


def plan_cache_summary() -> dict:
    """Aggregate plan-cache stats over every engine the benchmarks built."""
    total = {"engines": len(ENGINES), "plans": 0, "traces": 0, "calls": 0,
             "hits": 0, "misses": 0, "regrows": 0}
    for eng in ENGINES.values():
        for k, v in eng.plan_stats().items():
            total[k] += v
    return total


def _timeit(fn, repeats=3):
    fn()                           # compile / warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def _run_blocked(engine: PHEngine, img, t=None):
    res = engine.run(img, t)
    jax.block_until_ready(res.diagram)
    return res


def table1_filtering(size=256, n_images=4, rows=None):
    """Variant-2 filtering levels (paper table 1)."""
    if rows is None:
        rows = []
    # One engine for all levels: the threshold is passed explicitly, so the
    # filter levels share a single compiled plan (traced once).
    engine = _engine(max_features=8192, max_candidates=32768)
    for level in ("vanilla", "filter_light", "filter_std", "filter_heavy"):
        ph_times, or_times, drops = [], [], []
        for i in range(n_images):
            img = astro.generate_image(i, size)
            # Threshold derived once outside the timed region (the paper
            # times the PH computation, not the host-side statistics).
            t, frac = astro.filter_threshold(img, level)
            drops.append(frac * 100)
            dt, _ = _timeit(lambda: _run_blocked(engine, img, t))
            ph_times.append(dt)
            t0 = time.perf_counter()
            persistence_oracle(img)      # oracle has no filtering path
            or_times.append(time.perf_counter() - t0)
        rows.append({
            "name": f"table1/{level}",
            "dropped_pct": round(float(np.mean(drops)), 2),
            "pixhomology_s": round(float(np.mean(ph_times)), 4),
            "oracle_s": round(float(np.mean(or_times)), 4),
        })
    return rows


def fig6_partitioning(n_images=96, size=128, rows=None):
    """Strategy comparison under the lockstep-round makespan model, using
    measured per-image PixHomology costs (paper fig 6)."""
    if rows is None:
        rows = []
    # Measure true per-image cost once (single-image calls, shared plan).
    engine = _engine(max_features=4096, max_candidates=16384)
    costs = {}
    est = {}
    for i in range(n_images):
        img = astro.generate_image(i, size)
        t, _ = astro.filter_threshold(img, "filter_std")
        if i == 0:
            _run_blocked(engine, img, t)  # warm the plan once
        t0 = time.perf_counter()
        _run_blocked(engine, img, t)
        costs[i] = time.perf_counter() - t0
        est[i] = astro.estimate_cost_from_id(i, size)
    ids = list(range(n_images))
    for m in (2, 4, 8, 12, 16, 18):
        for strat in ("part_executors", "part_images", "part_LPT"):
            # LPT schedules on the *estimate* (Variant 3), is judged on the
            # measured cost — exactly the paper's setup.
            sched = make_schedule(strat, ids, m, est, seed=1)
            rows.append({
                "name": f"fig6/{strat}/m={m}",
                "round_makespan_s": round(sched.makespan(costs), 4),
                "queue_makespan_s": round(sched.queue_makespan(costs), 4),
            })
    return rows


def fig7_equality(size=50, rows=None):
    """Bottleneck distance between PixHomology and the oracle (paper fig 7:
    distance 0; we additionally get exact pixel-coordinate equality)."""
    if rows is None:
        rows = []
    img = astro.generate_image(11, 256)[100:100 + size, 80:80 + size]
    res = _engine(max_features=size * size,
                  max_candidates=size * size).run(img)
    got = res.to_array()
    want = persistence_oracle(img)
    exact = got.shape == want.shape and np.array_equal(got, want)
    # bottleneck distance == max row-wise birth/death deviation under exact
    # row matching (0 when exact)
    bd = 0.0 if exact else float(np.max(np.abs(got[:, :2] - want[:, :2])))
    rows.append({"name": "fig7/bottleneck_distance", "value": bd,
                 "exact_match": bool(exact),
                 "features": int(res.diagram.count)})
    return rows


def fig9_10_scaling(rows=None, sizes=(20, 50, 100, 200, 400, 800)):
    """Time + peak heap vs crop size: PixHomology vs classical oracle."""
    if rows is None:
        rows = []
    big = astro.generate_image(21, max(sizes))
    for s in sizes:
        img = big[:s, :s]
        engine = _engine(max_features=min(s * s, 16384),
                         max_candidates=min(s * s, 65536))
        dt, _ = _timeit(lambda: _run_blocked(engine, img))

        tracemalloc.start()
        persistence_oracle(img)
        _, or_peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        t0 = time.perf_counter()
        persistence_oracle(img)
        or_t = time.perf_counter() - t0

        # PixHomology device memory: fixed-size arrays ~ 5 int32/f32 planes
        # + diagram capacities (analytic; device allocator is pooled).
        ph_mem = s * s * 4 * 6
        rows.append({
            "name": f"fig9_10/size={s}",
            "pixhomology_s": round(dt, 4),
            "oracle_s": round(or_t, 4),
            "pixhomology_mem_mb": round(ph_mem / 1e6, 2),
            "oracle_peak_mb": round(or_peak / 1e6, 2),
        })
    return rows


def perf_merge_impl(rows=None, size=512):
    """Beyond-paper: sequential merge scan vs Boruvka parallel merge.

    Wall time on CPU already shows the depth effect (the scan's K steps
    serialize); on TPU the gap widens (vector units idle during the scan).
    Outputs are bit-identical (tests/test_parallel_merge.py).
    """
    if rows is None:
        rows = []
    img = astro.generate_image(31, size)
    t, _ = astro.filter_threshold(img, "filter_std")
    for impl in ("scan", "boruvka"):
        engine = _engine(max_features=16384, max_candidates=65536,
                         merge_impl=impl)
        dt, _ = _timeit(lambda: _run_blocked(engine, img, t))
        rows.append({"name": f"perf/merge_{impl}/size={size}",
                     "pixhomology_s": round(dt, 4)})
    return rows


def tiled_vs_whole(rows=None, size=256, grids=((1, 1), (2, 2), (4, 4)),
                   out_path=None):
    """Beyond-paper: halo-tiled PH vs the whole-image path on one image.

    Every grid is bit-identical to the whole-image diagram (asserted); the
    ``tiled_vs_whole_x`` column is the per-grid wall-time ratio, and the
    per-tile cost model shows working memory shrinking with the grid — the
    property that lets one image exceed a device.  Emits ``BENCH_tiled.json``
    so the perf trajectory accumulates across commits.
    """
    import jax.numpy as jnp
    from repro.core.tiling import per_tile_cost

    if rows is None:
        rows = []
    img = astro.generate_image(41, size)
    whole = _engine(max_features=8192, max_candidates=32768)
    t_whole, res_whole = _timeit(lambda: _run_blocked(whole, img))
    want = res_whole.to_array()
    rows.append({"name": f"tiled/whole/size={size}",
                 "pixhomology_s": round(t_whole, 4),
                 "tiled_vs_whole_x": 1.0,
                 "features": int(res_whole.diagram.count)})
    bench = [dict(rows[-1], grid=None)]
    for grid in grids:
        eng = _engine(max_features=8192,
                      tile=TileSpec(grid=tuple(grid),
                                    max_features_per_tile=8192,
                                    max_candidates_per_tile=32768))

        def run_tiled():
            res = eng.run_tiled(img)
            jax.block_until_ready(res.diagram)
            return res

        dt, res = _timeit(run_tiled)
        np.testing.assert_array_equal(res.to_array(), want)
        tr, tc = size // grid[0], size // grid[1]
        cost = per_tile_cost((tr, tc), jnp.float32,
                             n_tiles=grid[0] * grid[1],
                             tile_max_features=min(8192, tr * tc),
                             tile_max_candidates=min(32768, tr * tc))
        row = {"name": f"tiled/grid={grid[0]}x{grid[1]}/size={size}",
               "pixhomology_s": round(dt, 4),
               "tiled_vs_whole_x": round(dt / t_whole, 3),
               "per_tile_peak_mb": round(
                   (cost["phase_a"]["peak_bytes_est"]
                    + cost["phase_b"]["peak_bytes_est"]) / 1e6, 3),
               "exact_match": True}
        rows.append(row)
        bench.append(dict(row, grid=list(grid)))

    out_path = Path(out_path) if out_path else ARTIFACTS / "BENCH_tiled.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(
        {"size": size, "rows": bench}, indent=1, default=float))
    return rows


def _dipha_style_patches(img: np.ndarray, m: int):
    """DIPHA's strategy: split ONE image into m row-bands with 1-px halo,
    compute local PH per band, then merge boundary components via the
    global union-find on the seam candidates (the cross-node traffic)."""
    h = img.shape[0]
    bands = np.array_split(np.arange(h), m)
    t_total = 0.0
    seam_pixels = 0
    engine = _engine(max_features=8192, max_candidates=32768)
    for b in bands:
        lo, hi = b[0], b[-1] + 1
        lo_h, hi_h = max(0, lo - 1), min(h, hi + 1)
        patch = img[lo_h:hi_h]
        _run_blocked(engine, patch)      # warm this band shape
        t0 = time.perf_counter()
        _run_blocked(engine, patch)
        t_total = max(t_total, time.perf_counter() - t0)   # parallel bands
        seam_pixels += 2 * img.shape[1]
    # seam merge: oracle union-find on the seam rows (host-side, serial)
    t0 = time.perf_counter()
    seams = np.concatenate([img[max(0, b[-1] - 1):b[-1] + 2]
                            for b in bands[:-1]], axis=0)
    persistence_oracle(seams)
    t_merge = time.perf_counter() - t0
    return t_total + t_merge, seam_pixels


def fig11_dipha(size=384, n_images=8, rows=None):
    """Whole-image distribution (ours) vs patch-split (DIPHA-style)."""
    if rows is None:
        rows = []
    imgs = np.stack([astro.generate_image(i, size) for i in range(n_images)])
    engine = _engine(max_features=8192, max_candidates=32768)
    for m in (2, 4, 8):
        # ours: m executors each take whole images; time = ceil(n/m) rounds
        _run_blocked(engine, imgs[0])
        per_img = []
        for i in range(n_images):
            s0 = time.perf_counter()
            _run_blocked(engine, imgs[i])
            per_img.append(time.perf_counter() - s0)
        rounds = -(-n_images // m)
        ours = sum(sorted(per_img, reverse=True)[:rounds])  # lockstep bound
        dipha_t, seam = _dipha_style_patches(imgs[0], m)
        dipha_total = dipha_t * -(-n_images // 1) / 1  # sequential images
        rows.append({
            "name": f"fig11/m={m}",
            "ours_batch_s": round(ours, 4),
            "dipha_style_batch_s": round(dipha_total, 4),
            "dipha_seam_pixels_per_image": seam,
        })
    return rows
