"""Differential oracle harness for the fused phase-C kernel (PR 7).

Same three-layer structure as ``test_merge_keys.py`` (whose helpers this
file reuses):

1. unit parity of the Pallas blocked reduction against its XLA reference
   (interpret mode off-TPU) — across key dtypes, tie storms, dead lanes,
   all-dead instances, and block sizes that do not divide the edge count;
2. whole-diagram bit-identity of ``phase_c_impl="fused"`` against
   ``"xla"`` and the scan merge across dtypes, plateaus, truncation, and
   tournament widths — including the overflow-flag contract;
3. a cross-path matrix {whole, batched, sharded, tiled} x {fused, xla}
   against the whole-image rank reference, so no path x impl combination
   can silently diverge.

Plus the merge-budget early exit: a fully merged forest must stop
without the final verification round, bit-identically.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from test_merge_keys import (
    _MATRIX_IMG,
    _assert_fields_equal,
    _image,
    _reference_diagram,
    run_path,
)

from repro.core import packed_keys as pk
from repro.core.parallel_merge import boruvka_forest
from repro.core.pixhomology import pixhomology
from repro.kernels.ph_phase_c import kernel
from repro.kernels.ph_phase_c import ops as phase_c_ops
from repro.kernels.ph_phase_c import ref


# ---------------------------------------------------------------------------
# 1. Pallas kernel parity vs the XLA reference (interpret mode off-TPU)
# ---------------------------------------------------------------------------

def _instance(e: int, nv: int, dtype, seed: int, dead_frac: float = 0.3):
    """Random reduction instance: ~keyspace of 10 values (tie storms),
    ~dead_frac pad lanes, endpoints uniform over the vertex set."""
    rng = np.random.default_rng(seed)
    pad = int(pk.key_pad(dtype))
    key = rng.integers(-5, 5, size=e).astype(np.int64)
    key = np.where(rng.random(e) < dead_frac, pad, key)
    ra = rng.integers(0, nv, size=e).astype(np.int32)
    rb = rng.integers(0, nv, size=e).astype(np.int32)
    return (jnp.asarray(key, dtype), jnp.asarray(ra), jnp.asarray(rb))


@pytest.mark.parametrize("e,nv,block", [(1, 1, 4), (7, 3, 4), (33, 4, 8),
                                        (64, 5, 16), (100, 9, 1024)])
@pytest.mark.parametrize("dtype", ["int32", "int64"])
def test_kernel_matches_ref(e, nv, block, dtype):
    scope = "packed" if dtype == "int64" else "rank"
    with pk.key_scope(scope):
        key, ra, rb = _instance(e, nv, jnp.dtype(dtype), seed=e * 31 + nv)
        best_k, win_k = kernel.best_edge_reduce(key, ra, rb, nv,
                                                block_edges=block,
                                                interpret=True)
        best_r, win_r = ref.best_edge_reduce(key, ra, rb, nv)
    np.testing.assert_array_equal(np.asarray(best_k), np.asarray(best_r))
    np.testing.assert_array_equal(np.asarray(win_k), np.asarray(win_r))


def test_kernel_all_dead_lanes():
    with pk.key_scope("rank"):
        pad = pk.key_pad(jnp.int32)
        key = jnp.full(17, pad, jnp.int32)
        ra = jnp.zeros(17, jnp.int32)
        rb = jnp.zeros(17, jnp.int32)
        best, win = kernel.best_edge_reduce(key, ra, rb, 4, block_edges=8,
                                            interpret=True)
    assert np.all(np.asarray(best) == int(pad))
    assert np.all(np.asarray(win) == -1)


def test_kernel_tie_break_is_max_edge_index():
    # Three equal-key edges into vertex 0: the winner must be the highest
    # edge index (the deterministic Boruvka tie rule), not block order.
    with pk.key_scope("rank"):
        key = jnp.array([7, 7, 7, 2], jnp.int32)
        ra = jnp.array([0, 0, 0, 1], jnp.int32)
        rb = jnp.array([1, 1, 1, 0], jnp.int32)
        best, win = kernel.best_edge_reduce(key, ra, rb, 2, block_edges=2,
                                            interpret=True)
    np.testing.assert_array_equal(np.asarray(best), [7, 7])
    np.testing.assert_array_equal(np.asarray(win), [2, 2])


def test_ops_dispatch_routes_off_tpu_to_ref():
    # use_pallas=None off-TPU must be the XLA reference (same objects out).
    with pk.key_scope("rank"):
        key, ra, rb = _instance(20, 3, jnp.dtype(jnp.int32), seed=1)
        auto = phase_c_ops.best_edge_reduce(key, ra, rb, 3)
        forced = phase_c_ops.best_edge_reduce(key, ra, rb, 3,
                                              use_pallas=True,
                                              interpret=True)
        want = ref.best_edge_reduce(key, ra, rb, 3)
    for got in (auto, forced):
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(want[1]))


# ---------------------------------------------------------------------------
# 2. Whole-diagram bit-identity: fused vs xla vs the scan merge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("merge_keys", ["packed", "rank"])
@pytest.mark.parametrize("dtype,kind", [("float32", "gaussian"),
                                        ("float32", "plateau"),
                                        ("uint8", "plateau"),
                                        ("int16", "negative")])
def test_fused_matches_xla_and_scan(dtype, kind, merge_keys):
    img = _image(dtype, kind, 7)
    xla = run_path(img, merge_keys, merge_impl="boruvka",
                   phase_c_impl="xla")
    fused = run_path(img, merge_keys, merge_impl="boruvka",
                     phase_c_impl="fused")
    scan = run_path(img, merge_keys, merge_impl="scan")
    np.testing.assert_array_equal(fused, xla)
    np.testing.assert_array_equal(fused, scan)


@pytest.mark.parametrize("merge_keys", ["packed", "rank"])
def test_fused_matches_xla_truncated(merge_keys):
    img = _image("float32", "gaussian", 21)
    tv = float(np.median(img))
    h, w = img.shape
    kw = dict(max_features=h * w, max_candidates=h * w,
              merge_impl="boruvka", merge_keys=merge_keys)
    d_x = pixhomology(jnp.asarray(img), tv, phase_c_impl="xla", **kw)
    d_f = pixhomology(jnp.asarray(img), tv, phase_c_impl="fused", **kw)
    _assert_fields_equal(d_f, d_x, f"truncated/{merge_keys}")
    assert not bool(d_x.overflow)


def test_fused_pallas_kernel_end_to_end():
    # The fused path with the Pallas reduction forced on (interpret mode
    # off-TPU) must still be bit-identical at the diagram level.
    img = _image("float32", "gaussian", 5)
    want = run_path(img, "packed", merge_impl="boruvka", phase_c_impl="xla")
    got = run_path(img, "packed", merge_impl="boruvka",
                   phase_c_impl="fused", use_pallas=True, interpret=True)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("width", [3, 4, 8])
def test_tournament_width_bit_identical(width):
    img = _image("float32", "plateau", 11)
    base = run_path(img, "packed", merge_impl="boruvka",
                    phase_c_impl="fused", tournament_width=2)
    got = run_path(img, "packed", merge_impl="boruvka",
                   phase_c_impl="fused", tournament_width=width)
    np.testing.assert_array_equal(got, base)


def test_tournament_width_validated():
    from repro.core.packed_keys import select_descending
    from repro.ph import PHConfig
    with pytest.raises(ValueError):
        PHConfig(tournament_width=1)
    with pk.key_scope("packed"):
        key = pk.pack_keys(jnp.arange(8, dtype=jnp.float32))
        with pytest.raises(ValueError):
            select_descending(key, jnp.ones(8, bool), 2, width=1)


def test_overflow_flag_parity_under_root_overflow():
    # max_features below the root count: both impls must raise the same
    # overflow flag (the engine's regrow contract) even though their
    # pre-regrow rows may legitimately differ.
    img = _image("float32", "gaussian", 3)
    h, w = img.shape
    kw = dict(max_features=2, max_candidates=h * w, merge_impl="boruvka",
              merge_keys="packed")
    d_x = pixhomology(jnp.asarray(img), phase_c_impl="xla", **kw)
    d_f = pixhomology(jnp.asarray(img), phase_c_impl="fused", **kw)
    assert bool(d_x.overflow) and bool(d_f.overflow)


# ---------------------------------------------------------------------------
# 3. Boruvka merge-budget early exit
# ---------------------------------------------------------------------------

def test_early_exit_skips_verification_round():
    # Two live clusters, one edge: the forest is fully merged after round
    # 1; the merge budget (n_live - 1 == 1) must stop there, while the
    # uncapped loop needs a second round to observe no alive edges.
    v_rank = jnp.array([5, 3], jnp.int32)
    e_rank = jnp.array([1], jnp.int32)
    e_val = jnp.array([1.0], jnp.float32)
    e_pos = jnp.array([7], jnp.int32)
    e_a = jnp.array([0], jnp.int32)
    e_b = jnp.array([1], jnp.int32)
    base = boruvka_forest(v_rank, e_rank, e_val, e_pos, e_a, e_b)
    capped = boruvka_forest(v_rank, e_rank, e_val, e_pos, e_a, e_b,
                            n_live=jnp.int32(2))
    assert int(capped[2]) < int(base[2])
    np.testing.assert_array_equal(np.asarray(capped[0]),
                                  np.asarray(base[0]))
    np.testing.assert_array_equal(np.asarray(capped[1]),
                                  np.asarray(base[1]))


def test_early_exit_overestimated_budget_is_safe():
    # Over-estimating n_live (callers pass root counts, an upper bound)
    # must never change results — only potentially cost a round.
    img = _image("float32", "gaussian", 13)
    want = run_path(img, "packed", merge_impl="boruvka", phase_c_impl="xla")
    got = run_path(img, "packed", merge_impl="boruvka",
                   phase_c_impl="fused")
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# 4. Cross-path bit-identity matrix (path x phase_c_impl)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("phase_c_impl", ["fused", "xla"])
@pytest.mark.parametrize("path", ["whole", "batched", "sharded", "tiled"])
def test_cross_path_phase_c_matrix(path, phase_c_impl):
    """No {path} x {phase_c_impl} combination may diverge from the
    whole-image rank/scan reference — bit-for-bit, including
    p_birth/p_death."""
    from repro.ph import PHConfig, PHEngine, TileSpec
    want = _reference_diagram()
    h, w = _MATRIX_IMG.shape
    n = h * w
    config = PHConfig(max_features=n, max_candidates=n,
                      merge_impl="boruvka", phase_c_impl=phase_c_impl,
                      phase_c_block=64, strip_rows=4,
                      tile=TileSpec(grid=(2, 2)))
    engine = PHEngine(config)
    img = jnp.asarray(_MATRIX_IMG)

    if path == "whole":
        got = engine.run(_MATRIX_IMG).diagram
    elif path == "batched":
        res = engine.run_batch(_MATRIX_IMG[None]).diagram
        got = jax.tree.map(lambda x: x[0], res)
    elif path == "sharded":
        from repro.launch.mesh import make_small_context
        ctx = make_small_context(1, 1)
        plan = engine.sharded_plan(ctx, (1, h, w), jnp.dtype(jnp.float32),
                                   n, n)
        tvals = jnp.full((1,), -jnp.inf, jnp.float32)  # vanilla sentinel
        res = plan(img[None], tvals)
        got = jax.tree.map(lambda x: x[0], res)
    else:   # tiled
        got = engine.run_tiled(_MATRIX_IMG).diagram
    _assert_fields_equal(got, want, f"{path}/{phase_c_impl}")
