"""Overlap engine: async staging rings, donated device buffers,
non-blocking regrow, harvest-thread D2H — across the engine, pipeline,
serving, and delta paths.

The contract under test everywhere: every overlapped path is
**bit-identical** to its synchronous twin (overflow semantics deferred,
never altered), the dispatch path performs zero blocking device
readbacks in steady state, and host-side round building allocates
nothing on device until the one fused ``jax.device_put``.
"""
import threading
import time

import numpy as np
import pytest

from repro.data import astro
from repro.distributed.context import single_device_ctx
from repro.ph import (DeltaSpec, OverlapSpec, PHConfig, PHEngine, ServeSpec,
                      TileSpec)
from repro.pipeline.driver import FailureInjector
from repro.pipeline.executor import ShardedPHExecutor
from repro.pipeline.scheduler import BucketRound, ImageMeta


def _bumpy(seed=0, shape=(8, 8)):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


def _assert_diagrams_equal(d, ref):
    c = int(d.count)
    assert c == int(ref.count)
    for a, b in ((d.birth, ref.birth), (d.death, ref.death),
                 (d.p_birth, ref.p_birth), (d.p_death, ref.p_death)):
        assert np.array_equal(np.asarray(a)[:c], np.asarray(b)[:c])


# ---------------------------------------------------------------------------
# OverlapSpec plumbing: validation, plan_key, flags, JSON round-trip
# ---------------------------------------------------------------------------

def test_overlap_spec_validation():
    spec = OverlapSpec()
    assert spec.enabled and spec.donate and spec.staging_depth == 2
    assert spec.async_overflow and spec.async_harvest
    with pytest.raises(ValueError):
        OverlapSpec(staging_depth=0)
    with pytest.raises(ValueError):
        OverlapSpec(donate="yes")
    with pytest.raises(ValueError):
        PHConfig(overlap="on")


def test_overlap_plan_key_and_roundtrip():
    cfg = PHConfig(overlap=OverlapSpec())
    again = PHConfig.from_json(cfg.to_json())
    assert again == cfg and again.plan_key() == cfg.plan_key()
    # donation changes input/output aliasing -> selects executables;
    # ring depth and the async toggles are host-side scheduling only.
    assert cfg.plan_key() != PHConfig().plan_key()
    assert cfg.plan_key() != PHConfig(
        overlap=OverlapSpec(donate=False)).plan_key()
    assert cfg.plan_key() == PHConfig(
        overlap=OverlapSpec(staging_depth=7, async_overflow=False,
                            async_harvest=False)).plan_key()


def test_overlap_from_flags():
    from types import SimpleNamespace
    cfg = PHConfig.from_flags(SimpleNamespace(
        overlap=True, overlap_depth=3, no_donate=True,
        no_async_overflow=False, no_async_harvest=False))
    assert cfg.overlap == OverlapSpec(staging_depth=3, donate=False)
    assert PHConfig.from_flags(SimpleNamespace()).overlap is None
    # any overlap sub-flag implies the spec even without --overlap
    assert PHConfig.from_flags(SimpleNamespace(
        no_async_harvest=True)).overlap == OverlapSpec(async_harvest=False)


# ---------------------------------------------------------------------------
# Host-side staging: no device bounce, one fused H2D per round
# ---------------------------------------------------------------------------

def test_cast_input_host_matches_device_cast():
    import jax.numpy as jnp
    for cfg in (PHConfig(), PHConfig(dtype="float32")):
        eng = PHEngine(cfg)
        for img in (np.ones((4, 4), np.float64),
                    np.ones((4, 4), np.float32),
                    np.arange(16, dtype=np.int32).reshape(4, 4)):
            host = eng.cast_input_host(img)
            dev = eng.cast_input(img)
            assert isinstance(host, np.ndarray)
            assert not isinstance(host, jnp.ndarray.__mro__[0]) or True
            assert host.dtype == np.asarray(dev).dtype
            np.testing.assert_array_equal(host, np.asarray(dev))


def test_build_host_round_allocates_nothing_on_device(monkeypatch):
    """Regression for the host->device->host staging bounce: building a
    padded round is pure numpy — any device_put (or implicit jnp
    conversion) during the build is a bug."""
    import jax
    eng = PHEngine(PHConfig(max_features=2048, filter_level="filter_std"))
    pool = ShardedPHExecutor(eng, single_device_ctx())
    rnd = BucketRound("whole", (32, 32), ((0, ImageMeta(0, (24, 24))),))

    def boom(*a, **kw):
        raise AssertionError("device_put during host-side round build")

    monkeypatch.setattr(jax, "device_put", boom)
    staged = pool._build_host_round(rnd)
    monkeypatch.undo()
    assert isinstance(staged.host_batch, np.ndarray)
    assert isinstance(staged.host_tvals, np.ndarray)
    assert staged.batch is None     # nothing staged yet
    # ... and the subsequent staging is exactly one fused device_put
    before = eng.overlap_counters.snapshot()
    staged = pool._stage_round(staged)
    after = eng.overlap_counters.snapshot()
    assert after["h2d_transfers"] - before["h2d_transfers"] == 1
    assert staged.batch is not None and staged.tvals is not None
    np.testing.assert_array_equal(np.asarray(staged.batch),
                                  staged.host_batch)
    np.testing.assert_array_equal(np.asarray(staged.tvals),
                                  staged.host_tvals)


# ---------------------------------------------------------------------------
# Engine: run_batch_async == run_batch, donation safety, deferred regrow
# ---------------------------------------------------------------------------

def test_run_batch_async_matches_run_batch():
    sync = PHEngine(PHConfig())
    over = PHEngine(PHConfig(overlap=OverlapSpec()))
    # uniform (stacked) and bucketed (mixed-shape) routes
    stacked = np.stack([_bumpy(0), _bumpy(1), _bumpy(2)])
    mixed = [_bumpy(3, (6, 5)), _bumpy(4, (8, 8)), _bumpy(5, (5, 9))]
    for imgs in (stacked, mixed):
        want = sync.run_batch(imgs)
        pending = over.run_batch_async(imgs)
        got = pending.resolve()
        assert pending.resolve() is got        # memoized
        n = len(imgs)
        for i in range(n):
            row = type(got.diagram)(
                *(np.asarray(f)[i] for f in got.diagram))
            ref = type(want.diagram)(
                *(np.asarray(f)[i] for f in want.diagram))
            _assert_diagrams_equal(row, ref)


def test_donating_batch_does_not_corrupt_caller_arrays():
    """Donation must only ever consume engine-owned padded buffers: the
    caller's arrays are intact and a repeat call is bit-identical."""
    over = PHEngine(PHConfig(overlap=OverlapSpec()))
    imgs = [_bumpy(7, (6, 6)), _bumpy(8, (8, 8))]
    copies = [im.copy() for im in imgs]
    first = over.run_batch(imgs)
    for im, cp in zip(imgs, copies):
        np.testing.assert_array_equal(im, cp)
    second = over.run_batch(imgs)
    for f in first.diagram._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(first.diagram, f)),
            np.asarray(getattr(second.diagram, f)), err_msg=f)


def test_nonblocking_regrow_still_regrows():
    """With async_overflow the check is deferred to resolve() — but an
    overflowing batch must still regrow to the same capacities and the
    same diagram bytes as the synchronous engine."""
    def cfg(overlap):
        return PHConfig(max_features=4, max_candidates=16, overlap=overlap)

    imgs = np.stack([_bumpy(11, (16, 16)), _bumpy(12, (16, 16))])
    want = PHEngine(cfg(None)).run_batch(imgs)
    over = PHEngine(cfg(OverlapSpec()))
    got = over.run_batch_async(imgs).resolve()
    assert want.regrow.regrown and got.regrow.regrown
    assert got.regrow.attempts == want.regrow.attempts
    assert got.regrow.final_max_features == want.regrow.final_max_features
    for f in want.diagram._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got.diagram, f)),
                                      np.asarray(getattr(want.diagram, f)),
                                      err_msg=f)
    assert over.overlap_counters.snapshot()["d2h_streams"] > 0


# ---------------------------------------------------------------------------
# Pipeline: overlap bit-identical to sync, zero dispatch-path syncs
# ---------------------------------------------------------------------------

def _tiled_engine(**kw):
    kw.setdefault("max_features", 4096)
    kw.setdefault("filter_level", "filter_std")
    return PHEngine(PHConfig(tile=TileSpec(
        grid=(2, 2), max_features_per_tile=1024,
        max_candidates_per_tile=2048, max_tile_pixels=32 * 32), **kw))


IMAGES = [(0, 24), (1, 32), (2, 64), (3, 32), (4, 24)]


def test_overlap_pipeline_bit_identical_to_sync():
    """Heterogeneous + tiled mix end to end: the overlap engine is a
    pure latency optimization, and in steady state every blocking
    readback happens on the harvest thread."""
    sync = _tiled_engine(prefetch_rounds=1)
    over = _tiled_engine(prefetch_rounds=1, overlap=OverlapSpec())
    want = sync.run_distributed(IMAGES)
    before = over.overlap_counters.snapshot()
    got = over.run_distributed(IMAGES)
    after = over.overlap_counters.snapshot()
    assert got.diagrams == want.diagrams
    assert after["dispatch_syncs"] == before["dispatch_syncs"]
    assert after["harvest_syncs"] > before["harvest_syncs"]
    # the sync engine pays its readbacks on the dispatch path instead
    assert sync.overlap_counters.snapshot()["harvest_syncs"] == 0


def test_overlap_failure_discards_inflight_and_resumes(tmp_path):
    """An injected executor failure while later rounds are staged and in
    flight: completed harvests are real results, unresolved rounds are
    discarded, and the retry completes everything from the work log —
    matching the synchronous pipeline bit for bit."""
    log = tmp_path / "overlap.jsonl"
    over = _tiled_engine(prefetch_rounds=1,
                         overlap=OverlapSpec(staging_depth=2))
    res = over.run_distributed(IMAGES, work_log=log,
                               failure_injector=FailureInjector([0, 1]))
    assert res.failures == 2
    assert len(res.diagrams) == len(IMAGES)
    want = _tiled_engine().run_distributed(IMAGES)
    assert res.diagrams == want.diagrams
    # nothing done twice: the log holds exactly one line per image
    import json
    ids = [json.loads(l)["image_id"] for l in log.read_text().splitlines()]
    assert sorted(ids) == sorted(i for i, _ in IMAGES)
    # resume recomputes nothing
    over2 = _tiled_engine(overlap=OverlapSpec())
    res2 = over2.run_distributed(IMAGES, work_log=log)
    assert res2.diagrams == res.diagrams
    assert over2.overlap_counters.snapshot()["h2d_transfers"] == 0


def test_overlap_failure_with_delta_does_not_poison_cache(tmp_path):
    """The delta frame store stays consistent when an overlapped round
    fails mid-flight: retried rounds replace entries in place and the
    resumed results match a delta-free, overlap-free pipeline."""
    def mk(delta, overlap):
        return PHEngine(PHConfig(
            max_features=4096, filter_level="filter_std", delta=delta,
            overlap=overlap, prefetch_rounds=1,
            tile=TileSpec(grid=(2, 2), max_features_per_tile=1024,
                          max_candidates_per_tile=2048,
                          max_tile_pixels=32 * 32)))

    log = tmp_path / "delta_overlap.jsonl"
    eng = mk(DeltaSpec(cache_entries=8), OverlapSpec())
    res = eng.run_distributed([(0, 32), (2, 64)], work_log=log,
                              failure_injector=FailureInjector([0, 1]))
    assert res.failures == 2 and len(res.diagrams) == 2
    assert len(eng._delta_cache._entries) == 1      # one oversized frame
    want = mk(None, None).run_distributed([(0, 32), (2, 64)])
    assert res.diagrams == want.diagrams


# ---------------------------------------------------------------------------
# Serving: harvest-thread future resolution, hammered, bit-identical
# ---------------------------------------------------------------------------

def test_server_async_harvest_bit_identical_under_hammer():
    from repro.serving import PHServer
    spec = ServeSpec(buckets=((8, 8), (16, 16)), batch_cap=3,
                     tick_interval_s=0.001)
    eng = PHEngine(PHConfig(serve=spec, overlap=OverlapSpec()))
    eng.warmup()
    shapes = [(6, 5), (8, 8), (12, 10), (16, 16)]
    imgs = [_bumpy(i, shapes[i % len(shapes)]) for i in range(16)]
    results = [None] * len(imgs)
    errs = []
    with PHServer(eng) as srv:
        srv.warmup()
        barrier = threading.Barrier(4)

        def hammer(k):
            try:
                barrier.wait(timeout=30)
                futs = [(i, srv.submit(imgs[i]))
                        for i in range(k, len(imgs), 4)]
                for i, f in futs:
                    results[i] = f.result(timeout=120)
            except Exception as e:          # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=hammer, args=(k,))
                   for k in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errs and all(r is not None for r in results)
        assert srv.steady_state_traces() == 0
        st = srv.stats()
    assert st["completed"] == len(imgs)
    assert st["overlap"]["dispatch_syncs"] == 0
    assert st["overlap"]["harvest_syncs"] > 0
    ref = PHEngine(PHConfig())
    for im, res in zip(imgs, results):
        want = ref.run(im, truncate_value=res.threshold)
        _assert_diagrams_equal(res.diagram, want.diagram)


def test_server_sync_and_async_harvest_agree():
    from repro.serving import PHServer
    spec = ServeSpec(buckets=((8, 8),), batch_cap=2,
                     tick_interval_s=0.001)
    imgs = [_bumpy(i) for i in range(5)]
    out = {}
    for label, overlap in (("sync", OverlapSpec(async_harvest=False)),
                           ("async", OverlapSpec())):
        eng = PHEngine(PHConfig(serve=spec, overlap=overlap))
        with PHServer(eng) as srv:
            futs = [srv.submit(im) for im in imgs]
            out[label] = [f.result(timeout=120) for f in futs]
    for a, b in zip(out["sync"], out["async"]):
        assert a.threshold == b.threshold
        _assert_diagrams_equal(a.diagram, b.diagram)


def test_server_shutdown_drains_harvest_thread():
    from repro.serving import PHServer
    spec = ServeSpec(buckets=((8, 8),), batch_cap=2,
                     tick_interval_s=0.001)
    eng = PHEngine(PHConfig(serve=spec, overlap=OverlapSpec()))
    srv = PHServer(eng)
    futs = [srv.submit(_bumpy(i)) for i in range(6)]
    srv.shutdown(drain=True)
    assert all(f.done() and f.exception() is None for f in futs)


# ---------------------------------------------------------------------------
# Delta path: host-side casting, overlap engine bit-identity
# ---------------------------------------------------------------------------

def test_run_delta_overlap_bit_identical():
    from repro.data.astro import FrameSequence
    def mk(overlap):
        return PHEngine(PHConfig(
            max_features=2048, delta=DeltaSpec(cache_entries=4),
            overlap=overlap,
            tile=TileSpec(grid=(2, 2), max_tile_pixels=16 * 16,
                          max_features_per_tile=256,
                          max_candidates_per_tile=512)))

    fs = FrameSequence(3, 32, grid=(2, 2), dirty_frac=0.3, stamp=3)
    tv, _ = astro.filter_threshold(fs.base(), "filter_std")
    a, b = mk(None), mk(OverlapSpec())
    for i in range(3):
        da = a.run_delta(fs.frame(i), tv)
        db = b.run_delta(fs.frame(i), tv)
        assert da.delta.hit == db.delta.hit
        for f in da.diagram._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(da.diagram, f)),
                np.asarray(getattr(db.diagram, f)), err_msg=f)
