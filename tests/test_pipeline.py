"""Distributed PH pipeline: scheduling, fault tolerance, work-log resume."""
import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import astro
from repro.distributed.context import single_device_ctx
from repro.ph import FilterLevel, PHConfig, PHEngine
from repro.pipeline.driver import FailureInjector, run_pipeline
from repro.pipeline.executor import ExecutorPool, ShardedPHExecutor
from repro.pipeline.scheduler import (make_schedule, part_executors,
                                      part_images, part_lpt)


# ---------------------------------------------------------------------------
# Scheduler properties
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 60), st.integers(1, 12), st.integers(0, 2 ** 20))
def test_schedules_cover_all_images_exactly_once(n, m, seed):
    rng = np.random.default_rng(seed)
    ids = list(range(n))
    costs = {i: float(rng.uniform(1, 100)) for i in ids}
    for strat in ("part_executors", "part_images", "part_LPT"):
        sched = make_schedule(strat, ids, m, costs, seed=seed)
        flat = [i for q in sched.queues for i in q]
        assert sorted(flat) == ids, strat
        assert len(sched.queues) == m


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 80), st.integers(2, 10), st.integers(0, 2 ** 20))
def test_lpt_beats_or_matches_static_on_skewed_costs(n, m, seed):
    """Paper fig 6: LPT's queue makespan <= static chunking, and is within
    the Graham 4/3 bound of the lower bound."""
    rng = np.random.default_rng(seed)
    ids = list(range(n))
    # heavy-tailed costs => stragglers exist
    costs = {i: float(rng.pareto(1.5) + 0.1) for i in ids}
    lpt = part_lpt(ids, m, costs).queue_makespan(costs)
    static = part_executors(ids, m, seed=seed).queue_makespan(costs)
    dynamic = part_images(ids, m, costs).queue_makespan(costs)
    lower = max(max(costs.values()), sum(costs.values()) / m)
    # Graham's theorems: LPT within 4/3 - 1/(3m) of OPT (>= lower bound);
    # greedy list scheduling within 2 - 1/m.
    assert lpt <= (4 / 3 - 1 / (3 * m)) * lower + 1e-6
    assert dynamic <= (2 - 1 / m) * lower + 1e-6
    # static is a valid schedule, so it can never beat the lower bound
    assert static >= lower - 1e-9
    assert lpt <= static * (4 / 3) + 1e-6


def test_lpt_beats_static_on_strong_skew():
    """Deterministic instance with a straggler: LPT clearly wins (fig 6)."""
    costs = {i: 1.0 for i in range(32)}
    costs[0] = 30.0
    ids = list(costs)
    m = 8
    lpt = part_lpt(ids, m, costs).queue_makespan(costs)
    static = np.mean([part_executors(ids, m, seed=s).queue_makespan(costs)
                      for s in range(10)])
    assert lpt == 30.0               # straggler isolated on its own executor
    assert static > lpt + 1.0        # chunking stacks work behind it


def test_lpt_requires_costs():
    with pytest.raises(ValueError):
        make_schedule("part_LPT", [1, 2], 2, None)


# ---------------------------------------------------------------------------
# Driver: fault tolerance + resume
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pool():
    engine = PHEngine(PHConfig(max_features=2048, max_candidates=8192,
                               filter_level=FilterLevel.STD))
    return ShardedPHExecutor(engine, single_device_ctx(), image_size=128)


def test_executor_pool_shim_is_deprecated_but_works():
    with pytest.warns(DeprecationWarning):
        shim = ExecutorPool(single_device_ctx(), image_size=64,
                            max_features=1024, max_candidates=4096)
    res = run_pipeline(shim, [0])
    assert len(res.diagrams) == 1
    assert not shim.engine.config.auto_regrow   # pre-engine semantics


def test_pipeline_completes_and_counts_objects(pool):
    res = run_pipeline(pool, list(range(4)), strategy="part_LPT")
    assert len(res.diagrams) == 4
    for d in res.diagrams.values():
        assert d["count"] > 0 and not d["overflow"]


def test_failure_recovery(pool):
    inj = FailureInjector([0])       # first round dies once
    res = run_pipeline(pool, list(range(3)), strategy="part_images",
                       failure_injector=inj)
    assert res.failures == 1
    assert len(res.diagrams) == 3    # everything still computed


def test_worklog_resume(tmp_path, pool):
    log = tmp_path / "work.jsonl"
    res1 = run_pipeline(pool, [0, 1], work_log=log)
    assert len(res1.diagrams) == 2
    lines_before = log.read_text().count("\n")
    # Second run with a superset: already-done images are NOT recomputed.
    res2 = run_pipeline(pool, [0, 1, 2], work_log=log)
    assert len(res2.diagrams) == 3
    new_lines = log.read_text().count("\n") - lines_before
    assert new_lines == 1            # only image 2 was processed


def test_pipeline_results_deterministic(pool):
    r1 = run_pipeline(pool, [5, 6], strategy="part_executors")
    r2 = run_pipeline(pool, [5, 6], strategy="part_LPT")
    for i in (5, 6):                 # schedule must not change the math
        assert r1.diagrams[i]["top_births"] == r2.diagrams[i]["top_births"]
        assert r1.diagrams[i]["count"] == r2.diagrams[i]["count"]


# ---------------------------------------------------------------------------
# Variant 2 data + filtering
# ---------------------------------------------------------------------------

def test_astro_images_deterministic_and_filterable():
    a = astro.generate_image(3, 128)
    b = astro.generate_image(3, 128)
    np.testing.assert_array_equal(a, b)
    c = astro.generate_image(4, 128)
    assert not np.array_equal(a, c)

    dropped = {}
    for level in ("vanilla", "filter_light", "filter_std", "filter_heavy"):
        _, frac = astro.filter_threshold(a, level)
        dropped[level] = frac
    assert dropped["vanilla"] == 0.0
    assert dropped["filter_light"] <= dropped["filter_std"] <= \
        dropped["filter_heavy"]
    assert dropped["filter_heavy"] > 0.5   # background dominates star fields


def test_truncation_preserves_above_threshold_pairs():
    """Variant 2 must not change births OR deaths above the threshold
    (table 1: 'no relevant degradation in output quality'), and must
    shrink the sequential merge sweep (the speedup mechanism)."""
    import jax.numpy as jnp
    from repro.core import num_candidates, pixhomology

    img = astro.generate_image(7, 128)
    t, frac = astro.filter_threshold(img, "filter_std")
    assert frac > 0.5
    d0 = pixhomology(jnp.asarray(img), max_features=4096,
                     max_candidates=16384)
    d1 = pixhomology(jnp.asarray(img), t, max_features=4096,
                     max_candidates=16384)
    assert not bool(d1.overflow)

    def rows(d):
        c = int(d.count)
        return np.stack([np.asarray(d.birth)[:c], np.asarray(d.death)[:c],
                         np.asarray(d.p_birth)[:c]], 1)

    r0, r1 = rows(d0), rows(d1)
    # every truncated row's birth is above t
    assert np.all(r1[:, 0] >= t)
    # rows with death >= t are bit-identical between the two runs
    keep0 = r0[r0[:, 1] >= t]
    keep1 = r1[r1[:, 1] >= t]
    np.testing.assert_array_equal(keep0, keep1)
    # births above t all survive truncation (deaths clipped at t)
    np.testing.assert_array_equal(r0[r0[:, 0] >= t][:, [0, 2]],
                                  r1[:, [0, 2]])
    # and the sequential sweep got shorter
    k0 = int(num_candidates(jnp.asarray(img)))
    k1 = int(num_candidates(jnp.asarray(img), truncate_value=t))
    assert k1 < 0.25 * k0, (k0, k1)


def test_cost_estimate_correlates_with_true_cost():
    """Variant 3: the schedule-time estimate must rank images usefully."""
    est, true = [], []
    for i in range(12):
        img = astro.generate_image(i, 128)
        est.append(astro.estimate_cost_from_id(i, 128))
        true.append(astro.estimate_cost(img))
    r = np.corrcoef(est, true)[0, 1]
    assert r > 0.5, f"cost model too weak: r={r:.2f}"
