"""Distributed PH pipeline: scheduling, fault tolerance, work-log resume,
shape-bucketed heterogeneous rounds, prefetch overlap, tile streaming."""
import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import astro
from repro.distributed.context import single_device_ctx
from repro.ph import FilterLevel, PHConfig, PHEngine, TileSpec
from repro.pipeline.driver import FailureInjector, run_pipeline
from repro.pipeline.executor import ShardedPHExecutor
from repro.pipeline.scheduler import (BucketRound, ImageMeta, bucket_shape,
                                      make_bucketed_schedule, make_schedule,
                                      normalize_images, part_executors,
                                      part_images, part_lpt)


# ---------------------------------------------------------------------------
# Scheduler properties
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 60), st.integers(1, 12), st.integers(0, 2 ** 20))
def test_schedules_cover_all_images_exactly_once(n, m, seed):
    rng = np.random.default_rng(seed)
    ids = list(range(n))
    costs = {i: float(rng.uniform(1, 100)) for i in ids}
    for strat in ("part_executors", "part_images", "part_LPT"):
        sched = make_schedule(strat, ids, m, costs, seed=seed)
        flat = [i for q in sched.queues for i in q]
        assert sorted(flat) == ids, strat
        assert len(sched.queues) == m


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 80), st.integers(2, 10), st.integers(0, 2 ** 20))
def test_lpt_beats_or_matches_static_on_skewed_costs(n, m, seed):
    """Paper fig 6: LPT's queue makespan <= static chunking, and is within
    the Graham 4/3 bound of the lower bound."""
    rng = np.random.default_rng(seed)
    ids = list(range(n))
    # heavy-tailed costs => stragglers exist
    costs = {i: float(rng.pareto(1.5) + 0.1) for i in ids}
    lpt = part_lpt(ids, m, costs).queue_makespan(costs)
    static = part_executors(ids, m, seed=seed).queue_makespan(costs)
    dynamic = part_images(ids, m, costs).queue_makespan(costs)
    lower = max(max(costs.values()), sum(costs.values()) / m)
    # Graham's theorems: LPT within 4/3 - 1/(3m) of OPT (>= lower bound);
    # greedy list scheduling within 2 - 1/m.
    assert lpt <= (4 / 3 - 1 / (3 * m)) * lower + 1e-6
    assert dynamic <= (2 - 1 / m) * lower + 1e-6
    # static is a valid schedule, so it can never beat the lower bound
    assert static >= lower - 1e-9
    assert lpt <= static * (4 / 3) + 1e-6


def test_lpt_beats_static_on_strong_skew():
    """Deterministic instance with a straggler: LPT clearly wins (fig 6)."""
    costs = {i: 1.0 for i in range(32)}
    costs[0] = 30.0
    ids = list(costs)
    m = 8
    lpt = part_lpt(ids, m, costs).queue_makespan(costs)
    static = np.mean([part_executors(ids, m, seed=s).queue_makespan(costs)
                      for s in range(10)])
    assert lpt == 30.0               # straggler isolated on its own executor
    assert static > lpt + 1.0        # chunking stacks work behind it


def test_lpt_requires_costs():
    with pytest.raises(ValueError):
        make_schedule("part_LPT", [1, 2], 2, None)
    with pytest.raises(ValueError):
        make_bucketed_schedule("part_LPT",
                               [ImageMeta(0, (8, 8))], 2, None)


# ---------------------------------------------------------------------------
# Shape-bucketed scheduling (heterogeneous datasets)
# ---------------------------------------------------------------------------

def _random_workload(rng, n, sizes=(64, 96, 128, 256, 512)):
    metas = [ImageMeta(i, (int(rng.choice(sizes)),) * 2) for i in range(n)]
    costs = {meta.image_id: meta.pixels * float(rng.uniform(0.2, 3.0))
             for meta in metas}
    return metas, costs


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 24), st.integers(1, 8), st.integers(0, 2 ** 20))
def test_bucketed_schedule_covers_all_images_exactly_once(n, m, seed):
    rng = np.random.default_rng(seed)
    metas, costs = _random_workload(rng, n)
    for strat in ("part_executors", "part_images", "part_LPT"):
        for pad in (True, False):
            sched = make_bucketed_schedule(
                strat, metas, m, costs, rounding="pow2", pad=pad,
                max_tile_pixels=256 * 256, seed=seed)
            got = sorted(i for r in sched.rounds() for i in r.image_ids)
            assert got == list(range(n)), (strat, pad)
            for r in sched.rounds():
                assert len(r.entries) <= (m if r.kind == "whole" else 1)
                slots = [s for s, _ in r.entries]
                assert len(set(slots)) == len(slots)
                if r.kind == "whole":
                    for _, meta in r.entries:
                        assert meta.shape[0] <= r.shape[0]
                        assert meta.shape[1] <= r.shape[1]
                        if not pad:
                            assert tuple(meta.shape) == tuple(r.shape)
                else:
                    assert r.entries[0][1].pixels > 256 * 256


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 24), st.integers(1, 8), st.integers(0, 2 ** 20))
def test_bucketed_lpt_beats_padded_part_images(n, m, seed):
    """The satellite property: on random heterogeneous workloads, the
    bucketed-LPT lockstep makespan never exceeds what shape-agnostic
    ``part_images`` pays once every image is padded to the global bucket
    (the only way a one-plan SPMD pipeline can run a mixed set)."""
    rng = np.random.default_rng(seed)
    metas, costs = _random_workload(rng, n)
    sched = make_bucketed_schedule("part_LPT", metas, m, costs,
                                   rounding="pow2", pad=True)
    base = make_schedule("part_images",
                         [meta.image_id for meta in metas], m, costs)
    pad_shape = bucket_shape(
        (max(meta.shape[0] for meta in metas),
         max(meta.shape[1] for meta in metas)), "pow2")
    baseline = base.padded_makespan(
        costs, {meta.image_id: meta for meta in metas}, pad_shape)
    assert sched.makespan(costs) <= baseline * (1 + 1e-9)


def test_bucketed_rounds_are_homogeneous_per_plan():
    """Every whole round carries exactly one padded shape (one compiled
    plan per round), and vanilla (pad=False) never mixes shapes at all."""
    metas = [ImageMeta(0, (64, 64)), ImageMeta(1, (96, 96)),
             ImageMeta(2, (64, 64)), ImageMeta(3, (128, 128))]
    costs = {i: float(metas[i].pixels) for i in range(4)}
    sched = make_bucketed_schedule("part_LPT", metas, 2, costs, pad=False)
    shapes = [r.shape for r in sched.rounds()]
    assert shapes == sorted(shapes, key=lambda s: -s[0] * s[1])
    for r in sched.rounds():
        assert {meta.shape for _, meta in r.entries} == {r.shape}


def test_normalize_images_accepts_mixed_specs():
    metas = normalize_images(
        [0, (1, 96), (2, (64, 48)), ImageMeta(3, (32, 32))],
        default_size=128)
    assert [meta.shape for meta in metas] == [
        (128, 128), (96, 96), (64, 48), (32, 32)]
    with pytest.raises(ValueError):
        normalize_images([0, (0, 64)])


# ---------------------------------------------------------------------------
# Driver: fault tolerance + resume
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pool():
    engine = PHEngine(PHConfig(max_features=2048, max_candidates=8192,
                               filter_level=FilterLevel.STD))
    return ShardedPHExecutor(engine, single_device_ctx(), image_size=128)


def test_pipeline_completes_and_counts_objects(pool):
    res = run_pipeline(pool, list(range(4)), strategy="part_LPT")
    assert len(res.diagrams) == 4
    for d in res.diagrams.values():
        assert d["count"] > 0 and not d["overflow"]


def test_failure_recovery(pool):
    inj = FailureInjector([0])       # first round dies once
    res = run_pipeline(pool, list(range(3)), strategy="part_images",
                       failure_injector=inj)
    assert res.failures == 1
    assert len(res.diagrams) == 3    # everything still computed


def test_worklog_resume(tmp_path, pool):
    log = tmp_path / "work.jsonl"
    res1 = run_pipeline(pool, [0, 1], work_log=log)
    assert len(res1.diagrams) == 2
    lines_before = log.read_text().count("\n")
    # Second run with a superset: already-done images are NOT recomputed.
    res2 = run_pipeline(pool, [0, 1, 2], work_log=log)
    assert len(res2.diagrams) == 3
    new_lines = log.read_text().count("\n") - lines_before
    assert new_lines == 1            # only image 2 was processed


def test_pipeline_results_deterministic(pool):
    r1 = run_pipeline(pool, [5, 6], strategy="part_executors")
    r2 = run_pipeline(pool, [5, 6], strategy="part_LPT")
    for i in (5, 6):                 # schedule must not change the math
        assert r1.diagrams[i]["top_births"] == r2.diagrams[i]["top_births"]
        assert r1.diagrams[i]["count"] == r2.diagrams[i]["count"]


def test_executor_costs_are_threaded_not_recomputed(pool, monkeypatch):
    """Satellite: the driver uses pool.estimate_costs (measured Variant-3
    costs after a load), not a private estimate_cost_from_id pass."""
    meta = ImageMeta(31, (32, 32))
    est = pool.estimate_costs([meta])[31]
    assert est == astro.estimate_cost_from_id(31, 32)   # nothing loaded yet
    run_pipeline(pool, [(31, 32)])
    measured = pool.estimate_costs([meta])[31]
    img = astro.generate_image(31, 32)
    assert measured == astro.estimate_cost(img, pool.engine.config.filter_level)
    assert measured != est
    # and the driver consults the pool, so a re-run sees measured costs
    calls = []
    orig = pool.estimate_costs
    monkeypatch.setattr(pool, "estimate_costs",
                        lambda metas: calls.append(1) or orig(metas))
    run_pipeline(pool, [(31, 32)])
    assert calls
    # shapes the astro loader cannot render fail at schedule time, not
    # mid-round on the prefetch thread
    monkeypatch.undo()
    with pytest.raises(ValueError):
        pool.estimate_costs([ImageMeta(40, (64, 48))])


# ---------------------------------------------------------------------------
# Heterogeneous end-to-end: padded buckets bit-identical per image
# ---------------------------------------------------------------------------

def _assert_rows_equal(got, want, f=None):
    """All valid diagram rows (and scalars) bit-equal; row arrays may have
    different capacities, so compare the common prefix past count."""
    assert int(got.count) == int(want.count)
    assert int(got.n_unmerged) == int(want.n_unmerged)
    k = min(got.birth.shape[0], want.birth.shape[0]) if f is None else f
    for field in ("birth", "death", "p_birth", "p_death"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field))[:k],
            np.asarray(getattr(want, field))[:k], err_msg=field)


def test_padded_round_bit_identical_to_unpadded(pool):
    """A 24x24 image computed inside a 32x32 bucket (pad + index remap +
    essential fixup) must equal the plain whole-image run on every field,
    including p_birth/p_death in unpadded coordinates."""
    import jax.numpy as jnp
    from repro.core import pixhomology
    meta = ImageMeta(7, (24, 24))
    staged = pool.load_round(BucketRound("whole", (32, 32), ((0, meta),)))
    got = pool.run_staged(staged)[7]
    img = astro.generate_image(7, 24)
    t, _ = astro.filter_threshold(img, "filter_std")
    want = pixhomology(jnp.asarray(img), t, max_features=2048,
                       max_candidates=8192)
    assert not bool(np.asarray(want.overflow))
    _assert_rows_equal(got, want)


def test_hetero_pipeline_matches_per_image_runs():
    """Mixed 24/32/48 set end-to-end: per-image summaries equal dedicated
    per-image engine runs, padded rounds and all."""
    import jax.numpy as jnp
    from repro.core import pixhomology
    engine = PHEngine(PHConfig(max_features=2048, max_candidates=8192,
                               filter_level=FilterLevel.STD))
    pool = ShardedPHExecutor(engine, single_device_ctx())
    res = run_pipeline(pool, [(0, 24), (1, 32), (2, 48), (3, 24)])
    assert len(res.diagrams) == 4
    for img_id, size in ((0, 24), (1, 32), (2, 48), (3, 24)):
        img = astro.generate_image(img_id, size)
        t, _ = astro.filter_threshold(img, "filter_std")
        want = pixhomology(jnp.asarray(img), t, max_features=2048,
                           max_candidates=8192)
        c = int(want.count)
        assert res.diagrams[img_id]["count"] == c
        np.testing.assert_array_equal(
            res.diagrams[img_id]["top_births"],
            np.asarray(want.birth[:5], np.float64))
        np.testing.assert_array_equal(
            res.diagrams[img_id]["top_deaths"],
            np.asarray(want.death[:5], np.float64))


def test_vanilla_hetero_uses_exact_buckets():
    """Without a finite threshold padding is not exact, so VANILLA runs
    must keep every shape in its own (unpadded) round — and still match
    dedicated vanilla per-image runs."""
    import jax.numpy as jnp
    from repro.core import pixhomology
    engine = PHEngine(PHConfig(max_features=2048, max_candidates=8192))
    pool = ShardedPHExecutor(engine, single_device_ctx())
    assert not pool.pad_ok
    res = run_pipeline(pool, [(0, 24), (1, 32)])
    assert res.rounds == 2           # one exact-shape round each
    for img_id, size in ((0, 24), (1, 32)):
        img = astro.generate_image(img_id, size)
        want = pixhomology(jnp.asarray(img), max_features=2048,
                           max_candidates=8192)
        assert res.diagrams[img_id]["count"] == int(want.count)
        np.testing.assert_array_equal(
            res.diagrams[img_id]["top_deaths"],
            np.asarray(want.death[:5], np.float64))


# ---------------------------------------------------------------------------
# Tiled rounds: streaming residency, fault injection, resume, prefetch
# ---------------------------------------------------------------------------

def _tiled_engine(**kw):
    kw.setdefault("max_features", 4096)
    kw.setdefault("filter_level", "filter_std")
    return PHEngine(PHConfig(tile=TileSpec(
        grid=(2, 2), max_features_per_tile=1024,
        max_candidates_per_tile=2048, max_tile_pixels=32 * 32), **kw))


def test_oversized_images_stream_without_whole_image_loads(monkeypatch):
    """Residency: an image above max_tile_pixels goes through the
    tile-provider path — generate_image is never called for it, and no
    window larger than one halo tile is ever materialized."""
    engine = _tiled_engine()
    whole_calls = []
    windows = []
    orig_img = astro.generate_image
    orig_win = astro.generate_window

    def spy_img(image_id, size=1024, **kw):
        whole_calls.append(image_id)
        return orig_img(image_id, size, **kw)

    def spy_win(image_id, r0, c0, h, w, **kw):
        windows.append((image_id, h * w))
        return orig_win(image_id, r0, c0, h, w, **kw)

    monkeypatch.setattr(astro, "generate_image", spy_img)
    monkeypatch.setattr(astro, "generate_window", spy_win)
    res = engine.run_distributed([(0, 24), (1, 32), (2, 64)])
    assert len(res.diagrams) == 3
    assert 2 not in whole_calls          # never whole-materialized
    tile_px = (64 // 2 + 2) * (64 // 2 + 2)
    assert windows                       # the tiled image loaded via windows
    assert max(px for i, px in windows if i == 2) <= tile_px


def test_tiled_result_matches_whole_image_at_same_threshold():
    engine = _tiled_engine()
    res = engine.run_distributed([(2, 64)])
    prov = astro.AstroImage(2, 64)
    # the executor samples the Variant-2 statistic at the tile budget
    t = prov.filter_threshold("filter_std", sample=32)
    whole = PHEngine(PHConfig(max_features=4096,
                              filter_level="filter_std"))
    want = whole.run(astro.generate_image(2, 64), t)
    assert res.diagrams[2]["count"] == int(want.diagram.count)
    np.testing.assert_array_equal(
        res.diagrams[2]["top_births"],
        np.asarray(want.diagram.birth[:5], np.float64))
    np.testing.assert_array_equal(
        res.diagrams[2]["top_deaths"],
        np.asarray(want.diagram.death[:5], np.float64))


def test_tiled_round_failure_recovery_and_worklog_resume(tmp_path):
    """Satellite: FailureInjector + work-log resume through *tiled* rounds
    (the schedule here is one whole round + one tiled round)."""
    engine = _tiled_engine()
    log = tmp_path / "tiled.jsonl"
    inj = FailureInjector([0, 1])    # both rounds die once each
    res = engine.run_distributed([(0, 32), (2, 64)], work_log=log,
                                 failure_injector=inj)
    assert res.failures == 2
    assert len(res.diagrams) == 2
    lines = [json.loads(l) for l in log.read_text().splitlines()]
    assert sorted(r["image_id"] for r in lines) == [0, 2]
    # resume: a superset run recomputes nothing already logged
    engine2 = _tiled_engine()
    res2 = engine2.run_distributed([(0, 32), (2, 64), (3, 32)],
                                   work_log=log)
    assert len(res2.diagrams) == 3
    lines2 = log.read_text().splitlines()
    assert len(lines2) - len(lines) == 1
    assert json.loads(lines2[-1])["image_id"] == 3
    # and the resumed summaries are the logged ones, bit for bit
    assert res2.diagrams[2] == res.diagrams[2]


def test_run_round_tiled_dedupes_any_identical_row():
    """Satellite: duplicate padded rows are computed once wherever they
    appear in the round, not only when consecutive."""
    engine = _tiled_engine()
    pool = ShardedPHExecutor(engine, single_device_ctx(), image_size=64)
    a = astro.generate_image(0, 64)
    b = astro.generate_image(1, 64)
    imgs = np.stack([a, b, a, b, a])          # non-consecutive duplicates
    t0, _ = astro.filter_threshold(a, "filter_std")
    t1, _ = astro.filter_threshold(b, "filter_std")
    tvals = np.asarray([t0, t1, t0, t1, t0], np.float32)
    calls = []
    orig = engine.run_tiled

    def spy(image, tv=None, **kw):
        calls.append(1)
        return orig(image, tv, **kw)

    engine.run_tiled = spy
    try:
        diags = pool._run_round_tiled(imgs, tvals)
    finally:
        engine.run_tiled = orig
    assert len(calls) == 2                    # one run per distinct image
    for i, j in ((0, 2), (0, 4), (1, 3)):
        for field in ("birth", "death", "p_birth", "p_death", "count"):
            np.testing.assert_array_equal(
                np.asarray(getattr(diags, field))[i],
                np.asarray(getattr(diags, field))[j], err_msg=field)
    # distinct rows stay distinct
    assert not np.array_equal(diags.p_birth[0], diags.p_birth[1])


def test_prefetch_and_serial_loading_agree():
    """Double-buffered rounds must be a pure latency optimization: same
    diagrams with prefetch_rounds=0 and 2, heterogeneous + tiled mix."""
    images = [(0, 24), (1, 32), (2, 64), (3, 32), (4, 24)]
    results = []
    for prefetch in (0, 2):
        engine = _tiled_engine(prefetch_rounds=prefetch)
        results.append(engine.run_distributed(images).diagrams)
    assert results[0] == results[1]


# ---------------------------------------------------------------------------
# Variant 2 data + filtering
# ---------------------------------------------------------------------------

def test_astro_images_deterministic_and_filterable():
    a = astro.generate_image(3, 128)
    b = astro.generate_image(3, 128)
    np.testing.assert_array_equal(a, b)
    c = astro.generate_image(4, 128)
    assert not np.array_equal(a, c)

    dropped = {}
    for level in ("vanilla", "filter_light", "filter_std", "filter_heavy"):
        _, frac = astro.filter_threshold(a, level)
        dropped[level] = frac
    assert dropped["vanilla"] == 0.0
    assert dropped["filter_light"] <= dropped["filter_std"] <= \
        dropped["filter_heavy"]
    assert dropped["filter_heavy"] > 0.5   # background dominates star fields


def test_generate_window_bit_identical_to_image_slice():
    """The tentpole's windowed loading contract: any window equals the
    same slice of the full frame, bit for bit."""
    img = astro.generate_image(11, 96)
    for r0, c0, h, w in ((0, 0, 96, 96), (17, 5, 41, 77), (95, 0, 1, 96),
                         (30, 30, 3, 3), (0, 64, 64, 32)):
        win = astro.generate_window(11, r0, c0, h, w, size=96)
        np.testing.assert_array_equal(win, img[r0:r0 + h, c0:c0 + w],
                                      err_msg=str((r0, c0, h, w)))
    with pytest.raises(ValueError):
        astro.generate_window(11, 90, 0, 10, 10, size=96)


def test_astro_image_provider_tiles_match_split():
    """AstroImage.halo_tile == split_tiles of the full frame (incl. the
    out-of-frame -inf halo), for every tile of a 3x2 grid."""
    import jax.numpy as jnp
    from repro.core.tiling import split_tiles
    prov = astro.AstroImage(5, 48)
    img = astro.generate_image(5, 48)
    ref = np.asarray(split_tiles(jnp.asarray(img), (3, 2), -jnp.inf))
    for t in range(6):
        np.testing.assert_array_equal(prov.halo_tile(t, (3, 2)), ref[t],
                                      err_msg=f"tile {t}")


def test_truncation_preserves_above_threshold_pairs():
    """Variant 2 must not change births OR deaths above the threshold
    (table 1: 'no relevant degradation in output quality'), and must
    shrink the sequential merge sweep (the speedup mechanism)."""
    import jax.numpy as jnp
    from repro.core import num_candidates, pixhomology

    img = astro.generate_image(7, 128)
    t, frac = astro.filter_threshold(img, "filter_std")
    assert frac > 0.5
    d0 = pixhomology(jnp.asarray(img), max_features=4096,
                     max_candidates=16384)
    d1 = pixhomology(jnp.asarray(img), t, max_features=4096,
                     max_candidates=16384)
    assert not bool(d1.overflow)

    def rows(d):
        c = int(d.count)
        return np.stack([np.asarray(d.birth)[:c], np.asarray(d.death)[:c],
                         np.asarray(d.p_birth)[:c]], 1)

    r0, r1 = rows(d0), rows(d1)
    # every truncated row's birth is above t
    assert np.all(r1[:, 0] >= t)
    # rows with death >= t are bit-identical between the two runs
    keep0 = r0[r0[:, 1] >= t]
    keep1 = r1[r1[:, 1] >= t]
    np.testing.assert_array_equal(keep0, keep1)
    # births above t all survive truncation (deaths clipped at t)
    np.testing.assert_array_equal(r0[r0[:, 0] >= t][:, [0, 2]],
                                  r1[:, [0, 2]])
    # and the sequential sweep got shorter
    k0 = int(num_candidates(jnp.asarray(img)))
    k1 = int(num_candidates(jnp.asarray(img), truncate_value=t))
    assert k1 < 0.25 * k0, (k0, k1)


def test_cost_estimate_correlates_with_true_cost():
    """Variant 3: the schedule-time estimate must rank images usefully."""
    est, true = [], []
    for i in range(12):
        img = astro.generate_image(i, 128)
        est.append(astro.estimate_cost_from_id(i, 128))
        true.append(astro.estimate_cost(img))
    r = np.corrcoef(est, true)[0, 1]
    assert r > 0.5, f"cost model too weak: r={r:.2f}"
