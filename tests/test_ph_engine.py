"""The repro.ph facade: config validation, plan-cache reuse, auto-regrow."""
import json

import numpy as np
import pytest

from repro.core import num_candidates, persistence_oracle
from repro.data import astro
from repro.ph import FilterLevel, PHConfig, PHEngine


def _bumpy(seed=0, shape=(16, 16)):
    """Noise image with many local maxima -> many features + candidates."""
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# PHConfig
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError):
        PHConfig(candidate_mode="nope")
    with pytest.raises(ValueError):
        PHConfig(merge_impl="bogus")
    with pytest.raises(ValueError):
        PHConfig(max_features=0)
    with pytest.raises(ValueError):
        PHConfig(dtype="float16")
    with pytest.raises(ValueError):
        PHConfig(max_features=100, regrow_features_ceiling=10)
    with pytest.raises(ValueError):
        PHConfig(filter_level="filter_extreme")


def test_config_accepts_filter_level_strings_and_enum():
    assert PHConfig(filter_level="filter_std").filter_level is FilterLevel.STD
    assert PHConfig(filter_level=FilterLevel.HEAVY).filter_level is \
        FilterLevel.HEAVY


def test_config_json_roundtrip():
    cfg = PHConfig(max_features=128, max_candidates=512,
                   candidate_mode="paper", merge_impl="boruvka",
                   filter_level=FilterLevel.LIGHT, auto_regrow=False)
    back = PHConfig.from_json(cfg.to_json())
    assert back == cfg
    assert json.loads(cfg.to_json())["filter_level"] == "filter_light"


def test_config_from_flags():
    import argparse
    ns = argparse.Namespace(max_features=64, max_candidates=256,
                            filter="filter_heavy", merge_impl="boruvka",
                            no_regrow=True)
    cfg = PHConfig.from_flags(ns)
    assert cfg.max_features == 64 and cfg.max_candidates == 256
    assert cfg.filter_level is FilterLevel.HEAVY
    assert cfg.merge_impl == "boruvka"
    assert not cfg.auto_regrow


def test_config_is_hashable_plan_key_ignores_regrow_policy():
    a = PHConfig(max_regrows=1)
    b = PHConfig(max_regrows=5)
    assert {a: 1}[a] == 1
    assert a.plan_key() == b.plan_key()


def test_config_stage_signature_keys_compiled_programs():
    with pytest.raises(ValueError):
        PHConfig(phase_a_impl="bogus")
    with pytest.raises(ValueError):
        PHConfig(strip_rows=0)
    # the stage signature selects compiled stage programs -> in the plan key
    assert PHConfig().plan_key() != \
        PHConfig(phase_a_impl="pooled").plan_key()
    assert PHConfig().plan_key() != PHConfig(strip_rows=16).plan_key()
    sig = PHConfig(phase_a_impl="fused", strip_rows=4).stage_signature()
    assert ("a", "fused", 4, None, False, "superlevel") in sig
    # filtration selects different compiled programs (key negation sites)
    assert PHConfig().plan_key() != \
        PHConfig(filtration="sublevel").plan_key()
    assert any(s[0] == "b" and "frontier" in s for s in sig)
    # pooled phase A resolves densely; fused on the compacted frontier
    assert any("dense" in s for s in
               PHConfig(phase_a_impl="pooled").stage_signature())
    cfg = PHConfig(phase_a_impl="pooled", strip_rows=32)
    assert PHConfig.from_json(cfg.to_json()) == cfg

    import argparse
    ns = argparse.Namespace(phase_a_impl="pooled", strip_rows=16)
    got = PHConfig.from_flags(ns)
    assert got.phase_a_impl == "pooled" and got.strip_rows == 16


def test_engine_stage_impls_agree_and_cache_separately():
    img = _bumpy(6, (12, 12))
    fused = PHEngine(PHConfig(max_features=256, max_candidates=256,
                              strip_rows=4))
    pooled = PHEngine(PHConfig(max_features=256, max_candidates=256,
                               phase_a_impl="pooled"))
    np.testing.assert_array_equal(fused.run(img).to_array(),
                                  pooled.run(img).to_array())
    np.testing.assert_array_equal(fused.run(img).to_array(),
                                  persistence_oracle(img))
    assert fused.num_candidates(img) == pooled.num_candidates(img)


def test_config_bucket_and_prefetch_knobs():
    with pytest.raises(ValueError):
        PHConfig(bucket_rounding="pow3")
    with pytest.raises(ValueError):
        PHConfig(prefetch_rounds=-1)
    cfg = PHConfig(bucket_rounding="exact", prefetch_rounds=3)
    back = PHConfig.from_json(cfg.to_json())
    assert back == cfg
    # bucket rounding picks compiled batch shapes -> in the plan key;
    # prefetch depth is pure host-side scheduling -> excluded.
    assert PHConfig(bucket_rounding="exact").plan_key() != \
        PHConfig(bucket_rounding="pow2").plan_key()
    assert PHConfig(prefetch_rounds=0).plan_key() == \
        PHConfig(prefetch_rounds=4).plan_key()

    import argparse
    ns = argparse.Namespace(bucket_rounding="exact", no_prefetch=True)
    cfg = PHConfig.from_flags(ns)
    assert cfg.bucket_rounding == "exact" and cfg.prefetch_rounds == 0
    ns = argparse.Namespace(prefetch_rounds=2)
    assert PHConfig.from_flags(ns).prefetch_rounds == 2


def test_astro_accepts_filter_level_enum():
    img = astro.generate_image(3, 64)
    t_str, frac_str = astro.filter_threshold(img, "filter_std")
    t_enum, frac_enum = astro.filter_threshold(img, FilterLevel.STD)
    assert t_str == t_enum and frac_str == frac_enum
    with pytest.raises(ValueError):
        astro.filter_threshold(img, "filter_bogus")


# ---------------------------------------------------------------------------
# Plan cache: the jitted callable is traced once across repeated calls
# ---------------------------------------------------------------------------

def test_plan_cache_traces_once_across_same_shape_calls():
    engine = PHEngine(PHConfig(max_features=256, max_candidates=256))
    for seed in range(4):
        engine.run(_bumpy(seed))
    stats = engine.plan_stats()
    assert stats["plans"] == 1
    assert stats["traces"] == 1          # compiled once, reused 3x
    assert stats["calls"] == 4
    assert stats["hits"] == 3 and stats["misses"] == 1


def test_plan_cache_distinct_shapes_get_distinct_plans():
    engine = PHEngine(PHConfig(max_features=256, max_candidates=256))
    engine.run(_bumpy(0, (8, 8)))
    engine.run(_bumpy(0, (8, 8)))
    engine.run(_bumpy(0, (12, 8)))
    stats = engine.plan_stats()
    assert stats["plans"] == 2 and stats["traces"] == 2


def test_batched_plan_reused():
    engine = PHEngine(PHConfig(max_features=128, max_candidates=128))
    imgs = np.stack([_bumpy(s, (10, 11)) for s in range(4)])
    r1 = engine.run_batch(imgs)
    r2 = engine.run_batch(imgs[::-1].copy())
    assert engine.plan_stats()["traces"] == 1
    np.testing.assert_array_equal(np.asarray(r1.diagram.birth)[0],
                                  np.asarray(r2.diagram.birth)[-1])


# ---------------------------------------------------------------------------
# Overflow: flag without regrow, oracle-equal diagram with regrow
# ---------------------------------------------------------------------------

def test_overflow_flag_without_regrow():
    img = _bumpy(1)
    k = int(num_candidates(img))
    assert k > 2                          # the tiny capacity truly undersizes
    engine = PHEngine(PHConfig(max_features=256, max_candidates=2,
                               auto_regrow=False))
    res = engine.run(img)
    assert bool(res.diagram.overflow)
    assert res.regrow.attempts == 0 and res.regrow.overflow
    assert engine.plan_stats()["regrows"] == 0


def test_auto_regrow_recovers_oracle_equal_diagram():
    img = _bumpy(2)
    engine = PHEngine(PHConfig(max_features=4, max_candidates=2))
    res = engine.run(img)
    assert res.regrow.attempts >= 1 and not res.regrow.overflow
    assert not bool(res.diagram.overflow)
    got = res.to_array()
    want = persistence_oracle(img)
    assert got.shape == want.shape
    np.testing.assert_array_equal(got, want)
    # the effective config records the grown capacities
    assert res.config.max_features > 4
    assert engine.plan_stats()["regrows"] == res.regrow.attempts


def test_regrow_is_sticky_across_same_shape_calls():
    engine = PHEngine(PHConfig(max_features=4, max_candidates=4))
    r1 = engine.run(_bumpy(2))
    assert r1.regrow.attempts >= 1
    r2 = engine.run(_bumpy(2))       # starts at the remembered capacity
    assert r2.regrow.attempts == 0
    assert r2.config.max_features == r1.config.max_features


def test_regrow_respects_max_regrows_and_ceiling():
    img = _bumpy(3)
    engine = PHEngine(PHConfig(max_features=2, max_candidates=2,
                               max_regrows=1))
    res = engine.run(img)
    assert res.regrow.attempts == 1
    assert res.config.max_features == 4   # one doubling only
    assert res.regrow.overflow            # still undersized, reported

    capped = PHEngine(PHConfig(max_features=4, max_candidates=4,
                               regrow_features_ceiling=8,
                               regrow_candidates_ceiling=8))
    r2 = capped.run(img)
    assert r2.config.max_features <= 8 and r2.config.max_candidates <= 8


def test_regrown_capacities_clamped_to_pixel_count():
    img = _bumpy(4, (6, 6))
    engine = PHEngine(PHConfig(max_features=1, max_candidates=1))
    res = engine.run(img)
    assert not res.regrow.overflow        # at n pixels overflow is impossible
    assert res.config.max_features <= img.size
    np.testing.assert_array_equal(res.to_array(), persistence_oracle(img))


def test_run_batch_regrows_on_any_overflow():
    imgs = np.stack([_bumpy(s) for s in range(3)])
    engine = PHEngine(PHConfig(max_features=4, max_candidates=8))
    res = engine.run_batch(imgs)
    assert res.regrow.attempts >= 1
    assert not np.any(np.asarray(res.diagram.overflow))
    for i in range(3):
        c = int(res.diagram.count[i])
        want = persistence_oracle(imgs[i])
        assert c == want.shape[0]


# ---------------------------------------------------------------------------
# run() semantics: filter level, dtype policy, explicit threshold
# ---------------------------------------------------------------------------

def test_run_applies_config_filter_level():
    img = astro.generate_image(7, 64)
    t, _ = astro.filter_threshold(img, "filter_std")
    eng_f = PHEngine(PHConfig(max_features=1024, max_candidates=4096,
                              filter_level=FilterLevel.STD))
    eng_v = PHEngine(PHConfig(max_features=1024, max_candidates=4096))
    res_f = eng_f.run(img)
    res_explicit = eng_v.run(img, truncate_value=t)
    assert res_f.threshold == pytest.approx(t)
    np.testing.assert_array_equal(res_f.to_array(), res_explicit.to_array())
    # every surviving birth is above the threshold
    assert np.all(res_f.to_array()[:, 0] >= t)


def test_int_image_fractional_threshold_not_truncated():
    # A fractional Variant-2 threshold on an integer image must not be
    # floor-cast to the image dtype (12.5 -> 12 would keep the 12-peak).
    img = np.zeros((5, 5), np.int32)
    img[1, 1] = 12
    img[3, 3] = 20
    engine = PHEngine(PHConfig(max_features=25, max_candidates=25))
    res = engine.run(img, truncate_value=12.5)
    assert int(res.diagram.count) == 1          # only the 20-peak survives
    res2 = engine.run(img, truncate_value=11.5)
    assert int(res2.diagram.count) == 2         # the 12-peak is back


def test_dtype_policy_casts_input():
    img = np.random.default_rng(0).integers(0, 50, (9, 9)).astype(np.int32)
    engine = PHEngine(PHConfig(max_features=128, max_candidates=128,
                               dtype="float32"))
    res = engine.run(img)
    assert np.asarray(res.diagram.birth).dtype == np.float32


def test_run_rejects_bad_rank():
    engine = PHEngine()
    with pytest.raises(ValueError):
        engine.run(np.zeros((2, 3, 4), np.float32))
    with pytest.raises(ValueError):
        engine.run_batch(np.zeros((3, 4), np.float32))


# ---------------------------------------------------------------------------
# Distributed entry point
# ---------------------------------------------------------------------------

def test_run_distributed_smoke_and_regrow():
    engine = PHEngine(PHConfig(max_features=16, max_candidates=16,
                               filter_level=FilterLevel.STD))
    res = engine.run_distributed([0, 1], image_size=64)
    assert len(res.diagrams) == 2
    assert all(not d["overflow"] for d in res.diagrams.values())
    assert engine.plan_stats()["regrows"] >= 1
