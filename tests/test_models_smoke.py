"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finiteness (no NaNs), plus a decode-consistency
check (prefill+decode logits == full-sequence logits)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.distributed.context import single_device_ctx
from repro.models.model import build_model

B, S = 2, 32


def make_batch(cfg, rng):
    batch = {
        "inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def ctx():
    return single_device_ctx()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, ctx):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, np.random.default_rng(0))

    with ctx.mesh:
        loss, metrics = jax.jit(
            lambda p, b: model.loss_fn(p, b, ctx))(params, batch)
        assert loss.shape == ()
        assert np.isfinite(float(loss)), f"{arch}: loss not finite"
        assert np.isfinite(float(metrics["ce"]))

        # One SGD step must keep the loss finite and change the params.
        grads = jax.jit(jax.grad(
            lambda p, b: model.loss_fn(p, b, ctx)[0]))(params, batch)
        gnorm = jax.tree.reduce(
            lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
            grads, jnp.zeros(()))
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0
        new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                                  params, grads)
        loss2, _ = jax.jit(
            lambda p, b: model.loss_fn(p, b, ctx))(new_params, batch)
        assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full(arch, ctx):
    """Teacher-forced decode after prefill must match the full forward pass."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"inputs": toks, "targets": toks, "mask": jnp.ones((B, S))}
    pre = {"tokens": toks[:, : S // 2]}
    if cfg.is_encdec:
        frames = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
        batch["frames"] = frames
        pre["frames"] = frames

    with ctx.mesh:
        # Full-sequence logits via the training path (decoder-only archs).
        from repro.models import transformer
        logits_full = None
        if not cfg.is_encdec:
            x = transformer.embed_tokens(params, toks, cfg)
            h, _, _ = transformer.backbone(params, x, cfg, ctx)
            logits_full = transformer.logits_from_hidden(params, h, cfg)

        logits_pre, caches = jax.jit(
            lambda p, b: model.prefill(p, b, ctx, max_len=S))(params, pre)
        assert np.all(np.isfinite(np.asarray(logits_pre, np.float32)))

        # Teacher forcing through decode_step.
        step = jax.jit(lambda p, t, c: model.decode_step(p, t, c, ctx))
        logits_steps = []
        for t in range(S // 2, S):
            lg, caches = step(params, toks[:, t:t + 1], caches)
            logits_steps.append(np.asarray(lg[:, 0], np.float32))
            assert np.all(np.isfinite(logits_steps[-1])), f"{arch} step {t}"

        if logits_full is not None:
            # decode_step(t) consumed token t and predicts t+1; compare with
            # full logits at position t.
            full = np.asarray(logits_full, np.float32)
            for i, t in enumerate(range(S // 2, S)):
                np.testing.assert_allclose(
                    logits_steps[i], full[:, t], rtol=2e-2, atol=2e-2,
                    err_msg=f"{arch}: decode/full mismatch at pos {t}")


def test_configs_exact_dims():
    """The full configs carry the exact assigned dimensions."""
    from repro.configs.base import get_config
    expect = {
        "rwkv6_3b": (32, 2560, 8960, 65536),
        "llama4_scout_17b_a16e": (48, 5120, 8192, 202048),
        "dbrx_132b": (40, 6144, 10752, 100352),
        "chameleon_34b": (48, 8192, 22016, 65536),
        "gemma_7b": (28, 3072, 24576, 256000),
        "mistral_nemo_12b": (40, 5120, 14336, 131072),
        "qwen1_5_0_5b": (24, 1024, 2816, 151936),
        "phi3_mini_3_8b": (32, 3072, 8192, 32064),
        "recurrentgemma_2b": (26, 2560, 7680, 256000),
        "whisper_small": (12, 768, 3072, 51865),
    }
    for arch, (l, d, f, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == \
            (l, d, f, v), arch
