"""Roofline analyzer: loop expansion, collective parsing, param counting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import analysis


def test_scan_flops_expanded():
    """XLA cost_analysis counts while bodies once; our analyzer must
    multiply by the trip count."""
    def body(c, _):
        return c @ c, None

    def scanned(x):
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    comp = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):             # older jax: one dict per device
        ca = ca[0]
    summ = analysis.analyze_hlo(comp.as_text())
    per_matmul = 2 * 128 ** 3
    assert abs(ca["flops"] - per_matmul) / per_matmul < 0.01   # XLA: once
    assert abs(summ.flops - 8 * per_matmul) / (8 * per_matmul) < 0.01
    assert summ.n_whiles == 1 and summ.unresolved_trip_counts == 0
    fl, _ = analysis.blended_totals(summ, ca["flops"],
                                    ca.get("bytes accessed", 0.0))
    assert abs(fl - 8 * per_matmul) / (8 * per_matmul) < 0.01


def test_collective_parse_synthetic_hlo():
    text = """
ENTRY %main.1 (p0: f32[256,256]) -> f32[256,256] {
  %p0 = f32[256,256]{1,0} parameter(0)
  %ag = f32[256,256]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[256,256]{1,0} all-reduce(%ag), to_apply=%add
  %a2a = f32[256,256]{1,0} all-to-all(%ar), replica_groups={}
  ROOT %cp = f32[256,256]{1,0} collective-permute(%a2a), source_target_pairs={}
}
"""
    summ = analysis.analyze_hlo(text)
    n = 256 * 256 * 4
    by = summ.coll_by_type
    assert by["all-gather"] == n
    assert by["all-reduce"] == 2 * n          # 2x ring accounting
    assert by["all-to-all"] == n
    assert by["collective-permute"] == n
    assert summ.coll_bytes == 5 * n


def test_async_collectives_counted_once():
    text = """
ENTRY %main.2 (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %ags = f32[64]{0} all-gather-start(%p0), replica_groups={}
  ROOT %agd = f32[64]{0} all-gather-done(%ags)
}
"""
    summ = analysis.analyze_hlo(text)
    assert summ.coll_bytes == 64 * 4


def test_trip_count_from_compare_constant():
    text = """
%cond (s: (s32[], f32[4])) -> pred[] {
  %s = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %c999 = s32[] constant(999999)
  %lim = s32[] constant(7)
  ROOT %cmp = pred[] compare(%i, %lim), direction=LT
}

%bodyc (s: (s32[], f32[4])) -> (s32[], f32[4]) {
  %s = (s32[], f32[4]) parameter(0)
  %x = f32[4]{0} get-tuple-element(%s), index=1
  %ar = f32[4]{0} all-reduce(%x), to_apply=%add
  ROOT %t = (s32[], f32[4]) tuple(%s, %ar)
}

ENTRY %main.3 (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  ROOT %w = (s32[], f32[4]) while(%p0), condition=%cond, body=%bodyc
}
"""
    summ = analysis.analyze_hlo(text)
    # trip count must come from the compare operand (7), NOT max const 999999
    assert summ.coll_bytes == 7 * 2 * 16


def test_param_counts_match_known_sizes():
    from repro.configs.base import get_config
    qwen = analysis.total_params(get_config("qwen1_5_0_5b"))
    assert 0.35e9 < qwen < 0.7e9                 # "0.5B" class
    dbrx = analysis.total_params(get_config("dbrx_132b"))
    assert 1.15e11 < dbrx < 1.55e11              # "132B" class
    scout_total = analysis.total_params(get_config("llama4_scout_17b_a16e"))
    scout_active = analysis.active_params(
        get_config("llama4_scout_17b_a16e"))
    assert 0.9e11 < scout_total < 1.3e11         # "109B" total
    assert 1.4e10 < scout_active < 2.3e10        # "17B" active
    assert scout_active < scout_total / 3


def test_roofline_terms_and_bottleneck():
    t = analysis.roofline_terms(197e12, 819e9 * 2, 50e9)
    assert t["compute_s"] == 1.0 and t["memory_s"] == 2.0
    assert t["bottleneck"] == "memory_s"
    assert np.isclose(t["roofline_fraction"], 0.5)
