"""Autotuner cache/engine contracts and the trajectory perf gate.

Pins the three contracts the engine relies on:

* the disk cache round-trips and ``lookup`` is a *pure* read — it never
  compiles or measures (a cache miss is DEFAULTS, not a search);
* tuned params fold into the engine's effective config deterministically
  — same cache, same ``plan_key``; different tuned entry, different
  ``plan_key`` — and never change the computed diagram;
* ``benchmarks/perf_gate.py`` trajectory rules fail on an injected
  regression against a committed baseline and pass on the baseline
  itself (the gate has teeth before CI depends on it).
"""
import importlib.util
import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ph import PHConfig, PHEngine
from repro.roofline import autotune as at

_REPO = Path(__file__).resolve().parents[1]


def _load_perf_gate():
    # benchmarks/ is not a package (no __init__.py): load by file path.
    spec = importlib.util.spec_from_file_location(
        "perf_gate", _REPO / "benchmarks" / "perf_gate.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# cache round-trip + graceful fallback
# ---------------------------------------------------------------------------

def test_cache_round_trip(tmp_path):
    path = tmp_path / "cache.json"
    key = at.cache_key((64, 64), "float32", "cpu")
    at.save_cache({key: {"strip_rows": 16, "phase_c_block": 256,
                         "tournament_width": 4, "source": "measured"}},
                  path)
    got = at.lookup((64, 64), "float32", path=path, backend="cpu")
    assert got == at.TunedParams(16, 256, 4, "cache")
    # Unknown shape in the same file: DEFAULTS, source "default".
    assert at.lookup((128, 128), "float32", path=path,
                     backend="cpu") == at.DEFAULTS


def test_lookup_never_measures(tmp_path, monkeypatch):
    # The engine-facing call must stay a pure cache read even on a miss.
    def boom(*a, **k):
        raise AssertionError("lookup must not compile or measure")
    monkeypatch.setattr(at, "model_score", boom)
    monkeypatch.setattr(at, "measure", boom)
    monkeypatch.setattr(at, "_build", boom)
    assert at.lookup((32, 32), "float32",
                     path=tmp_path / "missing.json") == at.DEFAULTS


@pytest.mark.parametrize("content", [
    "not json {", json.dumps(["a", "list"]),
    json.dumps({"32x32|float32|cpu": "not-a-dict"}),
    json.dumps({"32x32|float32|cpu": {"strip_rows": "NaN?"}}),
])
def test_lookup_corrupt_cache_falls_back(tmp_path, content):
    path = tmp_path / "cache.json"
    path.write_text(content)
    assert at.lookup((32, 32), "float32", path=path,
                     backend="cpu") == at.DEFAULTS


def test_autotune_all_candidates_fail_returns_defaults(tmp_path,
                                                       monkeypatch):
    path = tmp_path / "cache.json"
    monkeypatch.setattr(at, "model_score",
                        lambda *a, **k: (_ for _ in ()).throw(RuntimeError))
    got = at.autotune((16, 16), "float32", path=path, backend="cpu")
    assert got == at.DEFAULTS
    assert not path.exists()    # nothing persisted on total failure


def test_autotune_persists_and_short_circuits(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    space = [at.TunedParams(4, 256, 2, "candidate"),
             at.TunedParams(8, 1024, 2, "candidate")]
    scores = {4: 1.0, 8: 2.0}
    monkeypatch.setattr(at, "model_score",
                        lambda s, d, p: scores[p.strip_rows])
    monkeypatch.setattr(at, "measure", lambda s, d, p, trials: 0.01)
    got = at.autotune((16, 16), "float32", path=path, backend="cpu",
                      measure_top=1, trials=1, space=space)
    assert (got.strip_rows, got.phase_c_block, got.source) == (4, 256,
                                                               "measured")
    entry = json.loads(path.read_text())["16x16|float32|cpu"]
    assert entry["strip_rows"] == 4 and entry["source"] == "measured"
    # Existing entry short-circuits: a re-tune may not compile anything.
    def boom(*a, **k):
        raise AssertionError("existing entry must short-circuit")
    monkeypatch.setattr(at, "model_score", boom)
    monkeypatch.setattr(at, "measure", boom)
    again = at.autotune((16, 16), "float32", path=path, backend="cpu")
    assert (again.strip_rows, again.source) == (4, "cache")


def test_autotune_model_only_budget(tmp_path, monkeypatch):
    # measure_top=0: zero measurement budget, the roofline rank decides.
    path = tmp_path / "cache.json"
    space = [at.TunedParams(4, 256, 2, "candidate"),
             at.TunedParams(8, 1024, 2, "candidate")]
    monkeypatch.setattr(at, "model_score",
                        lambda s, d, p: 1.0 if p.strip_rows == 8 else 2.0)
    monkeypatch.setattr(
        at, "measure",
        lambda *a, **k: (_ for _ in ()).throw(AssertionError("no trials")))
    got = at.autotune((16, 16), "float32", path=path, backend="cpu",
                      measure_top=0, space=space)
    assert (got.strip_rows, got.source) == (8, "model")


def test_autotune_real_search_smoke(tmp_path):
    # End-to-end on a tiny image: real compile, real trial, real cache.
    path = tmp_path / "cache.json"
    got = at.autotune((8, 8), "float32", path=path,
                      measure_top=1, trials=1,
                      space=[at.TunedParams(4, 256, 2, "candidate")])
    assert got.source == "measured"
    assert at.lookup((8, 8), "float32", path=path).source == "cache"


# ---------------------------------------------------------------------------
# engine folding: deterministic plan keys, unchanged diagrams
# ---------------------------------------------------------------------------

def _engine(tmp_cache, **kw):
    return PHEngine(PHConfig(max_features=256, max_candidates=256,
                             merge_impl="boruvka", autotune=True,
                             autotune_cache=str(tmp_cache), **kw))


def test_effective_config_folds_cache_deterministically(tmp_path):
    path = tmp_path / "cache.json"
    key = at.cache_key((12, 11), "float32", None)   # live backend
    at.save_cache({key: {"strip_rows": 4, "phase_c_block": 256,
                         "tournament_width": 4, "source": "measured"}},
                  path)
    eng = _engine(path)
    eff = eng._effective_config((12, 11), jnp.dtype(jnp.float32))
    assert (eff.strip_rows, eff.phase_c_block,
            eff.tournament_width) == (4, 256, 4)
    # Deterministic: a second resolve (memoized) and a fresh engine over
    # the same cache produce the same plan key.
    eff2 = eng._effective_config((12, 11), jnp.dtype(jnp.float32))
    assert eff2.plan_key() == eff.plan_key()
    assert _engine(path)._effective_config(
        (12, 11), jnp.dtype(jnp.float32)).plan_key() == eff.plan_key()
    # The tuned knobs are plan-key-bearing: defaults select a different
    # compiled program.
    base = PHConfig(max_features=256, max_candidates=256,
                    merge_impl="boruvka")
    assert eff.plan_key() != base.plan_key()
    # Unknown shape: the config's own fields stand, plan key unchanged
    # relative to autotune-off (autotune itself is not in the plan key).
    miss = eng._effective_config((7, 7), jnp.dtype(jnp.float32))
    assert miss.strip_rows == base.strip_rows
    assert miss.plan_key() == base.plan_key()


def test_autotuned_engine_diagram_unchanged(tmp_path):
    # Tuned knobs only re-block programs: the diagram is bit-identical
    # to the default engine's.
    rng = np.random.default_rng(0)
    img = (rng.standard_normal((12, 11)) * 50).astype(np.float32)
    path = tmp_path / "cache.json"
    at.save_cache({at.cache_key((12, 11), "float32", None): {
        "strip_rows": 4, "phase_c_block": 256, "tournament_width": 4,
        "source": "measured"}}, path)
    got = _engine(path).run(img).diagram
    want = PHEngine(PHConfig(max_features=256, max_candidates=256,
                             merge_impl="boruvka")).run(img).diagram
    for f in ("birth", "death", "p_birth", "p_death", "count"):
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f)), f)


def test_missing_cache_file_engine_falls_back(tmp_path):
    eng = _engine(tmp_path / "never_written.json")
    eff = eng._effective_config((12, 11), jnp.dtype(jnp.float32))
    assert eff.strip_rows == eng.config.strip_rows
    assert eff.phase_c_block == eng.config.phase_c_block


# ---------------------------------------------------------------------------
# trajectory perf gate: must fail on an injected regression
# ---------------------------------------------------------------------------

_BASE_ROW = {
    "name": "core_256", "phase_c_packed_s": 0.01, "phase_c_rank_s": 0.02,
    "phase_c_packed_speedup": 2.0, "hlo_sorts_packed": 3,
    "full_image_sorts_packed": 0, "full_image_sorts_rank": 1,
    "full_image_sorts_fused": 0,
    "phase_c_fused_s": 0.005, "phase_c_xla_s": 0.01,
    "phase_c_fused_speedup": 2.0, "boruvka_rounds_xla": 6,
    "boruvka_rounds_fused": 4,
}


def _gate_core(tmp_path, current, baseline):
    pg = _load_perf_gate()
    cur = tmp_path / "cur.json"
    base = tmp_path / "base.json"
    cur.write_text(json.dumps(current))
    base.write_text(json.dumps(baseline))
    return pg.run_gate("core", str(cur), str(base))


def test_gate_passes_on_baseline_itself(tmp_path):
    assert _gate_core(tmp_path, [_BASE_ROW], [_BASE_ROW]) == []


def test_gate_fails_on_speedup_regression(tmp_path):
    bad = dict(_BASE_ROW, phase_c_fused_speedup=0.8)   # < 0.5 x 2.0
    fails = _gate_core(tmp_path, [bad], [_BASE_ROW])
    assert any("phase_c_fused_speedup" in f for f in fails)


def test_gate_fails_on_round_count_regression(tmp_path):
    bad = dict(_BASE_ROW, boruvka_rounds_fused=9)      # > baseline 4
    fails = _gate_core(tmp_path, [bad], [_BASE_ROW])
    assert any("boruvka_rounds_fused" in f for f in fails)


def test_gate_fails_on_full_sort_reappearing(tmp_path):
    bad = dict(_BASE_ROW, full_image_sorts_fused=2)
    fails = _gate_core(tmp_path, [bad], [_BASE_ROW])
    # Both the structural rule and the trajectory rule should fire.
    assert sum("full_image_sorts_fused" in f for f in fails) >= 2


def test_gate_skips_unmatched_rows_and_fields(tmp_path):
    # Extra current row + a baseline row missing a field: both skipped;
    # zero name overlap is itself a failure (a renamed bench must not
    # silently disable the gate).
    extra = dict(_BASE_ROW, name="core_512")
    thin = {k: v for k, v in _BASE_ROW.items()
            if k != "phase_c_fused_speedup"}
    cur_ok = dict(_BASE_ROW, phase_c_fused_speedup=0.1)
    assert _gate_core(tmp_path, [cur_ok, extra], [thin]) == []
    fails = _gate_core(tmp_path, [dict(_BASE_ROW, name="renamed")],
                       [_BASE_ROW])
    assert any("no rows matched" in f for f in fails)


def test_gate_serve_trajectory(tmp_path):
    pg = _load_perf_gate()
    doc = {"steady": {"steady_state_traces": 0, "failed": 0, "rejected": 0,
                      "completed": 4, "submitted": 4,
                      "buckets": {"256": {"occupancy": 0.5,
                                          "queue_wait_s": {"p50": 1, "p95": 2,
                                                           "p99": 3},
                                          "e2e_s": {"p50": 1, "p95": 2,
                                                    "p99": 3}}}},
           "saturation": None}
    doc["saturation"] = {"rejected": 2, "retry_after_s_mean": 0.1,
                         "failed": 0}
    cur = tmp_path / "serve.json"
    base = tmp_path / "serve_base.json"
    cur.write_text(json.dumps(doc))
    base.write_text(json.dumps(doc))
    assert pg.run_gate("serve", str(cur), str(base)) == []
    bad = json.loads(json.dumps(doc))
    bad["steady"]["steady_state_traces"] = 3
    bad["steady"]["completed"] = 4      # keep other rules focused
    cur.write_text(json.dumps(bad))
    fails = pg.run_gate("serve", str(cur), str(base))
    assert any("steady_state_traces" in f and "baseline" in f
               for f in fails)


# ---------------------------------------------------------------------------
# tile-grid search: candidates, persistence, engine folding
# ---------------------------------------------------------------------------

def test_grid_candidates_divide_and_rank():
    got = at.grid_candidates((128, 128))
    assert got[:4] == [(2, 2), (4, 4), (8, 8), (16, 16)]
    for gr, gc in at.grid_candidates((96, 64), limit=12):
        assert 96 % gr == 0 and 64 % gc == 0
        assert 96 // gr >= 8 and 64 // gc >= 8
        assert 2 <= gr * gc <= 1024
    # max_tile_pixels caps the coarse end of the space
    for gr, gc in at.grid_candidates((128, 128), max_tile_pixels=32 * 32):
        assert (128 // gr) * (128 // gc) <= 32 * 32
    assert len(at.grid_candidates((128, 128), limit=2)) == 2


def test_grid_model_score_orders_by_traffic():
    # More tiles -> more halo+table bytes for one image: the model must
    # rank a finer grid as costlier on a fixed shape.
    a = at.grid_model_score((128, 128), "float32", (2, 2))
    b = at.grid_model_score((128, 128), "float32", (8, 8))
    assert 0 < a < b


def test_grid_only_cache_entry_keeps_default_scalars(tmp_path):
    path = tmp_path / "cache.json"
    key = at.cache_key((64, 64), "float32", "cpu")
    at.save_cache({key: {"tile_grid": [4, 4],
                         "tile_grid_source": "model"}}, path)
    got = at.lookup((64, 64), "float32", path=path, backend="cpu")
    assert got.tile_grid == (4, 4)
    # scalar knobs keep config defaults: source stays "default" so the
    # engine does not fold DEFAULTS over the user's scalar settings
    assert got.source == "default"
    assert got.strip_rows == at.DEFAULTS.strip_rows


def test_autotune_grid_persists_and_short_circuits(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    monkeypatch.setattr(at, "grid_model_score",
                        lambda s, d, g: float(g[0] * g[1]))
    monkeypatch.setattr(at, "measure_grid",
                        lambda s, d, g, trials: 0.01 * g[0])
    got = at.autotune_grid((64, 64), "float32", path=path, backend="cpu",
                           measure_top=2, trials=1,
                           space=[(2, 2), (4, 4)])
    assert got == (2, 2)
    entry = json.loads(path.read_text())["64x64|float32|cpu"]
    assert entry["tile_grid"] == [2, 2]
    assert entry["tile_grid_source"] == "measured"

    def boom(*a, **k):
        raise AssertionError("existing tile_grid must short-circuit")
    monkeypatch.setattr(at, "grid_model_score", boom)
    monkeypatch.setattr(at, "measure_grid", boom)
    assert at.autotune_grid((64, 64), "float32", path=path,
                            backend="cpu") == (2, 2)


def test_autotune_grid_model_only_and_all_fail(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    monkeypatch.setattr(at, "grid_model_score",
                        lambda s, d, g: float(g[0]))
    monkeypatch.setattr(
        at, "measure_grid",
        lambda *a, **k: (_ for _ in ()).throw(AssertionError("no trials")))
    got = at.autotune_grid((64, 64), "float32", path=path, backend="cpu",
                           measure_top=0, space=[(4, 4), (2, 2)])
    assert got == (2, 2)
    entry = json.loads(path.read_text())["64x64|float32|cpu"]
    assert entry["tile_grid_source"] == "model"
    # every candidate failing -> None, nothing persisted
    monkeypatch.setattr(
        at, "grid_model_score",
        lambda *a, **k: (_ for _ in ()).throw(RuntimeError("boom")))
    assert at.autotune_grid((32, 32), "float32", path=path,
                            backend="cpu", space=[(2, 2)]) is None
    assert "32x32|float32|cpu" not in json.loads(path.read_text())


def test_autotune_grid_and_scalars_share_one_entry(tmp_path, monkeypatch):
    # Both searches merge into ONE cache entry per shape family, and one
    # lookup recovers both (scalars flip source to "cache").
    path = tmp_path / "cache.json"
    monkeypatch.setattr(at, "grid_model_score", lambda s, d, g: 1.0)
    monkeypatch.setattr(at, "measure_grid", lambda s, d, g, trials: 0.01)
    at.autotune_grid((16, 16), "float32", path=path, backend="cpu",
                     trials=1, space=[(2, 2)])
    monkeypatch.setattr(at, "model_score", lambda s, d, p: 1.0)
    monkeypatch.setattr(at, "measure", lambda s, d, p, trials: 0.01)
    at.autotune((16, 16), "float32", path=path, backend="cpu",
                measure_top=1, trials=1,
                space=[at.TunedParams(4, 256, 2, "candidate")])
    raw = json.loads(path.read_text())
    assert list(raw) == ["16x16|float32|cpu"]
    entry = raw["16x16|float32|cpu"]
    assert entry["tile_grid"] == [2, 2] and entry["strip_rows"] == 4
    got = at.lookup((16, 16), "float32", path=path, backend="cpu")
    assert got.tile_grid == (2, 2) and got.strip_rows == 4
    assert got.source == "cache"


def test_engine_folds_tuned_grid_into_tiled_runs(tmp_path):
    rng = np.random.default_rng(3)
    img = rng.standard_normal((32, 32)).astype(np.float32)
    path = tmp_path / "cache.json"
    at.save_cache({at.cache_key((32, 32), "float32", None): {
        "tile_grid": [2, 2], "tile_grid_source": "model"}}, path)
    eng = _engine(path)
    res = eng.run_tiled(img)
    assert tuple(res.config.tile.grid) == (2, 2)
    # bit-identical to pinning the same grid by hand
    want = PHEngine(PHConfig(max_features=256, max_candidates=256,
                             merge_impl="boruvka")).run_tiled(img,
                                                              grid=(2, 2))
    for f in res.diagram._fields:
        np.testing.assert_array_equal(np.asarray(getattr(res.diagram, f)),
                                      np.asarray(getattr(want.diagram, f)),
                                      f)
    # an explicit spec grid always wins over the tuned one
    from repro.ph import TileSpec
    pinned = PHEngine(PHConfig(max_features=256, max_candidates=256,
                               merge_impl="boruvka", autotune=True,
                               autotune_cache=str(path),
                               tile=TileSpec(grid=(4, 4))))
    assert tuple(pinned.run_tiled(img).config.tile.grid) == (4, 4)


def test_engine_ignores_stale_tuned_grid(tmp_path):
    # A cached grid that no longer divides the shape must be skipped,
    # not crash the run.
    rng = np.random.default_rng(4)
    img = rng.standard_normal((32, 32)).astype(np.float32)
    path = tmp_path / "cache.json"
    at.save_cache({at.cache_key((32, 32), "float32", None): {
        "tile_grid": [5, 5], "tile_grid_source": "model"}}, path)
    res = _engine(path).run_tiled(img)
    assert 32 % res.config.tile.grid[0] == 0


def test_autotune_grid_real_search_smoke(tmp_path):
    path = tmp_path / "cache.json"
    got = at.autotune_grid((16, 16), "float32", path=path,
                           measure_top=1, trials=1, space=[(2, 2)])
    assert got == (2, 2)
    assert at.lookup((16, 16), "float32",
                     path=path).tile_grid == (2, 2)


# ---------------------------------------------------------------------------
# pipeline gate: delta rows + serve cache tier
# ---------------------------------------------------------------------------

_DELTA_ROW = {
    "name": "pipeline/delta_frame_seq_256", "size": 256,
    "mean_dirty_frac": 0.0625, "delta_speedup_10pct": 1.9,
    "delta_bit_identical": True, "delta_full_hit_ok": True,
    "cache": {"hits": 2, "partial_hits": 9, "misses": 3,
              "inserts": 12, "evictions": 4, "collisions": 0},
}

_OVERLAP_ROW = {
    "name": "pipeline/heterogeneous_128", "max_size": 128,
    "host_parallelism": 1, "overlap_speedup": 1.1,
    "steady_state_dispatch_syncs": 0, "h2d_transfers_per_round": 1.0,
    "d2h_streams_per_round": 1.0, "donation_replays": 0,
}


def _gate_pipeline(tmp_path, cur_rows, base_rows=None):
    pg = _load_perf_gate()
    cur = tmp_path / "pipe.json"
    cur.write_text(json.dumps({"rows": cur_rows}))
    base = None
    if base_rows is not None:
        basep = tmp_path / "pipe_base.json"
        basep.write_text(json.dumps({"rows": base_rows}))
        base = str(basep)
    return pg.run_gate("pipeline", str(cur), base)


def test_gate_pipeline_passes_and_requires_delta_rows(tmp_path):
    rows = [_DELTA_ROW, _OVERLAP_ROW]
    assert _gate_pipeline(tmp_path, rows, rows) == []
    fails = _gate_pipeline(tmp_path, [])
    assert any("no delta frame-sequence rows" in f for f in fails)
    assert any("no overlap-instrumented streaming rows" in f
               for f in fails)


def test_gate_pipeline_fails_on_identity_break(tmp_path):
    fails = _gate_pipeline(
        tmp_path, [dict(_DELTA_ROW, delta_bit_identical=False)])
    assert any("diverged from cold runs" in f for f in fails)
    fails = _gate_pipeline(
        tmp_path, [dict(_DELTA_ROW, delta_full_hit_ok=False)])
    assert any("did not full-hit" in f for f in fails)
    no_partial = dict(_DELTA_ROW, cache=dict(_DELTA_ROW["cache"],
                                             partial_hits=0))
    fails = _gate_pipeline(tmp_path, [no_partial])
    assert any("no partial hits" in f for f in fails)


def test_gate_pipeline_full_scale_floor(tmp_path):
    big = dict(_DELTA_ROW, name="pipeline/delta_frame_seq_1024",
               size=1024, delta_speedup_10pct=6.3)
    assert _gate_pipeline(tmp_path, [_DELTA_ROW, big, _OVERLAP_ROW]) == []
    slow = dict(big, delta_speedup_10pct=3.0)
    fails = _gate_pipeline(tmp_path, [slow])
    assert any("< 5x at full scale" in f for f in fails)
    too_dirty = dict(big, mean_dirty_frac=0.25)
    fails = _gate_pipeline(tmp_path, [too_dirty])
    assert any("> 10%" in f for f in fails)
    # smoke-scale rows only need to not be slower than cold
    slower = dict(_DELTA_ROW, delta_speedup_10pct=0.7)
    fails = _gate_pipeline(tmp_path, [slower])
    assert any("delta slower than" in f for f in fails)


def test_gate_pipeline_trajectory_on_speedup(tmp_path):
    regressed = dict(_DELTA_ROW, delta_speedup_10pct=0.9)  # < 0.5 x 1.9
    fails = _gate_pipeline(tmp_path, [regressed], [_DELTA_ROW])
    assert any("delta_speedup_10pct" in f for f in fails)
    flipped = dict(_DELTA_ROW, delta_bit_identical=False)
    fails = _gate_pipeline(tmp_path, [flipped], [_DELTA_ROW])
    assert any("delta_bit_identical" in f for f in fails)


def test_gate_pipeline_overlap_rule(tmp_path):
    # structural invariants gate on every instrumented row
    synced = dict(_OVERLAP_ROW, steady_state_dispatch_syncs=3)
    fails = _gate_pipeline(tmp_path, [_DELTA_ROW, synced])
    assert any("blocking dispatch-path" in f for f in fails)
    split = dict(_OVERLAP_ROW, h2d_transfers_per_round=2.0)
    fails = _gate_pipeline(tmp_path, [_DELTA_ROW, split])
    assert any("fused batch+thresholds staging broken" in f for f in fails)
    unfused = dict(_OVERLAP_ROW, h2d_transfers_per_round=0.5)
    fails = _gate_pipeline(tmp_path, [_DELTA_ROW, unfused])
    assert any("want exactly 1 (fused)" in f for f in fails)
    # tiled mixes stage oversize rounds through the provider: < 1 is fine
    tiled = dict(_OVERLAP_ROW, name="pipeline/tiled_mix_192",
                 max_size=192, h2d_transfers_per_round=0.833)
    assert _gate_pipeline(tmp_path, [_DELTA_ROW, tiled]) == []
    # the 1.2x floor binds only at gate scale on a parallel host
    slow = dict(_OVERLAP_ROW, name="pipeline/heterogeneous_384",
                max_size=384, host_parallelism=4, overlap_speedup=1.05)
    fails = _gate_pipeline(tmp_path, [_DELTA_ROW, slow])
    assert any("overlap_speedup" in f for f in fails)
    fast = dict(slow, overlap_speedup=1.3)
    assert _gate_pipeline(tmp_path, [_DELTA_ROW, fast]) == []
    # ... and is exempt on a serial host or at smoke scale
    serial_host = dict(slow, host_parallelism=1)
    assert _gate_pipeline(tmp_path, [_DELTA_ROW, serial_host]) == []
    smoke = dict(slow, name="pipeline/heterogeneous_128", max_size=128)
    assert _gate_pipeline(tmp_path, [_DELTA_ROW, smoke]) == []


def test_gate_pipeline_trajectory_on_overlap(tmp_path):
    regressed = dict(_OVERLAP_ROW, overlap_speedup=0.4)  # < 0.5 x 1.1
    fails = _gate_pipeline(tmp_path, [_DELTA_ROW, regressed],
                           [_DELTA_ROW, _OVERLAP_ROW])
    assert any("overlap_speedup" in f for f in fails)
    synced = dict(_OVERLAP_ROW, steady_state_dispatch_syncs=1)
    fails = _gate_pipeline(tmp_path, [_DELTA_ROW, synced],
                           [_DELTA_ROW, _OVERLAP_ROW])
    assert any("steady_state_dispatch_syncs" in f for f in fails)


def test_gate_serve_cache_tier_rule(tmp_path):
    pg = _load_perf_gate()
    # pre-delta artifact (no cache section): rule skips
    assert pg._serve_cache_tier({}) is None
    ok = {"cache": {"steady_state_hits": 12, "misses": 4}}
    assert pg._serve_cache_tier(ok) is None
    cold = {"cache": {"steady_state_hits": 0, "misses": 4}}
    assert "no exact-hash cache hits" in pg._serve_cache_tier(cold)
    no_miss = {"cache": {"steady_state_hits": 3, "misses": 0}}
    assert "no misses" in pg._serve_cache_tier(no_miss)
