"""Dual-filtration contract, diagram-distance kernels, NaN edge sweep.

Three test families for the filtration axis and the ``ph_distance``
kernel package:

* **Duality** — ``sublevel(x)`` must equal ``superlevel(-x)`` with every
  birth/death negated, *bit-identically*, across the full path matrix
  ({whole, batched, sharded, tiled} x {fused, xla} phase C) and as a
  seeded property sweep.  Padded dispatch keeps the identity even when
  the essential extremum sits in the padded margin (the
  filtration-aware ``pad_fixup`` bug regression).

* **Distances** — the Pallas kernel is bit-identical to the XLA
  reference (interpret mode: CI's parity path), both agree with a dense
  O(n^2) numpy re-implementation, the metric axioms hold (symmetry,
  zero diagonal, sampled triangle inequality), capacity pads are inert,
  and the engine's "distance" plan kind caches.

* **Edge cases** — NaN raises the same clear error on every public
  entry point (engine casts, core wrappers, tiled wrappers, the
  distance boundary, under *both* key encodings); ±inf is rejected at
  the engine boundary; subnormals compute correct diagrams.

Satellite: serving metrics reservoirs summarize all-zero when empty
(fresh-server snapshot) and the perf gate's percentile rule skips
degenerate (< 2 sample) windows.
"""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import persistence_oracle, pixhomology, tiled_pixhomology
from repro.kernels.ph_distance import ops as dist_ops
from repro.kernels.ph_distance import ref as dist_ref
from repro.ph import PHConfig, PHEngine, TileSpec

H = W = 16
N = H * W


def _image(seed, shape=(H, W)):
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:shape[0], 0:shape[1]].astype(np.float32)
    img = rng.normal(0.0, 0.1, shape).astype(np.float32)
    for _ in range(5):
        cy, cx = rng.uniform(0, shape[0]), rng.uniform(0, shape[1])
        img += rng.uniform(0.5, 2.0) * np.exp(
            -((yy - cy) ** 2 + (xx - cx) ** 2) / 6.0).astype(np.float32)
    return img


def _config(filtration, **kw):
    kw.setdefault("max_features", N)
    kw.setdefault("max_candidates", N)
    kw.setdefault("strip_rows", 4)
    kw.setdefault("tile", TileSpec(grid=(2, 2)))
    return PHConfig(filtration=filtration, **kw)


def _assert_dual(sub, sup, msg):
    """sublevel diagram == superlevel diagram of the negated image with
    births/deaths negated — bit-for-bit, positions included."""
    np.testing.assert_array_equal(np.asarray(sub.birth),
                                  -np.asarray(sup.birth), err_msg=msg)
    np.testing.assert_array_equal(np.asarray(sub.death),
                                  -np.asarray(sup.death), err_msg=msg)
    for f in ("p_birth", "p_death", "count"):
        np.testing.assert_array_equal(np.asarray(getattr(sub, f)),
                                      np.asarray(getattr(sup, f)),
                                      err_msg=f"{msg} field={f}")


# ---------------------------------------------------------------------------
# 1. Dual-filtration bit-identity across the path matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("phase_c_impl", ["fused", "xla"])
@pytest.mark.parametrize("path", ["whole", "batched", "sharded", "tiled"])
def test_sublevel_matches_negated_superlevel(path, phase_c_impl):
    img = _image(3)
    sub_e = PHEngine(_config("sublevel", phase_c_impl=phase_c_impl))
    sup_e = PHEngine(_config("superlevel", phase_c_impl=phase_c_impl))

    if path == "whole":
        sub = sub_e.run(img).diagram
        sup = sup_e.run(-img).diagram
    elif path == "batched":
        sub = jax.tree.map(lambda x: x[0],
                           sub_e.run_batch(img[None]).diagram)
        sup = jax.tree.map(lambda x: x[0],
                           sup_e.run_batch(-img[None]).diagram)
    elif path == "sharded":
        from repro.launch.mesh import make_small_context
        ctx = make_small_context(1, 1)
        dt = jnp.dtype(jnp.float32)
        sub_p = sub_e.sharded_plan(ctx, (1, H, W), dt, N, N)
        sup_p = sup_e.sharded_plan(ctx, (1, H, W), dt, N, N)
        # Each filtration's inert "no truncation" sentinel, user space.
        sub = jax.tree.map(lambda x: x[0], sub_p(
            jnp.asarray(img)[None], jnp.full((1,), jnp.inf, jnp.float32)))
        sup = jax.tree.map(lambda x: x[0], sup_p(
            jnp.asarray(-img)[None],
            jnp.full((1,), -jnp.inf, jnp.float32)))
    else:   # tiled
        sub = sub_e.run_tiled(img).diagram
        sup = sup_e.run_tiled(-img).diagram
    _assert_dual(sub, sup, f"{path}/{phase_c_impl}")


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_sublevel_duality_property(seed):
    """Seeded sweep of the whole-image duality (the shapes stay fixed so
    every example reuses two compiled programs)."""
    img = _image(seed)
    sub = pixhomology(jnp.asarray(-img), max_features=N, max_candidates=N,
                      filtration="sublevel")
    sup = pixhomology(jnp.asarray(img), max_features=N, max_candidates=N)
    _assert_dual(sub, sup, f"seed={seed}")
    # And against the oracle: sublevel features of -x are superlevel
    # features of x with both coordinates negated.
    want = persistence_oracle(img)
    rows = int(np.asarray(sub.count))
    got = np.stack([-np.asarray(sub.birth, np.float64)[:rows],
                    -np.asarray(sub.death, np.float64)[:rows],
                    np.asarray(sub.p_birth, np.float64)[:rows],
                    np.asarray(sub.p_death, np.float64)[:rows]], axis=1)
    np.testing.assert_array_equal(got, want)


def test_sublevel_requires_floating_dtype():
    with pytest.raises(ValueError, match="floating"):
        PHConfig(filtration="sublevel", dtype="int32")
    with pytest.raises(ValueError, match="float"):
        pixhomology(jnp.arange(16, dtype=jnp.int32).reshape(4, 4),
                    max_features=4, max_candidates=16,
                    filtration="sublevel")


# ---------------------------------------------------------------------------
# 2. Padded dispatch: essential extremum in the padded margin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("filtration", ["superlevel", "sublevel"])
def test_padded_batch_bit_identical_extremum_on_border(filtration):
    """The pad fixup must restore the essential death even when the
    image's global extremum sits on the row/column that abuts the pad
    margin (the fill used to be assumed to be the global minimum —
    wrong side entirely under sublevel)."""
    img = _image(11, shape=(13, 11))
    ext = np.argmin(img) if filtration == "superlevel" else np.argmax(img)
    r, c = np.unravel_index(ext, img.shape)
    # Move the extremum to the bottom-right corner (adjacent to pads).
    img[-1, -1], img[r, c] = img[r, c], img[-1, -1]
    eng = PHEngine(_config(filtration, tile=None))
    whole = eng.run(img).diagram
    padded = jax.tree.map(
        lambda x: x[0], eng.run_batch([img], bucket=(16, 16)).diagram)
    count = int(np.asarray(whole.count))
    assert int(np.asarray(padded.count)) == count
    # Capacities differ (143-pixel whole plan vs 256-pixel bucket), so
    # compare the count-trimmed records — row 0 carries the essential
    # class whose death the fixup restored.
    for f in ("birth", "death", "p_birth", "p_death"):
        np.testing.assert_array_equal(
            np.asarray(getattr(padded, f))[:count],
            np.asarray(getattr(whole, f))[:count],
            err_msg=f"{filtration} field={f}")


# ---------------------------------------------------------------------------
# 3. NaN / inf / subnormal boundary sweep
# ---------------------------------------------------------------------------

def _nan_image():
    img = _image(5)
    img[3, 7] = np.nan
    return img


@pytest.mark.parametrize("merge_keys", ["packed", "rank"])
def test_nan_rejected_on_every_entry_point(merge_keys):
    img = _nan_image()
    eng = PHEngine(_config("superlevel", merge_keys=merge_keys))
    for call in (lambda: eng.run(img),
                 lambda: eng.run_batch(img[None]),
                 lambda: eng.run_tiled(img),
                 lambda: eng.cast_input(img),
                 lambda: eng.cast_input_host(img),
                 lambda: pixhomology(img, max_features=N,
                                     max_candidates=N,
                                     merge_keys=merge_keys),
                 lambda: tiled_pixhomology(img, grid=(2, 2),
                                           max_features=N,
                                           tile_max_features=N,
                                           tile_max_candidates=N,
                                           merge_keys=merge_keys)):
        with pytest.raises(ValueError, match="ordered by a filtration"):
            call()


def test_inf_rejected_at_engine_boundary_only():
    img = _image(6)
    img[0, 0] = np.inf
    eng = PHEngine(_config("superlevel"))
    with pytest.raises(ValueError, match="pad sentinels"):
        eng.run(img)
    with pytest.raises(ValueError, match="pad sentinels"):
        eng.cast_input_host(img)
    # The core wrappers allow ±inf (padded/halo frames legitimately
    # carry the fill) — only NaN is rejected there.
    pixhomology(jnp.asarray(img), max_features=N, max_candidates=N)


def test_subnormals_accepted_and_correct():
    # A subnormal pixel among normal-scale values: accepted (the finite
    # check must not reject it) and ordered exactly — with no zeros and
    # a single subnormal, backend flush-to-zero cannot reorder anything,
    # so the diagram matches the (non-flushing) numpy oracle bitwise.
    img = _image(7)
    assert not (img == 0).any()
    img[5, 5] = np.float32(1e-40)
    assert 0 < img[5, 5] < np.finfo(np.float32).tiny
    d = PHEngine(_config("superlevel", tile=None)).run(img)
    np.testing.assert_array_equal(d.to_array(), persistence_oracle(img))

    # All-subnormal magnitudes: still accepted, and both key encodings
    # agree bit-for-bit under whatever flush semantics the backend has
    # (the packed_keys contract: key equality == comparison equality).
    tiny = (_image(7) * np.float32(1e-42)).astype(np.float32)
    packed = PHEngine(_config("superlevel", tile=None,
                              merge_keys="packed")).run(tiny).diagram
    rank = PHEngine(_config("superlevel", tile=None,
                            merge_keys="rank")).run(tiny).diagram
    for f in ("birth", "death", "p_birth", "p_death", "count"):
        np.testing.assert_array_equal(np.asarray(getattr(packed, f)),
                                      np.asarray(getattr(rank, f)),
                                      err_msg=f"field={f}")


def test_nan_rejected_at_distance_boundary():
    img = _image(8)
    eng = PHEngine(_config("superlevel", tile=None))
    res = eng.run(img)
    birth, death, p_birth = eng._stack_diagrams(res)
    birth[0, 0] = np.nan
    with pytest.raises(ValueError, match="ordered by a filtration"):
        eng.distance_matrix((birth, death, p_birth))
    with pytest.raises(ValueError, match="ordered by a filtration"):
        dist_ops.diagram_distances(birth, death, p_birth)


# ---------------------------------------------------------------------------
# 4. Distance kernels: parity, axioms, inertness, plan cache
# ---------------------------------------------------------------------------

def _diagram_batch(n=5, seed=9):
    eng = PHEngine(_config("superlevel", tile=None))
    imgs = np.stack([_image(seed + i) for i in range(n)])
    return eng, eng._stack_diagrams(eng.run_batch(imgs))


def test_pallas_kernel_bit_identical_to_ref():
    _, (birth, death, p_birth) = _diagram_batch()
    sw_x, bn_x = dist_ops.diagram_distances(birth, death, p_birth)
    sw_p, bn_p = dist_ops.diagram_distances(birth, death, p_birth,
                                            use_pallas=True)
    np.testing.assert_array_equal(np.asarray(sw_x), np.asarray(sw_p))
    np.testing.assert_array_equal(np.asarray(bn_x), np.asarray(bn_p))


def _np_points(birth, death, p_birth, i):
    m = p_birth[i] >= 0
    return np.stack([birth[i][m], death[i][m]], axis=1).astype(np.float64)


def _np_sw(pa, pb, n_dirs=16):
    theta = (np.arange(n_dirs) + 0.5) * np.pi / n_dirs
    total = 0.0
    for t in theta:
        c, s = np.cos(t), np.sin(t)
        proj = lambda p: p[:, 0] * c + p[:, 1] * s          # noqa: E731
        dpro = lambda p: (p[:, 0] + p[:, 1]) / 2 * (c + s)  # noqa: E731
        va = np.sort(np.concatenate([proj(pa), dpro(pb)]))
        vb = np.sort(np.concatenate([proj(pb), dpro(pa)]))
        total += np.abs(va - vb).sum()
    return total / n_dirs


def _np_bn(pa, pb, f):
    prof = lambda p: np.sort(np.concatenate(       # noqa: E731
        [np.abs(p[:, 0] - p[:, 1]), np.zeros(f - len(p))]))[::-1]
    return 0.5 * np.abs(prof(pa) - prof(pb)).max()


def test_distances_match_dense_numpy_reference():
    _, (birth, death, p_birth) = _diagram_batch()
    sw, bn = (np.asarray(a) for a in
              dist_ops.diagram_distances(birth, death, p_birth))
    f = birth.shape[1]
    for i in range(birth.shape[0]):
        for j in range(birth.shape[0]):
            pa = _np_points(birth, death, p_birth, i)
            pb = _np_points(birth, death, p_birth, j)
            np.testing.assert_allclose(sw[i, j], _np_sw(pa, pb),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(bn[i, j], _np_bn(pa, pb, f),
                                       rtol=1e-5, atol=1e-6)


def test_distance_metric_axioms():
    _, (birth, death, p_birth) = _diagram_batch(n=6)
    for mat in dist_ops.diagram_distances(birth, death, p_birth):
        m = np.asarray(mat)
        n = m.shape[0]
        np.testing.assert_array_equal(m, m.T)             # symmetry
        np.testing.assert_array_equal(np.diag(m), 0.0)    # d(A, A) = 0
        assert (m >= 0).all()
        eps = 1e-5 * max(m.max(), 1.0)
        for i in range(n):                # triangle inequality, all triples
            for j in range(n):
                for k in range(n):
                    assert m[i, j] <= m[i, k] + m[k, j] + eps


def test_capacity_pads_are_inert():
    _, (birth, death, p_birth) = _diagram_batch()
    sw1, bn1 = (np.asarray(a) for a in
                dist_ops.diagram_distances(birth, death, p_birth))
    grow = lambda a, fill: np.concatenate(    # noqa: E731
        [a, np.full_like(a, fill)], axis=1)
    sw2, bn2 = (np.asarray(a) for a in dist_ops.diagram_distances(
        grow(birth, -np.inf), grow(death, -np.inf), grow(p_birth, -1)))
    np.testing.assert_array_equal(bn1, bn2)   # profile pads: bit-exact
    np.testing.assert_allclose(sw1, sw2, rtol=1e-5)  # sum reassociates


def test_engine_distance_plan_cached_and_filtration_exact():
    eng, (birth, death, p_birth) = _diagram_batch(n=4)
    eng.distance_matrix((birth, death, p_birth))
    before = eng.plan_stats()["traces"]
    sw_a, bn_a = eng.distance_matrix((birth, death, p_birth))
    assert eng.plan_stats()["traces"] == before     # cached plan, no trace

    # Sublevel engine on the sublevel view of the same diagrams -> the
    # canonicalization makes the matrices bit-equal.
    sub = PHEngine(_config("sublevel", tile=None))
    sw_s, bn_s = sub.distance_matrix((-birth, -death, p_birth))
    np.testing.assert_array_equal(np.asarray(sw_a), np.asarray(sw_s))
    np.testing.assert_array_equal(np.asarray(bn_a), np.asarray(bn_s))


def test_profiles_match_across_key_encodings():
    from repro.core.packed_keys import key_scope
    _, (birth, death, p_birth) = _diagram_batch(n=3)
    with key_scope("packed"):
        packed = np.asarray(dist_ref.persistence_profiles(
            birth, death, p_birth, merge_keys="packed"))
    rank = np.asarray(dist_ref.persistence_profiles(
        birth, death, p_birth, merge_keys="rank"))
    np.testing.assert_array_equal(packed, rank)
    assert (np.diff(rank, axis=1) <= 0).all()       # descending


# ---------------------------------------------------------------------------
# 5. Serving metrics: empty/degenerate reservoirs
# ---------------------------------------------------------------------------

def test_empty_reservoir_zeroed_not_raising():
    from repro.serving.metrics import Reservoir, ServeMetrics
    r = Reservoir(8)
    assert r.percentile(99.0) == 0.0
    assert r.summary() == {"count": 0, "mean": 0.0, "p50": 0.0,
                           "p95": 0.0, "p99": 0.0, "max": 0.0}
    r.add(0.25)     # single sample: every percentile is that sample
    s = r.summary()
    assert s["count"] == 1 and s["p50"] == s["p95"] == s["p99"] == 0.25

    m = ServeMetrics(batch_cap=4)
    m.record_submit((16, 16))       # bucket exists, nothing dispatched
    snap = m.snapshot()["buckets"]["16x16"]
    assert snap["e2e_s"]["p99"] == 0.0 and snap["e2e_s"]["count"] == 0
    assert m.mean_batch_seconds((16, 16)) is None   # server retry fallback


def _load_perf_gate():
    p = pathlib.Path(__file__).resolve().parent.parent / "benchmarks" \
        / "perf_gate.py"
    spec = importlib.util.spec_from_file_location("perf_gate_under_test", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_perf_gate_skips_degenerate_latency_windows():
    gate = _load_perf_gate()
    summary = {"count": 1, "mean": 1.0, "p50": 9.0, "p95": 1.0, "p99": 1.0}
    doc = {"steady": {"buckets": {"16x16": {
        "occupancy": 0.5, "queue_wait_s": summary, "e2e_s": summary}}}}
    assert gate._serve_latency_summaries(doc) is None   # < 2 samples: skip
    bad = dict(summary, count=2)
    doc["steady"]["buckets"]["16x16"]["e2e_s"] = bad
    assert "unordered" in gate._serve_latency_summaries(doc)


def test_perf_gate_distance_rules():
    gate = _load_perf_gate()
    row = {"name": "distance/b6_s48", "distance_bit_identical": True,
           "sublevel_bit_identical": True, "pad_inert_bn": True,
           "pad_inert_sw_rel": 0.0, "steady_traces": 0}
    assert gate._distance_invariants([row]) is None
    assert "diverged" in gate._distance_invariants(
        [dict(row, distance_bit_identical=False)])
    assert "steady-state" in gate._distance_invariants(
        [dict(row, steady_traces=2)])
    traj = gate._distance_trajectory([row])
    assert traj([row]) is None
    assert traj([dict(row, sublevel_bit_identical=False)]) is not None
