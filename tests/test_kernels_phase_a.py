"""Fused phase-A kernel: interpret-mode parity with the XLA reference.

The Pallas kernel and ``ref.py`` must agree bit-for-bit on pointers AND
the higher-neighbor bitmask — across dtypes, tie-heavy images, and
non-divisible strip heights — and the snapped pointers must satisfy the
frontier invariant (every non-root pointer lands in a strip boundary
row).  The interpret-mode cases here are the phase-A smoke tier-1 CI runs
on every push (this container is CPU-only, like CI).
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    diagram_to_array,
    exact_candidates,
    exact_candidates_masked,
    persistence_oracle,
    pixhomology,
    resolve_labels,
    resolve_labels_frontier,
    steepest_neighbors,
    total_order_rank,
)
from repro.kernels.ph_phase_a import boundary_rows, fused_phase_a
from repro.kernels.ph_phase_a import kernel as pha_kernel
from repro.kernels.ph_phase_a import ref as pha_ref


def assert_kernel_matches_ref(img: np.ndarray, strip_rows: int):
    x = jnp.asarray(img)
    p_ref, m_ref = pha_ref.phase_a(x, strip_rows=strip_rows)
    p_ker, m_ker = pha_kernel.phase_a(x, strip_rows=strip_rows,
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_ker),
                                  err_msg=f"ptr strip_rows={strip_rows}")
    np.testing.assert_array_equal(np.asarray(m_ref), np.asarray(m_ker),
                                  err_msg=f"mask strip_rows={strip_rows}")
    return np.asarray(p_ref), np.asarray(m_ref)


# ---------------------------------------------------------------------------
# Kernel (interpret) vs XLA reference parity
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.sampled_from([(8, 8), (13, 9), (12, 16), (7, 5)]),
       st.sampled_from([1, 3, 4, 8, 32]),
       st.integers(0, 2 ** 31 - 1))
def test_kernel_parity_gaussian(shape, strip_rows, seed):
    img = np.random.default_rng(seed).normal(size=shape).astype(np.float32)
    assert_kernel_matches_ref(img, strip_rows)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([(9, 7), (12, 12)]), st.sampled_from([2, 5, 8]),
       st.integers(0, 2 ** 31 - 1), st.integers(2, 4))
def test_kernel_parity_heavy_ties(shape, strip_rows, seed, levels):
    """Tiny value range => massive ties; the static per-offset index
    tie-break must agree with ref.py's (value, flat) order exactly."""
    img = np.random.default_rng(seed).integers(
        0, levels, size=shape).astype(np.float32)
    assert_kernel_matches_ref(img, strip_rows)


@pytest.mark.parametrize("dtype", [np.float32, np.int32, jnp.bfloat16])
def test_kernel_parity_dtypes(dtype):
    rng = np.random.default_rng(5)
    img = rng.integers(0, 40, size=(11, 13)).astype(np.float32)
    img = jnp.asarray(img).astype(dtype)
    assert_kernel_matches_ref(np.asarray(img), strip_rows=4)


def test_kernel_parity_nondivisible_strips():
    """H % strip_rows != 0: the padded rows must not perturb real pixels."""
    rng = np.random.default_rng(7)
    for h, s in [(13, 8), (17, 4), (9, 5), (3, 2)]:
        img = rng.normal(size=(h, 11)).astype(np.float32)
        assert_kernel_matches_ref(img, s)


def test_kernel_parity_degenerate_shapes():
    rng = np.random.default_rng(8)
    for shape in [(1, 1), (1, 9), (9, 1), (2, 2)]:
        img = rng.normal(size=shape).astype(np.float32)
        for s in (1, 4, 64):
            assert_kernel_matches_ref(img, s)


def test_phase_a_interpret_smoke():
    """The tier-1 CI smoke: full fused pixhomology through the Pallas
    kernel in interpret mode stays oracle-equal."""
    img = np.random.default_rng(0).normal(size=(12, 10)).astype(np.float32)
    d = pixhomology(jnp.asarray(img), max_features=120, max_candidates=120,
                    use_pallas=True, interpret=True)
    assert not bool(d.overflow)
    np.testing.assert_array_equal(diagram_to_array(d),
                                  persistence_oracle(img))


# ---------------------------------------------------------------------------
# Snapped-pointer invariant + frontier resolution equivalence
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.sampled_from([(16, 12), (13, 9), (8, 24)]),
       st.sampled_from([1, 4, 8, 16]),
       st.integers(0, 2 ** 31 - 1))
def test_snap_invariant_and_frontier_equivalence(shape, strip_rows, seed):
    """Every snapped pointer is a basin root or lives in a boundary row,
    and frontier resolution equals dense whole-image doubling bit-for-bit.
    """
    h, w = shape
    img = jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(np.float32))
    ptr, _ = fused_phase_a(img, strip_rows=strip_rows, use_pallas=False)
    ptr_np = np.asarray(ptr)

    raw = steepest_neighbors(img)
    roots = np.flatnonzero(np.asarray(raw) == np.arange(h * w))
    b_rows = set(boundary_rows(h, strip_rows).tolist())
    for tgt in np.unique(ptr_np):
        assert tgt in roots or (tgt // w) in b_rows

    dense = np.asarray(resolve_labels(raw))
    frontier = np.asarray(resolve_labels_frontier(ptr, (h, w), strip_rows))
    np.testing.assert_array_equal(dense, frontier)


def test_boundary_rows_static_structure():
    np.testing.assert_array_equal(boundary_rows(12, 4),
                                  [0, 3, 4, 7, 8, 11])
    np.testing.assert_array_equal(boundary_rows(13, 4),
                                  [0, 3, 4, 7, 8, 11, 12])
    np.testing.assert_array_equal(boundary_rows(5, 8), [0, 4])
    np.testing.assert_array_equal(boundary_rows(1, 1), [0])
    np.testing.assert_array_equal(boundary_rows(4, 1), [0, 1, 2, 3])


# ---------------------------------------------------------------------------
# Masked candidate generator == rank-based generator
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.sampled_from([(10, 10), (13, 7)]), st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["gauss", "ties"]))
def test_masked_candidates_match_rank_based(shape, seed, kind):
    rng = np.random.default_rng(seed)
    if kind == "gauss":
        img = rng.normal(size=shape).astype(np.float32)
    else:
        img = rng.integers(0, 3, size=shape).astype(np.float32)
    x = jnp.asarray(img)
    h, w = shape
    rank = total_order_rank(x.reshape(-1))
    labels = resolve_labels(steepest_neighbors(x))
    _, mask = fused_phase_a(x, strip_rows=4, use_pallas=False)
    want = exact_candidates(rank.reshape(h, w), labels.reshape(h, w))
    got = exact_candidates_masked(mask.reshape(h, w), labels.reshape(h, w))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# Fused pipeline == pooled pipeline == oracle (stage interchangeability)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.sampled_from([(8, 8), (13, 9), (16, 5)]),
       st.sampled_from([1, 4, 8]),
       st.integers(0, 2 ** 31 - 1))
def test_fused_pixhomology_matches_pooled_and_oracle(shape, strip_rows,
                                                     seed):
    img = np.random.default_rng(seed).normal(size=shape).astype(np.float32)
    h, w = shape
    kw = dict(max_features=h * w, max_candidates=h * w)
    fused = pixhomology(jnp.asarray(img), phase_a_impl="fused",
                        strip_rows=strip_rows, **kw)
    pooled = pixhomology(jnp.asarray(img), phase_a_impl="pooled", **kw)
    for a, b in zip(fused, pooled):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(diagram_to_array(fused),
                                  persistence_oracle(img))
