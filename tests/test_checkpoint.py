"""Checkpoint: save/restore round-trip, rotation, async, elastic re-shard."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import ckpt


def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5,
                  "n": jnp.asarray(7, jnp.int32)},
            "l": [jnp.zeros((2,), jnp.float32),
                  jnp.full((2, 2), -3.0, jnp.float32)]}


def assert_tree_equal(x, y):
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), x, y)


def test_roundtrip_bf16_and_ints(tmp_path):
    t = tree()
    ckpt.save(tmp_path, 5, t, metadata={"k": "v"})
    restored, meta, step = ckpt.restore(tmp_path, t)
    assert step == 5 and meta == {"k": "v"}
    assert restored["b"]["w"].dtype == jnp.bfloat16
    assert_tree_equal(t, restored)


def test_rotation_keeps_latest(tmp_path):
    t = tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, t, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000004", "step_00000005"]
    assert ckpt.latest_step(tmp_path) == 5


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer()
    t = tree()
    saver.save(tmp_path, 1, t)
    saver.save(tmp_path, 2, t)     # joins the previous write
    saver.join()
    assert ckpt.latest_step(tmp_path) == 2
    restored, _, _ = ckpt.restore(tmp_path, t)
    assert_tree_equal(t, restored)


def test_missing_leaf_and_shape_mismatch(tmp_path):
    t = tree()
    ckpt.save(tmp_path, 1, t)
    bad = dict(t, extra=jnp.zeros((1,)))
    with pytest.raises(KeyError):
        ckpt.restore(tmp_path, bad)
    bad2 = dict(t, a=jnp.zeros((9, 9)))
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, bad2)


def test_elastic_reshard_subprocess(tmp_path):
    """Save on 1 device, restore re-sharded onto a 2x4 host-device mesh
    (the elastic-scaling path).  Runs in a subprocess so the 8-device
    XLA_FLAGS doesn't leak into this process."""
    import subprocess
    import sys

    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import ckpt

t = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
ckpt.save(r"{tmp_path}", 1, t)
mesh = jax.make_mesh((2, 4), ("data", "model"))
sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
restored, _, _ = ckpt.restore(r"{tmp_path}", t, shardings=sh)
assert restored["w"].sharding.spec == P("data", "model")
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
print("ELASTIC_OK")
"""
    import os
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=180,
                         env=env, cwd="/root/repo")
    assert "ELASTIC_OK" in res.stdout, res.stderr[-2000:]
