"""Tile-decomposed PixHomology: bit-identity with the whole-image path.

The acceptance bar is *exact* equality of every Diagram field — including
``p_birth``/``p_death`` in global pixel coordinates — against whole-image
``pixhomology`` (itself bit-tested against the union-find oracle), across
random grids, tie-heavy images, and basins/saddles spanning 3+ tiles; plus
two-level overflow regrow and the per-tile cost-model scaling property.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import persistence_oracle, pixhomology
from repro.core.tiling import (
    TiledDiagram,
    choose_grid,
    per_tile_cost,
    tiled_pixhomology,
    validate_grid,
)
from repro.ph import PHConfig, PHEngine, TileSpec


def assert_tiled_equal(img: np.ndarray, grid, tv=None):
    h, w = img.shape
    whole = pixhomology(jnp.asarray(img), tv, max_features=h * w,
                        max_candidates=h * w)
    tvj = None if tv is None else jnp.asarray(tv, jnp.float32)
    td = tiled_pixhomology(jnp.asarray(img), tvj, grid=tuple(grid),
                           max_features=h * w, tile_max_features=h * w,
                           tile_max_candidates=h * w)
    assert isinstance(td, TiledDiagram)
    assert not bool(td.tile_overflow) and not bool(td.merge_overflow)
    for field in whole._fields:
        if field == "overflow":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(whole, field)),
            np.asarray(getattr(td.diagram, field)),
            err_msg=f"grid={grid} field={field}")


# ---------------------------------------------------------------------------
# Property-based equivalence (shapes drawn from a small pool to bound
# compile count; every draw still exercises a distinct image)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.sampled_from([(8, 8), (12, 8), (8, 12), (12, 12)]),
       st.sampled_from([(1, 1), (2, 2), (4, 2), (2, 4), (4, 4)]),
       st.integers(0, 2 ** 31 - 1))
def test_tiled_matches_whole_gaussian(shape, grid, seed):
    img = np.random.default_rng(seed).normal(
        size=shape).astype(np.float32)
    assert_tiled_equal(img, grid)


@settings(max_examples=16, deadline=None)
@given(st.sampled_from([(8, 8), (12, 12)]),
       st.sampled_from([(2, 2), (4, 4)]),
       st.integers(0, 2 ** 31 - 1), st.integers(2, 4))
def test_tiled_matches_whole_heavy_ties(shape, grid, seed, levels):
    """Tiny integer range => massive (value) ties: the per-tile local rank
    must still reproduce the global (value, index) total order exactly."""
    img = np.random.default_rng(seed).integers(
        0, levels, size=shape).astype(np.float32)
    assert_tiled_equal(img, grid)


def test_tiled_int_dtype():
    img = np.random.default_rng(3).integers(
        0, 50, size=(12, 8)).astype(np.int32)
    assert_tiled_equal(img, (3, 2))


def test_tiled_matches_fused_kernel_whole_image():
    """The tiled path (shared stages: keyed_steepest_pointers +
    resolve_labels with the halo frozen) must equal the whole-image fused
    phase-A kernel route, including through the Pallas interpret backend.
    """
    import jax.numpy as jnp
    from repro.core.tiling import TiledDiagram, tiled_pixhomology
    img = np.random.default_rng(13).normal(size=(12, 12)).astype(np.float32)
    whole = pixhomology(jnp.asarray(img), max_features=144,
                        max_candidates=144, phase_a_impl="fused",
                        strip_rows=4, use_pallas=True, interpret=True)
    td = tiled_pixhomology(jnp.asarray(img), grid=(3, 3), max_features=144,
                           tile_max_features=144, tile_max_candidates=144)
    assert isinstance(td, TiledDiagram)
    for field in whole._fields:
        if field == "overflow":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(whole, field)),
            np.asarray(getattr(td.diagram, field)), err_msg=field)


# ---------------------------------------------------------------------------
# Basins and merge saddles spanning 3+ tiles
# ---------------------------------------------------------------------------

def test_basin_spanning_all_tiles_monotone_ramp():
    """One basin covering every tile: every chain exits through seams."""
    img = np.arange(16 * 16, dtype=np.float32).reshape(16, 16)
    assert_tiled_equal(img, (4, 4))


def test_ridge_crossing_tile_rows():
    """A single ridge basin crossing a 4x4 grid horizontally, with noise
    maxima merging into it across seams."""
    rng = np.random.default_rng(0)
    yy, xx = np.mgrid[0:16, 0:16].astype(np.float32)
    img = -((yy - 8) ** 2) * 0.1 + xx * 0.01 \
        + rng.normal(scale=1e-3, size=(16, 16)).astype(np.float32)
    assert_tiled_equal(img, (4, 4))


def test_constant_image_pure_tiebreak():
    """All-equal values: label resolution and the single essential class
    are decided purely by the global-index tie-break across tiles."""
    assert_tiled_equal(np.zeros((12, 12), np.float32), (3, 3))


def test_two_blobs_saddle_on_seam():
    """Two maxima in different tiles whose merge saddle sits on the tile
    boundary column — the death must come from a seam edge."""
    yy, xx = np.mgrid[0:8, 0:16].astype(np.float32)
    img = (2.0 * np.exp(-((yy - 4) ** 2 + (xx - 3) ** 2) / 6.0)
           + 1.5 * np.exp(-((yy - 4) ** 2 + (xx - 12) ** 2) / 6.0))
    img += np.random.default_rng(1).normal(
        scale=1e-4, size=img.shape).astype(np.float32)
    assert_tiled_equal(img, (1, 2))   # seam at column 8, between the blobs
    assert_tiled_equal(img, (2, 2))


def test_tiled_truncation_matches_whole():
    rng = np.random.default_rng(5)
    img = rng.normal(size=(12, 12)).astype(np.float32)
    for tv in (-0.5, 0.3):
        assert_tiled_equal(img, (3, 3), tv=tv)


def test_degenerate_tiles():
    rng = np.random.default_rng(6)
    assert_tiled_equal(np.array([[3.5]], np.float32), (1, 1))
    assert_tiled_equal(rng.normal(size=(2, 2)).astype(np.float32), (2, 2))
    assert_tiled_equal(rng.normal(size=(1, 8)).astype(np.float32), (1, 4))


# ---------------------------------------------------------------------------
# Grid selection / validation
# ---------------------------------------------------------------------------

def test_validate_grid_rejects_nondividing():
    with pytest.raises(ValueError):
        validate_grid((12, 12), (5, 2))
    with pytest.raises(ValueError):
        validate_grid((12, 12), (0, 2))


def test_choose_grid_respects_budget_and_divides():
    h, w = 96, 64
    gr, gc = choose_grid((h, w), max_tile_pixels=1024)
    assert h % gr == 0 and w % gc == 0
    assert (h // gr) * (w // gc) <= 1024
    assert choose_grid((64, 64), max_tile_pixels=64 * 64) == (1, 1)


def test_tilespec_validation_and_json_roundtrip():
    with pytest.raises(ValueError):
        TileSpec(halo=2)
    with pytest.raises(ValueError):
        TileSpec(grid=(0, 2))
    with pytest.raises(ValueError):
        TileSpec(max_features_per_tile=0)
    cfg = PHConfig(tile=TileSpec(grid=(2, 2), max_features_per_tile=64))
    back = PHConfig.from_json(cfg.to_json())
    assert back == cfg and back.tile.grid == (2, 2)
    # TileSpec participates in the plan key
    assert PHConfig().plan_key() != cfg.plan_key()
    assert {cfg: 1}[cfg] == 1    # still hashable


# ---------------------------------------------------------------------------
# Engine: two-level overflow regrow (per tile AND seam merge)
# ---------------------------------------------------------------------------

def test_run_tiled_regrows_tile_capacities_to_oracle_equal():
    img = np.random.default_rng(2).normal(size=(16, 16)).astype(np.float32)
    engine = PHEngine(PHConfig(
        max_features=512,
        tile=TileSpec(grid=(4, 4), max_features_per_tile=1,
                      max_candidates_per_tile=1)))
    res = engine.run_tiled(img)
    assert res.regrow.attempts >= 1 and not res.regrow.overflow
    np.testing.assert_array_equal(res.to_array(), persistence_oracle(img))
    assert res.config.tile.max_features_per_tile > 1
    assert any(r["kind"] == "tiled" for r in engine.regrow_log)


def test_run_tiled_regrows_seam_merge_capacity():
    """Global diagram rows undersized while tiles are fine: only
    max_features must regrow (the seam-merge level)."""
    img = np.random.default_rng(4).normal(size=(16, 16)).astype(np.float32)
    engine = PHEngine(PHConfig(
        max_features=2,
        tile=TileSpec(grid=(2, 2), max_features_per_tile=256,
                      max_candidates_per_tile=256)))
    res = engine.run_tiled(img)
    assert res.regrow.attempts >= 1 and not res.regrow.overflow
    assert res.config.max_features > 2
    assert res.config.tile.max_features_per_tile == 64   # clamped, untouched
    np.testing.assert_array_equal(res.to_array(), persistence_oracle(img))


def test_run_tiled_regrow_sticky_and_plan_cached():
    img = np.random.default_rng(8).normal(size=(12, 12)).astype(np.float32)
    engine = PHEngine(PHConfig(
        max_features=4, tile=TileSpec(grid=(3, 3), max_features_per_tile=2,
                                      max_candidates_per_tile=2)))
    r1 = engine.run_tiled(img)
    assert r1.regrow.attempts >= 1
    r2 = engine.run_tiled(img)
    assert r2.regrow.attempts == 0
    stats = engine.plan_stats()
    assert stats["hits"] >= 1          # the regrown plan was reused

    small = PHEngine(PHConfig(max_features=256, tile=TileSpec(
        grid=(3, 3), max_features_per_tile=16, max_candidates_per_tile=32)))
    small.run_tiled(img)
    small.run_tiled(img.copy())
    assert small.plan_stats()["traces"] == 1


def test_run_tiled_respects_max_regrows():
    img = np.random.default_rng(9).normal(size=(16, 16)).astype(np.float32)
    engine = PHEngine(PHConfig(
        max_features=512, max_regrows=1,
        tile=TileSpec(grid=(4, 4), max_features_per_tile=1,
                      max_candidates_per_tile=1)))
    res = engine.run_tiled(img)
    assert res.regrow.attempts == 1
    assert res.regrow.overflow          # still undersized, reported


def test_run_tiled_honors_regrow_ceilings():
    img = np.random.default_rng(10).normal(size=(16, 16)).astype(np.float32)
    engine = PHEngine(PHConfig(
        max_features=2, max_candidates=8,
        regrow_features_ceiling=4, regrow_candidates_ceiling=8,
        tile=TileSpec(grid=(2, 2), max_features_per_tile=1,
                      max_candidates_per_tile=1)))
    res = engine.run_tiled(img)
    assert res.config.max_features <= 4
    assert res.config.tile.max_features_per_tile <= 4
    assert res.config.tile.max_candidates_per_tile <= 8
    assert res.regrow.overflow          # capped below need, reported


def test_run_tiled_rejects_paper_mode():
    engine = PHEngine(PHConfig(candidate_mode="paper"))
    with pytest.raises(ValueError):
        engine.run_tiled(np.zeros((4, 4), np.float32))


# ---------------------------------------------------------------------------
# num_candidates (capacity planning satellite)
# ---------------------------------------------------------------------------

def test_num_candidates_forwards_backend_and_engine_exposes_it():
    from repro.core import num_candidates
    img = np.random.default_rng(1).normal(size=(10, 10)).astype(np.float32)
    k_default = int(num_candidates(jnp.asarray(img)))
    k_ref = int(num_candidates(jnp.asarray(img), use_pallas=False))
    assert k_default == k_ref > 0
    engine = PHEngine(PHConfig(use_pallas=False))
    assert engine.num_candidates(img) == k_ref
    # threshold filtering matches the core helper
    assert engine.num_candidates(img, truncate_value=np.max(img)) <= k_ref


# ---------------------------------------------------------------------------
# Distributed: sharded tiles + pipeline routing of oversized images
# ---------------------------------------------------------------------------

def test_run_tiled_sharded_ctx_bit_identical():
    from repro.distributed.context import single_device_ctx
    img = np.random.default_rng(11).normal(size=(12, 12)).astype(np.float32)
    engine = PHEngine(PHConfig(max_features=256, tile=TileSpec(
        grid=(2, 2), max_features_per_tile=64, max_candidates_per_tile=64)))
    res = engine.run_tiled(img, ctx=single_device_ctx())
    np.testing.assert_array_equal(res.to_array(), persistence_oracle(img))


def test_pipeline_routes_oversized_images_through_tiles():
    engine = PHEngine(PHConfig(
        max_features=4096, filter_level="filter_std",
        tile=TileSpec(grid=(2, 2), max_features_per_tile=1024,
                      max_candidates_per_tile=2048,
                      max_tile_pixels=32 * 32)))
    assert engine.should_tile(64 * 64) and not engine.should_tile(32 * 32)
    res = engine.run_distributed([0, 1], image_size=64)
    assert len(res.diagrams) == 2
    assert all(not d["overflow"] for d in res.diagrams.values())
    # the tiled summaries match a whole-image engine bit-for-bit (at the
    # tile-budget-sampled Variant-2 threshold the streaming path uses)
    from repro.data import astro
    whole = PHEngine(PHConfig(max_features=4096,
                              filter_level="filter_std"))
    img = astro.generate_image(0, 64)
    t = astro.AstroImage(0, 64).filter_threshold("filter_std", sample=32)
    want = whole.run(img, t)
    assert res.diagrams[0]["count"] == int(want.diagram.count)
    np.testing.assert_allclose(
        res.diagrams[0]["top_births"],
        np.asarray(want.diagram.birth[:5], np.float64))


def test_run_tiled_accepts_provider_and_staged_tiles():
    """The streaming entry points: a tile provider (windowed loading) and
    pre-staged tile stacks must both be bit-identical to the whole-image
    array path, including p_birth/p_death."""
    from repro.core.tiling import load_tile_stacks
    from repro.data import astro
    engine = PHEngine(PHConfig(max_features=4096, tile=TileSpec(
        grid=(2, 2), max_features_per_tile=1024,
        max_candidates_per_tile=2048)))
    prov = astro.AstroImage(9, 48)
    img = astro.generate_image(9, 48)
    want = engine.run_tiled(img)
    got_prov = engine.run_tiled(prov)
    staged = load_tile_stacks(prov, (2, 2))
    assert staged.shape == (48, 48) and staged.grid == (2, 2)
    got_staged = engine.run_tiled(staged)
    for name, res in (("provider", got_prov), ("staged", got_staged)):
        for field in want.diagram._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(want.diagram, field)),
                np.asarray(getattr(res.diagram, field)),
                err_msg=f"{name}:{field}")


def test_run_tiled_provider_derives_threshold_and_staged_requires_it():
    from repro.data import astro
    engine = PHEngine(PHConfig(
        max_features=4096, filter_level="filter_std",
        tile=TileSpec(grid=(2, 2), max_features_per_tile=1024,
                      max_candidates_per_tile=2048)))
    prov = astro.AstroImage(3, 48)
    res = engine.run_tiled(prov)           # threshold from the provider
    t = prov.filter_threshold("filter_std")
    assert res.threshold == t
    want = engine.run_tiled(astro.generate_image(3, 48), t)
    np.testing.assert_array_equal(res.to_array(), want.to_array())

    class NoThreshold:
        shape = (48, 48)
        dtype = np.float32

        def halo_tile(self, t, grid, fill=-np.inf):
            return prov.halo_tile(t, grid, fill=fill)

    with pytest.raises(ValueError):
        engine.run_tiled(NoThreshold())


def test_halo_gidx_tile_matches_split():
    from repro.core.tiling import halo_gidx_tile, split_tiles
    h, w, grid = 24, 36, (2, 3)
    gidx2d = jnp.arange(h * w, dtype=jnp.int32).reshape(h, w)
    ref = np.asarray(split_tiles(gidx2d, grid, jnp.int32(-1)))
    for t in range(6):
        np.testing.assert_array_equal(halo_gidx_tile((h, w), grid, t),
                                      ref[t], err_msg=f"tile {t}")


# ---------------------------------------------------------------------------
# Cost model: per-tile working memory ~ tile size, not image size
# ---------------------------------------------------------------------------

def test_per_tile_memory_scales_with_tile_not_image():
    tile = (16, 16)
    small = per_tile_cost(tile, jnp.float32, n_tiles=4,
                          tile_max_features=64, tile_max_candidates=64)
    big = per_tile_cost(tile, jnp.float32, n_tiles=64,
                        tile_max_features=64, tile_max_candidates=64)
    # Phase A is strictly tile-local: byte-identical across image sizes.
    assert small["phase_a"] == big["phase_a"]
    # Phase B adds only the O(boundary) condensation table.
    extra = big["phase_b"]["peak_bytes_est"] \
        - small["phase_b"]["peak_bytes_est"]
    table_bytes = (big["table_entries"] - small["table_entries"]) * 4 * 2
    assert extra <= 2 * table_bytes
    # And a 16x-area whole image costs far more than its per-tile program.
    whole = per_tile_cost((64, 64), jnp.float32, n_tiles=1,
                          tile_max_features=64, tile_max_candidates=64)
    assert whole["phase_a"]["peak_bytes_est"] \
        > 4 * big["phase_a"]["peak_bytes_est"]
