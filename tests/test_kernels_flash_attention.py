"""Flash attention Pallas kernel vs jnp oracle: shape/dtype/GQA sweeps
(interpret mode), plus consistency with the model's blockwise XLA path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import kernel, ops, ref

CASES = [
    # (B, H, KV, Sq, Skv, hd, causal, window)
    (1, 1, 1, 128, 128, 64, True, None),
    (2, 4, 2, 128, 128, 64, True, None),
    (1, 8, 1, 256, 256, 128, True, None),      # MQA
    (2, 4, 4, 128, 128, 128, False, None),     # bidirectional MHA
    (1, 2, 2, 256, 256, 64, True, 128),        # local window
    (1, 4, 2, 128, 256, 64, False, None),      # cross-ish (Sq != Skv)
]


def _mk(b, h, kv, sq, skv, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, sq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, kv, skv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, kv, skv, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(case, dtype):
    b, h, kv, sq, skv, hd, causal, window = case
    q, k, v = _mk(b, h, kv, sq, skv, hd, dtype)
    got = kernel.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                     q_block=64, kv_block=64, interpret=True)
    want = ref.attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_block_size_invariance():
    q, k, v = _mk(1, 2, 2, 256, 256, 64, jnp.float32)
    outs = [kernel.flash_attention_fwd(q, k, v, causal=True, q_block=qb,
                                       kv_block=kb, interpret=True)
            for qb, kb in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


def test_custom_vjp_grads_match_ref():
    q, k, v = _mk(1, 2, 1, 128, 128, 64, jnp.float32)

    def loss_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True, None, True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention(q, k, v, causal=True) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_matches_model_blockwise_path():
    """The XLA blockwise path (models/attention.py) and the Pallas kernel
    compute the same attention."""
    from repro.models.attention import blockwise_attention

    b, h, kv, s, hd = 2, 4, 2, 128, 64
    q, k, v = _mk(b, h, kv, s, s, hd, jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    xla = blockwise_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), q_positions=pos, kv_positions=pos,
        causal=True, window=None, q_block=64, kv_block=64)
    pall = kernel.flash_attention_fwd(q, k, v, causal=True, q_block=64,
                                      kv_block=64, interpret=True)
    np.testing.assert_allclose(np.asarray(xla),
                               np.asarray(pall.transpose(0, 2, 1, 3)),
                               atol=2e-5, rtol=2e-5)
