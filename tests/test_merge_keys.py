"""Differential oracle harness for the rank-free phase C (packed keys).

Three layers of evidence that ``merge_keys="packed"`` changed *nothing*
but the compiled program:

1. unit tests of the key-packing primitive itself — monotonicity of the
   float32 -> uint32 bit trick over sorted values (including signed zeros
   and subnormals), integer dtypes, and the index round-trip;
2. a hypothesis property suite asserting packed phase C is bit-identical
   (diagram values AND ``p_birth``/``p_death`` positions) to both the
   ``rank`` path and the classical union-find oracle
   (``core/reference.py``), across dtypes, tie-heavy plateaus, negative
   values, and the degenerate single-pixel / all-equal images;
3. a cross-path bit-identity matrix sweeping
   {whole, batched, sharded, tiled} x {fused, pooled phase A}
   x {packed, rank merge keys} on one fixed seed image, so no path
   combination can silently diverge again.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    diagram_to_array,
    monotone_key32,
    pack_keys,
    packable_dtype,
    packed_index,
    persistence_oracle,
    pixhomology,
    resolve_merge_keys,
)
from repro.core import packed_keys as pk


def _image(dtype: str, kind: str, seed: int, shape=(12, 11)) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "plateau":            # tiny value range => massive ties
        img = rng.integers(0, 3, size=shape)
    elif kind == "negative":
        img = -np.abs(rng.normal(size=shape) * 50)
    else:
        img = rng.normal(size=shape) * 50
    if dtype == "uint8":
        return np.clip(np.abs(img), 0, 255).astype(np.uint8)
    if dtype == "int16":
        return img.astype(np.int16)
    return img.astype(np.float32)


def run_path(img: np.ndarray, merge_keys: str, **kw) -> np.ndarray:
    h, w = img.shape
    d = pixhomology(jnp.asarray(img), max_features=h * w,
                    max_candidates=h * w, merge_keys=merge_keys, **kw)
    assert not bool(d.overflow)
    return diagram_to_array(d)


# ---------------------------------------------------------------------------
# 1. The key-packing primitive
# ---------------------------------------------------------------------------

def _keys_under_scope(values: np.ndarray):
    with pk.key_scope("packed"):
        k32 = np.asarray(monotone_key32(jnp.asarray(values)))
        packed = np.asarray(pack_keys(jnp.asarray(values)))
        idx = np.asarray(packed_index(jnp.asarray(packed)))
    return k32, packed, idx


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_float32_key_monotone_over_sorted_values(seed):
    rng = np.random.default_rng(seed)
    vals = np.sort((rng.normal(size=64) *
                    10.0 ** rng.integers(-3, 4)).astype(np.float32))
    k32, _, _ = _keys_under_scope(vals)
    jeq = np.asarray(jnp.asarray(vals[1:]) == jnp.asarray(vals[:-1]))
    # Strictly increasing wherever the backend's own comparison says the
    # values differ, equal where it says they tie (flush-to-zero safe).
    assert np.all(np.where(jeq, k32[1:] == k32[:-1], k32[1:] > k32[:-1]))


def test_float32_key_signed_zeros_and_subnormals():
    vals = np.array([-np.inf, -1.0, -1e-45, -0.0, 0.0, 1e-45, 1e-38, 1.0,
                     np.inf], np.float32)
    k32, _, _ = _keys_under_scope(vals)
    iz, pz = 3, 4
    assert k32[iz] == k32[pz], "-0.0 and +0.0 must share a key (argsort ties)"
    # Same order the rank path (stable jnp.argsort) produces.
    with pk.key_scope("packed"):
        packed = np.asarray(pack_keys(jnp.asarray(vals)))
    want = np.asarray(jnp.argsort(jnp.asarray(vals), stable=True))
    assert np.array_equal(np.argsort(packed, kind="stable"), want)


def test_integer_keys_monotone():
    for dtype in (np.uint8, np.int16, np.int32, np.uint16):
        info = np.iinfo(dtype)
        vals = np.unique(np.array(
            [info.min, info.min + 1, -3, -1, 0, 1, 7, info.max - 1, info.max],
            np.int64).clip(info.min, info.max)).astype(dtype)
        k32, _, _ = _keys_under_scope(vals)
        assert np.all(np.diff(k32.astype(np.int64)) > 0), dtype


def test_packed_index_round_trip():
    rng = np.random.default_rng(5)
    vals = rng.normal(size=257).astype(np.float32)
    _, packed, idx = _keys_under_scope(vals)
    np.testing.assert_array_equal(idx, np.arange(257))
    # Packed order == (value, index) lexicographic order.
    order = np.argsort(packed, kind="stable")
    want = np.lexsort((np.arange(257), vals))
    np.testing.assert_array_equal(order, want)


def test_pad_sentinel_strictly_below_all_keys():
    # Even a full-range int32 image (values down to int32 min at pixel 0)
    # stays strictly above the pad sentinel: low word is index + 1 >= 1.
    vals = np.array([np.iinfo(np.int32).min, 0, np.iinfo(np.int32).max],
                    np.int32)
    _, packed, _ = _keys_under_scope(vals)
    assert np.all(packed > np.iinfo(np.int64).min)


def test_resolution_rules():
    assert resolve_merge_keys("rank", np.float32) == "rank"
    assert resolve_merge_keys("packed", np.float32) == "packed"
    assert resolve_merge_keys("packed", np.float64) == "rank"
    assert resolve_merge_keys("packed", np.int64) == "rank"
    assert packable_dtype(jnp.bfloat16) and packable_dtype(np.uint8)
    assert not packable_dtype(np.float64)
    with pytest.raises(ValueError):
        resolve_merge_keys("nope", np.float32)


# ---------------------------------------------------------------------------
# 2. Differential oracle: packed == rank == union-find reference
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.sampled_from(["float32", "int16", "uint8"]),
       st.sampled_from(["gaussian", "plateau", "negative"]),
       st.integers(0, 2 ** 31 - 1))
def test_packed_equals_rank_equals_oracle(dtype, kind, seed):
    img = _image(dtype, kind, seed)
    got_packed = run_path(img, "packed")
    got_rank = run_path(img, "rank")
    want = persistence_oracle(img)
    np.testing.assert_array_equal(got_packed, want,
                                  err_msg=f"packed vs oracle {dtype} {kind}")
    np.testing.assert_array_equal(got_packed, got_rank,
                                  err_msg=f"packed vs rank {dtype} {kind}")


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(["scan", "boruvka"]), st.integers(0, 2 ** 31 - 1))
def test_packed_merge_impls_match_oracle(merge_impl, seed):
    img = _image("float32", "plateau", seed, shape=(9, 13))
    got = run_path(img, "packed", merge_impl=merge_impl)
    np.testing.assert_array_equal(got, persistence_oracle(img))


def test_degenerate_images():
    for img in (np.array([[3.5]], np.float32),            # single pixel
                np.zeros((6, 7), np.float32),             # all-equal
                np.full((5, 5), -2.25, np.float32),       # all-equal negative
                np.full((4, 9), 7, np.uint8)):            # all-equal integer
        got = run_path(img, "packed")
        np.testing.assert_array_equal(got, persistence_oracle(img))
        np.testing.assert_array_equal(got, run_path(img, "rank"))


def test_packed_with_truncation_matches_rank():
    img = _image("float32", "gaussian", 17, shape=(16, 12))
    t = float(np.median(img))
    h, w = img.shape
    for mi in ("scan", "boruvka"):
        a = pixhomology(jnp.asarray(img), t, max_features=h * w,
                        max_candidates=h * w, merge_keys="packed",
                        merge_impl=mi)
        b = pixhomology(jnp.asarray(img), t, max_features=h * w,
                        max_candidates=h * w, merge_keys="rank",
                        merge_impl="scan")
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_paper_candidate_mode_packed_matches_rank():
    img = _image("float32", "gaussian", 23)
    a = run_path(img, "packed", candidate_mode="paper")
    b = run_path(img, "rank", candidate_mode="paper")
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# 3. Cross-path bit-identity matrix
# ---------------------------------------------------------------------------

_MATRIX_IMG = _image("float32", "gaussian", 42, shape=(16, 16))


def _reference_diagram():
    h, w = _MATRIX_IMG.shape
    return pixhomology(jnp.asarray(_MATRIX_IMG), max_features=h * w,
                       max_candidates=h * w, merge_keys="rank",
                       phase_a_impl="pooled")


def _assert_fields_equal(got, want, msg):
    for f in want._fields:
        if f == "overflow":
            continue
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f)),
                                      err_msg=f"{msg} field={f}")


@pytest.mark.parametrize("merge_keys", ["packed", "rank"])
@pytest.mark.parametrize("phase_a_impl", ["fused", "pooled"])
@pytest.mark.parametrize("path", ["whole", "batched", "sharded", "tiled"])
def test_cross_path_matrix(path, phase_a_impl, merge_keys):
    """No {path} x {phase A impl} x {key encoding} combination may ever
    diverge from the whole-image rank reference — bit-for-bit, including
    p_birth/p_death."""
    from repro.ph import PHConfig, PHEngine, TileSpec
    want = _reference_diagram()
    h, w = _MATRIX_IMG.shape
    n = h * w
    config = PHConfig(max_features=n, max_candidates=n,
                      merge_keys=merge_keys, phase_a_impl=phase_a_impl,
                      strip_rows=4, tile=TileSpec(grid=(2, 2)))
    engine = PHEngine(config)
    img = jnp.asarray(_MATRIX_IMG)

    if path == "whole":
        got = engine.run(_MATRIX_IMG).diagram
    elif path == "batched":
        res = engine.run_batch(_MATRIX_IMG[None]).diagram
        got = jax.tree.map(lambda x: x[0], res)
    elif path == "sharded":
        from repro.launch.mesh import make_small_context
        ctx = make_small_context(1, 1)
        plan = engine.sharded_plan(ctx, (1, h, w), jnp.dtype(jnp.float32),
                                   n, n)
        tvals = jnp.full((1,), -jnp.inf, jnp.float32)  # vanilla sentinel
        res = plan(img[None], tvals)
        got = jax.tree.map(lambda x: x[0], res)
    else:   # tiled
        got = engine.run_tiled(_MATRIX_IMG).diagram
    _assert_fields_equal(got, want,
                         f"{path}/{phase_a_impl}/{merge_keys}")
