"""Correctness of the PixHomology core vs the classical union-find oracle.

The paper validates against Ripser with bottleneck distance 0 (fig 7); we
assert *exact* equality (values AND pixel coordinates) against the oracle,
which is stronger, plus property-based sweeps with hypothesis.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    Diagram,
    batched_pixhomology,
    diagram_to_array,
    num_candidates,
    persistence_oracle,
    pixhomology,
)


def run_exact(img: np.ndarray, mode: str = "exact") -> np.ndarray:
    h, w = img.shape
    d = pixhomology(jnp.asarray(img), max_features=h * w,
                    max_candidates=h * w, candidate_mode=mode)
    assert not bool(d.overflow)
    return diagram_to_array(d)


# ---------------------------------------------------------------------------
# Exact equality with the oracle
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(1, 14), st.integers(1, 14), st.integers(0, 2 ** 31 - 1))
def test_matches_oracle_gaussian(h, w, seed):
    img = np.random.default_rng(seed).normal(size=(h, w)).astype(np.float32)
    got = run_exact(img)
    want = persistence_oracle(img)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 12), st.integers(2, 12), st.integers(0, 2 ** 31 - 1),
       st.integers(2, 4))
def test_matches_oracle_heavy_ties(h, w, seed, levels):
    """Tiny integer range => massive value ties; the paper's strict-max
    precondition is violated, the total order must still make both sides agree."""
    img = np.random.default_rng(seed).integers(
        0, levels, size=(h, w)).astype(np.float32)
    np.testing.assert_array_equal(run_exact(img), persistence_oracle(img))


def test_matches_oracle_integer_dtype():
    img = np.random.default_rng(3).integers(0, 50, size=(17, 9)).astype(np.int32)
    got = run_exact(img)
    np.testing.assert_array_equal(got, persistence_oracle(img))


def test_constant_image():
    img = np.zeros((6, 7), np.float32)
    got = run_exact(img)
    want = persistence_oracle(img)
    np.testing.assert_array_equal(got, want)
    assert got.shape[0] == 1  # single component, pure tie-break order


def test_single_pixel():
    img = np.array([[3.5]], np.float32)
    got = run_exact(img)
    assert got.shape == (1, 4)
    assert got[0, 0] == got[0, 1] == pytest.approx(3.5)


def test_monotone_ramp():
    img = np.arange(30, dtype=np.float32).reshape(5, 6)
    got = run_exact(img)
    assert got.shape[0] == 1
    np.testing.assert_array_equal(got, persistence_oracle(img))


def test_two_gaussian_blobs_known_saddle():
    """Two bumps joined by a col: the younger dies exactly at the col value."""
    yy, xx = np.mgrid[0:41, 0:81].astype(np.float32)
    img = (2.0 * np.exp(-((yy - 20) ** 2 + (xx - 20) ** 2) / 40.0)
           + 1.5 * np.exp(-((yy - 20) ** 2 + (xx - 60) ** 2) / 40.0))
    img += np.random.default_rng(0).normal(scale=1e-4, size=img.shape).astype(np.float32)
    got = run_exact(img)
    want = persistence_oracle(img)
    np.testing.assert_array_equal(got, want)
    # Row 0: essential class born at the global max; row 1: the smaller bump.
    assert got[0, 0] == pytest.approx(2.0, abs=0.05)
    assert got[1, 0] == pytest.approx(1.5, abs=0.05)
    assert got[1, 1] < got[1, 0]


# ---------------------------------------------------------------------------
# Paper-literal distillation: births exact, deaths may only move DOWN
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(3, 12), st.integers(3, 12), st.integers(0, 2 ** 31 - 1))
def test_paper_mode_births_exact_deaths_lower(h, w, seed):
    img = np.random.default_rng(seed).normal(size=(h, w)).astype(np.float32)
    got = run_exact(img, mode="paper")
    want = persistence_oracle(img)
    assert got.shape == want.shape
    np.testing.assert_array_equal(got[:, [0, 2]], want[:, [0, 2]])  # births
    # A missed saddle can only postpone a merge to a lower value.
    assert np.all(got[:, 1] <= want[:, 1] + 0)


# ---------------------------------------------------------------------------
# Batched / capacity / diagnostics behaviour
# ---------------------------------------------------------------------------

def test_batched_matches_single():
    rng = np.random.default_rng(7)
    imgs = rng.normal(size=(4, 10, 11)).astype(np.float32)
    batched = batched_pixhomology(jnp.asarray(imgs), max_features=128,
                                  max_candidates=128)
    for i in range(imgs.shape[0]):
        single = pixhomology(jnp.asarray(imgs[i]), max_features=128,
                             max_candidates=128)
        for a, b in zip(batched, single):
            np.testing.assert_array_equal(np.asarray(a[i]), np.asarray(b))


def test_feature_overflow_flag():
    img = np.random.default_rng(0).normal(size=(16, 16)).astype(np.float32)
    full = pixhomology(jnp.asarray(img), max_features=256, max_candidates=256)
    c = int(full.count)
    assert c > 4
    small = pixhomology(jnp.asarray(img), max_features=4, max_candidates=256)
    assert bool(small.overflow)
    assert int(small.count) == 4
    # The 4 retained rows are the highest-birth ones, in the same order.
    np.testing.assert_array_equal(np.asarray(small.birth),
                                  np.asarray(full.birth[:4]))


def test_candidate_overflow_flag():
    img = np.random.default_rng(1).normal(size=(16, 16)).astype(np.float32)
    k = int(num_candidates(jnp.asarray(img)))
    assert k > 2
    d = pixhomology(jnp.asarray(img), max_features=256, max_candidates=2)
    assert bool(d.overflow)


def test_diagram_is_sorted_and_padded():
    img = np.random.default_rng(2).normal(size=(12, 12)).astype(np.float32)
    d = pixhomology(jnp.asarray(img), max_features=512, max_candidates=512)
    c = int(d.count)
    b = np.asarray(d.birth)
    assert np.all(np.diff(b[:c]) <= 0)          # descending births
    assert np.all(b[c:] == -np.inf)             # padding
    assert np.all(np.asarray(d.p_birth)[c:] == -1)
    assert int(d.n_unmerged) == 0
    # All finite deaths lie strictly below their births (superlevel PD is
    # below the diagonal in (birth, death) with death < birth).
    dd = np.asarray(d.death)[:c]
    assert np.all(dd[1:] < b[1:c] + 1e-9)


def test_jit_cache_stable_across_shapes():
    # Different shapes are distinct jit traces; results stay correct.
    for shape in [(5, 9), (9, 5), (7, 7)]:
        img = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        np.testing.assert_array_equal(run_exact(img), persistence_oracle(img))


# ---------------------------------------------------------------------------
# Stage graph: fused and pooled phase A are interchangeable implementations
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 14), st.integers(1, 14), st.integers(0, 2 ** 31 - 1))
def test_pooled_stage_matches_oracle(h, w, seed):
    """The unfused baseline stage pipeline stays oracle-exact (the suite's
    other oracle tests run the fused default)."""
    img = np.random.default_rng(seed).normal(size=(h, w)).astype(np.float32)
    d = pixhomology(jnp.asarray(img), max_features=h * w,
                    max_candidates=h * w, phase_a_impl="pooled")
    np.testing.assert_array_equal(diagram_to_array(d),
                                  persistence_oracle(img))


def test_fused_stage_with_boruvka_and_truncation():
    """Stage choices compose: fused phase A x Boruvka merge x Variant-2
    truncation must all agree with the pooled/scan reference."""
    img = np.random.default_rng(11).normal(size=(14, 10)).astype(np.float32)
    for tv in (None, 0.2):
        want = pixhomology(jnp.asarray(img), tv, max_features=140,
                           max_candidates=140, phase_a_impl="pooled",
                           merge_impl="scan")
        got = pixhomology(jnp.asarray(img), tv, max_features=140,
                          max_candidates=140, phase_a_impl="fused",
                          strip_rows=4, merge_impl="boruvka")
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_num_candidates_agrees_across_stage_impls():
    img = jnp.asarray(np.random.default_rng(3).normal(
        size=(12, 12)).astype(np.float32))
    k_fused = int(num_candidates(img, phase_a_impl="fused", strip_rows=4))
    k_pooled = int(num_candidates(img, phase_a_impl="pooled"))
    assert k_fused == k_pooled > 0
    t = float(np.asarray(img).mean())
    assert int(num_candidates(img, truncate_value=t)) == \
        int(num_candidates(img, truncate_value=t, phase_a_impl="pooled"))
