"""Graceful fallback when ``hypothesis`` is not installed.

The property-based tests import ``given``/``settings``/``st`` from here.
With hypothesis available they get the real thing (full strategy sweeps,
shrinking).  On minimal installs they get a deterministic mini-runner that
draws a small, seeded sample from the same strategy specs — the suite still
collects and exercises every property, just with fewer examples.

Only the strategy combinators the suite actually uses are implemented:
``st.integers(lo, hi)`` and ``st.sampled_from(seq)``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    # Keep the fallback sweeps fast: many properties jit-compile per drawn
    # shape, so a handful of samples already covers the interesting space.
    FALLBACK_MAX_EXAMPLES = 8

    class _Strategy:
        def sample(self, rng):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom(_Strategy):
        def __init__(self, seq):
            self.seq = list(seq)

        def sample(self, rng):
            return self.seq[int(rng.integers(len(self.seq)))]

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(seq):
            return _SampledFrom(seq)

    st = _St()

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_compat_max_examples", 20),
                        FALLBACK_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    drawn = [s.sample(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # pytest resolves fixtures through __wrapped__'s signature;
            # the drawn parameters must not look like fixtures.
            del wrapper.__wrapped__
            return wrapper
        return deco
