"""Distribution layer: sharding rules, multi-device dry-run, MoE paths.

Multi-device coverage runs in subprocesses with
``--xla_force_host_platform_device_count=8`` so the main test process keeps
its single CPU device (per the brief: only the dry-run sees many devices).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, get_config, get_smoke_config
from repro.distributed import sharding
from repro.models.model import build_model


def _mesh_2x4_probe(code: str, timeout=420) -> str:
    env = dict(os.environ, PYTHONPATH="src", REPRO_DRYRUN_DEVICES="8")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env,
                         cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


# ---------------------------------------------------------------------------
# Rules (pure, no devices needed)
# ---------------------------------------------------------------------------

def _fake_mesh():
    import collections
    M = collections.namedtuple("M", ["shape"])
    return M(shape={"data": 16, "model": 16})


def test_param_specs_respect_divisibility():
    mesh = _fake_mesh()
    cfg = get_config("llama4_scout_17b_a16e")
    model = build_model(cfg)
    specs = sharding.param_specs(model.param_shapes(), mesh, cfg.name)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    shapes = jax.tree_util.tree_flatten_with_path(model.param_shapes())[0]
    n_sharded = 0
    for (kp, spec), (_, sds) in zip(flat, shapes):
        for dim, part in zip(sds.shape, tuple(spec) + (None,) * 10):
            if part is None:
                continue
            size = 16 if isinstance(part, str) else 256
            assert dim % size == 0, (kp, sds.shape, spec)
            n_sharded += 1
    assert n_sharded > 10


def test_moe_experts_on_model_axis():
    mesh = _fake_mesh()
    cfg = get_config("dbrx_132b")
    model = build_model(cfg)
    specs = sharding.param_specs(model.param_shapes(), mesh, cfg.name)
    blocks = specs["blocks"]
    assert tuple(blocks["moe"]["w_gate"])[:2] == (None, "model")   # (L, E,..)
    assert "data" in tuple(blocks["moe"]["w_gate"])                # ZeRO-3


def test_kv_cache_seq_sharded():
    mesh = _fake_mesh()
    cfg = get_config("mistral_nemo_12b")
    model = build_model(cfg)
    specs = model.input_specs(SHAPES["decode_32k"])
    cspec = sharding.cache_specs(specs["caches"], mesh)
    k_spec = tuple(jax.tree_util.tree_leaves(
        cspec, is_leaf=lambda x: isinstance(x, P))[0])
    # (L, B, S, KV, hd): S over model (flash-decoding), B over data.
    assert k_spec[2] == "model" or "model" in k_spec


# ---------------------------------------------------------------------------
# Multi-device execution (8 host devices, subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_moe_a2a_matches_single_device():
    """EP all_to_all path on a (2,4) mesh == single-device reference."""
    out = _mesh_2x4_probe("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_smoke_config
from repro.distributed.context import DistContext, single_device_ctx
from repro.models.model import build_model

# capacity_factor 8 => no token dropping, so the EP a2a path must agree
# with the single-device path up to f32 reduction order.  (At default
# capacity, *which* tokens are dropped legitimately depends on the dispatch
# grouping — Switch semantics — so only the no-drop case is bit-comparable.)
cfg = get_smoke_config("dbrx_132b").replace(capacity_factor=8.0)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {"inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}
batch["targets"] = batch["inputs"]
batch["mask"] = jnp.ones((8, 32), jnp.float32)

ctx1 = single_device_ctx()
with ctx1.mesh:
    l1, m1 = jax.jit(lambda p, b: model.loss_fn(p, b, ctx1))(params, batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx2 = DistContext(mesh=mesh, dp_axes=("data",), tp_axis="model")
with mesh:
    l2, m2 = jax.jit(lambda p, b: model.loss_fn(p, b, ctx2))(params, batch)
np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=1e-3)
print("MOE_MATCH", float(l1), float(l2))
""")
    assert "MOE_MATCH" in out


@pytest.mark.slow
def test_dryrun_cell_on_8_devices(tmp_path):
    """The dry-run entry point compiles a train cell on a reduced mesh."""
    env = dict(os.environ, PYTHONPATH="src", REPRO_DRYRUN_DEVICES="8")
    env.pop("XLA_FLAGS", None)
    out = tmp_path / "cell.json"
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "gemma_7b", "--shape", "train_4k", "--out", str(out)],
        capture_output=True, text=True, timeout=420, env=env,
        cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    import json
    rec = json.loads(out.read_text())
    assert rec["compile_ok"] and rec["roofline"]["compute_s"] > 0


@pytest.mark.slow
def test_train_step_sharded_loss_matches_single():
    """Full sharded train step on (2,4) == single-device step (same seed)."""
    out = _mesh_2x4_probe("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ShapeConfig, get_smoke_config
from repro.distributed.context import DistContext, single_device_ctx
from repro.launch import steps as steps_lib
from repro.models.model import build_model
from repro.optim.adamw import AdamW
from repro.data.tokens import TokenStream

cfg = get_smoke_config("phi3_mini_3_8b")
shape = ShapeConfig("t", 64, 8, "train")
stream = TokenStream(cfg.vocab_size, 64, 8)
batch = jax.tree.map(jnp.asarray, stream.batch_at(0))
model = build_model(cfg)
losses = {}
for name, ctx in [
    ("single", single_device_ctx()),
    ("mesh", DistContext(mesh=jax.make_mesh((2, 4), ("data", "model")),
                         dp_axes=("data",), tp_axis="model"))]:
    bundle = steps_lib.train_bundle(cfg, shape, ctx, AdamW())
    with ctx.mesh:
        params = jax.jit(model.init)(jax.random.PRNGKey(0))
        opt_state = AdamW().init(params)
        p2, o2, metrics = bundle.fn(params, opt_state, batch)
        losses[name] = float(metrics["loss"])
print("LOSSES", losses)
assert abs(losses["single"] - losses["mesh"]) < 2e-3 * max(1, abs(losses["single"]))
""")
    assert "LOSSES" in out
