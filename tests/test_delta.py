"""Delta recompute: content-hashed tile cache, O(changed-area) re-runs,
batch dedupe, and the serving cache tier.

The load-bearing property throughout: ``run_delta`` is **bit-identical**
to a cold ``run_tiled`` of the same frame for *every* dirty mask — 0%
(full hit), a single tile, everything, a transient straddling a seam, a
seam-elder flip, and randomized masks.  Plus: adversarial hash-collision
injection (verify mode detects and recomputes), cache idempotence under
pipeline retry/resume (no poisoning, no double-insert), ``run_batch``
content-hash dedupe, and the PHServer exact-hash tier.
"""
import dataclasses
import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.cache import CacheStats, DiagramCache, FrameCacheEntry, LRUCache
from repro.core import delta as dm
from repro.core.tiling import load_tile_stacks
from repro.data.astro import FrameSequence
from repro.ph import (DeltaSpec, FilterLevel, PHConfig, PHEngine, ServeSpec,
                      TileSpec)

GRID = (4, 4)
SIZE = 48          # 12x12 tiles — fast compiles, 16 tiles to classify


def _img(seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(SIZE, SIZE)).astype(np.float32)


def _engine(**kw):
    kw.setdefault("filter_level", FilterLevel.VANILLA)
    kw.setdefault("delta", DeltaSpec(cache_entries=64))
    kw.setdefault("tile", TileSpec(grid=GRID, max_features_per_tile=64,
                                   max_candidates_per_tile=64))
    return PHEngine(PHConfig(**kw))


@pytest.fixture(scope="module")
def engine():
    """Shared engine: one plan cache across the bit-identity matrix."""
    return _engine()


def _assert_same(a, b, msg=""):
    for field in a.diagram._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.diagram, field)),
            np.asarray(getattr(b.diagram, field)), err_msg=f"{msg}:{field}")


def _perturb(img, tiles, bump=5.0):
    """+bump at the center of each listed tile — strictly interior, so
    exactly those tiles' halo windows change."""
    out = img.copy()
    tr, tc = SIZE // GRID[0], SIZE // GRID[1]
    for t in tiles:
        r0, c0 = (t // GRID[1]) * tr, (t % GRID[1]) * tc
        out[r0 + tr // 2, c0 + tc // 2] += bump
    return out


# ---------------------------------------------------------------------------
# Cache stores (no jax)
# ---------------------------------------------------------------------------

def test_lru_cache_eviction_and_counters():
    c = LRUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1          # refreshes "a"
    c.put("c", 3)                   # evicts "b" (stalest)
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert c.stats.evictions == 1 and c.stats.misses == 1
    assert c.stats.hits == 3 and len(c) == 2


def test_lru_cache_rejects_bad_capacity():
    with pytest.raises(ValueError):
        LRUCache(0)
    with pytest.raises(ValueError):
        DiagramCache(0)


def _entry(digests, caps=(8, 4, 4), tile_bytes=None):
    return FrameCacheEntry(digests=tuple(digests), state="state",
                           result="result", capacities=caps,
                           tile_bytes=tile_bytes)


def test_diagram_cache_classifies_hit_partial_miss():
    c = DiagramCache(4)
    ctx = ("ctx",)
    c.put(ctx, _entry([b"a", b"b", b"c"]))
    kind, entry, mask = c.lookup(ctx, (b"a", b"b", b"c"), (8, 4, 4))
    assert kind == "hit" and entry.result == "result" and mask is None
    kind, entry, mask = c.lookup(ctx, (b"a", b"X", b"c"), (8, 4, 4))
    assert kind == "partial"
    np.testing.assert_array_equal(mask, [False, True, False])
    kind, entry, mask = c.lookup(ctx, (b"x", b"y", b"z"), (8, 4, 4))
    assert kind == "miss" and entry is None and mask is None
    # different context: never matched
    kind, _, _ = c.lookup(("other",), (b"a", b"b", b"c"), (8, 4, 4))
    assert kind == "miss"
    assert c.stats.hits == 1 and c.stats.partial_hits == 1
    assert c.stats.misses == 2


def test_diagram_cache_partial_requires_equal_capacities():
    c = DiagramCache(4)
    ctx = ("ctx",)
    c.put(ctx, _entry([b"a", b"b"], caps=(8, 4, 4)))
    kind, _, _ = c.lookup(ctx, (b"a", b"X"), (16, 8, 8))
    assert kind == "miss"           # state arrays are shape-static
    # ... but a full hit returns the finished result regardless
    kind, _, _ = c.lookup(ctx, (b"a", b"b"), (16, 8, 8))
    assert kind == "hit"


def test_diagram_cache_picks_best_candidate_and_evicts_lru():
    c = DiagramCache(2)
    ctx = ("ctx",)
    c.put(ctx, FrameCacheEntry((b"a", b"b", b"c"), "s1", "r1", (8, 4, 4)))
    c.put(ctx, FrameCacheEntry((b"a", b"X", b"Y"), "s2", "r2", (8, 4, 4)))
    kind, entry, mask = c.lookup(ctx, (b"a", b"b", b"Z"), (8, 4, 4))
    assert kind == "partial" and entry.result == "r1"   # 2 clean > 1 clean
    c.put(ctx, FrameCacheEntry((b"q", b"r", b"s"), "s3", "r3", (8, 4, 4)))
    assert len(c) == 2 and c.stats.evictions == 1
    # the partial hit refreshed r1, so the s2 entry was the one evicted
    kind, entry, _ = c.lookup(ctx, (b"a", b"b", b"c"), (8, 4, 4))
    assert kind == "hit" and entry.result == "r1"


def test_diagram_cache_put_replaces_in_place():
    c = DiagramCache(4)
    ctx = ("ctx",)
    c.put(ctx, _entry([b"a"]))
    c.put(ctx, FrameCacheEntry((b"a",), "state2", "result2", (8, 4, 4)))
    assert len(c) == 1 and c.stats.inserts == 2
    _, entry, _ = c.lookup(ctx, (b"a",), (8, 4, 4))
    assert entry.result == "result2"


def test_cache_stats_snapshot_roundtrips():
    s = CacheStats(hits=3, misses=1)
    assert s.snapshot() == {"hits": 3, "partial_hits": 0, "misses": 1,
                            "inserts": 0, "evictions": 0, "collisions": 0}


# ---------------------------------------------------------------------------
# Hashing / plumbing
# ---------------------------------------------------------------------------

def test_dirty_bucket_is_pow2_clamped():
    assert dm.dirty_bucket(1, 16) == 1
    assert dm.dirty_bucket(3, 16) == 4
    assert dm.dirty_bucket(9, 16) == 16
    assert dm.dirty_bucket(5, 6) == 6       # clamped to the tile count
    with pytest.raises(ValueError):
        dm.dirty_bucket(0, 16)


def test_frame_digests_host_and_staged_agree():
    img = _img(7)

    class Prov:
        shape = img.shape
        dtype = np.float32

        def halo_tile(self, t, grid, fill=-np.inf):
            gr, gc = grid
            tr, tc = img.shape[0] // gr, img.shape[1] // gc
            out = np.full((tr + 2, tc + 2), fill, np.float32)
            r0, c0 = (t // gc) * tr, (t % gc) * tc
            y0, y1 = max(0, r0 - 1), min(img.shape[0], r0 + tr + 1)
            x0, x1 = max(0, c0 - 1), min(img.shape[1], c0 + tc + 1)
            out[y0 - (r0 - 1):y1 - (r0 - 1),
                x0 - (c0 - 1):x1 - (c0 - 1)] = img[y0:y1, x0:x1]
            return out

    host, _ = dm.frame_digests(img, GRID)
    staged, _ = dm.frame_digests(load_tile_stacks(Prov(), GRID), GRID)
    assert host == staged


def test_halo_hashing_dirties_neighbors_of_border_changes():
    """A change ON a tile border enters the neighbors' halo windows, so
    they hash dirty too — no separate halo bookkeeping to get wrong."""
    img = _img(8)
    tr = SIZE // GRID[0]
    img2 = img.copy()
    img2[tr, tr] += 1.0       # top-left corner pixel of tile (1, 1)
    a, _ = dm.frame_digests(img, GRID)
    b, _ = dm.frame_digests(img2, GRID)
    dirty = sorted(np.flatnonzero([x != y for x, y in zip(a, b)]))
    # owner tile 5 plus the three tiles whose halos cover pixel (12, 12)
    assert dirty == [0, 1, 4, 5]


def test_hash_algos_all_work_and_unknown_raises():
    img = _img(9)
    for algo in dm.HASH_ALGOS:
        d, _ = dm.frame_digests(img, GRID, algo=algo)
        assert len(d) == GRID[0] * GRID[1]
    with pytest.raises(ValueError):
        dm.hasher("crc32")


# ---------------------------------------------------------------------------
# Bit-identity matrix: run_delta == cold run_tiled
# ---------------------------------------------------------------------------

def _seam_straddle(img):
    """One transient crossing the tile-row seam at SIZE // GRID[0]."""
    out = img.copy()
    s = SIZE // GRID[0]
    out[s - 2:s + 2, 30:34] += 5.0
    return out


def _seam_elder_flip(img):
    """Flip which side of a seam holds the elder (larger) maximum by
    perturbing one tile's interior only: the seam merge orientation must
    re-resolve from the cached clean state + one fresh tile."""
    out = img.copy()
    tr, tc = SIZE // GRID[0], SIZE // GRID[1]
    out[tr // 2, tc // 2] = float(np.abs(img).max()) + 10.0
    return out


DIRTY_CASES = [
    ("none", lambda im: im.copy()),
    ("single_tile", lambda im: _perturb(im, [5])),
    ("all_tiles", lambda im: _perturb(im, range(16))),
    ("seam_straddle", _seam_straddle),
    ("seam_elder_flip", _seam_elder_flip),
]


@pytest.mark.parametrize("name,mutate", DIRTY_CASES,
                         ids=[c[0] for c in DIRTY_CASES])
def test_delta_bit_identical_across_dirty_masks(engine, name, mutate):
    base = _img(1)
    frame = mutate(base)
    engine.run_delta(base)                      # prime the store
    got = engine.run_delta(frame)
    want = engine.run_tiled(frame)
    _assert_same(want, got, name)
    if name == "none":
        assert got.delta.hit == "full" and got.delta.n_dirty == 0
    else:
        assert got.delta.hit in ("partial", "miss")
        assert got.delta.n_dirty >= 1


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 16 - 1))
def test_delta_bit_identical_on_random_dirty_masks(bitmask):
    """Property: any dirty-tile subset reproduces the cold diagram."""
    eng = test_delta_bit_identical_on_random_dirty_masks._engine
    base = _img(2)
    tiles = [t for t in range(16) if bitmask >> t & 1]
    frame = _perturb(base, tiles, bump=3.0 + bitmask % 7)
    eng.run_delta(base)
    got = eng.run_delta(frame)
    want = eng.run_tiled(frame)
    _assert_same(want, got, f"mask={bitmask:04x}")
    if not tiles:
        assert got.delta.hit == "full"


test_delta_bit_identical_on_random_dirty_masks._engine = _engine()


def test_delta_threshold_is_part_of_the_context(engine):
    """Same bytes under a different Variant-2 threshold must not reuse
    state (the threshold filters inside phase B): full miss, never a
    wrong answer."""
    img = _img(3)
    a = engine.run_delta(img, truncate_value=0.0)
    b = engine.run_delta(img, truncate_value=0.5)
    assert a.delta.hit in ("miss", "partial")
    assert b.delta.hit == "miss"
    _assert_same(engine.run_tiled(img, 0.5), b, "tv=0.5")
    # and re-running at the first threshold is a full hit again
    assert engine.run_delta(img, truncate_value=0.0).delta.hit == "full"


def test_delta_accepts_staged_tiles(engine):
    img = _img(4)

    class Prov:
        shape = img.shape
        dtype = np.float32

        def halo_tile(self, t, grid, fill=-np.inf):
            gr, gc = grid
            tr, tc = img.shape[0] // gr, img.shape[1] // gc
            out = np.full((tr + 2, tc + 2), fill, np.float32)
            r0, c0 = (t // gc) * tr, (t % gc) * tc
            y0, y1 = max(0, r0 - 1), min(img.shape[0], r0 + tr + 1)
            x0, x1 = max(0, c0 - 1), min(img.shape[1], c0 + tc + 1)
            out[y0 - (r0 - 1):y1 - (r0 - 1),
                x0 - (c0 - 1):x1 - (c0 - 1)] = img[y0:y1, x0:x1]
            return out

    staged = load_tile_stacks(Prov(), GRID)
    want = engine.run_tiled(img)
    got = engine.run_delta(staged)
    _assert_same(want, got, "staged")
    # the host-array form of the same frame is a full hit on its entry
    assert engine.run_delta(img).delta.hit == "full"


def test_delta_disabled_falls_back_to_run_tiled():
    eng = _engine(delta=None)
    img = _img(5)
    res = eng.run_delta(img)
    assert res.delta.hit == "cold"
    _assert_same(eng.run_tiled(img), res, "disabled")
    eng2 = _engine(delta=DeltaSpec(enabled=False))
    assert eng2.run_delta(img).delta.hit == "cold"


def test_run_sequence_full_hits_after_first_pass(engine):
    frames = [_img(6), _perturb(_img(6), [3]), _img(6)]
    first = [r.delta.hit for r in engine.run_sequence(frames)]
    again = [r.delta.hit for r in engine.run_sequence(frames)]
    assert first[0] in ("miss", "partial", "full")
    assert again == ["full", "full", "full"]


# ---------------------------------------------------------------------------
# Adversarial: hash collisions
# ---------------------------------------------------------------------------

def test_verify_mode_detects_injected_hash_collision(monkeypatch):
    """All-frames-collide digests + verify mode: the byte-compare demotes
    colliding tiles to dirty, the diagram stays correct, and the
    collision counter records the catch."""
    eng = _engine(delta=DeltaSpec(cache_entries=8, verify=True))
    base = _img(10)
    frame = _perturb(base, [2, 7])

    real = dm.frame_digests

    def colliding(source, grid, *, algo="blake2b", with_bytes=False, **kw):
        digests, raw = real(source, grid, algo=algo, with_bytes=True, **kw)
        fake = tuple(b"\x00" * 16 for _ in digests)
        return fake, (raw if with_bytes else None)

    monkeypatch.setattr(dm, "frame_digests", colliding)
    eng.run_delta(base)
    got = eng.run_delta(frame)              # digests say "identical frame"
    monkeypatch.setattr(dm, "frame_digests", real)
    want = eng.run_tiled(frame)
    _assert_same(want, got, "collision")
    assert got.delta.hit == "partial" and got.delta.n_dirty >= 2
    assert eng.delta_cache_stats()["collisions"] >= 2


def test_without_verify_identical_digests_are_trusted(monkeypatch):
    """Control for the collision test: without verify mode the (forged)
    exact digest match returns the cached result — documenting exactly
    what ``DeltaSpec.verify`` buys."""
    eng = _engine(delta=DeltaSpec(cache_entries=8, verify=False))
    base = _img(11)
    real = dm.frame_digests

    def colliding(source, grid, *, algo="blake2b", with_bytes=False, **kw):
        digests, raw = real(source, grid, algo=algo, with_bytes=with_bytes,
                            **kw)
        return tuple(b"\x01" * 16 for _ in digests), raw

    monkeypatch.setattr(dm, "frame_digests", colliding)
    first = eng.run_delta(base)
    hit = eng.run_delta(_perturb(base, [2]))
    assert hit.delta.hit == "full"
    _assert_same(first, hit, "trusted")


# ---------------------------------------------------------------------------
# Resume / retry: the cache is idempotent under re-execution
# ---------------------------------------------------------------------------

def test_repeated_runs_replace_not_duplicate(engine):
    img = _img(12)
    engine.run_delta(img)
    before = len(engine._delta_cache._entries)
    engine.run_delta(img)                   # full hit: no insert at all
    engine.run_delta(_perturb(img, [1]))
    engine.run_delta(_perturb(img, [1]))    # full hit on the new entry
    assert len(engine._delta_cache._entries) == before + 1


def test_pipeline_retry_with_delta_does_not_poison_cache(tmp_path):
    """PR 3 failure-injection + work-log resume with delta enabled: the
    tiled rounds route through run_delta, a retried round re-runs the
    same frame (cache entry replaced in place, not duplicated), and the
    resumed results match a delta-free pipeline bit for bit."""
    from repro.pipeline.driver import FailureInjector

    def mk(delta):
        return PHEngine(PHConfig(
            max_features=4096, filter_level="filter_std", delta=delta,
            tile=TileSpec(grid=(2, 2), max_features_per_tile=1024,
                          max_candidates_per_tile=2048,
                          max_tile_pixels=32 * 32)))

    log = tmp_path / "delta.jsonl"
    eng = mk(DeltaSpec(cache_entries=8))
    res = eng.run_distributed([(0, 32), (2, 64)], work_log=log,
                              failure_injector=FailureInjector([0, 1]))
    assert res.failures == 2 and len(res.diagrams) == 2
    stats = eng.delta_cache_stats()
    assert len(eng._delta_cache._entries) <= stats["inserts"]
    assert len(eng._delta_cache._entries) == 1      # one oversized frame
    # bit-identical to the same pipeline without delta
    want = mk(None).run_distributed([(0, 32), (2, 64)])
    assert res.diagrams[2] == want.diagrams[2]
    assert res.diagrams[0] == want.diagrams[0]
    # resume from the log recomputes nothing and leaves the store alone
    eng2 = mk(DeltaSpec(cache_entries=8))
    res2 = eng2.run_distributed([(0, 32), (2, 64)], work_log=log)
    assert res2.diagrams[2] == res.diagrams[2]
    assert eng2.delta_cache_stats()["inserts"] == 0
    lines = [json.loads(l) for l in log.read_text().splitlines()]
    assert sorted(r["image_id"] for r in lines) == [0, 2]


def test_frame_sequence_with_injected_fault_keeps_cache_consistent():
    """A frame loader that dies mid-sequence: the failed frame inserts
    nothing, the retry computes it correctly, and later near-duplicates
    still hit the store."""
    eng = _engine()
    fs = FrameSequence(21, SIZE, grid=GRID, dirty_frac=0.1, stamp=3)
    boom = {"armed": True}

    def frames():
        yield fs.frame(0)
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("loader died")
        yield fs.frame(1)

    it = eng.run_sequence(frames())
    next(it)
    with pytest.raises(RuntimeError):
        next(it)
    inserts = eng.delta_cache_stats()["inserts"]
    out = list(eng.run_sequence(fs.frames(3)))      # retry from scratch
    assert out[0].delta.hit == "full"               # frame 0 survived
    assert out[1].delta.hit == "partial"
    assert eng.delta_cache_stats()["inserts"] > inserts
    want = eng.run_tiled(fs.frame(2))
    _assert_same(want, out[2], "post-fault")


# ---------------------------------------------------------------------------
# FrameSequence ground truth
# ---------------------------------------------------------------------------

def test_frame_sequence_dirty_tiles_match_hash_classification():
    fs = FrameSequence(3, SIZE, grid=GRID, dirty_frac=0.2, stamp=3)
    d0, _ = dm.frame_digests(fs.frame(0), GRID)
    for i in (1, 2, 3):
        di, _ = dm.frame_digests(fs.frame(i), GRID)
        dirty = np.flatnonzero([a != b for a, b in zip(d0, di)])
        np.testing.assert_array_equal(dirty, fs.dirty_tiles(i))
    assert fs.dirty_tiles(0).size == 0
    assert np.array_equal(fs.frame(2), FrameSequence(
        3, SIZE, grid=GRID, dirty_frac=0.2, stamp=3).frame(2))


def test_frame_sequence_validates_inputs():
    with pytest.raises(ValueError):
        FrameSequence(0, 50, grid=GRID)         # grid does not divide
    with pytest.raises(ValueError):
        FrameSequence(0, SIZE, grid=GRID, dirty_frac=1.5)
    with pytest.raises(ValueError):
        FrameSequence(0, 32, grid=(4, 4), stamp=15)   # tile < stamp+margin


# ---------------------------------------------------------------------------
# run_batch dedupe
# ---------------------------------------------------------------------------

def test_run_batch_dedupe_matches_full_compute():
    eng = PHEngine(PHConfig(filter_level=FilterLevel.VANILLA))
    a, b = _img(13), _img(14)
    batch = np.stack([a, b, a, a, b])
    got = eng.run_batch(batch)
    want = eng.run_batch(batch, dedupe=False)
    for field in got.diagram._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got.diagram,
                                                         field)),
                                      np.asarray(getattr(want.diagram,
                                                         field)), field)


def test_run_batch_dedupe_respects_thresholds():
    """Same bytes under different thresholds are different requests."""
    eng = PHEngine(PHConfig(filter_level=FilterLevel.VANILLA))
    a = _img(15)
    got = eng.run_batch([a, a, a], truncate_values=[0.0, 0.5, 0.0])
    want = eng.run_batch([a, a, a], truncate_values=[0.0, 0.5, 0.0],
                         dedupe=False)
    for field in got.diagram._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got.diagram, field)),
            np.asarray(getattr(want.diagram, field)), field)
    np.testing.assert_array_equal(np.asarray(got.threshold, np.float64),
                                  [0.0, 0.5, 0.0])


def test_run_batch_dedupe_shrinks_dispatch():
    """All-identical batch: one distinct image computes, B rows return."""
    eng = PHEngine(PHConfig(filter_level=FilterLevel.VANILLA))
    a = _img(16)
    res = eng.run_batch(np.stack([a] * 4))
    assert np.asarray(res.diagram.birth).shape[0] == 4
    single = eng.run(a)
    for i in range(4):
        np.testing.assert_array_equal(
            np.asarray(res.diagram.birth)[i],
            np.asarray(single.diagram.birth))


def test_run_batch_dedupe_mixed_shapes():
    eng = PHEngine(PHConfig(filter_level=FilterLevel.VANILLA))
    a, b = _img(17), _img(18)[:32, :32]
    got = eng.run_batch([a, b, a])
    want = eng.run_batch([a, b, a], dedupe=False)
    for field in got.diagram._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got.diagram, field)),
            np.asarray(getattr(want.diagram, field)), field)


# ---------------------------------------------------------------------------
# Serving cache tier
# ---------------------------------------------------------------------------

def _serve_engine(**kw):
    kw.setdefault("delta", DeltaSpec(cache_entries=16))
    return PHEngine(PHConfig(
        filter_level=FilterLevel.VANILLA,
        tile=TileSpec(grid=GRID, max_features_per_tile=64,
                      max_candidates_per_tile=64),
        serve=ServeSpec(buckets=((SIZE, SIZE),), batch_cap=4,
                        tick_interval_s=0.0), **kw))


def test_server_exact_hash_hit_bypasses_queue():
    from repro.serving import PHServer
    img = _img(19)
    with PHServer(_serve_engine()) as srv:
        first = srv.submit(img).result(120)
        fut = srv.submit(img)
        assert fut.done()               # resolved on the submit thread
        hit = fut.result(0)
        _assert_same(first, hit, "tier")
        snap = srv.stats()
        assert snap["cache"]["hits"] == 1 and snap["cache"]["misses"] == 1
        assert srv.metrics.cache_hits == 1


def test_server_near_duplicate_rides_delta_path():
    from repro.serving import PHServer
    img = _img(20)
    near = _perturb(img, [6])
    eng = _serve_engine()
    with PHServer(eng) as srv:
        srv.submit(img).result(120)
        res = srv.submit(near).result(120)
        assert res.delta is not None and res.delta.hit == "partial"
        assert res.delta.n_dirty < res.delta.n_tiles
        _assert_same(eng.run_tiled(near), res, "near-dup")
        assert srv.cache_stats()["delta_store"]["partial_hits"] >= 1


def test_server_without_delta_config_has_no_tier():
    from repro.serving import PHServer
    eng = PHEngine(PHConfig(
        filter_level=FilterLevel.VANILLA,
        serve=ServeSpec(buckets=((SIZE, SIZE),), batch_cap=4,
                        tick_interval_s=0.0)))
    with PHServer(eng) as srv:
        res = srv.submit(_img(22)).result(120)
        assert res.delta is None
        snap = srv.stats()
        assert snap["cache"]["enabled"] is False
        assert snap["cache"]["hits"] == 0


# ---------------------------------------------------------------------------
# Config plumbing
# ---------------------------------------------------------------------------

def test_delta_spec_validation_and_plan_key():
    with pytest.raises(ValueError):
        DeltaSpec(cache_entries=0)
    with pytest.raises(ValueError):
        DeltaSpec(hash_algo="crc32")
    base = PHConfig()
    on = PHConfig(delta=DeltaSpec())
    assert base.plan_key() != on.plan_key()
    # cache_entries / hash_algo / verify are host knobs: same plans
    assert PHConfig(delta=DeltaSpec(cache_entries=2)).plan_key() == \
        on.plan_key()
    assert PHConfig(delta=DeltaSpec(hash_algo="sha1")).plan_key() == \
        on.plan_key()
    assert PHConfig(delta=DeltaSpec(verify=True)).plan_key() == \
        on.plan_key()
    # dict coercion mirrors the other spec fields
    assert PHConfig(delta={"cache_entries": 3}).delta.cache_entries == 3


def test_delta_stats_dirty_frac():
    s = dm.DeltaStats(16, 2, "partial")
    assert s.dirty_frac == 2 / 16
    assert dm.DeltaStats(0, 0, "full").dirty_frac == 0.0
