"""Boruvka parallel merge == sequential scan == classical oracle (bit-exact).

The parallel merge is the main beyond-paper optimization (O(log C) rounds
vs O(K) sequential scan steps); it must be indistinguishable in output.
"""
import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import diagram_to_array, persistence_oracle, pixhomology


def run(img, impl, t=None):
    h, w = img.shape
    d = pixhomology(jnp.asarray(img), t, max_features=h * w,
                    max_candidates=h * w, merge_impl=impl)
    return diagram_to_array(d), d


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 16), st.integers(2, 16), st.integers(0, 2 ** 31 - 1))
def test_boruvka_matches_oracle_gaussian(h, w, seed):
    img = np.random.default_rng(seed).normal(size=(h, w)).astype(np.float32)
    got, _ = run(img, "boruvka")
    np.testing.assert_array_equal(got, persistence_oracle(img))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(2, 12), st.integers(0, 2 ** 31 - 1),
       st.integers(2, 4))
def test_boruvka_matches_with_ties(h, w, seed, levels):
    img = np.random.default_rng(seed).integers(
        0, levels, size=(h, w)).astype(np.float32)
    got, _ = run(img, "boruvka")
    np.testing.assert_array_equal(got, persistence_oracle(img))


def test_boruvka_equals_scan_on_astro_with_truncation():
    from repro.data import astro
    img = astro.generate_image(9, 128)
    t, _ = astro.filter_threshold(img, "filter_std")
    a, da = run(img, "scan", t)
    b, db = run(img, "boruvka", t)
    np.testing.assert_array_equal(a, b)
    assert int(da.count) == int(db.count)


def test_boruvka_batched():
    from repro.core import batched_pixhomology
    rng = np.random.default_rng(3)
    imgs = jnp.asarray(rng.normal(size=(3, 12, 13)).astype(np.float32))
    d = batched_pixhomology(imgs, max_features=256, max_candidates=256,
                            merge_impl="boruvka")
    for i in range(3):
        want = persistence_oracle(np.asarray(imgs[i]))
        c = int(d.count[i])
        got = np.stack([np.asarray(d.birth[i][:c], np.float64),
                        np.asarray(d.death[i][:c], np.float64),
                        np.asarray(d.p_birth[i][:c], np.float64),
                        np.asarray(d.p_death[i][:c], np.float64)], 1)
        np.testing.assert_array_equal(got, want)
