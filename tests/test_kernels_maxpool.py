"""Pallas maxpool kernel vs pure-jnp oracle: shape/dtype sweeps, interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.maxpool import kernel, ref

SHAPES = [(1, 1), (1, 7), (7, 1), (3, 3), (8, 8), (5, 130), (17, 129),
          (32, 32), (33, 257), (64, 64)]
DTYPES = [np.float32, np.int32, jnp.bfloat16]


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if dtype == np.int32:
        return rng.integers(-1000, 1000, size=shape).astype(np.int32)
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_maxargmax_matches_ref(shape, dtype):
    x = jnp.asarray(_rand(shape, dtype, hash(shape) % 1000))
    kv, ka = kernel.maxargmaxpool3x3(x, interpret=True)
    rv, ra = ref.maxargmaxpool3x3(x)
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(ka), np.asarray(ra))


@pytest.mark.parametrize("shape", SHAPES)
def test_min_max_pool_match_ref(shape):
    x = jnp.asarray(_rand(shape, np.float32, 0))
    np.testing.assert_array_equal(
        np.asarray(kernel.maxpool3x3(x, interpret=True)),
        np.asarray(ref.maxpool3x3(x)))
    np.testing.assert_array_equal(
        np.asarray(kernel.minpool3x3(x, interpret=True)),
        np.asarray(ref.minpool3x3(x)))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 24), st.integers(1, 24), st.integers(0, 2 ** 31 - 1),
       st.sampled_from([1, 2, 4, 8, 16]))
def test_property_ties_and_blocks(h, w, seed, block_rows):
    """Heavy ties + arbitrary block sizes: tie-break must equal the total order."""
    x = jnp.asarray(np.random.default_rng(seed).integers(
        0, 3, size=(h, w)).astype(np.float32))
    kv, ka = kernel.maxargmaxpool3x3(x, interpret=True, block_rows=block_rows)
    rv, ra = ref.maxargmaxpool3x3(x)
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(ka), np.asarray(ra))


def test_pixhomology_with_pallas_pools_matches():
    """End-to-end: core algorithm using the Pallas kernel (interpret) == oracle."""
    from repro.core import diagram_to_array, persistence_oracle, pixhomology
    img = np.random.default_rng(11).normal(size=(24, 18)).astype(np.float32)
    d = pixhomology(jnp.asarray(img), max_features=512, max_candidates=512,
                    use_pallas=True, interpret=True)
    np.testing.assert_array_equal(diagram_to_array(d),
                                  persistence_oracle(img))
