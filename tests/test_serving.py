"""PH-as-a-service: daemon lifecycle, admission, drain, faults, metrics.

One warmed module-scoped engine backs most tests (compiles are the cost
here); per-test PHServers override only host-side knobs (max_queue /
tick / admission), which never enter plan_key, so the warmed plans are
reused throughout.
"""
import threading
import time

import numpy as np
import pytest

from repro.ph import PHConfig, PHEngine, FilterLevel, ServeSpec
from repro.pipeline.scheduler import assign_bucket
from repro.serving import (
    AdmissionError,
    PHServer,
    Reservoir,
    ServeMetrics,
    bucket_label,
)

BUCKETS = ((8, 8), (16, 16))
CAP = 3
SPEC = ServeSpec(buckets=BUCKETS, batch_cap=CAP, tick_interval_s=0.001)


def _bumpy(seed=0, shape=(8, 8)):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


def _mixed_images(seed=0, n=6):
    shapes = [(6, 5), (8, 8), (12, 10), (16, 16), (5, 9), (9, 14)]
    rng = np.random.default_rng(seed)
    return [rng.normal(size=shapes[i % len(shapes)]).astype(np.float32)
            for i in range(n)]


def _assert_diagrams_equal(d, ref):
    """Valid rows bit-identical (capacity padding may differ)."""
    c = int(d.count)
    assert c == int(ref.count)
    assert int(d.n_unmerged) == int(ref.n_unmerged)
    assert bool(np.any(np.asarray(d.overflow))) == \
        bool(np.any(np.asarray(ref.overflow)))
    for a, b in ((d.birth, ref.birth), (d.death, ref.death),
                 (d.p_birth, ref.p_birth), (d.p_death, ref.p_death)):
        assert np.array_equal(np.asarray(a)[:c], np.asarray(b)[:c])


@pytest.fixture(scope="module")
def engine():
    eng = PHEngine(PHConfig(serve=SPEC))
    info = eng.warmup()
    assert info["plans"] == info["traces"] == 2 * len(BUCKETS)
    return eng


# ---------------------------------------------------------------------------
# Lifecycle: submit -> coalesce -> compute -> future resolution
# ---------------------------------------------------------------------------

def test_submit_to_future_bit_identity(engine):
    imgs = _mixed_images(seed=1, n=8)
    with PHServer(engine) as srv:
        futs = [srv.submit(im) for im in imgs]
        results = [f.result(timeout=120) for f in futs]
    # Reference on a *separate* engine so this test leaves the shared
    # plan cache untouched for the zero-trace test.
    ref_eng = PHEngine(PHConfig())
    for im, res in zip(imgs, results):
        ref = ref_eng.run(im, truncate_value=res.threshold)
        _assert_diagrams_equal(res.diagram, ref.diagram)


def test_warmed_server_zero_steady_state_traces(engine):
    with PHServer(engine) as srv:
        srv.warmup()        # plans cached -> instant; snapshots traces
        assert srv.steady_state_traces() == 0
        futs = [srv.submit(im) for im in _mixed_images(seed=2, n=12)]
        for f in futs:
            f.result(timeout=120)
        assert srv.steady_state_traces() == 0
        st = srv.stats()
    assert st["completed"] == 12
    assert st["failed"] == st["rejected"] == 0
    for b in st["buckets"].values():
        if b["batches"]:
            assert 0 < b["occupancy"] <= 1
            assert b["e2e_s"]["p50"] <= b["e2e_s"]["p99"]


def test_unstarted_server_queues_then_dispatches(engine):
    srv = PHServer(engine, start=False)
    futs = [srv.submit(_bumpy(i)) for i in range(4)]
    time.sleep(0.05)
    assert not any(f.done() for f in futs)
    srv.start()
    assert all(f.result(timeout=120).diagram.count >= 0 for f in futs)
    srv.shutdown()


# ---------------------------------------------------------------------------
# Admission control and backpressure
# ---------------------------------------------------------------------------

def test_backpressure_reject_at_full_queue(engine):
    srv = PHServer(engine, start=False,
                   spec=SPEC.replace(max_queue=2))
    f1, f2 = srv.submit(_bumpy(0)), srv.submit(_bumpy(1))
    with pytest.raises(AdmissionError) as ei:
        srv.submit(_bumpy(2))
    assert ei.value.retry_after_s > 0
    srv.start()     # accepted requests still resolve
    assert f1.result(timeout=120) and f2.result(timeout=120)
    st = srv.stats()
    srv.shutdown()
    assert st["rejected"] == 1
    assert st["buckets"][bucket_label(BUCKETS[0])]["rejected"] == 1
    assert st["completed"] == 2


def test_backpressure_block_until_space(engine):
    srv = PHServer(engine, start=False,
                   spec=SPEC.replace(max_queue=1, admission="block"))
    f1 = srv.submit(_bumpy(0))
    unblocked = []

    def blocked_submit():
        unblocked.append(srv.submit(_bumpy(1)))

    t = threading.Thread(target=blocked_submit, daemon=True)
    t.start()
    time.sleep(0.1)
    assert t.is_alive() and not unblocked     # parked at admission
    srv.start()                               # tick frees the slot
    t.join(timeout=120)
    assert not t.is_alive()
    assert f1.result(timeout=120) and unblocked[0].result(timeout=120)
    srv.shutdown()


def test_blocked_submitter_released_by_shutdown(engine):
    srv = PHServer(engine, start=False,
                   spec=SPEC.replace(max_queue=1, admission="block"))
    srv.submit(_bumpy(0))
    errs = []

    def blocked_submit():
        try:
            srv.submit(_bumpy(1))
        except RuntimeError as e:
            errs.append(e)

    t = threading.Thread(target=blocked_submit, daemon=True)
    t.start()
    time.sleep(0.05)
    srv.shutdown(drain=False)
    t.join(timeout=10)
    assert len(errs) == 1 and not isinstance(errs[0], AdmissionError)


def test_submit_validation(engine):
    with PHServer(engine, start=False) as srv:
        with pytest.raises(ValueError):
            srv.submit(np.zeros((2, 3, 4), np.float32))   # not 2D
        with pytest.raises(ValueError):
            srv.submit(np.zeros((17, 17), np.float32))    # over top bucket
    with pytest.raises(RuntimeError):
        srv.submit(_bumpy())                              # shut down
    with pytest.raises(RuntimeError):
        srv.start()                                       # cannot restart


# ---------------------------------------------------------------------------
# Graceful drain and shutdown
# ---------------------------------------------------------------------------

def test_graceful_drain_delivers_all_inflight(engine):
    srv = PHServer(engine, start=False)
    futs = [srv.submit(im) for im in _mixed_images(seed=3, n=7)]
    srv.start()
    srv.shutdown(drain=True)        # stops admission, finishes the queue
    assert all(f.done() for f in futs)
    assert all(f.exception() is None for f in futs)


def test_shutdown_without_drain_fails_pending(engine):
    srv = PHServer(engine, start=False)
    futs = [srv.submit(_bumpy(i)) for i in range(3)]
    srv.shutdown(drain=False)
    for f in futs:
        with pytest.raises(RuntimeError):
            f.result(timeout=5)


# ---------------------------------------------------------------------------
# Fault injection: one round's failure stays in that round
# ---------------------------------------------------------------------------

def test_fault_injected_round_isolated(engine, monkeypatch):
    # The tick thread dispatches through run_batch_async (run_batch is
    # its resolve-immediately wrapper), so inject the failure there.
    real = engine.run_batch_async
    fails = {"left": 1}

    def flaky(*a, **kw):
        if fails["left"]:
            fails["left"] -= 1
            raise RuntimeError("injected dispatch failure")
        return real(*a, **kw)

    monkeypatch.setattr(engine, "run_batch_async", flaky)
    srv = PHServer(engine, start=False)
    # 2*CAP same-bucket requests -> exactly two dispatch rounds, FIFO.
    futs = [srv.submit(_bumpy(i)) for i in range(2 * CAP)]
    srv.start()
    assert srv.drain(120)
    for f in futs[:CAP]:        # first round: the injected failure
        with pytest.raises(RuntimeError, match="injected"):
            f.result(timeout=5)
    for f in futs[CAP:]:        # second round: unharmed
        assert f.result(timeout=120).diagram.count >= 0
    # the daemon survives: a fresh submit still resolves
    assert srv.submit(_bumpy(99)).result(timeout=120)
    st = srv.stats()
    srv.shutdown()
    assert st["failed"] == CAP
    assert st["completed"] == CAP + 1


# ---------------------------------------------------------------------------
# Thread-safe shared engine (satellite: plan-cache lock)
# ---------------------------------------------------------------------------

def test_engine_hammered_from_threads_traces_once():
    eng = PHEngine(PHConfig())
    img = np.stack([_bumpy(0), _bumpy(1)])
    barrier = threading.Barrier(8)
    errs = []

    def hammer():
        try:
            barrier.wait(timeout=30)
            eng.run_batch(img)
        except Exception as e:      # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errs
    st = eng.plan_stats()
    # 8 racing cache misses -> one plan, traced exactly once.
    assert st["plans"] == 1 and st["traces"] == 1 and st["calls"] == 8


# ---------------------------------------------------------------------------
# Mixed-shape run_batch (satellite: bucketed padding bit-identity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("level", [FilterLevel.VANILLA, FilterLevel.STD])
def test_run_batch_mixed_shapes_bit_identical(level):
    eng = PHEngine(PHConfig(filter_level=level))
    imgs = [_bumpy(0, (6, 5)), _bumpy(1, (8, 8)), _bumpy(2, (5, 9))]
    out = eng.run_batch(imgs)
    thr = np.asarray(out.threshold)
    for i, im in enumerate(imgs):
        row = type(out.diagram)(
            *(np.asarray(f)[i] for f in out.diagram))
        tv = None if not np.isfinite(thr[i]) else float(thr[i])
        _assert_diagrams_equal(row, eng.run(im, truncate_value=tv).diagram)


def test_run_batch_bucket_forces_padded_dispatch():
    eng = PHEngine(PHConfig())
    imgs = [_bumpy(0, (6, 6)), _bumpy(1, (6, 6))]
    out = eng.run_batch(imgs, bucket=(8, 8))
    ref = eng.run_batch(np.stack(imgs))
    for i in range(2):
        row = type(out.diagram)(*(np.asarray(f)[i] for f in out.diagram))
        refr = type(ref.diagram)(*(np.asarray(f)[i] for f in ref.diagram))
        _assert_diagrams_equal(row, refr)


# ---------------------------------------------------------------------------
# ServeSpec config plumbing
# ---------------------------------------------------------------------------

def test_serve_spec_validation():
    assert ServeSpec(buckets=(32, (8, 16))).buckets == ((8, 16), (32, 32))
    with pytest.raises(ValueError):
        ServeSpec(buckets=(16, (16, 16)))       # duplicate after squaring
    with pytest.raises(ValueError):
        ServeSpec(batch_cap=0)
    with pytest.raises(ValueError):
        ServeSpec(max_queue=0)
    with pytest.raises(ValueError):
        ServeSpec(tick_interval_s=-1.0)
    with pytest.raises(ValueError):
        ServeSpec(admission="maybe")


def test_serve_config_roundtrip_and_plan_key():
    cfg = PHConfig(serve=ServeSpec(buckets=(16, 32), batch_cap=2))
    again = PHConfig.from_json(cfg.to_json())
    assert again == cfg and again.plan_key() == cfg.plan_key()
    # host-side knobs stay out of plan_key; shape knobs go in
    assert cfg.plan_key() == PHConfig(serve=ServeSpec(
        buckets=(16, 32), batch_cap=2, max_queue=7,
        admission="block")).plan_key()
    assert cfg.plan_key() != PHConfig(serve=ServeSpec(
        buckets=(16, 32), batch_cap=3)).plan_key()
    assert PHConfig().plan_key()[-1] is None


def test_serve_from_flags():
    from types import SimpleNamespace
    cfg = PHConfig.from_flags(SimpleNamespace(
        serve=True, serve_buckets=["16", "32x48"], serve_batch_cap=8,
        serve_tick_ms=5.0, serve_admission="block", serve_max_queue=9))
    assert cfg.serve.buckets == ((16, 16), (32, 48))
    assert cfg.serve.batch_cap == 8 and cfg.serve.max_queue == 9
    assert abs(cfg.serve.tick_interval_s - 0.005) < 1e-12
    assert cfg.serve.admission == "block"
    assert PHConfig.from_flags(SimpleNamespace()).serve is None


def test_assign_bucket():
    bs = ((16, 16), (32, 32))
    assert assign_bucket((5, 5), bs) == (16, 16)      # tightest fit
    assert assign_bucket((16, 16), bs) == (16, 16)    # exact fit
    assert assign_bucket((17, 4), bs) == (32, 32)
    assert assign_bucket((33, 1), bs) is None         # over the top
    assert assign_bucket((40, 40), None) == (64, 64)  # dynamic pow2


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_reservoir_window_and_percentiles():
    r = Reservoir(4)
    # empty reservoirs summarize as zeros (scrapers need stable fields)
    assert r.summary() == {"count": 0, "mean": 0.0, "max": 0.0,
                           "p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert r.percentile(50) == 0.0
    for v in range(1, 11):
        r.add(float(v))
    assert len(r) == 10
    s = r.summary()
    assert s["count"] == 10 and s["max"] == 10.0
    # only the ring window (last 4 values: 7..10) backs percentiles
    assert 7.0 <= s["p50"] <= 10.0 and r.percentile(0) == 7.0
    with pytest.raises(ValueError):
        Reservoir(0)


def test_serve_metrics_snapshot():
    m = ServeMetrics(batch_cap=4)
    b = (16, 16)
    m.record_submit(b)
    m.record_submit(b)
    m.record_batch(b, queue_waits=[0.1, 0.2], e2e=[0.3, 0.4], batch_s=0.2)
    m.record_reject(b)
    snap = m.snapshot()
    assert snap["submitted"] == 2 and snap["completed"] == 2
    assert snap["rejected"] == 1
    bs = snap["buckets"]["16x16"]
    assert bs["occupancy"] == 0.5       # 2 rows of a 4-cap batch
    assert bs["e2e_s"]["count"] == 2 and bs["rejected"] == 1
    assert bucket_label((8, 128)) == "8x128"
