"""End-to-end distributed PixHomology pipeline (the paper's production job).

    PYTHONPATH=src python examples/distributed_ph.py

Runs the full Spark-equivalent pipeline on the local device pool through
the ``repro.ph`` facade:
  * Variant 1 (load_self): executors generate/load their own images,
  * Variant 2 (filter_std): per-image threshold, background excluded,
  * Variant 3 (part_LPT): cost-estimated LPT scheduling,
  * fault tolerance: an injected executor failure + work-log recovery,
  * output: per-image persistence summaries (object counts, top births).

On a real pod the same engine runs over ``make_context()`` (256/512 chips);
here it uses whatever devices exist.
"""
import json

from repro.pipeline.driver import FailureInjector
from repro.ph import FilterLevel, PHConfig, PHEngine


def main():
    config = PHConfig(max_features=8192, max_candidates=32768,
                      filter_level=FilterLevel.STD)
    engine = PHEngine(config)

    result = engine.run_distributed(
        list(range(12)), image_size=256, strategy="part_LPT",
        work_log="/tmp/ph_worklog.jsonl",
        failure_injector=FailureInjector([2]),   # round 2 dies once
        verbose=True)

    print(f"\ncompleted {len(result.diagrams)} images in {result.rounds} "
          f"rounds, recovered from {result.failures} failure(s), "
          f"{result.elapsed_s:.1f}s")
    print(f"plan cache: {engine.plan_stats()}")
    sample = result.diagrams[0]
    print("image 0 summary:", json.dumps(sample, indent=1)[:400])


if __name__ == "__main__":
    main()
