"""PH-as-a-service client snippet: submit, await futures, read SLOs.

    PYTHONPATH=src python examples/serve_ph.py

Boots an in-process :class:`repro.serving.PHServer` over one warmed
engine, submits a burst of mixed-shape star fields from a few client
threads (what the daemon is for — request-at-a-time traffic, not a
prepared batch), and prints per-bucket latency percentiles. Each future
resolves to exactly what ``engine.run(image)`` would return.

For the CLI twin see ``python -m repro.launch.ph_serve``; for the gated
benchmark see ``benchmarks/serve_bench.py``.
"""
import threading

import numpy as np

from repro.ph import PHConfig, PHEngine, ServeSpec
from repro.serving import AdmissionError, PHServer


def main():
    config = PHConfig(serve=ServeSpec(buckets=(64, 128), batch_cap=4,
                                      max_queue=32, admission="reject"))
    engine = PHEngine(config)

    with PHServer(engine) as server:
        info = server.warmup()     # pre-trace the warm plan pool
        print(f"warmup: {info['plans']} plans in {info['seconds']:.1f}s")

        from repro.data import astro
        rng = np.random.default_rng(0)
        done = []
        lock = threading.Lock()

        def client(cid, n=8):
            for i in range(n):
                size = int(rng.integers(40, 129))
                img = astro.generate_image(image_id=cid * 100 + i,
                                           size=size)
                try:
                    fut = server.submit(img)
                except AdmissionError as e:     # backpressure engaged
                    print(f"client {cid}: rejected, retry in "
                          f"{e.retry_after_s:.3f}s")
                    continue
                res = fut.result(timeout=120)   # a full PHResult
                with lock:
                    done.append(int(res.diagram.count))

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        stats = server.stats()
        print(f"\nresolved {len(done)} requests "
              f"(total objects: {sum(done)}); "
              f"steady-state traces: {stats['steady_state_traces']}")
        for label, b in stats["buckets"].items():
            e2e = b["e2e_s"]
            if not e2e.get("count"):
                continue
            print(f"  bucket {label}: occupancy {b['occupancy']:.2f}, "
                  f"e2e p50 {e2e['p50'] * 1e3:.1f}ms "
                  f"p95 {e2e['p95'] * 1e3:.1f}ms "
                  f"p99 {e2e['p99'] * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
