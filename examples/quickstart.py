"""Quickstart: 0-dim persistent homology of one astronomical image.

    PYTHONPATH=src python examples/quickstart.py

Generates a synthetic star field (paper §6.2 recipe), computes its
persistence diagram through the ``repro.ph`` facade — deliberately starting
from undersized capacities so the engine's overflow auto-regrow kicks in —
validates the result against the classical union-find oracle, and prints
the most persistent objects.
"""
import numpy as np

from repro.core import persistence_oracle
from repro.ph import PHConfig, PHEngine


def main():
    from repro.data import astro
    img = astro.generate_image(image_id=42, size=256)
    print(f"image: {img.shape}, sky≈{np.median(img):.1f}, "
          f"max={img.max():.1f}")

    # Undersized on purpose: the engine re-dispatches at doubled capacities
    # until the diagram fits (see src/repro/ph/README.md for the policy).
    engine = PHEngine(PHConfig(max_features=512, max_candidates=1024))
    result = engine.run(img)
    n = int(result.diagram.count)
    print(f"\nPixHomology found {n} components "
          f"(regrow attempts={result.regrow.attempts}, final capacities="
          f"{result.config.max_features}/{result.config.max_candidates})")

    rows = result.to_array()
    print("\ntop-10 by birth (birth, death, persistence, y, x):")
    w = img.shape[1]
    for b, d, pb, pd in rows[:10]:
        print(f"  birth={b:9.2f} death={d:9.2f} pers={b - d:9.2f} "
              f"at ({int(pb) // w:4d},{int(pb) % w:4d})")

    # Repeated same-shape calls reuse the compiled plan (no re-trace).
    engine.run(astro.generate_image(image_id=43, size=256))
    print(f"\nplan cache: {engine.plan_stats()}")

    # Validate against the classical algorithm — exact equality, which is
    # stronger than the paper's bottleneck-distance-0 check (fig 7).
    want = persistence_oracle(img)
    assert rows.shape == want.shape and np.array_equal(rows, want)
    print(f"\nvalidated: {rows.shape[0]} diagram rows match the classical "
          "union-find oracle exactly (bottleneck distance 0).")


if __name__ == "__main__":
    main()
