"""Quickstart: 0-dim persistent homology of one astronomical image.

    PYTHONPATH=src python examples/quickstart.py

Generates a synthetic star field (paper §6.2 recipe), computes its
persistence diagram with PixHomology (Algorithm 1), validates it against
the classical union-find oracle, and prints the most persistent objects.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import diagram_to_array, persistence_oracle, pixhomology
from repro.data import astro


def main():
    img = astro.generate_image(image_id=42, size=256)
    print(f"image: {img.shape}, sky≈{np.median(img):.1f}, "
          f"max={img.max():.1f}")

    diag = pixhomology(jnp.asarray(img), max_features=8192,
                       max_candidates=32768)
    n = int(diag.count)
    print(f"\nPixHomology found {n} components "
          f"(overflow={bool(diag.overflow)})")

    rows = diagram_to_array(diag)
    print("\ntop-10 by birth (birth, death, persistence, y, x):")
    w = img.shape[1]
    for b, d, pb, pd in rows[:10]:
        print(f"  birth={b:9.2f} death={d:9.2f} pers={b - d:9.2f} "
              f"at ({int(pb) // w:4d},{int(pb) % w:4d})")

    # Validate against the classical algorithm — exact equality, which is
    # stronger than the paper's bottleneck-distance-0 check (fig 7).
    want = persistence_oracle(img)
    assert rows.shape == want.shape and np.array_equal(rows, want)
    print(f"\nvalidated: {rows.shape[0]} diagram rows match the classical "
          "union-find oracle exactly (bottleneck distance 0).")


if __name__ == "__main__":
    main()
