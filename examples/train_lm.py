"""Train a small LM end-to-end with the full framework stack.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--d-model 512]

Uses the same train_bundle / sharding / checkpointing path as the production
configs — only the size differs (CPU container).  Defaults give a ~20M-param
qwen-style model; ``--d-model 1024 --layers 12`` reaches ~100M params for a
longer run on bigger hosts.
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    overrides = {
        "num_layers": args.layers,
        "d_model": args.d_model,
        "num_heads": max(4, args.d_model // 64),
        "num_kv_heads": max(4, args.d_model // 64),
        "head_dim": 64,
        "d_ff": args.d_model * 3,
        "vocab_size": 8192,
        "dtype": "float32",
    }
    history = train(
        "qwen1_5_0_5b", steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, smoke=True, overrides=overrides,
        lr=1e-3, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(50, args.steps // 4))
    first, last = history[0], history[-1]
    print(f"\nloss: {first['loss']:.3f} -> {last['loss']:.3f} over "
          f"{args.steps} steps ({last['tokens_per_s']:.0f} tok/s); "
          f"checkpoints in {args.ckpt_dir} (kill and rerun to resume)")
    assert last["loss"] < first["loss"], "loss must decrease"


if __name__ == "__main__":
    main()
