"""Tile-decomposed PixHomology: halo-tiled PH with a cross-tile seam merge.

The paper (§5.2) distributes *whole images* across executors, so the largest
analyzable image is bounded by one worker's memory.  Following the spatial
decompositions of Bauer-Kerber-Reininghaus (DIPHA) and Dory, this module
lets one image span a ``(gr, gc)`` grid of halo-padded tiles (and devices)
while staying **bit-identical** to ``pixhomology`` on the whole image:

1. *Per tile* (steps 1-4, embarrassingly parallel, memory ~ tile size):
   steepest-ascent pointers under the global (value, flat index) total
   order — the 1-pixel halo makes every owned pixel's 3x3 window exact;
   pointer-doubling label resolution *frozen at the halo* (each owned pixel
   resolves to an in-tile basin root or to a halo pixel it exits through);
   exact candidate detection and clique-chained saddle edges computed on a
   per-tile total-order key that is order-isomorphic to the global order —
   packed ``(value, global index)`` int64 bit-keys by default (no per-tile
   sort; ``repro.core.packed_keys``), or lexsort-materialized dense ranks
   on the ``merge_keys="rank"`` fallback.

2. *Boundary condensation* (O(boundary), not O(n)): the 1-px ring of every
   tile is collected into a sorted (pixel -> exit pointer) table; pointer
   doubling on that table resolves every cross-tile basin chain in O(log)
   rounds, since a chain can only leave a tile through a ring pixel.

3. *Global seam merge*: per-tile basin roots and saddle-edge lists are
   concatenated into a compact elder-rule instance and reduced by the same
   :func:`repro.core.parallel_merge.boruvka_forest` machinery the
   whole-image Boruvka path uses — O(log C) rounds over basins, not pixels.

Correctness argument (see also ``src/repro/ph/README.md``): the halo makes
pointers, candidates, and edge chains at owned pixels *pixel-for-pixel equal*
to the whole-image computation (comparisons use (value, global index), so
per-tile ranks can substitute for global ranks); the condensed ring table
reaches the same label fixed point as whole-image pointer doubling; and the
elder-rule deaths are a graph invariant of the (basin, saddle-edge) multiset,
which both paths build identically — so diagrams match bit-for-bit,
including ``p_birth``/``p_death`` in global coordinates.

Capacities are two-level: per-tile (``tile_max_features`` roots +
``tile_max_candidates`` saddle candidates per tile) and global
(``max_features`` diagram rows).  Each level reports its own overflow flag
so :meth:`repro.ph.PHEngine.run_tiled` can regrow exactly the undersized
level.

Residency: :func:`tiled_pixhomology` takes a host-resident ``(H, W)`` array
(convenient for tests and small images), but the compute core is
:func:`tiled_pixhomology_stacks`, which takes the halo-padded tile stacks
directly.  :func:`load_tile_stacks` builds those stacks from a **tile
provider** (anything with ``shape`` / ``dtype`` / ``halo_tile(t, grid)``,
e.g. :class:`repro.data.astro.AstroImage`) one tile at a time — each tile
is placed on device as soon as it is generated, so the host never holds
more than one halo-padded tile of the image (the streaming pipeline's
"no host holds a full image" guarantee; Variant-1 ``load_self`` for tiles).
With ``shard_ctx`` the stacks are sharding-constrained on the mesh's data
axes, so all downstream intermediates are tile-resident per device.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packed_keys
from repro.core.grid import (
    fixed_point_iterate,
    higher_neighbor_basins,
    neg_inf as _neg_inf,
)
from repro.core.packed_keys import key_pad, masked_top_k, pack_keys
from repro.core.parallel_merge import boruvka_forest, chain_clique_edges
from repro.core.pixhomology import (
    Diagram,
    exact_candidates,
    keyed_steepest_pointers,
    resolve_labels,
)

_I32_MAX = np.iinfo(np.int32).max


class TiledDiagram(NamedTuple):
    """Whole-image :class:`Diagram` plus the two-level overflow split."""

    diagram: Diagram
    tile_overflow: jnp.ndarray    # () bool: some tile's F_t/K_t undersized
    merge_overflow: jnp.ndarray   # () bool: global diagram capacity undersized
    n_tile_roots: jnp.ndarray     # (T,) int32 roots per tile (capacity sizing)
    n_tile_cands: jnp.ndarray     # (T,) int32 candidates per tile


class TileBoundaryState(NamedTuple):
    """Everything the seam merge needs, per tile — the cacheable artifact.

    Every field is **tile-local**: computed from one halo-padded tile alone
    (:func:`tile_phase_ab`), never from another tile's state or from the
    resolved cross-tile labels.  That locality is the delta-recompute
    contract (``repro.core.delta``): a tile whose halo-padded bytes are
    unchanged has bit-identical state, so a cached row can stand in for a
    recompute.  Consequently saddle-edge endpoints ``e_a``/``e_b`` carry
    *pre-labels* (an in-tile basin root or the halo pixel the ascent chain
    exits through), not final global basin labels — the final resolution
    through the ring table happens once, in :func:`merge_tile_state`.

    All arrays have a leading tile axis ``T`` when stacked; invariants:

    * ``ring_gidx``/``ring_ptr`` (T, R): the tile's 1-px boundary ring and
      its exit pointers — the condensation-table rows.
    * ``e_*`` (T, k, 8): clique-chained saddle candidate edges keyed by the
      saddle pixel (``e_val``/``e_pos``); endpoints are pre-labels.
    * ``root_*`` (T, f): top-``f`` owned basin roots (a root's final label
      is itself, so these are global already); ``rmax_*`` the unfiltered
      per-tile maximum root for the essential class.
    * ``n_roots``/``n_cand`` (T,): exact counts for overflow detection.
    """

    ring_gidx: jnp.ndarray        # (T, R) int32
    ring_ptr: jnp.ndarray         # (T, R) int32
    min_val: jnp.ndarray          # (T,) image dtype
    min_gidx: jnp.ndarray         # (T,) int32
    e_val: jnp.ndarray            # (T, k, 8) image dtype
    e_pos: jnp.ndarray            # (T, k, 8) int32
    e_a: jnp.ndarray              # (T, k, 8) int32 pre-label endpoint
    e_b: jnp.ndarray              # (T, k, 8) int32 pre-label endpoint
    e_ok: jnp.ndarray             # (T, k, 8) bool
    root_val: jnp.ndarray         # (T, f) image dtype
    root_gidx: jnp.ndarray        # (T, f) int32
    root_valid: jnp.ndarray       # (T, f) bool
    rmax_val: jnp.ndarray         # (T,) image dtype
    rmax_gidx: jnp.ndarray        # (T,) int32
    n_roots: jnp.ndarray          # (T,) int32
    n_cand: jnp.ndarray           # (T,) int32


# ---------------------------------------------------------------------------
# Grid selection / validation
# ---------------------------------------------------------------------------

def validate_grid(shape: tuple[int, int], grid: tuple[int, int]) -> None:
    h, w = shape
    gr, gc = grid
    if gr < 1 or gc < 1:
        raise ValueError(f"tile grid must be >= (1, 1), got {grid}")
    if h % gr or w % gc:
        raise ValueError(f"tile grid {grid} does not divide image {shape}; "
                         f"pick divisors (see choose_grid)")


def choose_grid(shape: tuple[int, int], max_tile_pixels: int
                ) -> tuple[int, int]:
    """Smallest dividing (gr, gc) whose tiles hold <= ``max_tile_pixels``.

    Prefers fewer tiles, then square-ish tiles.  Always solvable: (h, w)
    gives 1-pixel tiles.
    """
    h, w = shape

    def divisors(x):
        return [d for d in range(1, x + 1) if x % d == 0]

    best = None
    for gr in divisors(h):
        tr = h // gr
        for gc in divisors(w):
            tc = w // gc
            if tr * tc > max_tile_pixels:
                continue
            key = (gr * gc, abs(tr - tc), gr, gc)
            if best is None or key < best[0]:
                best = (key, (gr, gc))
            break   # larger gc only shrinks tiles further for this gr
    if best is None:   # max_tile_pixels < 1; degenerate, one pixel per tile
        return (h, w)
    return best[1]


def _ring_coords(tr: int, tc: int) -> tuple[np.ndarray, np.ndarray]:
    """Owned coordinates of the tile's 1-px boundary ring (static)."""
    rr, cc = np.mgrid[0:tr, 0:tc]
    mask = (rr == 0) | (rr == tr - 1) | (cc == 0) | (cc == tc - 1)
    return rr[mask], cc[mask]


def _interior_mask(ph: int, pw: int) -> np.ndarray:
    m = np.zeros((ph, pw), bool)
    m[1:-1, 1:-1] = True
    return m


# ---------------------------------------------------------------------------
# Tile extraction
# ---------------------------------------------------------------------------

def split_tiles(arr2d: jnp.ndarray, grid: tuple[int, int], fill
                ) -> jnp.ndarray:
    """(H, W) -> (T, tr+2, tc+2) halo-padded tiles, row-major tile order."""
    h, w = arr2d.shape
    gr, gc = grid
    tr, tc = h // gr, w // gc
    padded = jnp.pad(arr2d, 1, constant_values=fill)
    oi, oj = jnp.meshgrid(jnp.arange(gr) * tr, jnp.arange(gc) * tc,
                          indexing="ij")
    origins = jnp.stack([oi.reshape(-1), oj.reshape(-1)], axis=1)
    return jax.vmap(lambda o: jax.lax.dynamic_slice(
        padded, (o[0], o[1]), (tr + 2, tc + 2)))(origins)


def halo_gidx_tile(shape: tuple[int, int], grid: tuple[int, int],
                   t: int) -> np.ndarray:
    """Global flat-index map of tile ``t``'s halo-padded window, computed
    arithmetically (O(tile), never touching an (H, W) array); out-of-frame
    halo pixels are -1, matching ``split_tiles(gidx2d, grid, -1)``."""
    h, w = shape
    gr, gc = grid
    tr, tc = h // gr, w // gc
    r0, c0 = (t // gc) * tr, (t % gc) * tc
    rows = np.arange(r0 - 1, r0 + tr + 1, dtype=np.int64)[:, None]
    cols = np.arange(c0 - 1, c0 + tc + 1, dtype=np.int64)[None, :]
    gidx = rows * w + cols
    inside = (rows >= 0) & (rows < h) & (cols >= 0) & (cols < w)
    return np.where(inside, gidx, -1).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class StagedTiles:
    """Device-resident halo-padded tile stacks of one image.

    Built by :func:`load_tile_stacks` (tile-provider path, O(tile) host
    residency) and accepted by :func:`tiled_pixhomology_stacks` /
    :meth:`repro.ph.PHEngine.run_tiled` in place of a host-resident image.
    """

    pvals: Any                    # (T, tr+2, tc+2) image dtype
    pgidx: Any                    # (T, tr+2, tc+2) int32 global indices
    shape: tuple[int, int]        # full-image (H, W)
    grid: tuple[int, int]         # (gr, gc)


def load_tile_stacks(provider, grid: tuple[int, int], *,
                     ctx=None, fill=None) -> StagedTiles:
    """Stage a tile provider's halo-padded tiles on device, one at a time.

    ``provider``: ``shape`` / ``dtype`` / ``halo_tile(t, grid, fill=...)``
    (e.g. :class:`repro.data.astro.AstroImage`).  Each tile is converted to
    a device array as soon as it is generated, so peak host residency is a
    single halo-padded tile regardless of the image size.  With ``ctx`` the
    stacks are placed on the mesh's data axes (the same tile placement the
    sharded per-tile phases use).  ``fill`` overrides the halo fill value
    (user-space inert extreme: ``+inf`` when the stacks will be consumed
    under the sublevel filtration; defaults to the superlevel ``-inf``).
    """
    h, w = provider.shape
    grid = tuple(grid)
    validate_grid((h, w), grid)
    n_tiles = grid[0] * grid[1]
    if fill is None:
        fill = _neg_inf(jnp.dtype(provider.dtype)).item()
    pv = [jnp.asarray(provider.halo_tile(t, grid, fill=fill))
          for t in range(n_tiles)]
    pg = [jnp.asarray(halo_gidx_tile((h, w), grid, t))
          for t in range(n_tiles)]
    pvals, pgidx = jnp.stack(pv), jnp.stack(pg)
    if ctx is not None:
        from repro.distributed.sharding import (constrain,
                                                tile_partition_spec)
        tile_p = tile_partition_spec(n_tiles, ctx.mesh, ctx.dp_axes)
        if tuple(tile_p) != ():
            pvals = constrain(pvals, ctx, (tile_p[0], None, None))
            pgidx = constrain(pgidx, ctx, (tile_p[0], None, None))
    return StagedTiles(pvals, pgidx, (h, w), grid)


# ---------------------------------------------------------------------------
# Phase A (per tile): pointers + in-tile label resolution, frozen at halo
# ---------------------------------------------------------------------------

def tile_phase_a(pvals: jnp.ndarray, pgidx: jnp.ndarray):
    """Steps 1-2 on one halo-padded tile — the per-tile instantiation of
    the core stage graph (``pixhomology.phase_a``/``phase_b`` with tiles
    as the locality unit instead of row strips).

    Pointers come from the shared :func:`~repro.core.pixhomology.\
keyed_steepest_pointers` stage keyed by *global* pixel index (per-tile
    order must be isomorphic to the global total order), and the
    halo-frozen resolution is the shared
    :func:`~repro.core.pixhomology.resolve_labels` doubling — exactly the
    in-strip snap the fused phase-A kernel performs, with the tile halo
    playing the strip boundary's role.

    Returns ``(ptr_owned, ring_gidx, ring_ptr, min_val, min_gidx)``:
    per owned pixel the global index of its in-tile basin root *or* of the
    halo pixel its ascent chain exits through; the boundary-ring slice of
    the same map (the tile's contribution to the condensation table); and
    the tile's (value, index)-minimum for the global essential death.
    """
    ph, pw = pvals.shape
    tr, tc = ph - 2, pw - 2
    interior = jnp.asarray(_interior_mask(ph, pw))
    flat = jnp.arange(ph * pw, dtype=jnp.int32).reshape(ph, pw)

    ptr_l = keyed_steepest_pointers(pvals, pgidx)
    m0 = jnp.where(interior, ptr_l, flat).reshape(-1)   # halo frozen to self
    m = resolve_labels(m0)
    resolved_g = pgidx.reshape(-1)[m].reshape(ph, pw)
    ptr_owned = resolved_g[1:-1, 1:-1]

    own_vals = pvals[1:-1, 1:-1]
    own_gidx = pgidx[1:-1, 1:-1]
    rr, cc = _ring_coords(tr, tc)
    ring_gidx = own_gidx[rr, cc]
    ring_ptr = ptr_owned[rr, cc]

    min_val = jnp.min(own_vals)
    min_gidx = jnp.min(jnp.where(own_vals == min_val, own_gidx,
                                 jnp.int32(_I32_MAX)))
    return ptr_owned, ring_gidx, ring_ptr, min_val, min_gidx


# ---------------------------------------------------------------------------
# Boundary condensation: sorted ring table + pointer doubling across tiles
# ---------------------------------------------------------------------------

def _table_follow(sg: jnp.ndarray, sv: jnp.ndarray, q: jnp.ndarray
                  ) -> jnp.ndarray:
    """values[q] where q is in the sorted-key table ``sg``, else q itself."""
    pos = jnp.clip(jnp.searchsorted(sg, q), 0, sg.shape[0] - 1)
    return jnp.where(sg[pos] == q, sv[pos], q)


def resolve_ring_table(ring_gidx: jnp.ndarray, ring_ptr: jnp.ndarray):
    """Condensed cross-tile label resolution.

    ``ring_gidx``/``ring_ptr``: (T, R) per-tile boundary rings.  A basin
    chain can only leave a tile through a halo pixel, which is a ring pixel
    of the neighboring tile — so pointer doubling on this table alone
    resolves every cross-tile chain to its basin root, in O(log) rounds of
    O(boundary) work (the tiled twin of the whole-image compacted
    frontier, ``pixhomology.resolve_labels_frontier``).  Returns
    ``(sg, sl)``: sorted ring pixel ids and their final global basin
    labels.
    """
    rg = ring_gidx.reshape(-1)
    rp = ring_ptr.reshape(-1)
    order = jnp.argsort(rg)
    sg = rg[order]
    sp = rp[order]
    sl, _ = fixed_point_iterate(lambda p: _table_follow(sg, p, p), sp)
    return sg, sl


# ---------------------------------------------------------------------------
# Phase B (per tile): pre-labels, exact candidates, seam/interior edges
# ---------------------------------------------------------------------------

def tile_phase_b(pvals, pgidx, ptr_owned, tv, *,
                 tile_max_candidates: int, tile_max_features: int,
                 truncated: bool, merge_keys: str = "rank"):
    """Steps 3-4 on one tile, **label-independent** (tile-local only).

    Returns per-tile compact pieces of the global merge instance:
    clique-chained saddle edges (endpoints are *pre-labels* — an in-tile
    basin root or the halo pixel the chain exits through, resolved to
    final global labels later by :func:`merge_tile_state`), the
    top-``tile_max_features`` basin roots, the tile's unfiltered maximum
    root (for the essential class), and candidate/root counts for
    overflow detection.

    Pre-labels keep the diagram bit-identical: equal pre-labels imply
    equal final labels, so every whole-image candidate/edge survives;
    distinct pre-labels that resolve to the *same* final label add only
    edges that become self-loops in the seam merge, which
    :func:`repro.core.parallel_merge.boruvka_forest` skips (``ra != rb``)
    — and duplicate real edges share the saddle pixel, hence the exact
    merge key, so the elder-rule outcome is unchanged.  In exchange the
    stage depends on nothing but this tile's halo-padded bytes, which is
    what makes its output cacheable for delta recompute.

    ``merge_keys="packed"`` keys every comparison on the packed
    ``(value, global index)`` int64 bit-key — per-tile packed keys are
    *globally* order-isomorphic by construction, so the two per-tile
    argsorts (the rank lexsort) disappear along with the full-tile
    ``top_k`` sorts (blockwise tournament selection).  ``"rank"`` keeps
    the lexsort-materialized per-tile dense ranks.
    """
    ph, pw = pvals.shape
    tr, tc = ph - 2, pw - 2
    n_loc = ph * pw
    interior = jnp.asarray(_interior_mask(ph, pw))
    fill_v = _neg_inf(pvals.dtype)

    own_vals = pvals[1:-1, 1:-1]
    own_gidx = pgidx[1:-1, 1:-1]

    # Pre-labels: owned pixels carry their in-tile resolution (basin root
    # or exit halo pixel); halo pixels stand for themselves (they are ring
    # pixels of a neighbor, resolved at seam time); out-of-frame fill -1.
    plbl = jnp.where(interior, jnp.pad(ptr_owned, 1, constant_values=-1),
                     jnp.where(pgidx >= 0, pgidx, -1))

    if merge_keys == "packed":
        # Packed (value, global index) keys are order-isomorphic to the
        # global total order on the padded tile directly — no sort.  Halo
        # fill cells (value -inf/int-min, gidx -1) pack low word 0: below
        # every real pixel (for integer dtype-min fills they reach the
        # pad sentinel itself, which is fine — halo cells are excluded by
        # the interior mask, never by key comparison).
        key = pack_keys(pvals.reshape(-1), pgidx.reshape(-1))
    else:
        # Per-tile rank, order-isomorphic to the global (value, index)
        # order (halo fill keys (-inf, -1) sort strictly below every real
        # pixel).
        order = jnp.lexsort((pgidx.reshape(-1), pvals.reshape(-1)))
        key = jnp.zeros(n_loc, jnp.int32).at[order].set(
            jnp.arange(n_loc, dtype=jnp.int32))
    pad = key_pad(key.dtype)

    cand2d = exact_candidates(key.reshape(ph, pw), plbl) & interior
    if truncated:
        cand2d &= pvals >= tv
    cand_flat = cand2d.reshape(-1)
    n_cand = jnp.sum(cand_flat, dtype=jnp.int32)

    k = min(tile_max_candidates, tr * tc)
    top_keys, top_loc = masked_top_k(key, cand_flat, k)
    valid = top_keys > pad
    ok, lbl = higher_neighbor_basins(top_loc, top_keys, key,
                                     plbl.reshape(-1), (ph, pw), valid)
    edge_ok, prev_lbl = chain_clique_edges(ok, lbl)          # (k, 8)
    e_val = jnp.broadcast_to(pvals.reshape(-1)[top_loc][:, None], ok.shape)
    e_pos = jnp.broadcast_to(pgidx.reshape(-1)[top_loc][:, None], ok.shape)
    e_a = jnp.where(edge_ok, lbl, 0)
    e_b = jnp.where(edge_ok, prev_lbl, 0)

    # Basin roots owned by this tile.  Root-ness is tile-local: ascent
    # chains are strictly increasing in (value, index), so a pixel whose
    # chain leaves the tile can never resolve back to itself —
    # ``ptr_owned == own_gidx`` iff the final global label is the pixel.
    root_mask = ptr_owned == own_gidx
    # Unfiltered per-tile maximum root: the global maximum pixel is always a
    # root, so the reduce over tiles finds the essential class even when a
    # Variant-2 threshold filters the listed roots.
    rmax_val = jnp.max(jnp.where(root_mask, own_vals, fill_v))
    rmax_gidx = jnp.max(jnp.where(root_mask & (own_vals == rmax_val),
                                  own_gidx, jnp.int32(-1)))
    if truncated:
        root_mask &= own_vals >= tv
    n_roots = jnp.sum(root_mask, dtype=jnp.int32)

    f = min(tile_max_features, tr * tc)
    own_key = key.reshape(ph, pw)[1:-1, 1:-1].reshape(-1)
    top_rk, top_ri = masked_top_k(own_key, root_mask.reshape(-1), f)
    rvalid = top_rk > pad
    root_gidx = jnp.where(rvalid, own_gidx.reshape(-1)[top_ri], -1)
    root_val = jnp.where(rvalid, own_vals.reshape(-1)[top_ri], fill_v)

    return (e_val, e_pos, e_a, e_b, edge_ok,
            root_val, root_gidx.astype(jnp.int32), rvalid,
            rmax_val, rmax_gidx, n_roots, n_cand)


def tile_phase_ab(pvals, pgidx, tv, *,
                  tile_max_candidates: int, tile_max_features: int,
                  truncated: bool, merge_keys: str = "rank"
                  ) -> TileBoundaryState:
    """Phases A+B on one halo-padded tile -> its :class:`TileBoundaryState`.

    This is the complete tile-local computation — a pure function of one
    tile's halo-padded bytes (plus the static capacities/threshold), which
    is exactly the unit the delta layer caches and replays.  The cold
    tiled path vmaps it over all ``T`` tiles; a delta run vmaps the same
    function over only the dirty subset.
    """
    (ptr_owned, ring_gidx, ring_ptr, min_val, min_gidx) = tile_phase_a(
        pvals, pgidx)
    (e_val, e_pos, e_a, e_b, e_ok, root_val, root_gidx, root_valid,
     rmax_val, rmax_gidx, n_roots, n_cand) = tile_phase_b(
        pvals, pgidx, ptr_owned, tv,
        tile_max_candidates=tile_max_candidates,
        tile_max_features=tile_max_features,
        truncated=truncated, merge_keys=merge_keys)
    return TileBoundaryState(ring_gidx, ring_ptr, min_val, min_gidx,
                             e_val, e_pos, e_a, e_b, e_ok,
                             root_val, root_gidx, root_valid,
                             rmax_val, rmax_gidx, n_roots, n_cand)


# ---------------------------------------------------------------------------
# Global seam merge on the compact (basin, saddle-edge) instance
# ---------------------------------------------------------------------------

def _slot_lookup(sorted_key, slot_of, q):
    """(slot, found) of global root ids in the compact root table."""
    pos = jnp.clip(jnp.searchsorted(sorted_key, q), 0,
                   sorted_key.shape[0] - 1)
    found = sorted_key[pos] == q
    return jnp.where(found, slot_of[pos], -1), found


def seam_merge(root_val, root_gidx, root_valid,
               e_val, e_pos, e_a, e_b, e_valid,
               rmax_val, rmax_gidx, gmin_val, gmin_gidx,
               tv, *, truncated: bool, max_features: int, dtype,
               merge_keys: str = "rank", phase_c_impl: str = "fused",
               phase_c_block: int = 1024):
    """Elder-rule reduction of the concatenated per-tile instances.

    Compact vertex set = listed basin roots; edges reference roots by
    global pixel id and are slotted through a sorted lookup table.  The
    reduction itself is :func:`repro.core.parallel_merge.boruvka_forest`.
    ``merge_keys="packed"`` keys vertices and edges on the packed
    ``(value, global index)`` int64 directly — edges sharing a saddle
    pixel are equal-keyed *by construction*, so the two dense-rank
    argsorts of the ``"rank"`` path (vertex lexsort + edge group ranking)
    disappear.  The seam instance is already compact (listed roots, never
    full-image), so ``phase_c_impl="fused"`` here selects only the round
    reduction backend: the blocked phase-C kernel dispatch
    (``repro.kernels.ph_phase_c.ops.best_edge_reduce`` with
    ``phase_c_block`` edges per step) instead of the plain XLA scatter —
    bit-identical either way.  Returns ``(birth, death, p_birth, p_death,
    count, n_unmerged, merge_overflow)``.
    """
    rv = root_val.reshape(-1)
    rg = root_gidx.reshape(-1)
    ok_r = root_valid.reshape(-1)
    nv = rv.shape[0]
    neg_inf = _neg_inf(dtype)

    # Root id -> compact slot (sorted table; invalid slots key to int-max).
    key_g = jnp.where(ok_r, rg, jnp.int32(_I32_MAX))
    order_g = jnp.argsort(key_g)
    sorted_g = key_g[order_g]

    ev = e_val.reshape(-1)
    ep = e_pos.reshape(-1)
    sa, fa = _slot_lookup(sorted_g, order_g, e_a.reshape(-1))
    sb, fb = _slot_lookup(sorted_g, order_g, e_b.reshape(-1))
    alive = e_valid.reshape(-1) & fa & fb   # missing endpoint => tile overflow

    if merge_keys == "packed":
        # Vertex birth / edge saddle keys: packed (value, global index) —
        # order-isomorphic with no sort, equal exactly when the saddle
        # pixel coincides.
        i64_pad = key_pad(jnp.int64)
        v_rank = jnp.where(ok_r, pack_keys(rv, rg), i64_pad)
        e_rank = jnp.where(alive, pack_keys(ev, ep), i64_pad)
    else:
        # Vertex birth keys: rank of (value, global index) among valid
        # roots.
        vorder = jnp.lexsort((rg, rv, ok_r.astype(jnp.int32)))
        vrank_raw = jnp.zeros(nv, jnp.int32).at[vorder].set(
            jnp.arange(nv, dtype=jnp.int32))
        v_rank = jnp.where(ok_r, vrank_raw, key_pad(jnp.int32))

        # Edge saddle keys: dense rank of (value, global index), EQUAL for
        # edges sharing a saddle pixel (the Boruvka tie rule depends on it).
        ne = ev.shape[0]
        akey = alive.astype(jnp.int32)
        eorder = jnp.lexsort((ep, ev, akey))
        s_ak, s_ev, s_ep = akey[eorder], ev[eorder], ep[eorder]
        new_grp = jnp.concatenate([
            jnp.ones((1,), bool),
            (s_ak[1:] != s_ak[:-1]) | (s_ev[1:] != s_ev[:-1])
            | (s_ep[1:] != s_ep[:-1])])
        grp = (jnp.cumsum(new_grp.astype(jnp.int32)) - 1)
        erank_raw = jnp.zeros(ne, jnp.int32).at[eorder].set(grp)
        e_rank = jnp.where(alive, erank_raw, key_pad(jnp.int32))

    if phase_c_impl == "fused":
        from repro.kernels.ph_phase_c import ops as phase_c_ops
        reduce_fn = functools.partial(phase_c_ops.best_edge_reduce,
                                      block_edges=phase_c_block)
    else:
        reduce_fn = None
    n_live = jnp.sum(ok_r, dtype=jnp.int32)
    dval, dpos, _rounds = boruvka_forest(
        v_rank, e_rank, ev.astype(dtype), ep,
        jnp.clip(sa, 0), jnp.clip(sb, 0),
        n_live=n_live, reduce_fn=reduce_fn)

    if truncated:
        # Survivors that never merged above the threshold die at it
        # (p_death stays -1, matching the whole-image semantics).
        undied = ok_r & (dpos < 0)
        dval = jnp.where(undied, jnp.asarray(tv, dtype), dval)

    # Essential class: the globally maximal root dies at the global minimum.
    gmax_val = jnp.max(rmax_val)
    gmax_gidx = jnp.max(jnp.where(rmax_val == gmax_val, rmax_gidx, -1))
    eslot, efound = _slot_lookup(sorted_g, order_g, gmax_gidx[None])
    es = jnp.clip(eslot[0], 0)
    assign = efound[0]
    dval = dval.at[es].set(jnp.where(assign, jnp.asarray(gmin_val, dtype),
                                     dval[es]))
    dpos = dpos.at[es].set(jnp.where(assign, gmin_gidx, dpos[es]))

    # Diagram rows, descending (birth value, birth index); ``v_rank`` is
    # already pad-keyed on invalid slots, and the vertex set is compact
    # (listed roots, never full-image), so one top_k serves both key paths.
    c = jnp.sum(ok_r, dtype=jnp.int32)
    f = max_features
    kk = min(f, nv)
    _, top_slot = jax.lax.top_k(v_rank, kk)
    row_valid = jnp.arange(kk) < c

    birth = jnp.full(f, neg_inf, dtype).at[:kk].set(
        jnp.where(row_valid, rv[top_slot].astype(dtype), neg_inf))
    death = jnp.full(f, neg_inf, dtype).at[:kk].set(
        jnp.where(row_valid, dval[top_slot], neg_inf))
    p_birth = jnp.full(f, -1, jnp.int32).at[:kk].set(
        jnp.where(row_valid, rg[top_slot], -1))
    p_death = jnp.full(f, -1, jnp.int32).at[:kk].set(
        jnp.where(row_valid, dpos[top_slot], -1))

    n_unmerged = jnp.sum(ok_r & (dpos < 0), dtype=jnp.int32)
    merge_overflow = c > f
    return (birth, death, p_birth, p_death, jnp.minimum(c, f), n_unmerged,
            merge_overflow)


def merge_tile_state(state: TileBoundaryState, tv, *,
                     shape: tuple[int, int], grid: tuple[int, int],
                     max_features: int, tile_max_features: int,
                     tile_max_candidates: int, truncated: bool,
                     merge_keys: str = "rank", phase_c_impl: str = "fused",
                     phase_c_block: int = 1024) -> TiledDiagram:
    """O(boundary) global replay: ring condensation + pre-label resolution
    + elder-rule seam merge over stacked :class:`TileBoundaryState`.

    This is the only stage that mixes tiles, and it never touches pixels —
    its cost scales with rings/roots/edges.  A delta run re-executes *this*
    against a state whose clean rows come from cache: pointer doubling on
    the full ring table re-resolves every cross-tile chain (a dirty tile
    re-routes chains through clean tiles correctly, because clean rows
    store pre-labels, not stale final labels), then ``e_a``/``e_b`` are
    mapped through the table.  A pre-label absent from the table is an
    in-tile *root* (interior roots never appear on a ring), and a root's
    final label is itself — exactly ``_table_follow``'s miss semantics.
    """
    h, w = shape
    gr, gc = grid
    tr, tc = h // gr, w // gc

    sg, sl = resolve_ring_table(state.ring_gidx, state.ring_ptr)

    gmin_val = jnp.min(state.min_val)
    gmin_gidx = jnp.min(jnp.where(state.min_val == gmin_val,
                                  state.min_gidx, jnp.int32(_I32_MAX)))

    e_a = _table_follow(sg, sl, state.e_a)
    e_b = _table_follow(sg, sl, state.e_b)

    f_global = min(max_features, h * w)
    (birth, death, p_birth, p_death, count, n_unmerged,
     merge_overflow) = seam_merge(
        state.root_val, state.root_gidx, state.root_valid,
        state.e_val, state.e_pos, e_a, e_b, state.e_ok,
        state.rmax_val, state.rmax_gidx, gmin_val, gmin_gidx, tv,
        truncated=truncated, max_features=f_global,
        dtype=state.root_val.dtype, merge_keys=merge_keys,
        phase_c_impl=phase_c_impl, phase_c_block=phase_c_block)

    tile_overflow = (
        jnp.any(state.n_cand > min(tile_max_candidates, tr * tc))
        | jnp.any(state.n_roots > min(tile_max_features, tr * tc)))
    diagram = Diagram(birth, death, p_birth, p_death, count, n_unmerged,
                      tile_overflow | merge_overflow)
    return TiledDiagram(diagram, tile_overflow, merge_overflow,
                        state.n_roots, state.n_cand)


# ---------------------------------------------------------------------------
# Full tiled algorithm
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("grid", "max_features", "tile_max_features",
                     "tile_max_candidates", "shard_ctx", "merge_keys",
                     "phase_c_impl", "phase_c_block", "filtration"))
def _tiled_pixhomology(image: jnp.ndarray, truncate_value=None, *,
                       grid: tuple[int, int],
                       max_features: int = 8192,
                       tile_max_features: int = 2048,
                       tile_max_candidates: int = 8192,
                       shard_ctx=None,
                       merge_keys: str = "rank",
                       phase_c_impl: str = "fused",
                       phase_c_block: int = 1024,
                       filtration: str = "superlevel") -> TiledDiagram:
    """Jitted host-resident-image core of :func:`tiled_pixhomology`."""
    if image.ndim != 2:
        raise ValueError(f"expected 2D image, got shape {image.shape}")
    h, w = image.shape
    validate_grid((h, w), grid)
    gidx2d = jnp.arange(h * w, dtype=jnp.int32).reshape(h, w)
    # Halo fill stays in user space here (the stacks core owns the
    # filtration negation): inert means below everything under superlevel,
    # above everything under sublevel.
    fill = _neg_inf(image.dtype)
    if filtration == "sublevel":
        fill = jnp.negative(fill)
    pvals = split_tiles(image, grid, fill)
    pgidx = split_tiles(gidx2d, grid, jnp.int32(-1))
    return _tiled_pixhomology_stacks(
        pvals, pgidx, truncate_value, shape=(h, w), grid=grid,
        max_features=max_features, tile_max_features=tile_max_features,
        tile_max_candidates=tile_max_candidates, shard_ctx=shard_ctx,
        merge_keys=merge_keys, phase_c_impl=phase_c_impl,
        phase_c_block=phase_c_block, filtration=filtration)


def tiled_pixhomology(image: jnp.ndarray, truncate_value=None, *,
                      merge_keys: str = "packed", **kwargs) -> TiledDiagram:
    """0-dim PH of one 2D image via halo-tiled decomposition (bit-identical
    to ``pixhomology(image, truncate_value, candidate_mode="exact")``).

    ``grid``: (gr, gc) tile grid; must divide the image shape
    (:func:`choose_grid` picks one from a tile-pixel budget).
    ``shard_ctx``: optional :class:`repro.distributed.DistContext` — the
    per-tile phases run under ``shard_map`` with tile rows placed on the
    mesh's data axes (tile count must divide by the dp size); the compact
    condensation/seam stages stay replicated (they are O(boundary), not
    O(pixels)).
    ``merge_keys``: packed int64 ``(value, global index)`` keys (default;
    no per-tile or seam argsorts) or the dense-rank fallback — resolved
    exactly like :func:`repro.core.pixhomology.pixhomology`.

    This is the host-resident-image convenience wrapper; the compute core
    is :func:`tiled_pixhomology_stacks`, fed either by the in-jit
    ``split_tiles`` below or by :func:`load_tile_stacks` (tile-provider
    path with O(tile) host residency).
    """
    packed_keys.check_finite(image, allow_inf=True)
    merge_keys = packed_keys.resolve_merge_keys(merge_keys, image.dtype)
    with packed_keys.key_scope(merge_keys):
        return _tiled_pixhomology(image, truncate_value,
                                  merge_keys=merge_keys, **kwargs)


@functools.partial(
    jax.jit,
    static_argnames=("shape", "grid", "max_features", "tile_max_features",
                     "tile_max_candidates", "shard_ctx", "merge_keys",
                     "phase_c_impl", "phase_c_block", "filtration"))
def _tiled_pixhomology_stacks(pvals: jnp.ndarray, pgidx: jnp.ndarray,
                              truncate_value=None, *,
                              shape: tuple[int, int],
                              grid: tuple[int, int],
                              max_features: int = 8192,
                              tile_max_features: int = 2048,
                              tile_max_candidates: int = 8192,
                              shard_ctx=None,
                              merge_keys: str = "rank",
                              phase_c_impl: str = "fused",
                              phase_c_block: int = 1024,
                              filtration: str = "superlevel"
                              ) -> TiledDiagram:
    """Jitted tile-stack core of :func:`tiled_pixhomology_stacks`."""
    h, w = shape
    validate_grid((h, w), grid)
    gr, gc = grid
    tr, tc = h // gr, w // gc
    n_tiles = gr * gc
    if pvals.shape != (n_tiles, tr + 2, tc + 2):
        raise ValueError(f"tile stack shape {pvals.shape} does not match "
                         f"image {shape} under grid {grid}")
    packed_keys.assert_key_context(merge_keys)
    # Sublevel runs on the exact negation: the stacks (user space, +inf
    # halo fill) and threshold negate here, every internal stage — tile
    # phases, ring condensation, seam merge — stays in superlevel order,
    # and only the output diagram negates back at the bottom.
    pvals = packed_keys.filtration_view(pvals, filtration)
    if truncate_value is not None and filtration == "sublevel":
        truncate_value = jnp.negative(truncate_value)
    truncated = truncate_value is not None
    tv = (jnp.asarray(truncate_value) if truncated
          else _neg_inf(jnp.float32))

    phase_ab = jax.vmap(
        functools.partial(tile_phase_ab,
                          tile_max_candidates=tile_max_candidates,
                          tile_max_features=tile_max_features,
                          truncated=truncated, merge_keys=merge_keys),
        in_axes=(0, 0, None))

    if shard_ctx is not None:
        from jax.sharding import PartitionSpec as P

        from repro.distributed.context import shard_map_compat
        from repro.distributed.sharding import constrain, tile_partition_spec

        tile_p = tile_partition_spec(n_tiles, shard_ctx.mesh,
                                     shard_ctx.dp_axes)
        if tile_p != P():   # dp size divides the tile count: shard phases
            # Pin the tile stacks (the O(n) intermediates) to the tile
            # placement right after the split, so only the (H, W) input and
            # its padded copy are ever full-size per device; everything
            # downstream of here is tile-resident.
            pvals = constrain(pvals, shard_ctx, (tile_p[0], None, None))
            pgidx = constrain(pgidx, shard_ctx, (tile_p[0], None, None))
            def sp(extra):
                return P(*((tile_p[0],) + (None,) * extra))

            phase_ab = shard_map_compat(
                phase_ab, mesh=shard_ctx.mesh,
                in_specs=(sp(2), sp(2), P()),
                out_specs=TileBoundaryState(
                    sp(1), sp(1), sp(0), sp(0),
                    sp(2), sp(2), sp(2), sp(2), sp(2),
                    sp(1), sp(1), sp(1), sp(0), sp(0), sp(0), sp(0)))

    state = phase_ab(pvals, pgidx, tv)
    td = merge_tile_state(
        state, tv, shape=(h, w), grid=grid, max_features=max_features,
        tile_max_features=tile_max_features,
        tile_max_candidates=tile_max_candidates, truncated=truncated,
        merge_keys=merge_keys, phase_c_impl=phase_c_impl,
        phase_c_block=phase_c_block)
    if filtration == "sublevel":
        d = td.diagram
        td = td._replace(diagram=d._replace(birth=jnp.negative(d.birth),
                                            death=jnp.negative(d.death)))
    return td


def tiled_pixhomology_stacks(pvals: jnp.ndarray, pgidx: jnp.ndarray,
                             truncate_value=None, *,
                             merge_keys: str = "packed",
                             **kwargs) -> TiledDiagram:
    """Halo-tiled PH on pre-staged tile stacks (the streaming entry point).

    ``pvals``/``pgidx``: (T, tr+2, tc+2) halo-padded value / global-index
    stacks in row-major tile order — exactly what ``split_tiles`` produces
    from a whole image, or :func:`load_tile_stacks` from a tile provider
    without any host ever materializing the image.  Semantics otherwise
    identical to :func:`tiled_pixhomology` (including ``merge_keys``
    resolution and its x64 scope).
    """
    packed_keys.check_finite(pvals, where="tile stacks", allow_inf=True)
    merge_keys = packed_keys.resolve_merge_keys(merge_keys, pvals.dtype)
    with packed_keys.key_scope(merge_keys):
        return _tiled_pixhomology_stacks(pvals, pgidx, truncate_value,
                                         merge_keys=merge_keys, **kwargs)


# ---------------------------------------------------------------------------
# Per-tile cost model (dryrun / capacity planning)
# ---------------------------------------------------------------------------

def per_tile_cost(tile_shape: tuple[int, int], dtype, n_tiles: int,
                  tile_max_features: int = 2048,
                  tile_max_candidates: int = 8192,
                  merge_keys: str = "packed") -> dict:
    """Compile the per-tile phase programs and report their memory footprint.

    This is the dryrun cost model for the tiled plan: everything here scales
    with the *tile* shape (plus the O(boundary) condensation table), never
    with the full image area — the property that lets one image exceed a
    device.
    """
    tr, tc = tile_shape
    merge_keys = packed_keys.resolve_merge_keys(merge_keys, dtype)
    pv = jax.ShapeDtypeStruct((tr + 2, tc + 2), dtype)
    pg = jax.ShapeDtypeStruct((tr + 2, tc + 2), jnp.int32)
    ring = len(_ring_coords(tr, tc)[0])
    table = jax.ShapeDtypeStruct((n_tiles * ring,), jnp.int32)
    ptr = jax.ShapeDtypeStruct((tr, tc), jnp.int32)
    tv = jax.ShapeDtypeStruct((), jnp.float32)

    out: dict = {"tile_shape": [tr, tc], "ring_pixels": ring,
                 "table_entries": n_tiles * ring, "merge_keys": merge_keys}
    del table   # phase B is label-independent now: no condensation input
    for name, fn, args in (
            ("phase_a", jax.jit(tile_phase_a), (pv, pg)),
            ("phase_b",
             jax.jit(functools.partial(
                 tile_phase_b, tile_max_candidates=tile_max_candidates,
                 tile_max_features=tile_max_features, truncated=True,
                 merge_keys=merge_keys)),
             (pv, pg, ptr, tv))):
        with packed_keys.key_scope(merge_keys):
            compiled = fn.lower(*args).compile()
        ma = compiled.memory_analysis()
        out[name] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
        }
    return out
