"""Shared 2D grid utilities for the 8-neighborhood stencils.

``shift2d`` is the single source of truth for neighbor access: the maxpool
reference oracle, the PixHomology candidate generators, and any future
stencil all shift through here so border semantics (constant fill, one-pixel
halo) stay bit-identical across layers.

``NEIGHBOR_OFFSETS`` fixes the 8-neighborhood iteration order once: the
sequential merge sweep, the Boruvka edge builder, the union-find oracle, and
the tiled seam-edge builder all walk neighbors in this order so their merge
processing is bit-identical.

``higher_neighbor_basins`` is the shared flat-index gather those call sites
used to copy-paste: for each pixel in ``x`` it reports, per neighbor slot,
whether that neighbor is in-bounds and strictly higher under the total
order, and which basin it belongs to.  It is generic over the key
encoding — dense int32 ranks and packed int64 ``(value, index)`` keys
(``repro.core.packed_keys``) compare identically.

``fixed_point_iterate`` is the single pointer-chase loop every label/root
resolution in the stage graph runs on (whole-image doubling, in-strip and
in-tile snaps, the condensed frontier/ring tables, union-find lookups) —
one ``step`` evaluation per iteration, so each doubling round costs one
gather instead of the two the old cond-recomputes-``m[m]`` pattern paid
(src/repro/ph/DESIGN.md §Perf PH-3).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def neg_inf(dtype) -> jnp.ndarray:
    """The minimal sentinel of ``dtype`` (stencil fill: never wins a max).

    Single shared implementation — the pooling reference/kernels, the
    phase-A kernel, the tiled path, and the keyed pointer stage all fill
    halos through here so the sentinel can never drift between layers.
    """
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).min, dtype)


def pos_inf(dtype) -> jnp.ndarray:
    """The maximal sentinel of ``dtype`` (min-pool fill)."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def fixed_point_iterate(step: Callable[[jnp.ndarray], jnp.ndarray],
                        x0: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Iterate ``x <- step(x)`` until unchanged; one ``step`` per iteration.

    Returns ``(x, n_steps)`` where ``n_steps`` (int32 scalar) counts the
    ``step`` evaluations executed, including the final one that verifies
    the fixed point.  The carried ``changed`` flag is computed from the
    step already taken, so ``step`` (typically a gather like ``m[m]``)
    runs exactly once per loop iteration.
    """
    def cond(state):
        return state[1]

    def body(state):
        x, _, k = state
        x2 = step(x)
        return x2, jnp.any(x2 != x), k + jnp.int32(1)

    x, _, k = jax.lax.while_loop(
        cond, body, (x0, jnp.asarray(True), jnp.int32(0)))
    return x, k

# 8-neighborhood offsets (self excluded), fixed order: every consumer uses
# the same order so merge processing is bit-identical across layers.
NEIGHBOR_OFFSETS = [(-1, -1), (-1, 0), (-1, 1),
                    (0, -1), (0, 1),
                    (1, -1), (1, 0), (1, 1)]


def shift2d(x: jnp.ndarray, dr: int, dc: int, fill) -> jnp.ndarray:
    """Return y with ``y[r, c] = x[r + dr, c + dc]``, ``fill`` outside.

    Supports the 3x3 stencil offsets ``dr, dc in {-1, 0, 1}`` (one-pixel
    constant-value halo, same-size output).
    """
    if not (-1 <= dr <= 1 and -1 <= dc <= 1):
        raise ValueError(f"shift2d supports |dr|,|dc| <= 1, got ({dr}, {dc})")
    h, w = x.shape
    padded = jnp.pad(x, 1, constant_values=fill)
    return padded[1 + dr : 1 + dr + h, 1 + dc : 1 + dc + w]


def higher_neighbor_basins(x: jnp.ndarray, xkey: jnp.ndarray,
                           key_flat: jnp.ndarray, labels_flat: jnp.ndarray,
                           shape: tuple[int, int],
                           valid=True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per 8-neighbor of flat pixel ids ``x``: (strictly-higher?, basin).

    ``key_flat`` is any order-isomorphic encoding of the ``(value, index)``
    total order — dense int32 ranks or packed int64 keys; only ``>`` is
    ever applied to it.  ``x``/``xkey`` may be scalars or any matching
    shape; ``valid`` is an extra mask broadcast against them (lanes with
    ``valid=False`` report ``ok=False`` everywhere).  Returns
    ``(ok, basin)`` with a trailing 8-slot axis in
    :data:`NEIGHBOR_OFFSETS` order:

    * ``ok[..., j]``  — neighbor j is inside ``shape`` AND has a strictly
      larger total-order key than ``xkey`` (AND ``valid``);
    * ``basin[..., j]`` — ``labels_flat`` at neighbor j (clamped garbage
      where ``ok`` is False; always mask with ``ok``).

    This is the single implementation of the gather that the sequential
    merge sweep, the Boruvka candidate-edge builder, and the tiled seam-edge
    builder all share — their edge processing must stay bit-identical.
    """
    h, w = shape
    n = h * w
    xr = x // w
    xc = x % w
    oks, basins = [], []
    for dr, dc in NEIGHBOR_OFFSETS:
        rr, cc = xr + dr, xc + dc
        inb = (rr >= 0) & (rr < h) & (cc >= 0) & (cc < w)
        nid = jnp.clip(rr * w + cc, 0, n - 1)
        higher = key_flat[nid] > xkey
        oks.append(inb & higher & valid)
        basins.append(labels_flat[nid])
    return jnp.stack(oks, axis=-1), jnp.stack(basins, axis=-1)
