"""Shared 2D grid utilities for the 8-neighborhood stencils.

``shift2d`` is the single source of truth for neighbor access: the maxpool
reference oracle, the PixHomology candidate generators, and any future
stencil all shift through here so border semantics (constant fill, one-pixel
halo) stay bit-identical across layers.
"""
from __future__ import annotations

import jax.numpy as jnp


def shift2d(x: jnp.ndarray, dr: int, dc: int, fill) -> jnp.ndarray:
    """Return y with ``y[r, c] = x[r + dr, c + dc]``, ``fill`` outside.

    Supports the 3x3 stencil offsets ``dr, dc in {-1, 0, 1}`` (one-pixel
    constant-value halo, same-size output).
    """
    if not (-1 <= dr <= 1 and -1 <= dc <= 1):
        raise ValueError(f"shift2d supports |dr|,|dc| <= 1, got ({dr}, {dc})")
    h, w = x.shape
    padded = jnp.pad(x, 1, constant_values=fill)
    return padded[1 + dr : 1 + dr + h, 1 + dc : 1 + dc + w]
