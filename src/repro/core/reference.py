"""Classical union-find oracle for 0-dim superlevel persistent homology.

This is the textbook algorithm (Edelsbrunner-Letscher-Zomorodian specialized
to H0, i.e. Kruskal/union-find over the pixel graph) — the same computation
``ripser.lower_star_img`` performs for dimension 0 (on the negated image).
It plays two roles:

1. correctness oracle: PixHomology must match it *bit-exactly*, including
   birth/death pixel coordinates (the paper validates against Ripser with
   bottleneck distance 0; we validate with exact equality, which is stronger);
2. the "Ripser-like" single-core baseline for the fig 9/10 benchmarks — it
   materializes and sorts the full pixel order and touches every pixel's
   edges, so its time and memory profile scales the way the paper reports for
   general-purpose tools.

Pixels are processed in descending (value, flat_index) order; an edge to each
already-processed 8-neighbor is union'd; when two components merge, the one
with the younger (smaller) birth key dies at the current pixel (elder rule).
The essential class (global maximum) dies at the global minimum.
"""
from __future__ import annotations

import numpy as np

from repro.core.pixhomology import NEIGHBOR_OFFSETS


def persistence_oracle(image: np.ndarray) -> np.ndarray:
    """Return the full diagram as a float/int structured array.

    Output: (C, 4) array of rows [birth, death, p_birth, p_death] sorted by
    descending (birth value, birth index); p_* are flat pixel indices.
    """
    img = np.asarray(image)
    h, w = img.shape
    n = h * w
    vals = img.reshape(-1)

    # Ascending stable argsort == ascending (value, index) total order.
    order_asc = np.argsort(vals, kind="stable")
    order = order_asc[::-1]  # descending total order
    rank = np.empty(n, np.int64)
    rank[order_asc] = np.arange(n)

    parent = np.full(n, -1, np.int64)   # -1 = not yet born
    comp_max = np.empty(n, np.int64)    # root -> pixel index of component max

    def find(p: int) -> int:
        root = p
        while parent[root] != root:
            root = parent[root]
        while parent[p] != root:        # path compression
            parent[p], p = root, parent[p]
        return root

    records = []  # (birth_val, death_val, p_birth, p_death)

    for p in order:
        r, c = divmod(int(p), w)
        roots = []
        for dr, dc in NEIGHBOR_OFFSETS:
            rr, cc = r + dr, c + dc
            if not (0 <= rr < h and 0 <= cc < w):
                continue
            q = rr * w + cc
            if parent[q] < 0:           # not yet in the filtration
                continue
            root = find(q)
            if root not in roots:
                roots.append(root)
        if not roots:
            # Local maximum under the total order: a component is born at p.
            parent[p] = p
            comp_max[p] = p
            continue
        # p joins the eldest adjacent component; every younger adjacent
        # component dies here (elder rule under the total order).
        elder = max(roots, key=lambda rt: rank[comp_max[rt]])
        parent[p] = elder
        for rt in roots:
            if rt == elder:
                continue
            records.append((vals[comp_max[rt]], vals[p],
                            int(comp_max[rt]), int(p)))
            parent[rt] = elder

    gmax = int(order[0])
    gmin = int(order[-1])
    records.append((vals[gmax], vals[gmin], gmax, gmin))

    rec = np.array([(b, d, pb, pd) for b, d, pb, pd in records],
                   dtype=np.float64).reshape(-1, 4)
    # Sort by descending (birth value, birth index) — same as Diagram order.
    key = np.lexsort((rec[:, 2], rec[:, 0]))[::-1]
    return rec[key]


def diagram_to_array(diag) -> np.ndarray:
    """Convert a (non-batched) core.Diagram to the oracle's (C, 4) layout."""
    count = int(diag.count)
    return np.stack([
        np.asarray(diag.birth[:count], np.float64),
        np.asarray(diag.death[:count], np.float64),
        np.asarray(diag.p_birth[:count], np.float64),
        np.asarray(diag.p_death[:count], np.float64),
    ], axis=1)
