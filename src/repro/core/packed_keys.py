"""Packed (value, index) merge keys: the rank-free phase-C total order.

Phase C used to key every merge decision on ``total_order_rank`` — a
full-image stable argsort whose cost dominates end-to-end CPU time once
phases A/B are fused (BENCH_core.json: ~4.3 s of ~5 s at 2k²).  But a rank
is just *one* order-isomorphic encoding of the strict total order
``(value, flat_index)``; this module provides another that needs no sort:

* :func:`monotone_key32` bit-casts a <= 32-bit value to a sign-corrected
  monotone ``int32`` — ``key(a) < key(b)`` iff ``a < b`` and
  ``key(a) == key(b)`` iff the backend's own comparisons call them equal
  (signed zeros are canonicalized first, so ``-0.0`` and ``+0.0`` share a
  key exactly like they tie under a stable argsort);
* :func:`pack_keys` packs ``(key32 << 32) | (flat_index + 1)`` into an
  ``int64`` that is order-isomorphic to the full ``(value, index)`` order.
  The ``+1`` reserves low word 0, so :data:`int64` min is a sentinel
  strictly below every real key even for full-range ``int32``/``uint32``
  images; :func:`packed_index` recovers the index (and maps the sentinel
  to -1, the usual "no pixel" value).

Every phase-C comparison (candidate ordering, elder selection, Boruvka
best-edge reduction, diagram top-k) consumes these keys exactly where it
consumed ranks, so the two paths are bit-identical
(``tests/test_merge_keys.py``) — only the compiled program changes.

The packed path needs 64-bit integers, which JAX disables by default.
Rather than flipping ``jax_enable_x64`` globally (which would change
default dtypes across the whole process), every public entry point wraps
its **outermost** jit call in :func:`key_scope` — the scope must cover
trace *and* lowering, which is why it cannot live inside a jitted
function.  :func:`resolve_merge_keys` falls back to ``"rank"`` whenever
packing cannot be used: > 32-bit dtypes, a missing x64 context manager,
or a caller tracing us inside their own jit without the scope active
(results are bit-identical either way; only performance differs).

NaNs are outside the contract: a stable argsort orders every NaN after
+inf while the bit trick orders negative NaNs below -inf.  Images are
filtrations here — NaN pixels are rejected upstream, not ordered.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.4: experimental but present; absence just disables packing
    from jax.experimental import enable_x64 as _enable_x64
except ImportError:  # pragma: no cover - exercised only on exotic installs
    _enable_x64 = None

MERGE_KEYS = ("packed", "rank")
FILTRATIONS = ("superlevel", "sublevel")

_I64_MIN = np.int64(np.iinfo(np.int64).min)
_LOW32 = np.int64(0xFFFFFFFF)


def resolve_filtration(filtration: str) -> str:
    """Validate a ``filtration`` request (superlevel or sublevel)."""
    if filtration not in FILTRATIONS:
        raise ValueError(f"filtration must be one of {FILTRATIONS}, "
                         f"got {filtration!r}")
    return filtration


def _float_dtype(dt) -> bool:
    dt = jnp.dtype(dt)
    return dt.kind == "f" or dt == jnp.bfloat16


def filtration_view(values, filtration: str):
    """Map values between user space and the internal superlevel order.

    The whole compute stack is written for the superlevel filtration
    (births at maxima, elder-rule merges downward).  A sublevel request
    is exact negation at the boundary: IEEE sign flips are bit-exact and
    order-reversing, so running the unchanged superlevel machinery on
    ``-x`` and negating the resulting diagram values is *bit-identical*
    to ``superlevel(-x)`` — the differential oracle the tests hold every
    path to.  Negation is an involution, so the same function maps both
    directions (image and threshold in, diagram births/deaths out).

    Sublevel needs a floating dtype: negating an integer image overflows
    at the dtype minimum (``-int32.min`` does not exist), so integer
    inputs are rejected with a clear error instead of wrapping silently.
    """
    resolve_filtration(filtration)
    if filtration == "superlevel":
        return values
    if not _float_dtype(values.dtype):
        raise ValueError(
            f"filtration='sublevel' requires a floating dtype (negation "
            f"of {jnp.dtype(values.dtype)} overflows at the minimum); "
            f"cast the image to a float dtype first")
    return -values


def check_finite(values, where: str = "image", *,
                 allow_inf: bool = False):
    """Reject non-finite pixels at a public boundary (shared message).

    Filtrations order pixels; NaN admits no order — the packed bit-cast
    (:func:`monotone_key32`) scatters negative-sign NaNs below ``-inf``
    while a stable argsort puts every NaN after ``+inf``, silently
    corrupting diagrams either way — so NaN is rejected with the same
    error at every public entry point (engine cast, core wrappers,
    packed *and* rank key paths).  ``±inf`` is rejected at the *user*
    boundary (``allow_inf=False``, the engine's ``cast_input_host``): it
    collides with the inert pad/halo sentinels.  The core wrappers pass
    ``allow_inf=True`` because padded/halo-filled frames legitimately
    carry the ``±inf`` fill by the time they reach them.  Subnormals are
    inside the contract: they order correctly under the sign-corrected
    bit-cast and the ``-0.0`` canonicalization keeps key equality
    matching comparison equality.

    Tracers pass through unchecked (a jitted caller's values are
    abstract); concrete device arrays sync once, which is the price of
    the check at an eager boundary.  Returns ``values`` unchanged.
    """
    if isinstance(values, jax.core.Tracer):
        return values
    arr = np.asarray(values)
    if not _float_dtype(arr.dtype):
        return values
    if arr.dtype.kind != "f":          # bfloat16: widen exactly for the test
        arr = arr.astype(np.float32)
    if np.isnan(arr).any():
        raise ValueError(
            f"non-finite pixel(s) in {where}: NaN values cannot be "
            f"ordered by a filtration; mask or clean the image before "
            f"calling")
    if not allow_inf and not np.isfinite(arr).all():
        raise ValueError(
            f"non-finite pixel(s) in {where}: infinite values collide "
            f"with the inert pad sentinels; mask or clean the image "
            f"before calling")
    return values


def packable_dtype(dtype) -> bool:
    """True when ``dtype`` values fit the 32-bit monotone key map."""
    dt = jnp.dtype(dtype)
    if dt.kind in ("i", "u"):
        return dt.itemsize <= 4
    if dt.kind == "f" or dt == jnp.bfloat16:
        return dt.itemsize <= 4
    return False


def x64_available() -> bool:
    """True when int64 keys can be materialized (scope or global flag)."""
    return _enable_x64 is not None or bool(jax.config.jax_enable_x64)


def resolve_merge_keys(requested: str, dtype) -> str:
    """Resolve a ``merge_keys`` request against what can actually run.

    ``"packed"`` degrades to ``"rank"`` (bit-identical, just argsort-keyed)
    when the dtype exceeds 32 bits, when no x64 scope can be opened, or
    when we are already inside someone else's trace without x64 active —
    entering the scope mid-trace would not cover lowering, and tracing
    int64 ops without it silently truncates them.
    """
    if requested not in MERGE_KEYS:
        raise ValueError(f"merge_keys must be one of {MERGE_KEYS}, "
                         f"got {requested!r}")
    if requested == "rank":
        return "rank"
    if not packable_dtype(dtype) or not x64_available():
        return "rank"
    if not jax.core.trace_state_clean() and not jax.config.jax_enable_x64:
        return "rank"
    return "packed"


def key_scope(merge_keys: str):
    """Context manager covering one packed-key trace+lower+execute.

    A no-op for the rank path, when x64 is already on, or when a trace is
    already in progress (the outer caller holds the scope then — entering
    here could not cover lowering anyway).
    """
    if (merge_keys == "packed" and _enable_x64 is not None
            and not jax.config.jax_enable_x64
            and jax.core.trace_state_clean()):
        return _enable_x64()
    return contextlib.nullcontext()


def assert_key_context(merge_keys: str) -> None:
    """Trace-time guard: packed keys without x64 active would silently
    truncate to int32 — fail loudly instead.  Call from jitted cores."""
    if merge_keys == "packed" and not jax.config.jax_enable_x64:
        raise ValueError(
            "merge_keys='packed' traced without an x64 scope; call through "
            "the public entry points (pixhomology, PHEngine) or wrap the "
            "outermost jit call in repro.core.packed_keys.key_scope")


def key_pad(dtype) -> jnp.ndarray:
    """Sentinel at or below every valid key of ``dtype``.

    ``int64`` packed keys of real pixels never reach int64 min (their low
    word is ``index + 1`` >= 1, since real pixels carry index >= 0);
    ``int32`` ranks are >= 0, so int32 min is below them too — one rule
    serves both encodings.  The one equality case: a tiled *halo fill*
    cell (index -1) whose fill value is the integer dtype's minimum packs
    to exactly this sentinel — callers there already exclude halo cells
    by mask (``& interior``), never by key comparison.
    """
    return jnp.asarray(jnp.iinfo(dtype).min, dtype)


def key_top(dtype) -> jnp.ndarray:
    """Sentinel >= every valid key of ``dtype`` (directional stencil fill)."""
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


def monotone_key32(values: jnp.ndarray) -> jnp.ndarray:
    """Order-isomorphic ``int32`` key of <= 32-bit values (any shape).

    Floats use the sign-corrected bit-cast: non-negative patterns are
    already ascending, negative ones are flipped.  Signed zeros are
    canonicalized through the backend's own equality (``v == 0``), so on
    backends that flush subnormals in comparisons the keys flush with
    them — key equality always matches comparison equality.
    """
    dt = jnp.dtype(values.dtype)
    if dt.kind in ("i", "u"):
        if dt.kind == "u" and dt.itemsize == 4:
            # Full-range uint32: recenter by flipping the top bit.
            return (values ^ jnp.uint32(0x80000000)).view(jnp.int32)
        return values.astype(jnp.int32)
    if not packable_dtype(dt):
        raise ValueError(f"dtype {dt} does not fit 32-bit monotone keys")
    v = values.astype(jnp.float32)
    v = jnp.where(v == 0, jnp.zeros_like(v), v)   # -0.0 ties +0.0
    u = v.view(jnp.uint32)
    return jnp.where(u >> 31 == 1, u ^ jnp.uint32(0x7FFFFFFF), u).view(
        jnp.int32)


def pack_keys(values_flat: jnp.ndarray,
              index_flat: jnp.ndarray | None = None) -> jnp.ndarray:
    """``(monotone_key32(v) << 32) | (index + 1)`` as int64 (flat arrays).

    Order-isomorphic to the strict total order ``(value, index)`` the
    stable-argsort ranks encode — the drop-in phase-C replacement that
    costs one bit-cast instead of a full-image sort.  ``index_flat``
    defaults to the flat position (the whole-image case); the tiled path
    passes *global* pixel indices so per-tile keys stay globally
    comparable.  Cells with index -1 (out-of-frame halo fill) pack low
    word 0: below every real pixel of equal value, above the int64-min
    pad sentinel.
    """
    k32 = monotone_key32(values_flat)
    if index_flat is None:
        index_flat = jnp.arange(values_flat.shape[0], dtype=jnp.int32)
    low = (index_flat.astype(jnp.int64) + 1) & _LOW32
    return (k32.astype(jnp.int64) << 32) | low


def packed_index(keys: jnp.ndarray) -> jnp.ndarray:
    """Recover the flat index from packed keys (pad sentinel maps to -1)."""
    return ((keys & _LOW32) - 1).astype(jnp.int32)


def select_descending(key_flat: jnp.ndarray, mask_flat: jnp.ndarray,
                      k: int, width: int = 2
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-``k`` masked keys in descending order: ``(keys, indices)``.

    Bit-identical to ``top_k(where(mask, key, pad), k)`` over the full
    array — same selected set, same order, valid keys are distinct by
    construction, **including under overflow** (more than ``k`` set
    lanes: the k largest keys are retained, exactly like the rank path's
    full ``top_k``) — but evaluated as a blockwise tournament: each
    round takes the per-block top-k of ``width * k``-wide blocks, so no
    sort ever spans more than ``width * k`` elements (``lax.top_k``
    lowers to a full sort of its operand on CPU; this is how "top-k over
    candidates only" stays true in the compiled HLO).  ``width`` trades
    round count against per-round sort extent (identical results for any
    ``width >= 2`` — every global top-k element survives its block's
    top-k — so it is a pure tuning knob, the one the autotuner picks).
    Lanes beyond the number of set entries return the pad key and
    index -1.
    """
    n = key_flat.shape[0]
    k = min(k, n)
    if width < 2:
        raise ValueError(f"tournament width must be >= 2, got {width}")
    pad = key_pad(key_flat.dtype)
    keys = jnp.where(mask_flat, key_flat, pad)
    ids = jnp.arange(n, dtype=jnp.int32)
    block = width * k
    while keys.shape[0] > block:
        length = keys.shape[0]
        m = -(-length // block)
        extra = m * block - length
        if extra:
            keys = jnp.concatenate(
                [keys, jnp.full(extra, pad, keys.dtype)])
            ids = jnp.concatenate([ids, jnp.full(extra, -1, jnp.int32)])
        top, order = jax.lax.top_k(keys.reshape(m, block), k)
        keys = top.reshape(-1)                       # shrinks: m*k <= L/w + k
        ids = jnp.take_along_axis(ids.reshape(m, block), order,
                                  axis=1).reshape(-1)
    top, order = jax.lax.top_k(keys, k)
    return top, jnp.where(top > pad, ids[order], -1)


def masked_top_k(key_flat: jnp.ndarray, mask_flat: jnp.ndarray,
                 k: int, width: int = 2) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Descending top-``k`` of the masked keys: ``(keys, positions)``.

    The single selection primitive every phase-C site uses: packed int64
    keys route through the blockwise tournament
    (:func:`select_descending`, block extent ``width * k``), dense int32
    ranks through one full-array ``top_k`` (their argsort already
    materialized the order, so there is nothing left to save).  Lanes
    beyond the number of set entries carry the pad key and an
    **in-range** position (clipped to 0) — consumers must mask on
    ``keys > key_pad(...)``, never on the position.
    """
    if key_flat.dtype == jnp.int64:
        top, idx = select_descending(key_flat, mask_flat, k, width)
        return top, jnp.clip(idx, 0)
    masked = jnp.where(mask_flat, key_flat, key_pad(key_flat.dtype))
    return jax.lax.top_k(masked, min(k, key_flat.shape[0]))
