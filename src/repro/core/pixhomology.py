"""PixHomology: 0-dimensional persistent homology of 2D images (paper §5.1).

Superlevel-set filtration: components are born at local maxima and die when
they merge into a component with an older (larger) birth (elder rule).  The
essential class of the global maximum dies at the global minimum (paper's
"ultimate death point").

The implementation is an explicit **three-stage graph** (see
``src/repro/ph/DESIGN.md`` §2 for the TPU adaptation rationale); the
whole-image, batched, sharded, and tiled paths all compose the same stages:

* **Phase A — pointers + candidate flags** (:func:`phase_a`).  Each pixel
  gets its steepest-ascent pointer under the strict total order
  ``(value, flat_index)`` plus the strictly-higher 8-neighbor bitmask.
  ``phase_a_impl="fused"`` (default) runs the
  :mod:`repro.kernels.ph_phase_a` kernel — one VMEM pass per
  ``strip_rows``-row strip that also pointer-chases every pixel to its
  furthest in-strip ancestor — on TPU when ``use_pallas`` resolves true,
  and the bit-identical pure-XLA reference elsewhere.
  ``phase_a_impl="pooled"`` is the unfused baseline: three pooled passes
  (``arg-maxpool2d`` via :mod:`repro.kernels.maxpool`) and raw pointers.

* **Phase B — label resolution** (:func:`phase_b`).  The paper iterates
  ``M[x] <- M[M[x]]`` to a fixed point; we pointer-double instead —
  O(log depth) iterations, not the paper's worst case O(n).  On fused
  phase-A output the doubling runs on a **compacted frontier** of
  strip-boundary rows and basin roots (:func:`resolve_labels_frontier`):
  snapped pointers only ever land on roots or the statically-known
  boundary rows, so each doubling round gathers O(n / strip_rows)
  entries instead of all n, plus one final dense gather — phase-B gather
  volume drops from O(n·log depth) to O(frontier·log depth + n)
  (DESIGN.md §Perf PH-3).  Pooled phase A resolves densely
  (:func:`resolve_labels`).

* **Phase C — merge + diagram** (:func:`phase_c`).  Death-point
  candidates (steps 3-4, below) are reduced by the sequential elder-rule
  sweep or the parallel Boruvka forest, the essential class is closed at
  the global minimum, and the fixed-capacity diagram is emitted.  Every
  comparison keys on an order-isomorphic encoding of the strict
  ``(value, flat_index)`` total order, selected by ``merge_keys``:
  ``"packed"`` (default) bit-casts each value to a monotone int64
  ``(key32 << 32) | index`` (:mod:`repro.core.packed_keys`) — **no
  full-image argsort anywhere**, every top-k a capacity-bounded
  blockwise tournament; ``"rank"`` is the argsort-materialized dense
  rank fallback
  (> 32-bit dtypes, or callers without an x64 scope).  Both paths are
  bit-identical (tests/test_merge_keys.py).

Candidate generators (steps 3-4): ``candidate_mode="exact"`` keeps pixels
whose *higher* 8-neighbors span >= 2 distinct basins — provably a superset
of all merge points and a subset of the paper's edge set; on the fused
path the rank comparisons come pre-packed in phase A's bitmask
(:func:`exact_candidates_masked`).  ``candidate_mode="paper"`` is the
paper's literal edge ∧ (local-min ∨ axis-saddle) distillation (kept for
fidelity; the axis saddle test can miss merge points on adversarial
images — documented in DESIGN.md §6).

All shapes are static (jit/vmap/shard_map friendly): diagrams are padded to
``max_features`` rows and candidate processing to ``max_candidates`` steps,
with explicit overflow flags so a driver can detect undersized capacities and
re-dispatch (fault-tolerance hook used by the pipeline).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# NEIGHBOR_OFFSETS is re-exported here for back-compat; it lives in
# repro.core.grid together with the shared neighbor-gather helpers.
from repro.core.grid import (  # noqa: F401
    NEIGHBOR_OFFSETS,
    fixed_point_iterate,
    higher_neighbor_basins,
    neg_inf,
    shift2d,
)
from repro.core import packed_keys
from repro.core.packed_keys import key_pad, key_top, masked_top_k
from repro.kernels.maxpool import ops as pool_ops
from repro.kernels.ph_phase_a import ops as phase_a_ops


class Diagram(NamedTuple):
    """Fixed-capacity persistence diagram (padded, shardable)."""

    birth: jnp.ndarray     # (F,) image dtype, descending; padding = -inf
    death: jnp.ndarray     # (F,) image dtype; -inf for padding/unmerged
    p_birth: jnp.ndarray   # (F,) int32 flat pixel index of the maximum; -1 pad
    p_death: jnp.ndarray   # (F,) int32 flat pixel index of the merge saddle
    count: jnp.ndarray     # () int32 number of valid rows (components found)
    n_unmerged: jnp.ndarray  # () int32 roots that never died (0 when exact)
    overflow: jnp.ndarray  # () bool: capacity exceeded -> retry with bigger F/K


class PhaseA(NamedTuple):
    """Phase-A artifacts (flat): pointers plus candidate pre-flags.

    ``pointers`` are strip-snapped (fused) or raw steepest-ascent (pooled);
    ``hi_mask`` is the strictly-higher 8-neighbor bitmask on the fused
    path and ``None`` on the pooled one (the dense candidate test derives
    the comparisons from ranks instead).
    """

    pointers: jnp.ndarray
    hi_mask: jnp.ndarray | None


# ---------------------------------------------------------------------------
# Total order helpers
# ---------------------------------------------------------------------------

def total_order_rank(values_flat: jnp.ndarray) -> jnp.ndarray:
    """rank[i] = position of pixel i in the ascending (value, index) order."""
    n = values_flat.shape[0]
    perm = jnp.argsort(values_flat, stable=True)  # ties -> ascending index
    return jnp.zeros(n, jnp.int32).at[perm].set(jnp.arange(n, dtype=jnp.int32))


def total_order_keys(values_flat: jnp.ndarray,
                     merge_keys: str) -> jnp.ndarray:
    """Phase-C merge keys: an order-isomorphic encoding of (value, index).

    ``"packed"``: :func:`repro.core.packed_keys.pack_keys` int64 bit-keys,
    O(n) with no sort; ``"rank"``: the dense int32 argsort ranks.  Both
    encodings compare identically under ``>``; phase C never uses any
    other operation on them.
    """
    if merge_keys == "packed":
        return packed_keys.pack_keys(values_flat)
    if merge_keys == "rank":
        return total_order_rank(values_flat)
    raise ValueError(f"unknown merge_keys {merge_keys!r}")


# ---------------------------------------------------------------------------
# Phase A: steepest-ascent pointers (+ in-strip snap / candidate flags)
# ---------------------------------------------------------------------------

def steepest_neighbors(image: jnp.ndarray, *, use_pallas: bool | None = None,
                       interpret: bool = False) -> jnp.ndarray:
    """arg-maxpool2d(I): flat index of each pixel's 3x3 max (paper line 1)."""
    _, arg = pool_ops.maxargmaxpool3x3(image, use_pallas=use_pallas,
                                       interpret=interpret)
    return arg.reshape(-1)


def keyed_steepest_pointers(values2d: jnp.ndarray,
                            keys2d: jnp.ndarray) -> jnp.ndarray:
    """Steepest-ascent pointer (local flat id) under the (value, key) total
    order; self included.  Fill cells (key -1, value -inf) never win.

    This is the shared stage the tiled path instantiates with *global*
    pixel indices as keys on a halo-padded tile (per-tile order must be
    isomorphic to the global one), and the generic fallback for any
    stencil whose tie-break key is not the local flat index.
    """
    h, w = values2d.shape
    flat = jnp.arange(h * w, dtype=jnp.int32).reshape(h, w)
    fill_v = neg_inf(values2d.dtype)
    best_v, best_k, best_l = values2d, keys2d, flat
    for dr, dc in NEIGHBOR_OFFSETS:
        v = shift2d(values2d, dr, dc, fill_v)
        k = shift2d(keys2d, dr, dc, jnp.int32(-1))
        l = shift2d(flat, dr, dc, jnp.int32(-1))
        better = (v > best_v) | ((v == best_v) & (k > best_k))
        best_v = jnp.where(better, v, best_v)
        best_k = jnp.where(better, k, best_k)
        best_l = jnp.where(better, l, best_l)
    return best_l


def phase_a(image: jnp.ndarray, *, phase_a_impl: str = "fused",
            strip_rows: int = 8, use_pallas: bool | None = None,
            interpret: bool = False) -> PhaseA:
    """Stage A: per-pixel pointers + candidate flags (paper lines 1-2a).

    ``"fused"`` routes through :mod:`repro.kernels.ph_phase_a` (Pallas on
    TPU / its bit-identical XLA reference elsewhere, per ``use_pallas``):
    pointers arrive snapped to in-strip ancestors with the higher-neighbor
    bitmask.  ``"pooled"`` is the unfused baseline: a pooled argmax pass
    and raw pointers (flags derived later from ranks).
    """
    if phase_a_impl == "fused":
        ptr, hi_mask = phase_a_ops.fused_phase_a(
            image, strip_rows=strip_rows, use_pallas=use_pallas,
            interpret=interpret)
        return PhaseA(ptr, hi_mask)
    if phase_a_impl == "pooled":
        return PhaseA(steepest_neighbors(image, use_pallas=use_pallas,
                                         interpret=interpret), None)
    raise ValueError(f"unknown phase_a_impl {phase_a_impl!r}")


# ---------------------------------------------------------------------------
# Phase B: label resolution (dense doubling or compacted frontier)
# ---------------------------------------------------------------------------

def resolve_labels(pointers: jnp.ndarray, *, with_count: bool = False):
    """Pointer-double ``M = M[M]`` to a fixed point (paper lines 2-4).

    Returns labels[i] = flat index of pixel i's basin root, converging in
    O(log(max basin depth)) iterations; each iteration is a single
    whole-array gather (the changed flag rides the carry instead of
    re-gathering in ``cond`` — DESIGN.md §Perf PH-3).
    """
    m, count = fixed_point_iterate(lambda q: q[q], pointers)
    return (m, count) if with_count else m


def resolve_labels_frontier(pointers: jnp.ndarray, shape: tuple[int, int],
                            strip_rows: int, *, with_count: bool = False):
    """Label resolution on the compacted strip-boundary frontier.

    ``pointers`` must be strip-snapped (fused phase A): every entry is a
    basin root or a pixel in a statically-known boundary row
    (:func:`repro.kernels.ph_phase_a.boundary_rows`).  Doubling therefore
    runs on the O(n / strip_rows) frontier table alone; one final dense
    gather extends the result to all pixels.  Output is bit-identical to
    :func:`resolve_labels` on the same (or raw) pointers.
    """
    h, w = shape
    b_rows = phase_a_ops.boundary_rows(h, strip_rows)
    row_slot_np = np.full(h, -1, np.int32)
    row_slot_np[b_rows] = np.arange(len(b_rows), dtype=np.int32)
    row_slot = jnp.asarray(row_slot_np)
    b_flat = jnp.asarray(
        (b_rows[:, None].astype(np.int64) * w
         + np.arange(w, dtype=np.int64)[None, :]).reshape(-1).astype(np.int32))

    def follow(table, q):
        rs = row_slot[q // w]
        slot = rs * w + q % w
        return jnp.where(rs >= 0, table[jnp.clip(slot, 0)], q)

    p0 = pointers[b_flat]
    table, count = fixed_point_iterate(lambda p: follow(p, p), p0)
    labels = follow(table, pointers)
    return (labels, count) if with_count else labels


def phase_b(pa: PhaseA, shape: tuple[int, int], *,
            phase_a_impl: str = "fused", strip_rows: int = 8) -> jnp.ndarray:
    """Stage B: basin labels from phase-A pointers (paper lines 2-4)."""
    if phase_a_impl == "fused":
        return resolve_labels_frontier(pa.pointers, shape, strip_rows)
    return resolve_labels(pa.pointers)


# ---------------------------------------------------------------------------
# Steps 3-4: candidate death points
# ---------------------------------------------------------------------------

def exact_candidates(key2d: jnp.ndarray, labels2d: jnp.ndarray) -> jnp.ndarray:
    """Pixels whose strictly-higher 8-neighbors span >= 2 distinct basins.

    This is exactly the set of pixels at which the union-find sweep can merge
    two components, so it is complete (no lost deaths) and is a strict subset
    of the paper's step-3 edge set (tighter distillation).

    ``key2d`` is any order-isomorphic total-order key image (dense ranks or
    packed int64 keys).  Labels may exceed the local pixel count (the tiled
    path passes *global* labels on a halo-padded tile), so the no-neighbor
    sentinel for ``hi_min`` is int32 max rather than ``key2d.size``.
    """
    no_lbl = jnp.iinfo(jnp.int32).max
    fill = key_pad(key2d.dtype)
    hi_max = jnp.full(key2d.shape, -1, jnp.int32)
    hi_min = jnp.full(key2d.shape, no_lbl, jnp.int32)
    for dr, dc in NEIGHBOR_OFFSETS:
        nkey = shift2d(key2d, dr, dc, fill)
        nlbl = shift2d(labels2d, dr, dc, jnp.int32(-1))
        higher = nkey > key2d  # border fill (dtype min) is never higher
        hi_max = jnp.where(higher, jnp.maximum(hi_max, nlbl), hi_max)
        hi_min = jnp.where(higher, jnp.minimum(hi_min, nlbl), hi_min)
    return (hi_max >= 0) & (hi_max != hi_min)


def exact_candidates_masked(hi_mask2d: jnp.ndarray,
                            labels2d: jnp.ndarray) -> jnp.ndarray:
    """:func:`exact_candidates` from phase A's higher-neighbor bitmask.

    Bit j of ``hi_mask2d`` (``NEIGHBOR_OFFSETS`` order) encodes exactly the
    rank comparison ``rank[nb_j] > rank[self]``, so the result is
    bit-identical to the rank-based test without re-deriving ranks —
    the fused path's candidate generator.
    """
    no_lbl = jnp.iinfo(jnp.int32).max
    hi_max = jnp.full(hi_mask2d.shape, -1, jnp.int32)
    hi_min = jnp.full(hi_mask2d.shape, no_lbl, jnp.int32)
    for j, (dr, dc) in enumerate(NEIGHBOR_OFFSETS):
        nlbl = shift2d(labels2d, dr, dc, jnp.int32(-1))
        higher = (hi_mask2d >> j) & 1 == 1
        hi_max = jnp.where(higher, jnp.maximum(hi_max, nlbl), hi_max)
        hi_min = jnp.where(higher, jnp.minimum(hi_min, nlbl), hi_min)
    return (hi_max >= 0) & (hi_max != hi_min)


def paper_candidates(key2d: jnp.ndarray, comp2d: jnp.ndarray,
                     *, use_pallas: bool | None = None,
                     interpret: bool = False) -> jnp.ndarray:
    """Paper-literal steps 3-4: component edges, then min/saddle distillation.

    comp2d: re-indexed component image (incremental ids, paper step 2).
    Edge:   maxpool2d(M) != -maxpool2d(-M)           (paper line 6)
    Keep:   local minima or axis saddles of I        (paper "distillation")
    """
    edge = (pool_ops.maxpool3x3(comp2d, use_pallas=use_pallas,
                                interpret=interpret)
            != pool_ops.minpool3x3(comp2d, use_pallas=use_pallas,
                                   interpret=interpret))

    # Neighbor keys with directional fills: for "min along" tests a missing
    # neighbor counts as higher (dtype max); for "max along" as lower
    # (dtype min) — valid keys never reach either sentinel.
    hi, lo = key_top(key2d.dtype), key_pad(key2d.dtype)

    def nb(dr, dc, fill):
        return shift2d(key2d, dr, dc, fill)

    local_min = jnp.ones(key2d.shape, bool)
    for dr, dc in NEIGHBOR_OFFSETS:
        local_min &= nb(dr, dc, hi) > key2d

    axes = [(0, 1), (1, 0), (1, 1), (1, -1)]
    min_along = []
    max_along = []
    for dr, dc in axes:
        min_along.append((nb(dr, dc, hi) > key2d) & (nb(-dr, -dc, hi) > key2d))
        max_along.append((nb(dr, dc, lo) < key2d) & (nb(-dr, -dc, lo) < key2d))
    saddle = jnp.zeros(key2d.shape, bool)
    for a in range(len(axes)):
        for b in range(len(axes)):
            if a != b:
                saddle |= min_along[a] & max_along[b]
    return edge & (local_min | saddle)


def reindex_components(key_flat: jnp.ndarray, labels_flat: jnp.ndarray,
                       is_root: jnp.ndarray) -> jnp.ndarray:
    """Paper step 2 re-indexing: component ids 0..C-1 ascending by birth.

    Returns per-pixel component id; id C-1 = component of the global
    maximum.  The root argsort here is inherent to the paper's incremental
    component ids (only ``candidate_mode="paper"`` pays it; the exact mode
    never re-indexes), so it remains on the packed-key path too.
    """
    n = key_flat.shape[0]
    c = jnp.sum(is_root, dtype=jnp.int32)
    root_key = jnp.where(is_root, key_flat, key_pad(key_flat.dtype))
    order = jnp.argsort(root_key)               # non-roots first, roots asc
    slot = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    comp_of_root = slot - (jnp.int32(n) - c)    # roots -> 0..C-1
    return comp_of_root[labels_flat]


# ---------------------------------------------------------------------------
# Phase C: merge sweep + diagram assembly (paper steps 5-6)
# ---------------------------------------------------------------------------

def _find_vec(parent: jnp.ndarray, start: jnp.ndarray) -> jnp.ndarray:
    """Vectorized union-find root lookup (parent is fixed during the search)."""
    p, _ = fixed_point_iterate(lambda q: parent[q], start)
    return p


def merge_components(image_flat: jnp.ndarray, key_flat: jnp.ndarray,
                     labels_flat: jnp.ndarray, cand_flat: jnp.ndarray,
                     shape: tuple[int, int], max_candidates: int,
                     truncate_value=None):
    """Process candidates in descending (value, index) order, union-find merge.

    ``key_flat``: dense int32 ranks or packed int64 keys — the sweep only
    compares them.  On packed keys the top-k selection runs as a
    blockwise tournament (``packed_keys.select_descending``): identical
    retained set and order — including under candidate overflow — but no
    sort spans more than 2k elements.  The rank path keeps the
    full-array ``top_k`` (its ranks already cost a full argsort, so
    there is nothing to save).

    Returns (death_val, death_pos, overflow): per-root death records.
    """
    h, w = shape
    n = h * w
    k = min(max_candidates, n)
    pad = key_pad(key_flat.dtype)

    if truncate_value is not None:
        # Variant 2 (paper §5.2.1): sub-threshold pixels are excluded from
        # the analysis — merges below the threshold never run; the survivors
        # are truncated at the threshold by the caller.
        cand_flat = cand_flat & (image_flat >= truncate_value)
    n_cand = jnp.sum(cand_flat, dtype=jnp.int32)
    top_keys, top_pix = masked_top_k(key_flat, cand_flat, k)  # descending
    overflow = n_cand > k

    neg_inf = (-jnp.inf if jnp.issubdtype(image_flat.dtype, jnp.floating)
               else jnp.iinfo(image_flat.dtype).min)

    def step(carry, xs):
        parent, dval, dpos = carry
        x, xkey = xs
        valid = xkey > pad
        ok, basin = higher_neighbor_basins(x, xkey, key_flat, labels_flat,
                                           (h, w), valid)  # (8,) each

        start = jnp.where(ok, basin, x)      # x is never a root: safe filler
        roots = _find_vec(parent, start)
        root_key = jnp.where(ok, key_flat[roots], pad)
        elder = roots[jnp.argmax(root_key)]

        # Deduplicate equal roots among the 8 slots; younger distinct roots die.
        dup = jnp.zeros(8, bool)
        for j in range(1, 8):
            seen = (roots[:j] == roots[j]) & ok[:j]
            dup = dup.at[j].set(jnp.any(seen))
        die = ok & ~dup & (roots != elder)

        drop = jnp.int32(n)  # scatter target for masked-out lanes
        parent = parent.at[jnp.where(ok, roots, drop)].set(elder, mode="drop")
        parent = parent.at[jnp.where(ok, basin, drop)].set(elder, mode="drop")
        dval = dval.at[jnp.where(die, roots, drop)].set(
            image_flat[x], mode="drop")
        dpos = dpos.at[jnp.where(die, roots, drop)].set(x, mode="drop")
        return (parent, dval, dpos), None

    parent0 = jnp.arange(n, dtype=jnp.int32)
    dval0 = jnp.full(n, neg_inf, image_flat.dtype)
    dpos0 = jnp.full(n, -1, jnp.int32)
    (parent, dval, dpos), _ = jax.lax.scan(
        step, (parent0, dval0, dpos0), (top_pix, top_keys))
    del parent
    return dval, dpos, overflow


def phase_c(image_flat: jnp.ndarray, key_flat: jnp.ndarray,
            labels_flat: jnp.ndarray, cand_flat: jnp.ndarray,
            shape: tuple[int, int], truncate_value=None, *,
            max_features: int, max_candidates: int,
            merge_impl: str = "scan", phase_c_impl: str = "fused",
            phase_c_block: int = 1024, tournament_width: int = 2,
            use_pallas: bool | None = None,
            interpret: bool = False) -> Diagram:
    """Stage C: elder-rule merge + essential class + diagram (steps 5-6).

    ``merge_impl="scan"`` is the paper-faithful sequential sweep;
    ``"boruvka"`` the parallel merge forest (O(log C) rounds,
    bit-identical — see ``parallel_merge.py``).  ``key_flat`` carries the
    total order in either encoding (ranks / packed); on packed keys the
    diagram's root top-k also runs as a blockwise tournament (extent
    ``tournament_width * k``), so phase C contains no full-image-length
    sort at all.

    ``phase_c_impl`` selects the Boruvka implementation (ignored by the
    scan sweep): ``"xla"`` runs the rounds over all n pixel-vertices;
    ``"fused"`` (the default) compacts to the top-``max_features`` root
    instance first and reduces with the ``repro.kernels.ph_phase_c``
    blocked kernel (``phase_c_block`` edges per VMEM block) — bit-
    identical whenever the roots fit ``max_features`` (under root
    overflow both impls raise the same flag and the engine regrows; see
    ``kernels/ph_phase_c/ops.py``).
    """
    h, w = shape
    n = h * w
    vals = image_flat
    is_root = labels_flat == jnp.arange(n, dtype=jnp.int32)
    f = min(max_features, n)
    neg_inf = (-jnp.inf if jnp.issubdtype(vals.dtype, jnp.floating)
               else jnp.iinfo(vals.dtype).min)
    gmax = jnp.argmax(key_flat).astype(jnp.int32)
    gmin = jnp.argmin(key_flat).astype(jnp.int32)
    root_mask = is_root if truncate_value is None else \
        is_root & (vals >= truncate_value)

    if merge_impl == "boruvka" and phase_c_impl == "fused":
        # Compact fused path: merge + diagram read the same top-f root
        # table, so deaths never materialize in the pixel domain at all.
        from repro.kernels.ph_phase_c import ops as phase_c_ops
        cand_b = cand_flat if truncate_value is None else \
            cand_flat & (vals >= truncate_value)
        (_, root_pix, rvalid, dval_c, dpos_c, overflow_k,
         _rounds) = phase_c_ops.fused_merge(
            vals, key_flat, labels_flat, cand_b, root_mask, (h, w),
            max_candidates=max_candidates, max_features=max_features,
            phase_c_block=phase_c_block, tournament_width=tournament_width,
            use_pallas=use_pallas, interpret=interpret)
        if truncate_value is not None:
            undied_c = rvalid & (dpos_c < 0)
            dval_c = jnp.where(undied_c,
                               jnp.asarray(truncate_value, dval_c.dtype),
                               dval_c)
        # Essential class on the compact table: slot 0 is the global
        # maximum's root whenever any root exists (paper fig 3).
        dval_c = dval_c.at[0].set(
            jnp.where(rvalid[0], vals[gmin], dval_c[0]))
        dpos_c = dpos_c.at[0].set(jnp.where(rvalid[0], gmin, dpos_c[0]))

        c = jnp.sum(root_mask, dtype=jnp.int32)
        row_valid = jnp.arange(f) < c
        birth = jnp.where(row_valid, vals[root_pix], neg_inf)
        death = jnp.where(row_valid, dval_c, neg_inf)
        p_birth = jnp.where(row_valid, root_pix, -1).astype(jnp.int32)
        p_death = jnp.where(row_valid, dpos_c, -1).astype(jnp.int32)
        n_unmerged = jnp.sum(rvalid & (dpos_c < 0), dtype=jnp.int32)
        overflow = overflow_k | (c > f)
        return Diagram(birth, death, p_birth, p_death,
                       jnp.minimum(c, f), n_unmerged, overflow)

    if merge_impl == "scan":
        dval, dpos, overflow_k = merge_components(
            vals, key_flat, labels_flat, cand_flat, (h, w), max_candidates,
            truncate_value=truncate_value)
    elif merge_impl == "boruvka":
        from repro.core import parallel_merge
        cand_b = cand_flat if truncate_value is None else \
            cand_flat & (vals >= truncate_value)
        dval, dpos, overflow_k, _rounds = parallel_merge.boruvka_merge(
            vals, key_flat, labels_flat, cand_b, (h, w), max_candidates,
            n_live=jnp.sum(root_mask, dtype=jnp.int32),
            tournament_width=tournament_width)
    else:
        raise ValueError(f"unknown merge_impl {merge_impl!r}")

    if truncate_value is not None:
        # Sub-threshold components are background; survivors die at t.
        is_root = root_mask
        undied = is_root & (dpos < 0)
        dval = jnp.where(undied, jnp.asarray(truncate_value, dval.dtype),
                         dval)

    # Essential class: global maximum dies at the global minimum (paper fig 3).
    dval = dval.at[gmax].set(vals[gmin])
    dpos = dpos.at[gmax].set(gmin)

    # Step 6: persistence diagram, descending by birth.
    _, root_pix = masked_top_k(key_flat, is_root, f, tournament_width)
    row_valid = jnp.arange(f) < jnp.sum(is_root, dtype=jnp.int32)

    birth = jnp.where(row_valid, vals[root_pix], neg_inf)
    death = jnp.where(row_valid, dval[root_pix], neg_inf)
    p_birth = jnp.where(row_valid, root_pix, -1).astype(jnp.int32)
    p_death = jnp.where(row_valid, dpos[root_pix], -1).astype(jnp.int32)

    c = jnp.sum(is_root, dtype=jnp.int32)
    n_unmerged = jnp.sum(is_root & (dpos < 0), dtype=jnp.int32)
    overflow = overflow_k | (c > f)
    return Diagram(birth, death, p_birth, p_death,
                   jnp.minimum(c, f), n_unmerged, overflow)


# ---------------------------------------------------------------------------
# Full algorithm (paper Algorithm 1): phase_a -> phase_b -> phase_c
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("max_features", "max_candidates", "candidate_mode",
                     "use_pallas", "interpret", "merge_impl", "phase_a_impl",
                     "strip_rows", "merge_keys", "phase_c_impl",
                     "phase_c_block", "tournament_width", "filtration"))
def _pixhomology(image: jnp.ndarray, truncate_value=None, *,
                 max_features: int = 256,
                 max_candidates: int = 4096,
                 candidate_mode: str = "exact",
                 use_pallas: bool | None = None,
                 interpret: bool = False,
                 merge_impl: str = "scan",
                 phase_a_impl: str = "fused",
                 strip_rows: int = 8,
                 merge_keys: str = "rank",
                 phase_c_impl: str = "fused",
                 phase_c_block: int = 1024,
                 tournament_width: int = 2,
                 filtration: str = "superlevel") -> Diagram:
    """Jitted Algorithm-1 core; ``merge_keys`` must arrive fully resolved
    (the public :func:`pixhomology` wrapper resolves it and opens the x64
    scope the packed encoding needs).

    ``filtration="sublevel"`` is an exact boundary negation: the image
    (and Variant-2 threshold, whose ``keep <= t`` semantics negate to the
    internal ``keep >= -t``) flip sign on entry, the unchanged superlevel
    machinery runs, and the diagram's birth/death values flip back on
    exit.  IEEE negation is bit-exact, so the result is bit-identical to
    ``superlevel(-image)`` with the signs flipped — the differential
    oracle in ``tests/test_filtration_distance.py``.
    """
    if image.ndim != 2:
        raise ValueError(f"expected 2D image, got shape {image.shape}")
    packed_keys.assert_key_context(merge_keys)
    image = packed_keys.filtration_view(image, filtration)
    if truncate_value is not None and filtration == "sublevel":
        truncate_value = jnp.negative(truncate_value)
    h, w = image.shape
    vals = image.reshape(-1)
    key = total_order_keys(vals, merge_keys)

    # Stage A: pointers + candidate flags; stage B: basin labels.
    pa = phase_a(image, phase_a_impl=phase_a_impl, strip_rows=strip_rows,
                 use_pallas=use_pallas, interpret=interpret)
    labels = phase_b(pa, (h, w), phase_a_impl=phase_a_impl,
                     strip_rows=strip_rows)

    # Steps 3-4: death-point candidates.
    key2d = key.reshape(h, w)
    if candidate_mode == "exact":
        if pa.hi_mask is not None:
            cand = exact_candidates_masked(pa.hi_mask.reshape(h, w),
                                           labels.reshape(h, w)).reshape(-1)
        else:
            cand = exact_candidates(key2d, labels.reshape(h, w)).reshape(-1)
    elif candidate_mode == "paper":
        is_root = labels == jnp.arange(h * w, dtype=jnp.int32)
        comp2d = reindex_components(key, labels, is_root).reshape(h, w)
        cand = paper_candidates(key2d, comp2d, use_pallas=use_pallas,
                                interpret=interpret).reshape(-1)
    else:
        raise ValueError(f"unknown candidate_mode {candidate_mode!r}")

    # Stage C: merge + essential class + diagram.
    d = phase_c(vals, key, labels, cand, (h, w), truncate_value,
                max_features=max_features, max_candidates=max_candidates,
                merge_impl=merge_impl, phase_c_impl=phase_c_impl,
                phase_c_block=phase_c_block,
                tournament_width=tournament_width,
                use_pallas=use_pallas, interpret=interpret)
    if filtration == "sublevel":
        # Back to user space: births ascend from minima, padding flips to
        # +inf, the essential class dies at the global maximum.
        d = d._replace(birth=jnp.negative(d.birth),
                       death=jnp.negative(d.death))
    return d


def pixhomology(image: jnp.ndarray, truncate_value=None, *,
                merge_keys: str = "packed", **kwargs) -> Diagram:
    """0-dim PH of a 2D image (Algorithm 1), superlevel by default.

    Returns a fixed-capacity :class:`Diagram`, rows sorted by descending
    (birth value, birth index); row 0 is the essential class of the global
    maximum with death at the global minimum.  ``filtration="sublevel"``
    flips the order (floating dtypes only): rows sort ascending by birth,
    padding is ``+inf``, and the essential class of the global minimum
    dies at the global maximum — bit-identical to ``superlevel(-image)``
    with the signs flipped.

    Non-finite pixels are rejected with :func:`packed_keys.check_finite`
    on concrete inputs (NaN admits no filtration order; ±inf collides
    with the pad sentinels) — identically on the packed and rank key
    paths, since the check precedes key resolution.

    ``truncate_value`` (optional, traced): the paper's Variant-2 threshold.
    Components born below it are dropped, merges below it are skipped, and
    surviving non-essential components die at the threshold — the diagram
    truncated at t.  Births/deaths >= t are bit-identical to the untruncated
    run (tests/test_pipeline.py).

    ``phase_a_impl``/``strip_rows``/``merge_keys`` select the stage
    implementations (see the module docstring); every combination is
    bit-identical — only the compiled program changes, which is why they
    are part of the engine's plan key (``PHConfig.stage_signature``).
    ``merge_keys="packed"`` (the default) resolves to ``"rank"`` for
    > 32-bit dtypes or when the int64 scope cannot be opened; the packed
    trace runs under :func:`repro.core.packed_keys.key_scope`, entered
    here when this is the outermost call.
    """
    packed_keys.check_finite(image, allow_inf=True)
    merge_keys = packed_keys.resolve_merge_keys(merge_keys, image.dtype)
    with packed_keys.key_scope(merge_keys):
        return _pixhomology(image, truncate_value, merge_keys=merge_keys,
                            **kwargs)


def batched_pixhomology(images: jnp.ndarray, truncate_values=None, *,
                        merge_keys: str = "packed", **kwargs) -> Diagram:
    """vmap'd PixHomology over a batch (B, H, W) — one executor task each.

    ``truncate_values``: optional (B,) per-image Variant-2 thresholds."""
    packed_keys.check_finite(images, allow_inf=True)
    merge_keys = packed_keys.resolve_merge_keys(merge_keys, images.dtype)
    fn = functools.partial(_pixhomology, merge_keys=merge_keys, **kwargs)
    with packed_keys.key_scope(merge_keys):
        if truncate_values is None:
            return jax.vmap(lambda im: fn(im))(images)
        return jax.vmap(lambda im, t: fn(im, t))(images, truncate_values)


def num_candidates(image: jnp.ndarray,
                   candidate_mode: str = "exact",
                   truncate_value=None, *,
                   use_pallas: bool | None = None,
                   interpret: bool = False,
                   phase_a_impl: str = "fused",
                   strip_rows: int = 8,
                   merge_keys: str = "packed",
                   filtration: str = "superlevel") -> jnp.ndarray:
    """Count death-point candidates (to size ``max_candidates``).

    The stage toggles follow the same semantics as :func:`pixhomology`
    (and must match it for the count to size the same dispatch);
    :meth:`repro.ph.PHEngine.num_candidates` forwards its config
    automatically.  The candidate *set* is key-encoding invariant, but
    ``merge_keys`` still picks how the total order is materialized on the
    branches that need it (packed bit-keys avoid the argsort here too).
    """
    h, w = image.shape
    packed_keys.check_finite(image, allow_inf=True)
    image = packed_keys.filtration_view(image, filtration)
    if truncate_value is not None and filtration == "sublevel":
        truncate_value = jnp.negative(truncate_value)
    merge_keys = packed_keys.resolve_merge_keys(merge_keys, image.dtype)
    with packed_keys.key_scope(merge_keys):
        pa = phase_a(image, phase_a_impl=phase_a_impl, strip_rows=strip_rows,
                     use_pallas=use_pallas, interpret=interpret)
        labels = phase_b(pa, (h, w), phase_a_impl=phase_a_impl,
                         strip_rows=strip_rows)
        # Total-order keys are only materialized on the branches that
        # consume them (this helper runs eagerly, and a rank argsort
        # dominates large images — the fused+exact path needs just the
        # phase-A bitmask).
        if candidate_mode == "exact":
            if pa.hi_mask is not None:
                cand = exact_candidates_masked(pa.hi_mask.reshape(h, w),
                                               labels.reshape(h, w))
            else:
                key = total_order_keys(image.reshape(-1), merge_keys)
                cand = exact_candidates(key.reshape(h, w),
                                        labels.reshape(h, w))
        else:
            key = total_order_keys(image.reshape(-1), merge_keys)
            is_root = labels == jnp.arange(h * w, dtype=jnp.int32)
            comp2d = reindex_components(key, labels, is_root).reshape(h, w)
            cand = paper_candidates(key.reshape(h, w), comp2d,
                                    use_pallas=use_pallas,
                                    interpret=interpret)
        if truncate_value is not None:
            cand = cand & (image >= truncate_value)
        return jnp.sum(cand, dtype=jnp.int32)
