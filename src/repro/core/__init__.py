"""Core PixHomology algorithm (the paper's primary contribution)."""
from repro.core.packed_keys import (  # noqa: F401
    monotone_key32,
    pack_keys,
    packable_dtype,
    packed_index,
    resolve_merge_keys,
)
from repro.core.pixhomology import (  # noqa: F401
    Diagram,
    PhaseA,
    batched_pixhomology,
    exact_candidates,
    exact_candidates_masked,
    keyed_steepest_pointers,
    merge_components,
    num_candidates,
    paper_candidates,
    phase_a,
    phase_b,
    phase_c,
    pixhomology,
    reindex_components,
    resolve_labels,
    resolve_labels_frontier,
    steepest_neighbors,
    total_order_keys,
    total_order_rank,
)
from repro.core.reference import diagram_to_array, persistence_oracle  # noqa: F401
from repro.core.tiling import (  # noqa: F401
    TiledDiagram,
    choose_grid,
    tiled_pixhomology,
)
