"""Parallel merge phase: Boruvka rounds instead of the sequential sweep.

The paper's step 5 processes candidates one-by-one in descending order
(inherently sequential; our faithful version is a fixed-length ``lax.scan``
— 16384 sequential steps for a 1k x 1k astro image).  0-dim superlevel
persistence is equivalent to elder-rule pairing on the *maximum spanning
forest* of the saddle graph, which Boruvka builds in O(log C) fully-parallel
rounds:

  round:  every cluster finds its highest incident saddle edge (segment-max
          via scatter-max, two passes for argmax);  every cluster whose best
          edge leads to an older cluster DIES there (death = that saddle);
          union pointers are resolved by pointer doubling.

Correctness: "die" pointers always point to strictly larger birth keys, so
the simultaneous merges form a forest (no cycles) and each dier's death
saddle equals the one the sequential sweep would assign — the output is
bit-identical to the union-find oracle (tests/test_parallel_merge.py).

Edges are generated from the exact candidate set: per candidate pixel, a
chain over its (masked) higher-neighbor basins — a spanning set of the
clique of basins meeting at that pixel, so all merges at a value-v saddle
still happen at value v.

Depth: the scan is O(K) sequential steps with O(1) work; Boruvka is
O(log C) rounds of O(E) parallel work — on a systolic/vector machine depth
is what matters (EXPERIMENTS.md §Perf PH-2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pixhomology import NEIGHBOR_OFFSETS


def candidate_edges(rank_flat, labels_flat, cand_flat, shape,
                    max_candidates: int):
    """Top-K candidates -> chained basin edges (K, 7, 3): [rank_x, a, b]."""
    h, w = shape
    n = h * w
    k = min(max_candidates, n)
    cand_rank = jnp.where(cand_flat, rank_flat, jnp.int32(-1))
    top_ranks, top_pix = jax.lax.top_k(cand_rank, k)
    valid = top_ranks >= 0

    xr = top_pix // w
    xc = top_pix % w
    lbls = []
    oks = []
    for dr, dc in NEIGHBOR_OFFSETS:
        rr, cc = xr + dr, xc + dc
        inb = (rr >= 0) & (rr < h) & (cc >= 0) & (cc < w)
        nid = jnp.clip(rr * w + cc, 0, n - 1)
        higher = rank_flat[nid] > top_ranks
        oks.append(inb & higher & valid)
        lbls.append(labels_flat[nid])
    ok = jnp.stack(oks, 1)       # (K, 8)
    lbl = jnp.stack(lbls, 1)     # (K, 8)

    # Chain consecutive valid slots: edge j connects slot j's basin to the
    # previous valid slot's basin (spanning set of the per-candidate clique).
    def chain(ok_row, lbl_row):
        def step(prev, xs):
            o, l = xs
            a = jnp.where(o, prev, -1)
            prev = jnp.where(o, l, prev)
            return prev, a

        _, prev_lbl = jax.lax.scan(step, jnp.int32(-1), (ok_row, lbl_row))
        return prev_lbl            # (8,) previous valid basin or -1

    prev_lbl = jax.vmap(chain)(ok, lbl)
    edge_ok = ok & (prev_lbl >= 0) & (prev_lbl != lbl)
    ranks = jnp.broadcast_to(top_ranks[:, None], ok.shape)
    return (jnp.where(edge_ok, ranks, -1).reshape(-1),
            jnp.where(edge_ok, lbl, 0).reshape(-1),
            jnp.where(edge_ok, prev_lbl, 0).reshape(-1))


def boruvka_merge(image_flat, rank_flat, labels_flat, cand_flat, shape,
                  max_candidates: int, max_rounds: int = 40):
    """Parallel replacement for ``pixhomology.merge_components``."""
    n = image_flat.shape[0]
    e_rank, e_a, e_b = candidate_edges(rank_flat, labels_flat, cand_flat,
                                       shape, max_candidates)
    n_edges = e_rank.shape[0]
    neg_inf = (-jnp.inf if jnp.issubdtype(image_flat.dtype, jnp.floating)
               else jnp.iinfo(image_flat.dtype).min)

    # Map candidate rank back to pixel id for death positions.
    perm = jnp.argsort(rank_flat, stable=True)       # rank -> pixel id

    parent0 = jnp.arange(n, dtype=jnp.int32)
    dval0 = jnp.full(n, neg_inf, image_flat.dtype)
    dpos0 = jnp.full(n, -1, jnp.int32)

    def resolve(p):
        def cond(q):
            return jnp.any(q[q] != q)

        def body(q):
            return q[q]

        return jax.lax.while_loop(cond, body, p)

    def round_body(state):
        parent, dval, dpos, _ = state
        roots = resolve(parent)
        ra = roots[e_a]
        rb = roots[e_b]
        alive = (e_rank >= 0) & (ra != rb)
        key = jnp.where(alive, e_rank, -1)

        # Pass 1: per-cluster best saddle rank (scatter-max on both ends).
        best = jnp.full(n, -1, jnp.int32)
        best = best.at[jnp.where(alive, ra, n)].max(key, mode="drop")
        best = best.at[jnp.where(alive, rb, n)].max(key, mode="drop")
        # Pass 2: per-cluster winning edge index among rank ties.
        eidx = jnp.arange(n_edges, dtype=jnp.int32)
        hit_a = alive & (key == best[ra])
        hit_b = alive & (key == best[rb])
        win = jnp.full(n, -1, jnp.int32)
        win = win.at[jnp.where(hit_a, ra, n)].max(
            jnp.where(hit_a, eidx, -1), mode="drop")
        win = win.at[jnp.where(hit_b, rb, n)].max(
            jnp.where(hit_b, eidx, -1), mode="drop")

        # For each cluster with a best edge: other endpoint + die rule.
        has = win >= 0
        wi = jnp.clip(win, 0)
        wa = roots[e_a[wi]]
        wb = roots[e_b[wi]]
        me = jnp.arange(n, dtype=jnp.int32)
        other = jnp.where(wa == me, wb, wa)
        saddle_rank = e_rank[wi]
        die = has & (rank_flat[other] > rank_flat[me]) & (roots == me)
        saddle_pix = perm[jnp.clip(saddle_rank, 0)]

        parent = jnp.where(die, other, parent)
        dval = jnp.where(die, image_flat[saddle_pix], dval)
        dpos = jnp.where(die, saddle_pix, dpos)
        any_alive = jnp.any(alive)
        return parent, dval, dpos, any_alive

    def cond(state):
        return state[3]

    def body(state):
        return round_body(state)

    state = (parent0, dval0, dpos0, jnp.asarray(True))
    # Seed round + loop until no alive inter-cluster edges remain.
    state = jax.lax.while_loop(cond, body, state)
    _, dval, dpos, _ = state

    n_cand = jnp.sum(cand_flat, dtype=jnp.int32)
    overflow = n_cand > min(max_candidates, n)
    return dval, dpos, overflow
