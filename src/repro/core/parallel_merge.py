"""Parallel merge phase: Boruvka rounds instead of the sequential sweep.

The paper's step 5 processes candidates one-by-one in descending order
(inherently sequential; our faithful version is a fixed-length ``lax.scan``
— 16384 sequential steps for a 1k x 1k astro image).  0-dim superlevel
persistence is equivalent to elder-rule pairing on the *maximum spanning
forest* of the saddle graph, which Boruvka builds in O(log C) fully-parallel
rounds:

  round:  every cluster finds its highest incident saddle edge (segment-max
          via scatter-max, two passes for argmax);  every cluster whose best
          edge leads to an older cluster DIES there (death = that saddle);
          union pointers are resolved by pointer doubling.

Correctness: "die" pointers always point to strictly larger birth keys, so
the simultaneous merges form a forest (no cycles) and each dier's death
saddle equals the one the sequential sweep would assign — the output is
bit-identical to the union-find oracle (tests/test_parallel_merge.py).

Edges are generated from the exact candidate set: per candidate pixel, a
chain over its (masked) higher-neighbor basins — a spanning set of the
clique of basins meeting at that pixel, so all merges at a value-v saddle
still happen at value v.

The round machinery is factored as :func:`boruvka_forest`, a generic
elder-rule forest reduction over an abstract (vertex ranks, edge list)
instance.  ``boruvka_merge`` instantiates it with vertices = pixels (the
whole-image path); ``repro.core.tiling`` instantiates it with vertices =
per-tile basin roots and edges = per-tile + boundary-seam edge lists (the
tiled path's global merge), so both paths share one bit-tested reduction.

Depth: the scan is O(K) sequential steps with O(1) work; Boruvka is
O(log C) rounds of O(E) parallel work — on a systolic/vector machine depth
is what matters (src/repro/ph/DESIGN.md §Perf PH-2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.grid import fixed_point_iterate, higher_neighbor_basins
from repro.core.packed_keys import key_pad, masked_top_k, packed_index


def candidate_edges(key_flat, labels_flat, cand_flat, shape,
                    max_candidates: int, tournament_width: int = 2):
    """Top-K candidates -> chained basin edges (K, 8) flat: [key_x, a, b].

    ``key_flat``: dense ranks or packed int64 keys; on packed keys the
    selection runs as a blockwise tournament
    (``packed_keys.masked_top_k``, block extent
    ``tournament_width * K``) — same retained set and order, no
    full-image sort.
    """
    h, w = shape
    n = h * w
    k = min(max_candidates, n)
    pad = key_pad(key_flat.dtype)
    top_keys, top_pix = masked_top_k(key_flat, cand_flat, k,
                                     tournament_width)
    valid = top_keys > pad
    ok, lbl = higher_neighbor_basins(top_pix, top_keys, key_flat,
                                     labels_flat, shape, valid)  # (K, 8)
    edge_ok, prev_lbl = chain_clique_edges(ok, lbl)
    keys = jnp.broadcast_to(top_keys[:, None], ok.shape)
    return (jnp.where(edge_ok, keys, pad).reshape(-1),
            jnp.where(edge_ok, lbl, 0).reshape(-1),
            jnp.where(edge_ok, prev_lbl, 0).reshape(-1))


def chain_clique_edges(ok: jnp.ndarray, lbl: jnp.ndarray):
    """Chain consecutive valid neighbor slots into clique-spanning edges.

    ``ok``/``lbl``: (K, 8) from :func:`~repro.core.grid.higher_neighbor_basins`.
    Edge j connects slot j's basin to the previous valid slot's basin — a
    spanning set of the per-candidate basin clique, in the fixed
    NEIGHBOR_OFFSETS order (shared by the whole-image and tiled builders so
    the edge multiset is identical).  Returns ``(edge_ok, prev_lbl)``.
    """
    def chain(ok_row, lbl_row):
        def step(prev, xs):
            o, l = xs
            a = jnp.where(o, prev, -1)
            prev = jnp.where(o, l, prev)
            return prev, a

        _, prev_lbl = jax.lax.scan(step, jnp.int32(-1), (ok_row, lbl_row))
        return prev_lbl            # (8,) previous valid basin or -1

    prev_lbl = jax.vmap(chain)(ok, lbl)
    edge_ok = ok & (prev_lbl >= 0) & (prev_lbl != lbl)
    return edge_ok, prev_lbl


def best_edge_reduce(key, ra, rb, nv: int):
    """Per-cluster best incident edge: ``(best key, winning edge index)``.

    The segmented reduction at the heart of every Boruvka round, factored
    out so implementations can be swapped (``reduce_fn`` of
    :func:`boruvka_forest`): ``repro.kernels.ph_phase_c`` supplies a
    blocked Pallas twin that accumulates the same scatters block-by-block
    in VMEM.  Both passes are **integer max reductions** — associative,
    commutative, and tie-free on the index pass — so any blocking of the
    edge axis is bit-identical by construction.

    ``key``: (E,) saddle keys, pre-masked to the dtype-min pad sentinel on
    dead lanes (the sentinel is strictly below every live key, so
    ``key > pad`` recovers liveness).  ``ra``/``rb``: (E,) resolved
    endpoint clusters, in ``[0, nv)`` on every lane.  Returns per-vertex
    ``best`` (pad where no live edge) and ``win`` (max winning edge index
    among best-key ties, -1 where none).
    """
    e_pad = key_pad(key.dtype)
    alive = key > e_pad
    # Pass 1: per-cluster best saddle key (scatter-max on both ends).
    best = jnp.full(nv, e_pad, key.dtype)
    best = best.at[jnp.where(alive, ra, nv)].max(key, mode="drop")
    best = best.at[jnp.where(alive, rb, nv)].max(key, mode="drop")
    # Pass 2: per-cluster winning edge index among key ties.
    eidx = jnp.arange(key.shape[0], dtype=jnp.int32)
    hit_a = alive & (key == best[ra])
    hit_b = alive & (key == best[rb])
    win = jnp.full(nv, -1, jnp.int32)
    win = win.at[jnp.where(hit_a, ra, nv)].max(
        jnp.where(hit_a, eidx, -1), mode="drop")
    win = win.at[jnp.where(hit_b, rb, nv)].max(
        jnp.where(hit_b, eidx, -1), mode="drop")
    return best, win


def boruvka_forest(v_rank, e_rank, e_val, e_pos, e_a, e_b, *,
                   n_live=None, reduce_fn=None):
    """Elder-rule Boruvka forest over an abstract vertex/edge instance.

    ``v_rank``: (V,) birth key per vertex — any order-isomorphic
    assignment under the (birth value, birth index) total order (dense
    int32 ranks or packed int64 keys); dead or padded vertices carry the
    dtype-min pad sentinel and must have no live edges.
    ``e_rank``: (E,) saddle key per edge — order-isomorphic to the
    saddle (value, index) total order, EQUAL for edges sharing a saddle
    pixel; the dtype-min sentinel marks padding.
    ``e_val``/``e_pos``: (E,) death value / position recorded when an edge
    kills a vertex.  ``e_a``/``e_b``: (E,) endpoint vertex ids.

    ``n_live``: optional (traced) upper bound on the number of clusters
    that can ever merge.  A spanning forest performs at most
    ``n_live - 1`` merges, so once that many clusters have died no
    inter-cluster edge can remain and the loop exits **without** paying
    the final verification round the plain any-alive test needs (for a
    fully merged forest — e.g. a single-component image — that round is
    pure overhead).  An over-estimate is always safe; callers pass their
    root/seam-vertex count.

    ``reduce_fn``: drop-in replacement for :func:`best_edge_reduce`
    (same signature) — the fused phase-C kernel's hook.

    Returns ``(dval, dpos, rounds)``: per-vertex death value (init -inf
    of ``e_val.dtype``), death position (init -1), and the number of
    Boruvka rounds executed (int32; BENCH telemetry).  Vertices that
    never meet an older cluster keep the init values.
    """
    nv = v_rank.shape[0]
    e_pad = key_pad(e_rank.dtype)
    neg_inf = (-jnp.inf if jnp.issubdtype(e_val.dtype, jnp.floating)
               else jnp.iinfo(e_val.dtype).min)
    reduce_ = best_edge_reduce if reduce_fn is None else reduce_fn

    parent0 = jnp.arange(nv, dtype=jnp.int32)
    dval0 = jnp.full(nv, neg_inf, e_val.dtype)
    dpos0 = jnp.full(nv, -1, jnp.int32)
    merge_cap = (jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)
                 if n_live is None
                 else jnp.asarray(n_live, jnp.int32) - 1)

    def resolve(p):
        q, _ = fixed_point_iterate(lambda r: r[r], p)
        return q

    def round_body(state):
        parent, dval, dpos, _, merges, rounds = state
        roots = resolve(parent)
        ra = roots[e_a]
        rb = roots[e_b]
        alive = (e_rank > e_pad) & (ra != rb)
        key = jnp.where(alive, e_rank, e_pad)

        best, win = reduce_(key, ra, rb, nv)

        # For each cluster with a best edge: other endpoint + die rule.
        has = win >= 0
        wi = jnp.clip(win, 0)
        wa = roots[e_a[wi]]
        wb = roots[e_b[wi]]
        me = jnp.arange(nv, dtype=jnp.int32)
        other = jnp.where(wa == me, wb, wa)
        die = has & (v_rank[other] > v_rank[me]) & (roots == me)

        parent = jnp.where(die, other, parent)
        dval = jnp.where(die, e_val[wi], dval)
        dpos = jnp.where(die, e_pos[wi], dpos)
        merges = merges + jnp.sum(die, dtype=jnp.int32)
        return parent, dval, dpos, jnp.any(alive), merges, rounds + 1

    def cond(state):
        return state[3] & (state[4] < merge_cap)

    state = (parent0, dval0, dpos0, jnp.asarray(True), jnp.int32(0),
             jnp.int32(0))
    # Seed round + loop until no alive inter-cluster edges remain (or the
    # merge budget proves none can).
    state = jax.lax.while_loop(cond, round_body, state)
    _, dval, dpos, _, _, rounds = state
    return dval, dpos, rounds


def boruvka_merge(image_flat, key_flat, labels_flat, cand_flat, shape,
                  max_candidates: int, *, n_live=None,
                  tournament_width: int = 2, reduce_fn=None):
    """Parallel replacement for ``pixhomology.merge_components``.

    Whole-image instantiation of :func:`boruvka_forest`: vertices are the n
    pixels keyed by the global total order (only basin roots carry live
    edges).  Packed keys carry their pixel index in the low bits, so the
    key -> pixel map is a mask; dense ranks need the inverse permutation
    (one more argsort — the fallback's price).  ``n_live``/``reduce_fn``
    forward to :func:`boruvka_forest`; returns
    ``(dval, dpos, overflow, rounds)``.
    """
    n = image_flat.shape[0]
    e_key, e_a, e_b = candidate_edges(key_flat, labels_flat, cand_flat,
                                      shape, max_candidates,
                                      tournament_width)
    # Map the saddle key back to its pixel id for death values/positions.
    if key_flat.dtype == jnp.int64:
        e_pos = jnp.clip(packed_index(e_key), 0)     # pad keys -> pixel 0
    else:
        perm = jnp.argsort(key_flat, stable=True)    # rank -> pixel id
        e_pos = perm[jnp.clip(e_key, 0)]
    e_val = image_flat[e_pos]

    dval, dpos, rounds = boruvka_forest(key_flat, e_key, e_val, e_pos,
                                        e_a, e_b, n_live=n_live,
                                        reduce_fn=reduce_fn)

    n_cand = jnp.sum(cand_flat, dtype=jnp.int32)
    overflow = n_cand > min(max_candidates, n)
    return dval, dpos, overflow, rounds
