"""Delta recompute on the tiled stage graph: O(changed area) per frame.

The paper's motivating workload is telescopes re-imaging the same sky —
consecutive frames differ only where transients appear.  PR 2's halo-tiled
decomposition makes the dependency structure explicit: every per-tile
artifact (:class:`repro.core.tiling.TileBoundaryState`) is a pure function
of that tile's **halo-padded bytes**, and only the O(boundary) seam merge
(:func:`repro.core.tiling.merge_tile_state`) mixes tiles.  So a frame that
changed in ``D`` of ``T`` tiles needs:

1. a host hash pass over the tile bytes (O(n), but at memory bandwidth —
   orders of magnitude cheaper than PH compute) classifying tiles
   clean/dirty against a cached frame's hash grid;
2. phases A+B for the ``D`` dirty tiles only, batched through the same
   vmapped :func:`tile_phase_ab` program the cold path uses (dirty counts
   are padded to power-of-two buckets so recompiles are logarithmic);
3. a scatter of the fresh rows into the cached state and one seam-merge
   replay — **bit-identical** to a cold ``run_tiled`` because clean rows
   store pre-labels, not stale resolved labels: the ring-table fixed
   point re-resolves every cross-tile chain against the new frame.

Hashing covers the halo-*padded* window of each tile, so a change in a
neighbor's border row dirties this tile automatically — there is no
separate halo-dependency bookkeeping to get wrong.

The engine surface is :meth:`repro.ph.PHEngine.run_delta` /
``run_sequence``; the frame store is
:class:`repro.cache.DiagramCache`.  This module owns the pure pieces:
content hashing, the batched phase-AB program, and the scatter+merge
program.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packed_keys
from repro.core.grid import neg_inf as _neg_inf
from repro.core.tiling import (
    StagedTiles,
    TileBoundaryState,
    TiledDiagram,
    _ring_coords,
    halo_gidx_tile,
    merge_tile_state,
    tile_phase_ab,
    validate_grid,
)

HASH_ALGOS = ("blake2b", "sha1", "md5")


@dataclasses.dataclass(frozen=True)
class DeltaStats:
    """What one ``run_delta`` call actually did."""

    n_tiles: int
    n_dirty: int               # tiles recomputed (0 on a full hit)
    hit: str                   # "full" | "partial" | "miss" | "cold"

    @property
    def dirty_frac(self) -> float:
        return self.n_dirty / max(self.n_tiles, 1)


# ---------------------------------------------------------------------------
# Content hashing (host side)
# ---------------------------------------------------------------------------

def hasher(algo: str):
    """Digest function for ``algo`` (128-bit blake2b by default; xxhash
    would do — blake2b is in hashlib everywhere and runs at memory
    bandwidth for tile-sized buffers)."""
    if algo == "blake2b":
        return lambda b: hashlib.blake2b(b, digest_size=16).digest()
    if algo in HASH_ALGOS:
        return lambda b: hashlib.new(algo, b).digest()
    raise ValueError(f"hash_algo must be one of {HASH_ALGOS}, got {algo!r}")


def frame_digests(source, grid: tuple[int, int], *, algo: str = "blake2b",
                  with_bytes: bool = False, filtration: str = "superlevel"
                  ) -> tuple[tuple[bytes, ...], tuple[bytes, ...] | None]:
    """Per-tile content digests of one frame's **halo-padded** tile bytes.

    ``source`` is a host 2D array or a :class:`StagedTiles` (one readback).
    Both hash exactly the bytes of ``split_tiles(image, grid, fill)`` rows,
    so entries created from either input form match each other — which is
    why ``filtration`` matters here: the halo fill is the *user-space*
    inert extreme of the filtration (``+inf`` under sublevel), matching
    what :func:`repro.core.tiling.load_tile_stacks` staged.  Returns
    ``(digests, tile_bytes)`` — the raw bytes only when ``with_bytes``
    (verify mode); digests include the halo, so a neighbor-border change
    dirties this tile with no extra bookkeeping.
    """
    h = hasher(algo)
    if isinstance(source, StagedTiles):
        stack = np.asarray(source.pvals)
        rows = [np.ascontiguousarray(stack[t]).tobytes()
                for t in range(stack.shape[0])]
    else:
        arr = np.asarray(source)
        if arr.ndim != 2:
            raise ValueError(f"expected a 2D frame, got shape {arr.shape}")
        gr, gc = grid
        validate_grid(arr.shape, (gr, gc))
        tr, tc = arr.shape[0] // gr, arr.shape[1] // gc
        fill = np.asarray(_neg_inf(arr.dtype))
        if filtration == "sublevel":
            fill = -fill
        padded = np.pad(arr, 1, constant_values=fill)
        rows = [np.ascontiguousarray(
            padded[(t // gc) * tr:(t // gc) * tr + tr + 2,
                   (t % gc) * tc:(t % gc) * tc + tc + 2]).tobytes()
            for t in range(gr * gc)]
    digests = tuple(h(b) for b in rows)
    return digests, (tuple(rows) if with_bytes else None)


def dirty_bucket(n_dirty: int, n_tiles: int) -> int:
    """Dirty-stack batch size: next power of two, clamped to the tile
    count — so the number of distinct compiled phase-AB batch shapes is
    logarithmic in ``T`` regardless of how dirty counts vary."""
    if n_dirty < 1:
        raise ValueError("dirty_bucket needs n_dirty >= 1")
    return min(n_tiles, 1 << (n_dirty - 1).bit_length())


# ---------------------------------------------------------------------------
# State plumbing
# ---------------------------------------------------------------------------

def empty_state(shape: tuple[int, int], grid: tuple[int, int], dtype,
                tile_max_features: int, tile_max_candidates: int
                ) -> TileBoundaryState:
    """An all-zeros :class:`TileBoundaryState` with the exact array shapes
    :func:`tile_phase_ab` produces under these capacities — the scatter
    base for a cold delta run (every row is overwritten)."""
    h, w = shape
    gr, gc = grid
    tr, tc = h // gr, w // gc
    n_tiles = gr * gc
    ring = len(_ring_coords(tr, tc)[0])
    k = min(tile_max_candidates, tr * tc)
    f = min(tile_max_features, tr * tc)
    zi = functools.partial(jnp.zeros, dtype=jnp.int32)
    zv = functools.partial(jnp.zeros, dtype=dtype)
    zb = functools.partial(jnp.zeros, dtype=bool)
    return TileBoundaryState(
        ring_gidx=zi((n_tiles, ring)), ring_ptr=zi((n_tiles, ring)),
        min_val=zv((n_tiles,)), min_gidx=zi((n_tiles,)),
        e_val=zv((n_tiles, k, 8)), e_pos=zi((n_tiles, k, 8)),
        e_a=zi((n_tiles, k, 8)), e_b=zi((n_tiles, k, 8)),
        e_ok=zb((n_tiles, k, 8)),
        root_val=zv((n_tiles, f)), root_gidx=zi((n_tiles, f)),
        root_valid=zb((n_tiles, f)),
        rmax_val=zv((n_tiles,)), rmax_gidx=zi((n_tiles,)),
        n_roots=zi((n_tiles,)), n_cand=zi((n_tiles,)))


def dirty_stacks(source, grid: tuple[int, int], dirty: np.ndarray,
                 bucket: int, filtration: str = "superlevel"
                 ) -> tuple[jnp.ndarray, jnp.ndarray, np.ndarray]:
    """Halo-padded (bucket, tr+2, tc+2) value/gidx stacks of the dirty
    tiles plus their padded slot vector.

    Host->device traffic is O(dirty area): only dirty windows are staged.
    Padding repeats the *last* dirty tile (stack row and slot alike), so
    the scatter writes pad rows as exact duplicates of a real row —
    idempotent by construction, no masking needed in the jitted program.
    """
    dirty = np.asarray(dirty, np.int64)
    if isinstance(source, StagedTiles):
        stack = np.asarray(source.pvals)
        shape = source.shape
        win = [stack[t] for t in dirty]
    else:
        arr = np.asarray(source)
        shape = arr.shape
        gr, gc = grid
        tr, tc = arr.shape[0] // gr, arr.shape[1] // gc
        fill = np.asarray(_neg_inf(arr.dtype))
        if filtration == "sublevel":
            fill = -fill
        padded = np.pad(arr, 1, constant_values=fill)
        win = [padded[(t // gc) * tr:(t // gc) * tr + tr + 2,
                      (t % gc) * tc:(t % gc) * tc + tc + 2] for t in dirty]
    gwin = [halo_gidx_tile(shape, grid, int(t)) for t in dirty]
    pad = bucket - len(win)
    if pad:
        win += [win[-1]] * pad
        gwin += [gwin[-1]] * pad
        dirty = np.concatenate([dirty, np.full(pad, dirty[-1])])
    return jnp.asarray(np.stack(win)), jnp.asarray(np.stack(gwin)), dirty


# ---------------------------------------------------------------------------
# Jitted programs: batched phase AB + scatter/seam-merge replay
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("tile_max_features", "tile_max_candidates",
                     "truncated", "merge_keys"))
def _phase_ab_stack(pvals, pgidx, tv, *, tile_max_features: int,
                    tile_max_candidates: int, truncated: bool,
                    merge_keys: str) -> TileBoundaryState:
    packed_keys.assert_key_context(merge_keys)
    fn = functools.partial(tile_phase_ab,
                           tile_max_candidates=tile_max_candidates,
                           tile_max_features=tile_max_features,
                           truncated=truncated, merge_keys=merge_keys)
    return jax.vmap(fn, in_axes=(0, 0, None))(pvals, pgidx, tv)


def phase_ab_stack(pvals, pgidx, tv=None, *, merge_keys: str = "packed",
                   filtration: str = "superlevel",
                   **kwargs) -> TileBoundaryState:
    """Per-tile phases A+B over a (D, tr+2, tc+2) stack — the *same*
    vmapped program the cold tiled path runs over all T tiles, applied to
    the dirty subset.  Row independence of ``vmap`` is what makes the
    delta state bit-identical to a cold one, row for row.

    Under ``filtration='sublevel'`` the user-space stacks and threshold
    negate here; the returned state is in the *internal* superlevel order,
    exactly what the cached :class:`TileBoundaryState` rows hold (diagrams
    only un-negate at :func:`scatter_merge`)."""
    packed_keys.check_finite(pvals, where="tile stacks", allow_inf=True)
    pvals = packed_keys.filtration_view(pvals, filtration)
    if tv is not None and filtration == "sublevel":
        tv = jnp.negative(tv)
    merge_keys = packed_keys.resolve_merge_keys(merge_keys, pvals.dtype)
    truncated = tv is not None
    tvj = tv if truncated else _neg_inf(jnp.float32)
    with packed_keys.key_scope(merge_keys):
        return _phase_ab_stack(pvals, pgidx, tvj, truncated=truncated,
                               merge_keys=merge_keys, **kwargs)


@functools.partial(
    jax.jit,
    static_argnames=("shape", "grid", "max_features", "tile_max_features",
                     "tile_max_candidates", "truncated", "merge_keys",
                     "phase_c_impl", "phase_c_block"))
def _scatter_merge(state: TileBoundaryState, fresh: TileBoundaryState,
                   slots, tv, *, shape, grid, max_features: int,
                   tile_max_features: int, tile_max_candidates: int,
                   truncated: bool, merge_keys: str,
                   phase_c_impl: str, phase_c_block: int
                   ) -> tuple[TileBoundaryState, TiledDiagram]:
    packed_keys.assert_key_context(merge_keys)
    new_state = jax.tree.map(lambda c, f: c.at[slots].set(f), state, fresh)
    td = merge_tile_state(
        new_state, tv, shape=shape, grid=grid, max_features=max_features,
        tile_max_features=tile_max_features,
        tile_max_candidates=tile_max_candidates, truncated=truncated,
        merge_keys=merge_keys, phase_c_impl=phase_c_impl,
        phase_c_block=phase_c_block)
    return new_state, td


def scatter_merge(state: TileBoundaryState, fresh: TileBoundaryState,
                  slots, tv=None, *, merge_keys: str = "packed",
                  filtration: str = "superlevel",
                  **kwargs) -> tuple[TileBoundaryState, TiledDiagram]:
    """Scatter fresh dirty-tile rows into the cached state and replay the
    O(boundary) seam merge.  Returns the updated full state (the next
    frame's cache entry) and the :class:`TiledDiagram`.

    ``slots`` may contain duplicates (bucket padding repeats a real dirty
    slot with an identical fresh row), so the scatter is idempotent
    whatever order XLA applies it in.

    Both states are in the internal superlevel order regardless of
    ``filtration`` (see :func:`phase_ab_stack`); under sublevel the
    user-space threshold negates in and only the diagram negates out.
    """
    if tv is not None and filtration == "sublevel":
        tv = jnp.negative(tv)
    merge_keys = packed_keys.resolve_merge_keys(merge_keys,
                                                state.root_val.dtype)
    truncated = tv is not None
    tvj = tv if truncated else _neg_inf(jnp.float32)
    with packed_keys.key_scope(merge_keys):
        new_state, td = _scatter_merge(
            state, fresh, jnp.asarray(slots, jnp.int32), tvj,
            truncated=truncated, merge_keys=merge_keys, **kwargs)
    if filtration == "sublevel":
        d = td.diagram
        td = td._replace(diagram=d._replace(birth=jnp.negative(d.birth),
                                            death=jnp.negative(d.death)))
    return new_state, td
