"""Jitted, sharded train / prefill / decode steps shared by the dry-run,
the training driver and the serving driver."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding
from repro.distributed.context import DistContext
from repro.models.model import Model, build_model
from repro.optim.adamw import AdamW, OptState


@dataclasses.dataclass
class StepBundle:
    """A lowered/compilable step with its arg specs (ShapeDtypeStructs)."""
    fn: Any                  # jitted function
    args: tuple              # ShapeDtypeStructs to .lower() with
    description: str


def train_bundle(cfg: ModelConfig, shape: ShapeConfig, ctx: DistContext,
                 opt: AdamW | None = None) -> StepBundle:
    model = build_model(cfg)
    opt = opt or AdamW()
    mesh = ctx.mesh

    params_sds = model.param_shapes()
    opt_sds = jax.eval_shape(opt.init, params_sds)
    batch_sds = model.input_specs(shape)

    pspec = sharding.param_specs(params_sds, mesh, cfg.name)
    mspec = sharding.opt_state_specs(pspec, params_sds, mesh)
    ospec = OptState(mspec, mspec, jax.sharding.PartitionSpec())
    bspec = sharding.batch_specs(batch_sds, mesh, ctx.dp_axes)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, ctx), has_aux=True)(params)
        new_params, new_opt, opt_metrics = opt.update(params, grads,
                                                      opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    named = lambda spec: sharding.to_named(spec, mesh)
    fn = jax.jit(train_step,
                 in_shardings=(named(pspec), named(ospec), named(bspec)),
                 out_shardings=(named(pspec), named(ospec), None),
                 donate_argnums=(0, 1))
    return StepBundle(fn, (params_sds, opt_sds, batch_sds),
                      f"train_step {cfg.name} {shape.name}")


def prefill_bundle(cfg: ModelConfig, shape: ShapeConfig,
                   ctx: DistContext) -> StepBundle:
    model = build_model(cfg)
    mesh = ctx.mesh
    params_sds = model.param_shapes()
    batch_sds = model.input_specs(shape)

    pspec = sharding.param_specs(params_sds, mesh, cfg.name)
    bspec = sharding.batch_specs(batch_sds, mesh, ctx.dp_axes)

    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch, ctx,
                                       max_len=shape.seq_len)
        return jnp.argmax(logits, -1).astype(jnp.int32), caches

    cache_sds = jax.eval_shape(prefill_step, params_sds, batch_sds)[1]
    cspec = sharding.cache_specs(cache_sds, mesh, dp_axes=ctx.dp_axes)

    named = lambda spec: sharding.to_named(spec, mesh)
    fn = jax.jit(prefill_step,
                 in_shardings=(named(pspec), named(bspec)),
                 out_shardings=(None, named(cspec)))
    return StepBundle(fn, (params_sds, batch_sds),
                      f"prefill {cfg.name} {shape.name}")


def decode_bundle(cfg: ModelConfig, shape: ShapeConfig,
                  ctx: DistContext) -> StepBundle:
    """serve_step: one new token against a seq_len KV cache (per brief)."""
    model = build_model(cfg)
    mesh = ctx.mesh
    params_sds = model.param_shapes()
    specs = model.input_specs(shape)
    token_sds, cache_sds = specs["token"], specs["caches"]

    pspec = sharding.param_specs(params_sds, mesh, cfg.name)
    tspec = sharding.batch_specs(token_sds, mesh, ctx.dp_axes)
    cspec = sharding.cache_specs(cache_sds, mesh, dp_axes=ctx.dp_axes)

    def serve_step(params, token, caches):
        logits, new_caches = model.decode_step(params, token, caches, ctx)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_caches

    named = lambda spec: sharding.to_named(spec, mesh)
    fn = jax.jit(serve_step,
                 in_shardings=(named(pspec), named(tspec), named(cspec)),
                 out_shardings=(named(tspec), named(cspec)),
                 donate_argnums=(2,))
    return StepBundle(fn, (params_sds, token_sds, cache_sds),
                      f"serve_step {cfg.name} {shape.name}")


def bundle_for(cfg: ModelConfig, shape: ShapeConfig,
               ctx: DistContext) -> StepBundle:
    if shape.kind == "train":
        return train_bundle(cfg, shape, ctx)
    if shape.kind == "prefill":
        return prefill_bundle(cfg, shape, ctx)
    if shape.kind == "decode":
        return decode_bundle(cfg, shape, ctx)
    raise ValueError(shape.kind)
