"""**Language-model** serving demo: prefill + greedy decode over batches.

Not the PH service — persistent-homology serving lives in
``launch/ph_serve.py`` (daemon: :mod:`repro.serving`).  This script is
the LM-side counterpart kept for the transformer scaffold.

Continuous-batching-lite: requests are grouped into fixed-size batches
(padded), prefilled once, then decoded step-by-step with the sharded
serve_step.  The KV cache layout/sharding comes from
distributed/sharding.cache_specs (sequence dim over `model`).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config, get_smoke_config
from repro.distributed.context import single_device_ctx
from repro.launch.mesh import make_small_context
from repro.models.model import build_model


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, gen_len: int = 32, max_len: int = 128,
          seed: int = 0, verbose: bool = True):
    cfg = (get_smoke_config if smoke else get_config)(arch)
    n_dev = len(jax.devices())
    ctx = make_small_context(data=n_dev, model=1) if n_dev > 1 \
        else single_device_ctx()
    model = build_model(cfg)
    rng = np.random.default_rng(seed)

    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len),
                           dtype=np.int32)
    batch_in = {"tokens": jnp.asarray(prompts)}
    if cfg.is_encdec:
        batch_in["frames"] = jnp.asarray(rng.normal(
            size=(batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    with ctx.mesh:
        params = model.init(jax.random.PRNGKey(0))
        prefill = jax.jit(lambda p, b: model.prefill(p, b, ctx,
                                                     max_len=max_len))
        t0 = time.time()
        logits, caches = prefill(params, batch_in)
        next_tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        t_prefill = time.time() - t0

        step = jax.jit(lambda p, t, c: model.decode_step(p, t, c, ctx))
        out_tokens = [np.asarray(next_tok)]
        t0 = time.time()
        for _ in range(gen_len - 1):
            logits, caches = step(params, next_tok, caches)
            next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out_tokens.append(np.asarray(next_tok))
        jax.block_until_ready(next_tok)
        t_decode = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    stats = {
        "arch": arch, "batch": batch, "prompt_len": prompt_len,
        "gen_len": gen_len,
        "prefill_s": round(t_prefill, 3),
        "decode_tokens_per_s": round(batch * (gen_len - 1)
                                     / max(t_decode, 1e-9), 1),
        "sample_output": gen[0][:16].tolist(),
    }
    if verbose:
        print(json.dumps(stats, indent=1))
    return gen, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()
    serve(args.arch, smoke=not args.full_config, batch=args.batch,
          prompt_len=args.prompt_len, gen_len=args.gen_len)


if __name__ == "__main__":
    main()
