import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module (jax locks the
# device count at first init).  Everything else follows.
if os.environ.get("REPRO_DRYRUN_DEVICES"):           # test override (pre-jax)
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: sharding mismatches, compile-time OOM and unsupported collectives
all fail here.  Artifacts (memory analysis, cost analysis, HLO-derived
roofline terms — see roofline/analysis.py) are written as JSON for
EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma_7b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --sweep [--multi-pod-too]   # all cells,
      one subprocess per cell (memory isolation, resumable via artifacts/)
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_path: Path,
             overrides: dict | None = None) -> dict:
    import jax
    from repro.configs.base import SHAPES, get_config
    from repro.launch import steps
    from repro.launch.mesh import make_context
    from repro.roofline import analysis

    t0 = time.time()
    ctx = make_context(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "devices": len(jax.devices())}

    try:
        if arch == "pixhomology":
            if overrides:
                rec["overrides"] = overrides
            rec.update(_run_pixhomology(ctx, shape_name, overrides))
        else:
            cfg = get_config(arch)
            if overrides:
                cfg = cfg.replace(**overrides)
                rec["overrides"] = overrides
            shape = SHAPES[shape_name]
            if shape.name == "long_500k" and not cfg.supports_long_context:
                rec["skipped"] = ("full-attention arch: quadratic at 500k; "
                                  "skipped per brief (DESIGN.md §4)")
                rec["seconds"] = time.time() - t0
                _write(out_path, rec)
                return rec
            bundle = steps.bundle_for(cfg, shape, ctx)
            with ctx.mesh:
                lowered = bundle.fn.lower(*bundle.args)
                rec["lower_ok"] = True
                compiled = lowered.compile()
                rec["compile_ok"] = True
                rec.update(_analyze(compiled, cfg, shape))
    except Exception as e:  # noqa: BLE001 — recorded, the sweep continues
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["seconds"] = round(time.time() - t0, 1)
    _write(out_path, rec)
    return rec


def _analyze(compiled, cfg, shape) -> dict:
    from repro.roofline import analysis

    out: dict = {}
    ma = compiled.memory_analysis()
    out["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes_est": int(ma.argument_size_in_bytes
                              + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes
                              - ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):             # older jax: one dict per device
        ca = ca[0] if ca else {}
    out["cost_analysis"] = {"flops": float(ca.get("flops", 0.0)),
                            "bytes_accessed":
                                float(ca.get("bytes accessed", 0.0))}
    text = compiled.as_text()
    summ = analysis.analyze_hlo(text)
    flops, bytes_ = analysis.blended_totals(
        summ, out["cost_analysis"]["flops"],
        out["cost_analysis"]["bytes_accessed"])
    out["hlo"] = {
        "flops": flops, "bytes": bytes_,
        "flops_ownparse": summ.flops, "bytes_ownparse": summ.bytes,
        "collective_bytes": summ.coll_bytes,
        "collectives_by_type": summ.coll_by_type,
        "n_while_loops": summ.n_whiles,
        "unresolved_trip_counts": summ.unresolved_trip_counts,
    }
    terms = analysis.roofline_terms(flops, bytes_, summ.coll_bytes)
    out["roofline"] = terms
    if cfg is not None:
        out["model_flops"] = analysis.model_flops(cfg, shape)
        out["params_total"] = analysis.total_params(cfg)
        out["params_active"] = analysis.active_params(cfg)
        out["useful_flops_ratio"] = (
            out["model_flops"]
            / max(flops * _n_devices_of(compiled), 1.0))
    return out


def _n_devices_of(compiled) -> int:
    import jax
    return len(jax.devices())


def _run_pixhomology(ctx, shape_name: str,
                     overrides: dict | None = None) -> dict:
    """The paper's own workload as a dry-run cell: a sharded image batch.

    ``overrides`` are :class:`PHConfig` field overrides (the hillclimb
    knobs — e.g. ``--override phase_c_impl=xla`` or
    ``--override phase_c_block=4096`` to compile-compare stage-C
    variants without touching code)."""
    import jax
    import jax.numpy as jnp
    from repro.ph import PHConfig, PHEngine

    if shape_name.startswith("ph_tiled"):
        return _run_pixhomology_tiled(shape_name)
    if shape_name.startswith("ph_hetero"):
        return _run_pixhomology_hetero(ctx, shape_name)

    presets = {"ph_batch_1k": (512, 1024, 1024, 16384, 8192),
               "ph_batch_4k": (512, 4096, 4096, 65536, 32768)}
    b, h, w, k, f = presets[shape_name]
    config = PHConfig(max_features=f, max_candidates=k,
                      use_pallas=False, auto_regrow=False)
    if overrides:
        config = config.replace(**overrides)
    engine = PHEngine(config)
    plan = engine.sharded_plan(ctx, (b, h, w), jnp.dtype(jnp.float32), f, k)
    sds = jax.ShapeDtypeStruct((b, h, w), jnp.float32)
    tsds = jax.ShapeDtypeStruct((b,), jnp.float32)
    with ctx.mesh:
        lowered = plan.lower(sds, tsds)
        compiled = lowered.compile()
    out = {"lower_ok": True, "compile_ok": True}
    out.update(_analyze(compiled, None, None))
    out.pop("model_flops", None)
    return out


def _run_pixhomology_hetero(ctx, shape_name: str) -> dict:
    """Heterogeneous pipeline cost model: one cached sharded plan per shape
    bucket.  The record shows each bucket's memory footprint and the pad
    overhead a mixed dataset pays when its shapes round up to pow2 buckets
    — the knob (`PHConfig.bucket_rounding`) the scheduler trades compile
    count against padded pixels with."""
    import jax
    import jax.numpy as jnp
    from repro.ph import PHConfig, PHEngine
    from repro.pipeline.scheduler import bucket_shape

    presets = {"ph_hetero_1k": ((320, 512, 1024), 16384, 8192)}
    sizes, k, f = presets[shape_name]
    engine = PHEngine(PHConfig(max_features=f, max_candidates=k,
                               use_pallas=False, auto_regrow=False))
    b = ctx.dp_size
    out: dict = {"lower_ok": True, "compile_ok": True, "buckets": {}}
    analyzed: dict = {}     # sizes sharing a bucket share one compile
    for size in sizes:
        hb, wb = bucket_shape((size, size), "pow2")
        name = f"{size}->bucket{hb}x{wb}"
        cell = analyzed.get((hb, wb))
        if cell is None:
            plan = engine.sharded_plan(ctx, (b, hb, wb),
                                       jnp.dtype(jnp.float32), f, k)
            with ctx.mesh:
                compiled = plan.lower(
                    jax.ShapeDtypeStruct((b, hb, wb), jnp.float32),
                    jax.ShapeDtypeStruct((b,), jnp.float32)).compile()
            cell = analyzed[(hb, wb)] = _analyze(compiled, None, None)
        out["buckets"][name] = {
            "memory": cell["memory"],
            "pad_overhead": round(hb * wb / (size * size) - 1.0, 4),
        }
    out["plan_cache"] = engine.plan_stats()
    return out


def _run_pixhomology_tiled(shape_name: str) -> dict:
    """Tiled-plan cost model: the per-tile phase programs are the unit of
    device residency, so their footprint must scale with the *tile* shape
    (plus the O(boundary) condensation table), never with the image area —
    that is what lets one image exceed a device.  The record reports the
    same tile compiled under two image sizes so the invariance is visible
    in the artifact."""
    import jax.numpy as jnp
    from repro.core.tiling import per_tile_cost

    # name -> (tile_h, tile_w, tiles at the small image, tiles at the big)
    presets = {"ph_tiled_1k": (256, 256, 16, 256),
               "ph_tiled_4k": (512, 512, 64, 1024)}
    th, tw, n_small, n_big = presets[shape_name]
    small = per_tile_cost((th, tw), jnp.float32, n_tiles=n_small)
    big = per_tile_cost((th, tw), jnp.float32, n_tiles=n_big)
    return {
        "lower_ok": True, "compile_ok": True,
        "tile_shape": [th, tw],
        "per_tile_small_image": small,
        "per_tile_big_image": big,
        "phase_a_peak_invariant": (
            small["phase_a"]["peak_bytes_est"]
            == big["phase_a"]["peak_bytes_est"]),
        "phase_b_peak_ratio": round(
            big["phase_b"]["peak_bytes_est"]
            / max(small["phase_b"]["peak_bytes_est"], 1), 3),
    }


def _write(path: Path, rec: dict):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1, default=float))


def sweep(multi_pod_too: bool, archs=None, shapes=None, force=False):
    """One subprocess per cell (memory isolation + resumability)."""
    from repro.configs.base import cells

    todo = []
    meshes = [False] + ([True] if multi_pod_too else [])
    for arch, shape_name, _skip in cells(archs, shapes):
        for mp in meshes:
            todo.append((arch, shape_name, mp))
    for shape_name in ["ph_batch_1k"]:
        for mp in meshes:
            todo.append(("pixhomology", shape_name, mp))
    todo.append(("pixhomology", "ph_tiled_1k", False))
    todo.append(("pixhomology", "ph_hetero_1k", False))

    results = []
    for i, (arch, shape_name, mp) in enumerate(todo):
        mesh_name = "2x16x16" if mp else "16x16"
        out = ARTIFACTS / f"{arch}__{shape_name}__{mesh_name}.json"
        if out.exists() and not force:
            rec = json.loads(out.read_text())
            status = ("skip" if rec.get("skipped")
                      else "ok" if rec.get("compile_ok") else "ERR")
            print(f"[{i+1}/{len(todo)}] cached {out.name}: {status}",
                  flush=True)
            results.append(rec)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape_name, "--out", str(out)]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=3600)
        dt = time.time() - t0
        if out.exists():
            rec = json.loads(out.read_text())
        else:
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "error": f"subprocess died: {proc.stderr[-2000:]}"}
            _write(out, rec)
        status = ("skip" if rec.get("skipped")
                  else "ok" if rec.get("compile_ok") else "ERR")
        print(f"[{i+1}/{len(todo)}] {out.name}: {status} ({dt:.0f}s)",
              flush=True)
        if status == "ERR":
            print("    ", rec.get("error", "?")[:300], flush=True)
        results.append(rec)

    n_ok = sum(1 for r in results if r.get("compile_ok"))
    n_skip = sum(1 for r in results if r.get("skipped"))
    n_err = len(results) - n_ok - n_skip
    print(f"SWEEP DONE: {n_ok} ok, {n_skip} skipped, {n_err} errors",
          flush=True)
    return 1 if n_err else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--multi-pod-too", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--archs", nargs="*")
    ap.add_argument("--shapes", nargs="*")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (hillclimb knobs)")
    args = ap.parse_args()

    if args.sweep:
        sys.exit(sweep(args.multi_pod_too, args.archs, args.shapes,
                       args.force))

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    out = Path(args.out) if args.out else \
        ARTIFACTS / f"{args.arch}__{args.shape}__{mesh_name}.json"
    rec = run_cell(args.arch, args.shape, args.multi_pod, out,
                   overrides or None)
    ok = rec.get("compile_ok") or rec.get("skipped")
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=1, default=float))
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
