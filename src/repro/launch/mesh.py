"""Production meshes (functions, not module constants: importing this module
never touches jax device state).

Target: TPU v5e pods.  Single pod = 256 chips as (16, 16) ("data", "model");
multi-pod = 2 pods as (2, 16, 16) ("pod", "data", "model") — `pod` is pure
data parallelism (one DCN gradient all-reduce per step).
"""
from __future__ import annotations

import jax

from repro.distributed.context import DistContext


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = len(jax.devices())
    need = 512 if multi_pod else 256
    if n < need:  # reduced test environments (REPRO_DRYRUN_DEVICES): shrink
        shape = (2, 2, 2) if multi_pod else (2, 4)
        if n < (8 if multi_pod else 8):
            shape = (1, 1, 1) if multi_pod else (1, 1)
    return jax.make_mesh(shape, axes)


def make_context(*, multi_pod: bool = False) -> DistContext:
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = ("pod", "data") if multi_pod else ("data",)
    return DistContext(mesh=mesh, dp_axes=dp, tp_axis="model")


def make_small_context(data: int = 1, model: int = 1) -> DistContext:
    """Small mesh over however many (host) devices exist — tests/examples."""
    mesh = jax.make_mesh((data, model), ("data", "model"))
    return DistContext(mesh=mesh, dp_axes=("data",), tp_axis="model")


def auto_context() -> DistContext:
    """Context over whatever devices exist: one data axis across all local
    devices, model axis 1 (the PH pipeline's default executor mesh)."""
    from repro.distributed.context import single_device_ctx
    n = len(jax.devices())
    return make_small_context(data=n, model=1) if n > 1 \
        else single_device_ctx()
