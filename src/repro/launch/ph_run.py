"""Distributed PixHomology pipeline driver (the paper's end-to-end job).

`python -m repro.launch.ph_run --images 64 --size 512 --strategy part_LPT`

Runs the full paper pipeline on whatever devices exist: LPT (or other
Variant-3 strategy) scheduling, executor self-loading (Variant 1),
threshold filtering (Variant 2), work-log fault tolerance, per-image
persistence diagram summaries.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.distributed.context import single_device_ctx
from repro.launch.mesh import make_small_context
from repro.pipeline.driver import FailureInjector, run_pipeline
from repro.pipeline.executor import ExecutorPool


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--images", type=int, default=16)
    ap.add_argument("--size", type=int, default=512)
    ap.add_argument("--strategy", default="part_LPT",
                    choices=["part_executors", "part_images", "part_LPT"])
    ap.add_argument("--filter", default="filter_std",
                    choices=["vanilla", "filter_light", "filter_std",
                             "filter_heavy"])
    ap.add_argument("--work-log")
    ap.add_argument("--inject-failure", type=int, nargs="*", default=[],
                    help="round indices to fail once (recovery demo)")
    ap.add_argument("--max-features", type=int, default=8192)
    ap.add_argument("--max-candidates", type=int, default=32768)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    ctx = make_small_context(data=n_dev, model=1) if n_dev > 1 \
        else single_device_ctx()
    pool = ExecutorPool(ctx, image_size=args.size,
                        max_features=args.max_features,
                        max_candidates=args.max_candidates,
                        filter_level=args.filter)
    injector = (FailureInjector(args.inject_failure)
                if args.inject_failure else None)
    res = run_pipeline(pool, list(range(args.images)),
                       strategy=args.strategy, work_log=args.work_log,
                       failure_injector=injector, verbose=True)
    total_objects = sum(d["count"] for d in res.diagrams.values())
    print(json.dumps({
        "images": len(res.diagrams), "rounds": res.rounds,
        "failures_recovered": res.failures, "elapsed_s": round(res.elapsed_s, 2),
        "executors": pool.num_executors,
        "total_objects": total_objects,
        "mean_objects_per_image": total_objects / max(len(res.diagrams), 1),
    }, indent=1))


if __name__ == "__main__":
    main()
