"""Distributed PixHomology pipeline driver (the paper's end-to-end job).

`python -m repro.launch.ph_run --images 64 --size 512 --strategy part_LPT`

Runs the full paper pipeline on whatever devices exist: LPT (or other
Variant-3 strategy) scheduling, executor self-loading (Variant 1),
threshold filtering (Variant 2), work-log fault tolerance, per-image
persistence diagram summaries.  All PH computation is constructed through
the :mod:`repro.ph` facade (``PHConfig`` + ``PHEngine``).

Heterogeneous datasets: ``--sizes 256 512 1024`` cycles image sizes over
``--images`` ids (shape-bucketed rounds, ``--bucket-rounding``); images
above ``--max-tile-pixels`` stream through the tiled path; the loader
thread prefetches ``--prefetch-rounds`` rounds ahead (``--no-prefetch``
serializes load and compute).

``--overlap`` turns on the overlap engine: the staging ring keeps
``--overlap-depth`` rounds device-staged and in flight, bucket batches
are donated to the compiled programs, overflow checks and result
materialization stream asynchronously, and a harvest thread drains
results so the dispatch loop never blocks on the device.  The opt-out
toggles (``--no-donate`` / ``--no-async-overflow`` /
``--no-async-harvest``) each imply ``--overlap`` with that one feature
off.  Every combination is bit-identical to the synchronous path.
"""
from __future__ import annotations

import argparse
import json

from repro.pipeline.driver import FailureInjector
from repro.ph import PHConfig, PHEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--images", type=int, default=16)
    ap.add_argument("--size", type=int, default=512)
    ap.add_argument("--sizes", type=int, nargs="*", default=None,
                    help="heterogeneous dataset: cycle these sizes over "
                         "the image ids (overrides --size)")
    ap.add_argument("--bucket-rounding", dest="bucket_rounding",
                    choices=["exact", "pow2"])
    ap.add_argument("--prefetch-rounds", dest="prefetch_rounds", type=int)
    ap.add_argument("--no-prefetch", action="store_true",
                    help="serialize loading and compute (prefetch_rounds=0)")
    ap.add_argument("--overlap", action="store_true",
                    help="overlap engine: async staging ring, donated "
                         "device buffers, non-blocking regrow, "
                         "harvest-thread result streaming (bit-identical "
                         "to the synchronous path)")
    ap.add_argument("--overlap-depth", dest="overlap_depth", type=int,
                    help="staging-ring depth: device-staged + in-flight "
                         "rounds allowed ahead of the harvest (implies "
                         "--overlap; default 2)")
    ap.add_argument("--no-donate", action="store_true",
                    help="keep staged batches unaliased instead of "
                         "donating them to the compiled programs "
                         "(implies --overlap)")
    ap.add_argument("--no-async-overflow", action="store_true",
                    help="block on every overflow check at dispatch time "
                         "instead of streaming it (implies --overlap)")
    ap.add_argument("--no-async-harvest", action="store_true",
                    help="materialize results on the dispatch thread "
                         "instead of a harvest thread (implies --overlap)")
    ap.add_argument("--strategy", default="part_LPT",
                    choices=["part_executors", "part_images", "part_LPT"])
    ap.add_argument("--filter", default="filter_std",
                    choices=["vanilla", "filter_light", "filter_std",
                             "filter_heavy"])
    ap.add_argument("--filtration", default="superlevel",
                    choices=["superlevel", "sublevel"],
                    help="filtration direction: superlevel (paper default, "
                         "births at maxima) or sublevel (births at minima; "
                         "runs the same machinery on the exactly negated "
                         "image — floating dtypes only)")
    ap.add_argument("--work-log")
    ap.add_argument("--inject-failure", type=int, nargs="*", default=[],
                    help="round indices to fail once (recovery demo)")
    ap.add_argument("--max-features", type=int, default=8192)
    ap.add_argument("--max-candidates", type=int, default=32768)
    ap.add_argument("--candidate-mode", choices=["exact", "paper"])
    ap.add_argument("--merge-impl", choices=["scan", "boruvka"])
    ap.add_argument("--merge-keys", dest="merge_keys",
                    choices=["packed", "rank"],
                    help="phase-C total-order keys: packed (value, index) "
                         "int64 bit-keys (no full-image argsort; falls "
                         "back to rank for > 32-bit dtypes) or dense "
                         "argsort ranks")
    ap.add_argument("--phase-a-impl", dest="phase_a_impl",
                    choices=["fused", "pooled"],
                    help="stage-A implementation: fused strip kernel "
                         "(+compacted-frontier phase B) or the unfused "
                         "pooled baseline")
    ap.add_argument("--strip-rows", dest="strip_rows", type=int,
                    help="fused phase-A strip height (Pallas block rows)")
    ap.add_argument("--phase-c-impl", dest="phase_c_impl",
                    choices=["fused", "xla"],
                    help="stage-C merge under merge_impl=boruvka: fused "
                         "compact-instance kernel or the plain full-image "
                         "Boruvka (bit-identical either way)")
    ap.add_argument("--phase-c-block", dest="phase_c_block", type=int,
                    help="fused phase-C edge-block size (edges per Pallas "
                         "grid step)")
    ap.add_argument("--tournament-width", dest="tournament_width", type=int,
                    help="blockwise top-k tournament width (>= 2; any "
                         "width is bit-identical)")
    ap.add_argument("--autotune", action="store_true",
                    help="fold cached autotuned (strip_rows, phase_c_block, "
                         "tournament_width) into plans per image shape "
                         "(repro.roofline.autotune disk cache; missing "
                         "entries fall back to the flags above)")
    ap.add_argument("--autotune-cache", dest="autotune_cache",
                    help="autotune cache path (default: "
                         "artifacts/autotune_cache.json)")
    ap.add_argument("--no-regrow", action="store_true",
                    help="surface overflow instead of auto-regrowing")
    ap.add_argument("--tile-grid", dest="tile_grid", metavar="RxC",
                    help="halo-tiled path: fixed tile grid, e.g. 2x2")
    ap.add_argument("--tile-max-features", dest="tile_max_features",
                    type=int)
    ap.add_argument("--tile-max-candidates", dest="tile_max_candidates",
                    type=int)
    ap.add_argument("--max-tile-pixels", dest="max_tile_pixels", type=int,
                    help="route images above this pixel count through the "
                         "tiled path (also the auto-grid tile budget)")
    args = ap.parse_args()
    if args.max_tile_pixels is None and (
            args.tile_grid or args.tile_max_features
            or args.tile_max_candidates):
        # An explicit tile flag is a request for the tiled path: lower the
        # routing bound so this run's images actually take it (the TileSpec
        # default of 1<<20 px would silently keep small images whole).
        top = max(args.sizes) if args.sizes else args.size
        args.max_tile_pixels = top * top - 1

    config = PHConfig.from_flags(args)
    engine = PHEngine(config)
    injector = (FailureInjector(args.inject_failure)
                if args.inject_failure else None)
    if args.sizes:
        images = [(i, args.sizes[i % len(args.sizes)])
                  for i in range(args.images)]
    else:
        images = list(range(args.images))
    res = engine.run_distributed(
        images, image_size=args.size,
        strategy=args.strategy, work_log=args.work_log,
        failure_injector=injector, verbose=True)
    total_objects = sum(d["count"] for d in res.diagrams.values())
    stats = engine.plan_stats()
    out = {
        "config": json.loads(config.to_json()),
        "images": len(res.diagrams), "rounds": res.rounds,
        "failures_recovered": res.failures, "elapsed_s": round(res.elapsed_s, 2),
        "total_objects": total_objects,
        "mean_objects_per_image": total_objects / max(len(res.diagrams), 1),
        "plan_cache": stats,
    }
    if config.overlap is not None and config.overlap.enabled:
        out["overlap"] = engine.overlap_counters.snapshot()
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
