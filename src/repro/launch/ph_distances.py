"""CLI: pairwise diagram-distance matrices over a batch of frames.

Computes persistence diagrams for a batch of synthetic astro frames (or
any ``.npy`` stack) through :class:`repro.ph.PHEngine`, then the
(B, B) sliced-Wasserstein and bottleneck-bound matrices through the
``ph_distance`` kernel package, and prints a JSON report::

  PYTHONPATH=src python -m repro.launch.ph_distances \
      --images 8 --size 256 --filtration sublevel --n-dirs 32

``--npy`` replaces the synthetic frames with a (B, H, W) array from
disk; ``--out`` writes the matrices alongside the report.  All engine
knobs ride :meth:`repro.ph.PHConfig.from_flags`, so the distance CLI
accepts the same ``--filtration`` / backend toggles as ``ph_run``.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.ph import PHConfig, PHEngine


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--images", type=int, default=8)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--npy", help="load a (B, H, W) .npy stack instead of "
                                  "synthetic frames")
    ap.add_argument("--n-dirs", dest="n_dirs", type=int, default=16,
                    help="sliced-Wasserstein projection directions")
    ap.add_argument("--filter", default="vanilla",
                    choices=["vanilla", "filter_light", "filter_std",
                             "filter_heavy"])
    ap.add_argument("--filtration", default="superlevel",
                    choices=["superlevel", "sublevel"],
                    help="filtration direction the diagrams are computed "
                         "under (distances canonicalize internally, so "
                         "matrices of dual runs on negated frames match "
                         "bit-for-bit)")
    ap.add_argument("--max-features", type=int, default=8192)
    ap.add_argument("--max-candidates", type=int, default=32768)
    ap.add_argument("--use-pallas", dest="use_pallas", action="store_true",
                    default=None,
                    help="force the Pallas distance kernel (interpret "
                         "mode off-TPU)")
    ap.add_argument("--interpret", action="store_true")
    ap.add_argument("--out", help="write {sw, bottleneck} matrices as .npz")
    args = ap.parse_args()

    config = PHConfig.from_flags(args)
    engine = PHEngine(config)

    if args.npy:
        frames = np.load(args.npy)
        if frames.ndim != 3:
            raise SystemExit(f"--npy needs a (B, H, W) stack, got shape "
                             f"{frames.shape}")
    else:
        from repro.data.astro import generate_image
        frames = np.stack([generate_image(i, args.size)
                           for i in range(args.images)])

    res = engine.run_batch(frames)
    sw, bn = engine.distance_matrix(res, n_dirs=args.n_dirs)
    sw, bn = np.asarray(sw), np.asarray(bn)

    iu = np.triu_indices(sw.shape[0], k=1)
    report = {
        "config": json.loads(config.to_json()),
        "images": int(sw.shape[0]),
        "n_dirs": args.n_dirs,
        "sw": {"mean": float(sw[iu].mean()) if iu[0].size else 0.0,
               "max": float(sw.max())},
        "bottleneck": {"mean": float(bn[iu].mean()) if iu[0].size else 0.0,
                       "max": float(bn.max())},
        "plan_cache": engine.plan_stats(),
    }
    if args.out:
        np.savez(args.out, sw=sw, bottleneck=bn)
        report["out"] = args.out
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
