"""End-to-end training driver: sharded train step, checkpoint/restart,
elastic resume, metrics log.

Works at every scale: smoke configs on this CPU container (see
examples/train_lm.py), full configs on a real pod (same code path — only the
mesh and config differ).  Fault tolerance: async checkpoints every
``--ckpt-every`` steps + data pipeline state (just the step counter, the
token stream is deterministic) => kill -9 at any point and rerun resumes.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ShapeConfig, get_config, get_smoke_config
from repro.data.tokens import TokenStream
from repro.distributed import sharding
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_context, make_small_context
from repro.optim.adamw import AdamW


def train(arch: str, *, steps: int = 100, seq_len: int = 128,
          global_batch: int = 8, smoke: bool = True, lr: float = 3e-4,
          ckpt_dir: str | None = None, ckpt_every: int = 50,
          resume: bool = True, production_mesh: bool = False,
          log_every: int = 10, overrides: dict | None = None,
          verbose: bool = True):
    cfg = (get_smoke_config if smoke else get_config)(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    if cfg.is_encdec:
        raise NotImplementedError("use whisper smoke via tests; train.py "
                                  "drives decoder-only archs")
    ctx = make_context() if production_mesh else make_small_context(
        data=len(jax.devices()), model=1)
    shape = ShapeConfig("custom", seq_len, global_batch, "train")
    opt = AdamW(lr=lr, total_steps=steps,
                warmup_steps=max(10, steps // 20))
    bundle = steps_lib.train_bundle(cfg, shape, ctx, opt)

    from repro.models.model import build_model
    model = build_model(cfg)
    mesh = ctx.mesh
    pspec = sharding.param_specs(model.param_shapes(), mesh, cfg.name)
    named_p = sharding.to_named(pspec, mesh)

    stream = TokenStream(cfg.vocab_size, seq_len, global_batch)
    saver = ckpt.AsyncCheckpointer()
    start_step = 0

    with mesh:
        params = jax.jit(model.init, out_shardings=named_p)(
            jax.random.PRNGKey(0))
        opt_state = jax.jit(opt.init)(params)
        if ckpt_dir and resume and ckpt.latest_step(ckpt_dir) is not None:
            (params, opt_state), meta, start_step = ckpt.restore(
                ckpt_dir, (params, opt_state))
            if verbose:
                print(f"resumed from step {start_step}", flush=True)

        history = []
        t0 = time.time()
        for step in range(start_step, steps):
            batch = jax.tree.map(jax.numpy.asarray, stream.batch_at(step))
            params, opt_state, metrics = bundle.fn(params, opt_state, batch)
            if step % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["tokens_per_s"] = (global_batch * seq_len * (step + 1
                                     - start_step)) / (time.time() - t0)
                history.append(m)
                if verbose:
                    print(json.dumps({k: round(v, 4) for k, v in m.items()}),
                          flush=True)
            if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
                saver.save(ckpt_dir, step + 1, (params, opt_state),
                           metadata={"arch": arch, "cfg": cfg.name})
        saver.join()
        if ckpt_dir:
            ckpt.save(ckpt_dir, steps, (params, opt_state),
                      metadata={"arch": arch, "done": True})
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--override", action="append", default=[])
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    train(args.arch, steps=args.steps, seq_len=args.seq_len,
          global_batch=args.global_batch, smoke=not args.full_config,
          production_mesh=args.production_mesh, ckpt_dir=args.ckpt_dir,
          ckpt_every=args.ckpt_every, resume=not args.no_resume,
          overrides=overrides or None)


if __name__ == "__main__":
    main()
