"""PH-as-a-service demo driver: warmed daemon + synthetic client load.

`python -m repro.launch.ph_serve --buckets 64 128 --clients 4 --requests 64`

Boots a :class:`repro.serving.PHServer` over one shared
:class:`~repro.ph.engine.PHEngine`, pre-traces the warm plan pool
(``--no-warmup`` to skip and watch cold-start traces instead), then
drives it from ``--clients`` submitter threads with random images whose
shapes cycle below the configured buckets.  Prints the serving stats
JSON: admission counters, per-bucket p50/p95/p99 queue-wait and
end-to-end latency, batch occupancy, plan-cache stats, and
``steady_state_traces`` (zero on a warmed server).

The LM-side serving demo is ``launch/serve_lm.py``; the gated benchmark
twin of this script is ``benchmarks/serve_bench.py``.
"""
from __future__ import annotations

import argparse
import json
import threading

import numpy as np

from repro.ph import PHConfig, PHEngine
from repro.serving import AdmissionError, PHServer


def client_shapes(buckets, rng, count):
    """Random 2D shapes fitting the bucket set (each at most its bucket,
    at least ~60% of it, so padding repair is always exercised)."""
    out = []
    for i in range(count):
        hb, wb = buckets[i % len(buckets)]
        out.append((int(rng.integers(max(2, int(hb * 0.6)), hb + 1)),
                    int(rng.integers(max(2, int(wb * 0.6)), wb + 1))))
    return out


def drive(server, shapes, *, seed=0, rejected_ok=True):
    """Submit every shape, resolve every future; returns (ok, rejected)."""
    rng = np.random.default_rng(seed)
    futs, rejected = [], 0
    for shape in shapes:
        img = rng.normal(size=shape).astype(np.float32)
        try:
            futs.append(server.submit(img))
        except AdmissionError:
            if not rejected_ok:
                raise
            rejected += 1
    for f in futs:
        f.result(timeout=300)
    return len(futs), rejected


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--buckets", dest="serve_buckets", type=int, nargs="+",
                    default=[64, 128], help="serve bucket sizes (square)")
    ap.add_argument("--batch-cap", dest="serve_batch_cap", type=int,
                    default=4, help="fixed dispatch batch per bucket")
    ap.add_argument("--max-queue", dest="serve_max_queue", type=int,
                    default=64, help="per-bucket admission bound")
    ap.add_argument("--tick-ms", dest="serve_tick_ms", type=float,
                    default=2.0, help="coalescing tick interval")
    ap.add_argument("--admission", dest="serve_admission",
                    choices=["reject", "block"], default="reject")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent submitter threads")
    ap.add_argument("--requests", type=int, default=64,
                    help="requests per client thread")
    ap.add_argument("--filter", default=None,
                    choices=["vanilla", "filter_std", "filter_database"])
    ap.add_argument("--max-features", type=int, default=None)
    ap.add_argument("--max-candidates", type=int, default=None)
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip plan pre-tracing (show cold-start traces)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    args.serve = True

    config = PHConfig.from_flags(args)
    engine = PHEngine(config)
    server = PHServer(engine)
    if not args.no_warmup:
        info = server.warmup()
        print(f"warmup: {json.dumps(info)}")

    rng = np.random.default_rng(args.seed)
    buckets = config.serve.buckets
    totals = {"ok": 0, "rejected": 0}
    lock = threading.Lock()

    def run_client(cid):
        shapes = client_shapes(buckets, np.random.default_rng(
            args.seed + 1000 + cid), args.requests)
        ok, rej = drive(server, shapes, seed=args.seed + cid)
        with lock:
            totals["ok"] += ok
            totals["rejected"] += rej

    threads = [threading.Thread(target=run_client, args=(i,), daemon=True)
               for i in range(args.clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    server.drain(60)
    stats = server.stats()
    server.shutdown()
    print(json.dumps({"clients": args.clients,
                      "resolved": totals["ok"],
                      "client_rejected": totals["rejected"],
                      "serve": stats}, indent=1))


if __name__ == "__main__":
    main()
