"""AdamW with f32 moments over (possibly bf16) params, ZeRO-shardable.

Hand-rolled (no optax in this environment).  Moments are stored f32 and
sharded with an extra `data` axis (distributed/sharding.opt_state_specs) so
the update lowers to reduce-scatter(grads) + sharded update + all-gather
(params) — ZeRO-1 — without any explicit collective in this file.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    mu: Any        # f32, like params
    nu: Any        # f32, like params
    count: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1

    def init(self, params) -> OptState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(zeros, jax.tree.map(jnp.copy, zeros),
                        jnp.zeros((), jnp.int32))

    def schedule(self, step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - self.warmup_steps)
                        / max(self.total_steps - self.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * (self.min_lr_ratio
                                 + (1 - self.min_lr_ratio) * cos)

    def update(self, params, grads, state: OptState):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(jax.tree.reduce(
            lambda a, g: a + jnp.sum(jnp.square(g)), grads, jnp.zeros(())))
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
        count = state.count + 1
        lr = self.schedule(count)
        c1 = 1 - self.b1 ** count.astype(jnp.float32)
        c2 = 1 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            step_ = lr * (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # decay matrices only
                step_ = step_ + lr * self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(new_mu, new_nu, count), \
            {"grad_norm": gnorm, "lr": lr}
