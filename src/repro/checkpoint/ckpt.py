"""Sharded checkpointing: save/restore pytrees with rotation + elastic
re-sharding (no orbax in this environment).

Format: one directory per step containing ``manifest.json`` (flattened key
paths, shapes, dtypes, pytree structure hints, user metadata) and one
``.npy``-style raw buffer file per leaf (bf16 stored as uint16 views).
Writes are atomic (tmp dir + rename); ``keep`` rotates old steps out;
``save_async`` runs host-side serialization on a worker thread so the train
loop isn't blocked (device->host copy happens before the thread handoff, so
donated buffers are safe).

Restore targets *any* mesh: arrays are stored unsharded (single-host
container; on a multi-host pod each host would write its addressable shards
— the manifest layout already carries per-leaf shape/dtype for that) and
``device_put`` against the new sharding re-shards — this is the elastic
scaling path (tests/test_checkpoint.py restores a 1-device checkpoint onto
a 2x4 mesh and vice versa).
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for kp, leaf in flat:
        key = "/".join(_keyname(k) for k in kp) or "_root"
        items.append((key, leaf))
    return items, treedef


def _keyname(k):
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return k.name
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    return str(k)


def save(ckpt_dir, step: int, tree, *, metadata: dict | None = None,
         keep: int = 3):
    """Synchronous checkpoint write (atomic)."""
    host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
    _write(Path(ckpt_dir), step, host_tree, metadata or {}, keep)


class AsyncCheckpointer:
    """Serialize to disk off-thread; join() before exit or next save."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, ckpt_dir, step: int, tree, *, metadata=None, keep=3):
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        self.join()
        self._thread = threading.Thread(
            target=_write,
            args=(Path(ckpt_dir), step, host_tree, metadata or {}, keep),
            daemon=True)
        self._thread.start()

    def join(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _write(root: Path, step: int, host_tree, metadata: dict, keep: int):
    items, _ = _flatten(host_tree)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {"step": step, "metadata": metadata, "leaves": {}}
    for i, (key, leaf) in enumerate(items):
        arr = np.asarray(leaf)
        dtype = str(arr.dtype)
        if dtype == "bfloat16":
            arr = arr.view(np.uint16)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr, allow_pickle=False)
        manifest["leaves"][key] = {"file": fname, "dtype": dtype,
                                   "shape": list(leaf.shape)}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    # rotation
    steps = sorted(p for p in root.glob("step_*") if p.is_dir())
    for old in steps[:-keep] if keep else []:
        shutil.rmtree(old)


def latest_step(ckpt_dir) -> int | None:
    root = Path(ckpt_dir)
    if not root.exists():
        return None
    steps = sorted(root.glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore(ckpt_dir, target_tree, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``target_tree`` (shapes must match).

    ``shardings``: optional matching pytree of NamedShardings — arrays are
    device_put against them (elastic re-shard onto any mesh).
    """
    import ml_dtypes

    root = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    items, treedef = _flatten(target_tree)
    shard_items = None
    if shardings is not None:
        shard_items, _ = _flatten(shardings)

    leaves = []
    for i, (key, target_leaf) in enumerate(items):
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(d / meta["file"], allow_pickle=False)
        if meta["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        if list(arr.shape) != list(target_leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {target_leaf.shape}")
        if shard_items is not None:
            arr = jax.device_put(arr, shard_items[i][1])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), \
        manifest["metadata"], step
