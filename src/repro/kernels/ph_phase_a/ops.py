"""Jit'd public wrapper for the fused phase-A stage with backend dispatch.

``use_pallas=None`` (default) auto-selects: the Pallas TPU kernel on TPU
backends, the pure-XLA reference elsewhere (this container is CPU-only, so
CI exercises the kernel via interpret mode in tests — the phase-A
interpret smoke in tier-1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def boundary_rows(h: int, strip_rows: int) -> np.ndarray:
    """Sorted first/last image rows of every ``strip_rows``-row strip.

    These rows are the **static frontier** of the strip decomposition: a
    strip-snapped pointer that is not a basin root always lands in one of
    them, so phase B's condensed label resolution only ever gathers over
    ``len(boundary_rows) * W`` entries instead of all ``H * W`` pixels.
    """
    s = max(1, min(strip_rows, h))
    rows = set()
    for r0 in range(0, h, s):
        rows.add(r0)
        rows.add(min(h, r0 + s) - 1)
    return np.asarray(sorted(rows), np.int32)


@functools.partial(jax.jit,
                   static_argnames=("strip_rows", "use_pallas", "interpret"))
def fused_phase_a(image: jnp.ndarray, *, strip_rows: int = 8,
                  use_pallas: bool | None = None, interpret: bool = False):
    """Fused phase A: ``(ptr, hi_mask)`` flat int32 arrays of ``image``.

    ``ptr`` is the strip-snapped steepest-ascent pointer (basin root or
    boundary-row pixel of an adjacent strip); ``hi_mask`` the
    strictly-higher 8-neighbor bitmask in ``NEIGHBOR_OFFSETS`` bit order.
    Both backends are bit-identical (tests/test_kernels_phase_a.py).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        from repro.kernels.ph_phase_a import kernel
        return kernel.phase_a(image, strip_rows=strip_rows,
                              interpret=interpret or not _on_tpu())
    from repro.kernels.ph_phase_a import ref
    return ref.phase_a(image, strip_rows=strip_rows)
