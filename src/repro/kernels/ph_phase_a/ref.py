"""Pure-XLA reference for the fused phase-A stage (the Pallas oracle).

Phase A of the stage graph (src/repro/ph/DESIGN.md §2) turns an image into
the two per-pixel artifacts the rest of PixHomology consumes:

* ``ptr``  — the **strip-snapped steepest-ascent pointer**: each pixel's
  ascent chain is followed while it stays inside the pixel's row strip
  (``strip_rows`` consecutive image rows), then one extra half-hop is
  taken, so ``ptr[i]`` is either a basin root or a pixel in the *boundary
  row* of an adjacent strip.  This is the invariant the compacted-frontier
  label resolution (phase B) relies on: every pointer target outside the
  root set lives in a statically-known O(n / strip_rows) row subset.

* ``hi_mask`` — an int32 bitmask over :data:`NEIGHBOR_OFFSETS` (bit j set
  iff 8-neighbor j is inside the image and strictly higher under the
  (value, flat index) total order).  ``popcount >= 2`` is the
  basin-candidate flag: a pixel whose higher neighbors cannot span two
  basins can never be a death candidate, and the mask lets the exact
  candidate test (phase B) skip re-deriving rank comparisons.

The strip snap is exact, not approximate: its fixed point composed with
the frontier resolution reaches the same labels as whole-image pointer
doubling (tests/test_kernels_phase_a.py proves bit-equality), so fused and
pooled phase A are interchangeable stage implementations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.grid import NEIGHBOR_OFFSETS, fixed_point_iterate, shift2d
from repro.kernels.maxpool.ref import _neg_inf


def pointer_and_mask_sweep(image: jnp.ndarray
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One 8-offset sweep emitting (steepest pointer, higher bitmask).

    This is the XLA expression of the kernel's fused VMEM pass: each
    shifted neighbor plane is materialized once and feeds *both* the
    argmax reduction (identical to ``maxpool.ref.argmaxpool3x3``) and the
    strictly-higher mask bit, instead of two separate pooled sweeps.

    Mask bit j (:data:`NEIGHBOR_OFFSETS` order) is set iff neighbor j is
    inside the image and ``(v_nb, flat_nb) > (v, flat)``; within a 3x3
    window the flat order equals the (dr, dc) lexicographic order, so the
    index tie-break is static per offset.  Out-of-image neighbors never
    win the argmax nor count as higher (exact parity with the rank-based
    test, even for images containing the fill value).
    """
    h, w = image.shape
    rows = jnp.arange(h, dtype=jnp.int32)[:, None]
    cols = jnp.arange(w, dtype=jnp.int32)[None, :]
    flat = rows * w + cols
    fill = _neg_inf(image.dtype)

    best_v = image
    best_i = flat
    mask = jnp.zeros(image.shape, jnp.int32)
    for j, (dr, dc) in enumerate(NEIGHBOR_OFFSETS):
        v = shift2d(image, dr, dc, fill)
        i = shift2d(flat, dr, dc, jnp.int32(-1))
        better = (v > best_v) | ((v == best_v) & (i > best_i))
        best_v = jnp.where(better, v, best_v)
        best_i = jnp.where(better, i, best_i)
        higher = v > image
        if (dr, dc) > (0, 0):      # neighbor flat index > self on value ties
            higher = higher | (v == image)
        mask = mask | jnp.where((i >= 0) & higher, jnp.int32(1 << j),
                                jnp.int32(0))
    return best_i, mask


@functools.partial(jax.jit, static_argnames=("strip_rows", "with_stats"))
def phase_a(image: jnp.ndarray, *, strip_rows: int = 8,
            with_stats: bool = False):
    """Fused phase A on the whole image: ``(ptr, hi_mask)`` flat int32.

    Semantics identical to the Pallas kernel: steepest-ascent pointers
    under the (value, flat index) total order, snapped to each pixel's
    furthest in-strip ancestor, plus one half-hop out of the strip; and
    the strictly-higher neighbor bitmask.  ``with_stats`` additionally
    returns the in-strip snap iteration count (benchmarks only).
    """
    h, w = image.shape
    n = h * w
    srows = max(1, min(strip_rows, h))
    span = w * srows                 # strip id of flat pixel g = g // span

    hop2d, mask2d = pointer_and_mask_sweep(image)      # one fused sweep
    hop = hop2d.reshape(-1)
    hi_mask = mask2d.reshape(-1)

    idx = jnp.arange(n, dtype=jnp.int32)
    esc = hop // span != idx // span                   # hop leaves the strip
    m0 = jnp.where(esc, idx, hop)                      # freeze escapes
    m, snap_iters = fixed_point_iterate(lambda q: q[q], m0)
    hm = hop[m]                                        # half-hop out
    ptr = jnp.where(hm // span != m // span, hm, m)
    if with_stats:
        return ptr, hi_mask, snap_iters
    return ptr, hi_mask
