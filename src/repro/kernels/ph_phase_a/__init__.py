"""Fused PixHomology phase-A kernel (pointers + in-strip snap + flags).

``ops.fused_phase_a`` is the public entry point; ``ref.py`` is the pure-XLA
oracle the Pallas kernel (``kernel.py``) must match bit-exactly, and the
backend the CPU path runs.  See ``src/repro/ph/DESIGN.md`` §2 for the
stage-graph contract this kernel implements.
"""
from repro.kernels.ph_phase_a.ops import (  # noqa: F401
    boundary_rows,
    fused_phase_a,
)
