"""Pallas TPU kernel for the fused PixHomology phase A.

One VMEM pass per ``strip_rows``-row strip replaces what the pooled stage
pipeline spends three HBM round trips plus the first ~log2(strip area)
whole-image doubling iterations on (src/repro/ph/DESIGN.md §2/§Perf):

  1. load three row-shifted planes of the (-inf)-padded image (the same
     halo trick as the maxpool kernel: BlockSpecs cannot express
     overlapping windows, so rows r-1 / r / r+1 arrive as separate
     BlockSpec-tiled inputs, double-buffered by the Pallas pipeline);
  2. reduce the 3x3 window to the steepest-ascent pointer with full
     (value, row, col) total-order tie-breaking, masking out-of-image
     lanes exactly (ref.py's fill index -1 can never win — unlike the
     maxpool kernel this holds even for images containing the fill value);
  3. pointer-chase **inside the strip**: doubling on the strip-local
     pointer array, entirely in VMEM, until every pixel is snapped to its
     furthest in-strip ancestor (escape targets frozen), then one
     half-hop so emitted pointers land on basin roots or boundary rows of
     adjacent strips — the invariant phase B's compacted frontier needs;
  4. emit the strictly-higher 8-neighbor bitmask (basin-candidate flags)
     from the planes already resident in VMEM.

VMEM working set: 3 value planes of (strip_rows, W+2) plus ~6 int32
(strip_rows, W) temporaries — ~56 KB per strip at strip_rows=8, W=1024,
f32; W up to ~32k columns fits 16 MB VMEM.  The in-kernel chase is a
1D gather over the strip-local flat array; rows are padded to a multiple
of ``strip_rows`` with -inf (pad pixels self-root, so the chase cannot
escape into them, and the host wrapper slices them off).

Caveat (CPU-only CI): tests pin this kernel down bit-exactly in
*interpret* mode; the Mosaic lowering of the data-dependent
``while_loop`` + dynamic 1D gather is not exercised here (no TPU in the
container).  If a given jaxlib's Mosaic rejects it, the stage graph
degrades cleanly: ``use_pallas=False`` keeps the fused stage semantics
on the bit-identical XLA twin (``ref.phase_a``), and
``phase_a_impl="pooled"`` is the unfused fallback — both produce
identical diagrams (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.grid import NEIGHBOR_OFFSETS, fixed_point_iterate
from repro.kernels.maxpool.kernel import _pad_rows, _row_shifted_planes
from repro.kernels.maxpool.ref import _neg_inf


def _phase_a_kernel(r0_ref, r1_ref, r2_ref, ptr_ref, mask_ref, *,
                    height: int, width: int, strip_rows: int):
    i = pl.program_id(0)
    s, w = strip_rows, width
    planes = (r0_ref[...], r1_ref[...], r2_ref[...])   # (S, W+2) each
    x = planes[1][:, 1:1 + w]                          # self values

    lr = jax.lax.broadcasted_iota(jnp.int32, (s, w), 0)  # row within strip
    cc = jax.lax.broadcasted_iota(jnp.int32, (s, w), 1)  # column
    grow = i * jnp.int32(s) + lr                         # global row

    # --- 3x3 argmax under (value, row, col), out-of-image never wins ---
    best_v = x
    best_dr = jnp.ones((s, w), jnp.int32)   # plane index: 1 = self row
    best_dc = jnp.ones((s, w), jnp.int32)
    for dr in (0, 1, 2):
        for dc in (0, 1, 2):
            if (dr, dc) == (1, 1):
                continue
            v = planes[dr][:, dc:dc + w]
            inb = ((grow + (dr - 1) >= 0) & (grow + (dr - 1) < height)
                   & (cc + (dc - 1) >= 0) & (cc + (dc - 1) < w))
            key_gt = ((jnp.int32(dr) > best_dr)
                      | ((jnp.int32(dr) == best_dr)
                         & (jnp.int32(dc) > best_dc)))
            take = inb & ((v > best_v) | ((v == best_v) & key_gt))
            best_v = jnp.where(take, v, best_v)
            best_dr = jnp.where(take, jnp.int32(dr), best_dr)
            best_dc = jnp.where(take, jnp.int32(dc), best_dc)

    # --- in-strip snap: doubling on the strip-local pointer array ---
    tr = lr + best_dr - 1                 # target row within strip
    tc = cc + best_dc - 1                 # target column (in-image by mask)
    esc = (tr < 0) | (tr >= s)            # hop leaves the strip
    lid = lr * w + cc
    m0 = jnp.where(esc, lid, tr * w + tc).reshape(-1)
    m, _ = fixed_point_iterate(lambda q: q[q], m0)

    # Half-hop: emitted pointers are roots or boundary-row pixels of the
    # adjacent strips, in global flat coordinates.
    tgt_g = ((grow + best_dr - 1) * jnp.int32(w) + tc).reshape(-1)
    gid = (grow * jnp.int32(w) + cc).reshape(-1)
    escf = esc.reshape(-1)
    ptr = jnp.where(escf[m], tgt_g[m], gid[m])
    ptr_ref[...] = ptr.reshape(s, w)

    # --- strictly-higher 8-neighbor bitmask (basin-candidate flags) ---
    mask = jnp.zeros((s, w), jnp.int32)
    for j, (dr, dc) in enumerate(NEIGHBOR_OFFSETS):
        v = planes[dr + 1][:, dc + 1:dc + 1 + w]
        inb = ((grow + dr >= 0) & (grow + dr < height)
               & (cc + dc >= 0) & (cc + dc < w))
        higher = v > x
        if (dr, dc) > (0, 0):             # flat-index tie-break is static
            higher = higher | (v == x)
        mask = mask | jnp.where(inb & higher, jnp.int32(1 << j),
                                jnp.int32(0))
    mask_ref[...] = mask


@functools.partial(jax.jit, static_argnames=("strip_rows", "interpret"))
def phase_a(image: jnp.ndarray, *, strip_rows: int = 8,
            interpret: bool = False):
    """Fused phase A; bit-identical to ``ref.phase_a`` (flat int32 pair)."""
    h, w = image.shape
    s = max(1, min(strip_rows, h))
    hp = -(-h // s) * s                    # ceil to a strip multiple
    fill = _neg_inf(image.dtype)

    r0, r1, r2 = _row_shifted_planes(image, fill)
    r0, r1, r2 = (_pad_rows(p, hp - h, fill) for p in (r0, r1, r2))

    kernel = functools.partial(_phase_a_kernel, height=h, width=w,
                               strip_rows=s)
    in_spec = pl.BlockSpec((s, w + 2), lambda i: (i, 0))
    out_spec = pl.BlockSpec((s, w), lambda i: (i, 0))
    ptr, mask = pl.pallas_call(
        kernel,
        grid=(hp // s,),
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((hp, w), jnp.int32),
                   jax.ShapeDtypeStruct((hp, w), jnp.int32)],
        interpret=interpret,
    )(r0, r1, r2)
    return ptr[:h].reshape(-1), mask[:h].reshape(-1)
