"""Public flash attention op: Pallas forward + flash-style recompute backward.

``flash_attention(q, k, v, causal=..., window=...)`` — layout (B, H, S, hd)
for q and (B, KV, S, hd) for k/v.  On non-TPU backends (this container) the
kernel runs in interpret mode inside tests; production model code uses the
XLA blockwise path by default and flips to this op on TPU
(models/attention.py dispatch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel, ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, window=None, interpret=False):
    return kernel.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                      interpret=interpret)


def _fwd(q, k, v, causal, window, interpret):
    out = kernel.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                     interpret=interpret)
    return out, (q, k, v)


def _bwd(causal, window, interpret, res, g):
    q, k, v = res
    # Flash-style backward: recompute attention (O(S) memory) through the
    # reference contraction and differentiate it.
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
