"""Pallas TPU flash attention (forward), GQA-aware.

Grid (B, H, n_q, n_kv) with the KV dimension innermost/sequential; online
softmax state (running max m, normalizer l, f32 accumulator) lives in VMEM
scratch and survives across KV grid steps; the output block is written once
at the final KV step.  K/V BlockSpecs index ``head // group`` so grouped
query heads share one KV stream — K/V are never repeated to H heads.

Block sizes default to (q_block, kv_block) = (128, 128): the MXU sees
(128, hd) x (hd, 128) tiles (lane-aligned for hd in {64, 128, 256}); the
VMEM working set is q + k + v + acc ≈ 4 * 128 * hd * 4 B plus the (128, 128)
f32 score tile — well under 1 MB, leaving the Pallas pipeline room to
double-buffer the K/V streams against the MXU.

Causal skipping: KV blocks entirely above the diagonal are skipped via
``pl.when`` (no MXU work), so the causal forward does ~half the rectangle's
FLOPs — this is the structural win over the XLA masked path whose HLO does
the full rectangle (see EXPERIMENTS.md §Roofline, useful-flops ratio).

Backward: ``ops.flash_attention`` wraps this in a ``jax.custom_vjp`` whose
backward recomputes attention with the blockwise-XLA path (flash-style
recompute; no O(S^2) residuals are ever stored).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               q_block: int, kv_block: int, n_kv: int, causal: bool,
               window: int | None, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * q_block
    k_start = ik * kv_block
    # Causal: skip blocks strictly above the diagonal; window: skip blocks
    # entirely older than the window.
    relevant = True
    if causal:
        relevant = k_start <= q_start + q_block - 1
    if window is not None:
        relevant = relevant & (k_start + kv_block - 1
                               > q_start - window)

    @pl.when(relevant)
    def compute():
        q = q_ref[0, 0]                                # (qb, hd)
        k = k_ref[0, 0]                                # (kb, hd)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (qb, kb)
        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1)
        mask = jnp.ones((q_block, kv_block), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ik == n_kv - 1)
    def finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: int | None = None, q_block: int = 128,
                        kv_block: int = 128, interpret: bool = False):
    """q: (B, H, Sq, hd); k/v: (B, KV, Skv, hd). Returns (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    if sq % qb or skv % kb:
        raise ValueError(f"seq lens ({sq},{skv}) must tile into blocks "
                         f"({qb},{kb}); pad upstream")
    n_q, n_kv = sq // qb, skv // kb

    kernel = functools.partial(
        _fa_kernel, q_block=qb, kv_block=kb, n_kv=n_kv, causal=causal,
        window=window, scale=hd ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, qb, hd), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, kb, hd),
                         lambda b_, h_, i, j, g=g: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, kb, hd),
                         lambda b_, h_, i, j, g=g: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, hd),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=_scratch(qb, hd),
        interpret=interpret,
    )(q, k, v)


def _scratch(qb, hd):
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, hd), jnp.float32)]
