"""Pure-jnp oracle for the flash attention kernel.

Materialized softmax attention with GQA, causal and local-window masking.
Layout: q (B, H, Sq, hd); k/v (B, KV, Skv, hd).  f32 accumulation.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax


def attention(q, k, v, *, causal: bool = True, window: int | None = None):
    b, h, sq, hd = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    q5 = q.reshape(b, kvh, g, sq, hd)
    s = jnp.einsum("bngqd,bnkd->bngqk", q5, k,
                   preferred_element_type=jnp.float32)
    s = s * (hd ** -0.5)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngqk,bnkd->bngqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, sq, hd).astype(q.dtype)
