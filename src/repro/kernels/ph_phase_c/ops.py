"""Dispatch + whole-image driver for the fused phase-C merge.

Two public entry points:

* :func:`best_edge_reduce` — the per-round segmented reduction, routed
  to the Pallas kernel (TPU, or ``interpret=True`` anywhere) or the
  bit-identical XLA reference; plugged into
  :func:`repro.core.parallel_merge.boruvka_forest` as ``reduce_fn``.

* :func:`fused_merge` — the whole-image fused phase C.  The plain
  Boruvka path (``phase_c_impl="xla"``) runs every round over all n
  pixel-vertices: each round's label resolve, scatter targets, and die
  masks are O(n) even though only the C basin roots can ever merge
  (C ~ 10³-10⁴ at n = 10⁶).  ``fused_merge`` compacts the instance
  first — and it compacts by **cumsum scatter**, not by selection:
  the XLA path's two n-length blockwise-tournament top-k's (candidate
  selection inside ``candidate_edges`` and the diagram's root table)
  each cost more than all of its Boruvka rounds combined on CPU, so
  the fused path gathers candidates and roots to their capacity-sized
  arrays in one O(n) pass each (``_compact_mask``) and sorts only the
  ≤ ``max_features``-length compact root table into diagram order.
  Edge endpoints map to compact slots through an O(f log f) sorted
  lookup table, and the Boruvka forest — with the blocked reduction
  and the merge-budget early exit (``n_live``) — runs entirely on
  (f, E)-sized arrays.  The diagram assembly reads the compact records
  directly, and the compact edge builder carries each saddle's pixel
  id alongside its key, so the rank-key fallback no longer pays the
  full-image inverse-argsort either.

Bit-identity with the XLA path holds whenever the root count fits
``max_features`` (the no-overflow contract): below capacity the
compacted-then-sorted root table equals the ``masked_top_k`` selection
the XLA diagram makes (same set, same descending total order — keys
are unique), every edge endpoint is a root above any truncation
threshold (its birth exceeds the saddle), and elder-rule deaths are a
graph invariant of the (basin, saddle-edge) multiset — the identical
multiset both paths build, merely enumerated in pixel order instead of
key order (the tiled seam merge already relies on this invariance: its
edges arrive in tile order).  Under root overflow
(``c > max_features``) edges touching a dropped root are dropped too,
so pre-regrow rows may differ from the XLA path's; both impls raise
the same ``Diagram.overflow`` and the engine's regrow re-dispatches at
a capacity where they agree again.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.grid import higher_neighbor_basins
from repro.core.packed_keys import key_pad
from repro.core.parallel_merge import boruvka_forest, chain_clique_edges
from repro.kernels.ph_phase_c import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def best_edge_reduce(key, ra, rb, nv: int, *, block_edges: int = 1024,
                     use_pallas: bool | None = None,
                     interpret: bool = False):
    """Per-cluster best incident edge, Pallas or XLA backend.

    ``use_pallas=None`` auto-selects: the Pallas kernel on TPU, the XLA
    reference elsewhere (on CPU the fused win comes from the compact
    instance, not from emulating the kernel).  Forcing ``use_pallas=True``
    off-TPU runs the kernel in interpret mode (CI's parity path).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.best_edge_reduce(key, ra, rb, nv)
    return kernel.best_edge_reduce(key, ra, rb, nv,
                                   block_edges=block_edges,
                                   interpret=interpret or not _on_tpu())


def _compact_mask(key_flat, mask, k: int):
    """Gather the ≤ k masked lanes to a k-slot table in flat-pixel order.

    One cumsum + two O(n) scatters — no selection sort of any width.
    Returns ``(keys, pix)``: dtype-min pad keys and pixel id 0 on empty
    slots; masked lanes beyond the k-th (capacity overflow — the caller
    raises the flag) fall in the drop lane.
    """
    n = key_flat.shape[0]
    slot = jnp.cumsum(mask.astype(jnp.int32)) - 1
    tgt = jnp.where(mask & (slot < k), slot, k)
    keys = jnp.full(k, key_pad(key_flat.dtype), key_flat.dtype)
    keys = keys.at[tgt].set(key_flat, mode="drop")
    pix = jnp.zeros(k, jnp.int32)
    pix = pix.at[tgt].set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    return keys, pix


def _compact_candidate_edges(key_flat, labels_flat, cand_flat, shape,
                             max_candidates: int):
    """Chained basin edges of the compacted candidate set: flat (K*8,)
    ``(key, a, b, saddle_pixel)``.

    The compaction twin of :func:`repro.core.parallel_merge.candidate_edges`
    (same ``higher_neighbor_basins`` + ``chain_clique_edges`` chain, so the
    edge *multiset* is identical); edges come out in candidate-pixel order
    rather than descending key order, which the merge forest is invariant
    to, and each edge carries its saddle pixel directly — no key→pixel
    inverse lookup for either key encoding.
    """
    h, w = shape
    k = min(max_candidates, h * w)
    pad = key_pad(key_flat.dtype)
    top_keys, top_pix = _compact_mask(key_flat, cand_flat, k)
    valid = top_keys > pad
    ok, lbl = higher_neighbor_basins(top_pix, top_keys, key_flat,
                                     labels_flat, shape, valid)  # (K, 8)
    edge_ok, prev_lbl = chain_clique_edges(ok, lbl)
    keys = jnp.broadcast_to(top_keys[:, None], ok.shape)
    pixs = jnp.broadcast_to(top_pix[:, None], ok.shape)
    return (jnp.where(edge_ok, keys, pad).reshape(-1),
            jnp.where(edge_ok, lbl, 0).reshape(-1),
            jnp.where(edge_ok, prev_lbl, 0).reshape(-1),
            pixs.reshape(-1))


def _slot_lookup(sorted_pix, order, q):
    """Binary-search ``q`` in the sorted compact-root pixel table.

    Returns ``(slot, found)``: the root's compact slot (0 where absent —
    callers must mask on ``found``).  Same sorted-table pattern as the
    tiled seam's ring lookup.
    """
    j = jnp.searchsorted(sorted_pix, q)
    j = jnp.clip(j, 0, sorted_pix.shape[0] - 1)
    found = sorted_pix[j] == q
    return jnp.where(found, order[j], 0), found


def fused_merge(image_flat, key_flat, labels_flat, cand_flat, root_mask,
                shape, *, max_candidates: int, max_features: int,
                phase_c_block: int = 1024, tournament_width: int = 2,
                use_pallas: bool | None = None, interpret: bool = False):
    """Compact fused phase-C merge over the top-``max_features`` roots.

    ``root_mask``: (n,) bool — the diagram's root set (already filtered
    by any truncation threshold; every candidate edge endpoint is in it
    because a basin's birth exceeds its saddles).  Returns
    ``(root_key, root_pix, rvalid, dval_c, dpos_c, overflow, rounds)``:
    the descending compact root table (== the XLA diagram's own
    ``masked_top_k`` selection), per-slot death value/position in pixel
    coordinates, the candidate-overflow flag, and the Boruvka round
    count.
    """
    n = image_flat.shape[0]
    f = min(max_features, n)
    e_key, e_a, e_b, e_pos = _compact_candidate_edges(
        key_flat, labels_flat, cand_flat, shape, max_candidates)
    e_val = image_flat[e_pos]

    # Compact vertex set: cumsum-compact the roots, then sort only the
    # f-length table into the diagram's descending key order (keys are
    # unique, so below capacity this equals the XLA ``masked_top_k``
    # selection exactly; pads sort to the tail).
    rk_c, rp_c = _compact_mask(key_flat, root_mask, f)
    order_desc = jnp.argsort(rk_c)[::-1].astype(jnp.int32)
    root_key = rk_c[order_desc]
    root_pix = rp_c[order_desc]
    rvalid = root_key > key_pad(root_key.dtype)

    # pixel id -> compact slot through one O(f log f) sorted table.
    imax = jnp.int32(jnp.iinfo(jnp.int32).max)
    pix_or_max = jnp.where(rvalid, root_pix, imax)
    order = jnp.argsort(pix_or_max).astype(jnp.int32)
    sorted_pix = pix_or_max[order]
    sa, fa = _slot_lookup(sorted_pix, order, e_a)
    sb, fb = _slot_lookup(sorted_pix, order, e_b)
    e_key_c = jnp.where(fa & fb, e_key, key_pad(e_key.dtype))

    c = jnp.sum(root_mask, dtype=jnp.int32)
    reduce_fn = functools.partial(best_edge_reduce,
                                  block_edges=phase_c_block,
                                  use_pallas=use_pallas,
                                  interpret=interpret)
    dval_c, dpos_c, rounds = boruvka_forest(
        root_key, e_key_c, e_val, e_pos, sa, sb,
        n_live=jnp.minimum(c, f), reduce_fn=reduce_fn)

    n_cand = jnp.sum(cand_flat, dtype=jnp.int32)
    overflow = n_cand > min(max_candidates, n)
    return root_key, root_pix, rvalid, dval_c, dpos_c, overflow, rounds
