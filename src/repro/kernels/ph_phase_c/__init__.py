"""Fused PixHomology phase-C kernel (segmented per-basin edge reduction).

``ops.best_edge_reduce`` dispatches the Boruvka round's per-cluster
best-edge reduction between the Pallas kernel (``kernel.py``) and the
pure-XLA oracle (``ref.py``); ``ops.fused_merge`` is the whole-image
fused phase-C driver that runs the Boruvka forest over a compact root
instance instead of the full pixel array.  See ``src/repro/ph/DESIGN.md``
§9 for the stage-graph contract this kernel implements.
"""
from repro.kernels.ph_phase_c.ops import (  # noqa: F401
    best_edge_reduce,
    fused_merge,
)
