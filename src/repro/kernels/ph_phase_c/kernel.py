"""Pallas kernel for the phase-C per-basin best-edge reduction.

One Boruvka round's segmented reduction — every cluster finds its best
incident saddle edge — executed block-by-block over the edge axis with
the per-cluster accumulator resident in VMEM:

* the grid iterates ``ceil(E / block_edges)`` edge blocks; the
  accumulator output uses a constant ``index_map`` so the same
  ``(1, nv)`` block stays in VMEM across the whole grid (initialized at
  ``program_id == 0``), while each step streams one
  ``(1, block_edges)`` slice of the edge arrays through the pipeline —
  this is what removes the full-edge-array HBM round trips the plain XLA
  scatter pays per pass;
* pass 1 scatter-maxes each block's saddle keys into the accumulator
  (both endpoints); pass 2 re-streams the blocks against the finished
  ``best`` table to scatter-max the winning edge index among key ties.

Bit-identity with ``ref.best_edge_reduce`` needs no tolerance argument:
integer max is associative/commutative with the pad sentinel as
identity, so the blocked accumulation order cannot change any output bit
(``tests/test_kernels_phase_c.py`` checks it anyway, across dtypes, tie
storms, and non-divisible block sizes).

VMEM working set per step: the ``nv``-entry accumulator (int64 keys:
8·nv bytes — 64 KiB at the default ``max_features = 8192``) plus four
``block_edges`` lanes.  Mosaic's scatter support on real TPUs is the
same caveat the phase-A kernel documents: CI pins ``interpret=True``
(the dispatcher does this automatically off-TPU), and the XLA reference
remains the production CPU backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packed_keys import key_pad


def _best_kernel(key_ref, ra_ref, rb_ref, best_ref, *, nv: int):
    pad = key_pad(key_ref.dtype)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        best_ref[...] = jnp.full(best_ref.shape, pad, best_ref.dtype)

    key = key_ref[0, :]
    alive = key > pad
    ra = jnp.where(alive, ra_ref[0, :], nv)      # nv == drop lane
    rb = jnp.where(alive, rb_ref[0, :], nv)
    acc = best_ref[0, :]
    acc = acc.at[ra].max(key, mode="drop")
    acc = acc.at[rb].max(key, mode="drop")
    best_ref[0, :] = acc


def _win_kernel(key_ref, ra_ref, rb_ref, eidx_ref, best_ref, win_ref, *,
                nv: int):
    pad = key_pad(key_ref.dtype)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        win_ref[...] = jnp.full(win_ref.shape, -1, jnp.int32)

    key = key_ref[0, :]
    alive = key > pad
    ra = ra_ref[0, :]
    rb = rb_ref[0, :]
    eidx = eidx_ref[0, :]
    best = best_ref[0, :]
    hit_a = alive & (key == best[ra])
    hit_b = alive & (key == best[rb])
    acc = win_ref[0, :]
    acc = acc.at[jnp.where(hit_a, ra, nv)].max(
        jnp.where(hit_a, eidx, -1), mode="drop")
    acc = acc.at[jnp.where(hit_b, rb, nv)].max(
        jnp.where(hit_b, eidx, -1), mode="drop")
    win_ref[0, :] = acc


def best_edge_reduce(key, ra, rb, nv: int, *, block_edges: int = 1024,
                     interpret: bool = False):
    """Blocked Pallas twin of ``ref.best_edge_reduce`` (same signature
    plus the block size).  ``key`` is pre-masked (pad sentinel on dead
    lanes); ``ra``/``rb`` must be in ``[0, nv)`` on every lane."""
    e = key.shape[0]
    block = max(1, min(block_edges, e))
    nb = -(-e // block)
    extra = nb * block - e
    pad = key_pad(key.dtype)
    eidx = jnp.arange(e, dtype=jnp.int32)
    if extra:
        key = jnp.concatenate([key, jnp.full(extra, pad, key.dtype)])
        ra = jnp.concatenate([ra, jnp.zeros(extra, ra.dtype)])
        rb = jnp.concatenate([rb, jnp.zeros(extra, rb.dtype)])
        eidx = jnp.concatenate([eidx, jnp.full(extra, -1, jnp.int32)])
    key2 = key.reshape(nb, block)
    ra2 = ra.reshape(nb, block)
    rb2 = rb.reshape(nb, block)
    eidx2 = eidx.reshape(nb, block)

    edge_spec = pl.BlockSpec((1, block), lambda i: (i, 0))
    acc_spec = pl.BlockSpec((1, nv), lambda i: (0, 0))

    best = pl.pallas_call(
        functools.partial(_best_kernel, nv=nv),
        grid=(nb,),
        in_specs=[edge_spec] * 3,
        out_specs=acc_spec,
        out_shape=jax.ShapeDtypeStruct((1, nv), key.dtype),
        interpret=interpret,
    )(key2, ra2, rb2)

    win = pl.pallas_call(
        functools.partial(_win_kernel, nv=nv),
        grid=(nb,),
        in_specs=[edge_spec] * 4 + [acc_spec],
        out_specs=acc_spec,
        out_shape=jax.ShapeDtypeStruct((1, nv), jnp.int32),
        interpret=interpret,
    )(key2, ra2, rb2, eidx2, best)

    return best[0], win[0]
