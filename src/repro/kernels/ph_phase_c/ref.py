"""Pure-XLA reference for the fused phase-C reduction (the Pallas oracle).

The reduction itself — two scatter-max passes turning an edge list into
per-cluster (best saddle key, winning edge index) — lives in
``repro.core.parallel_merge.best_edge_reduce``: it *is* the factored
round body of :func:`~repro.core.parallel_merge.boruvka_forest`, so the
whole-image Boruvka path, the tiled seam merge, and this kernel package
all reduce through literally the same code.  This module re-exports it
under the kernel-package layout (``ref`` = the bit-identical XLA twin the
Pallas kernel is verified against, and the backend the CPU path runs),
mirroring ``repro.kernels.ph_phase_a``.

Why blocking cannot change the result: both passes are integer ``max``
scatter reductions — associative and commutative, with the dtype-min pad
sentinel as the identity element — so accumulating the edge axis in any
block order (the Pallas kernel's grid) produces bit-identical outputs.
The index pass breaks best-key ties by maximum edge index, which is
itself another max reduction, so ties are deterministic too.
"""
from __future__ import annotations

from repro.core.parallel_merge import best_edge_reduce  # noqa: F401
