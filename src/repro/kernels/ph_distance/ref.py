"""Pure-XLA reference for the diagram-distance kernels (the Pallas oracle).

Everything the distance matrix needs happens in two stages:

1. **Preparation** (per diagram, O(B·K·F), shared by both backends):
   :func:`diagram_projections` turns each capacity-padded diagram into
   its direction projections + diagonal projections, and
   :func:`persistence_profiles` into its descending persistence profile.
   Both run as plain XLA whichever backend computes the matrix, so the
   Pallas kernel and this reference consume *identical* device arrays.

2. **Pair reduction** (per (i, j) pair): :func:`pair_distances` — the
   single op sequence both backends execute.  The Pallas kernel imports
   it and calls it once per grid point; :func:`distance_matrix` here
   vmaps it over the full pair grid.  Sharing the function (not a
   re-implementation) is what the bit-identity contract rests on, the
   same structure as ``ph_phase_c.ref`` re-exporting the Boruvka round
   body.

Distances computed, for 0-dim diagrams padded to capacity ``F``:

``sw``
    Sliced Wasserstein (Carrière et al.): for each direction ``θ_k``,
    diagram A's projected points are augmented with the *diagonal
    projections* of B's points (and vice versa), both 2F-vectors are
    sorted, and the 1-D W1 distance is their elementwise L1 **sum** (not
    mean — W1 between equal-mass point measures is the matched-pair
    sum).  ``sw`` averages the K directions.
``bn``
    Bottleneck lower bound: ``max_k |pA_(k) - pB_(k)| / 2`` over the
    descending persistence profiles.  Matching the k-th most persistent
    features against each other (or the diagonal, at cost ``pers/2``)
    bounds any bottleneck matching from below; it is symmetric,
    vanishes at A = B, and satisfies the triangle inequality exactly
    (it is a scaled sup-norm on profile space).

**Capacity-pad inertness.** Pad rows (``p_birth < 0``) are canonicalized
to the diagonal point (0, 0) before projection.  A pad in diagram A then
contributes a 0 to A's own projected vector *and* its diagonal
projection contributes a 0 to B's augmented vector — both sorted
2F-vectors gain equal multisets of zeros, and 1-D optimal transport
between equal-mass sorted vectors is invariant under inserting identical
values into both sides, so the W1 *sum* is unchanged.  The profile side
is immediate: pads carry persistence 0, and appending equal zeros to
both profiles cannot change a max of absolute differences.  Distances
therefore do not depend on ``max_features`` (tests check this by
recomputing at doubled capacity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packed_keys import (
    key_pad,
    masked_top_k,
    monotone_key32,
    pack_keys,
    packable_dtype,
)

__all__ = ["canonical_points", "diagram_projections", "distance_matrix",
           "pair_distances", "persistence_profiles"]


def canonical_points(birth, death, p_birth):
    """Pad rows -> the diagonal point (0, 0); returns ``(b, d, valid)``.

    Valid rows of engine-produced diagrams are finite (the engine rejects
    non-finite images and the essential death is the global extremum),
    so after this the projection math never sees an inf — pad rows'
    ±inf sentinels are replaced wholesale, whichever filtration's
    convention they follow.
    """
    valid = p_birth >= 0
    zero = jnp.zeros((), birth.dtype)
    return (jnp.where(valid, birth, zero),
            jnp.where(valid, death, zero),
            valid)


def _directions(n_dirs: int, dtype):
    """K half-circle directions, midpoints of equal angular bins —
    deterministic (no RNG anywhere near a cached plan)."""
    k = jnp.arange(n_dirs, dtype=jnp.dtype(dtype))
    theta = (k + jnp.asarray(0.5, dtype)) * (jnp.pi / n_dirs)
    return jnp.cos(theta), jnp.sin(theta)


def diagram_projections(birth, death, p_birth, *, n_dirs: int = 16):
    """Per-diagram projection tables: ``(pts, diag)``, each (..., K, F).

    ``pts[..., k, f]`` is point f projected on direction k;
    ``diag[..., k, f]`` is the projection of point f's nearest diagonal
    point ``((b+d)/2, (b+d)/2)``.  Pad rows project to 0 on both tables
    (the inertness precondition).
    """
    b, d, _ = canonical_points(birth, death, p_birth)
    ct, st = _directions(n_dirs, b.dtype)
    pts = b[..., None, :] * ct[:, None] + d[..., None, :] * st[:, None]
    mid = (b + d) * jnp.asarray(0.5, b.dtype)
    diag = mid[..., None, :] * (ct + st)[:, None]
    return pts, diag


def persistence_profiles(birth, death, p_birth, *, merge_keys: str = "rank",
                         width: int = 2):
    """Descending persistence profile per diagram: (..., F).

    Persistence is ``|birth - death|`` on valid rows (filtration-neutral:
    superlevel diagrams carry birth >= death, sublevel the reverse) and
    exactly 0 on pads.  Selection goes through the repo's single
    selection primitive: packed int64 keys run the
    ``select_descending`` blockwise tournament, 32-bit monotone keys a
    full ``top_k`` — tie *order* may differ between encodings but the
    selected persistence *values* are identical, which is all a profile
    is.  Non-packable dtypes (float64 without packed keys) fall back to
    a plain float ``top_k`` (persistence >= 0, so -1 is a safe mask
    fill).
    """
    b, d, valid = canonical_points(birth, death, p_birth)
    pers = jnp.abs(b - d)   # pads are (0, 0) -> persistence exactly 0

    def _row(p, v):
        f = p.shape[0]
        if merge_keys == "packed":
            keys = pack_keys(p)
        elif packable_dtype(p.dtype):
            keys = monotone_key32(p)
        else:
            top = jax.lax.top_k(jnp.where(v, p, -jnp.ones((), p.dtype)),
                                f)[0]
            return jnp.maximum(top, jnp.zeros((), p.dtype))
        top, pos = masked_top_k(keys, v, f, width)
        return jnp.where(top > key_pad(top.dtype), p[pos],
                         jnp.zeros_like(p))

    if pers.ndim == 1:
        return _row(pers, valid)
    flat = pers.reshape(-1, pers.shape[-1])
    vflat = valid.reshape(-1, valid.shape[-1])
    out = jax.vmap(_row)(flat, vflat)
    return out.reshape(pers.shape)


def pair_distances(pts_a, diag_a, prof_a, pts_b, diag_b, prof_b):
    """One (A, B) pair: ``(sw, bn)`` scalars.

    THE shared op sequence — the Pallas kernel executes this function
    per grid point, the XLA reference vmaps it; fixed per-axis
    reductions (sum along F, then mean along K) keep the accumulation
    shape identical on both sides.
    """
    va = jnp.sort(jnp.concatenate([pts_a, diag_b], axis=-1), axis=-1)
    vb = jnp.sort(jnp.concatenate([pts_b, diag_a], axis=-1), axis=-1)
    w1 = jnp.sum(jnp.abs(va - vb), axis=-1)          # (K,) per-direction W1
    sw = jnp.sum(w1, axis=-1) / w1.shape[-1]         # mean over directions
    bn = jnp.asarray(0.5, prof_a.dtype) * jnp.max(jnp.abs(prof_a - prof_b),
                                                  axis=-1)
    return sw, bn


def distance_matrix(pts, diag, prof):
    """Full (B, B) pair grid of :func:`pair_distances` -> ``(sw, bn)``.

    This is the production CPU backend and the oracle the Pallas kernel
    is verified against (``tests/test_filtration_distance.py`` asserts
    bit-equality in interpret mode).  Pairs are enumerated through
    ``lax.map`` — one unbatched trace of :func:`pair_distances` per
    pair, exactly the program the kernel runs per grid point — rather
    than vmap: batched reductions may reassociate the W1 sums and break
    the last-bit contract (each pair's inner work is already (K, 2F)
    vectorized, so the scan costs nothing at realistic B).
    """
    pts, diag, prof = (jnp.asarray(a) for a in (pts, diag, prof))
    n = pts.shape[0]
    idx = jnp.arange(n * n, dtype=jnp.int32)

    def _one(p):
        i, j = p // n, p % n
        return pair_distances(pts[i], diag[i], prof[i],
                              pts[j], diag[j], prof[j])

    sw, bn = jax.lax.map(_one, idx)
    return sw.reshape(n, n), bn.reshape(n, n)
