"""Pallas kernel for the pairwise diagram-distance matrix.

The (B, B) pair grid streams per-diagram blocks through the pipeline:
each grid step (i, j) receives row i of the projection / diagonal /
profile tables through one set of BlockSpecs and row j through a second
set over the *same* device arrays (two ``in_specs`` per array, i- and
j-indexed — the pair-grid twin of the phase-C edge stream), sorts the
two augmented 2F-vectors per direction on-chip, and writes the two
scalar distances straight into their (i, j) output cells.  Relative to
the XLA reference — which materializes the full (B, B, K, 2F)
augmented/sorted tensor through vmap — the kernel's working set per
step is just the two diagrams' tables: 4·K·F lanes plus two profiles
(K = 16, F = 8192, f32: ~2 MiB of VMEM), independent of B.

Bit-identity with ``ref.distance_matrix`` holds by construction: the
kernel body calls :func:`ref.pair_distances` — the literal function the
reference vmaps — on identically prepared inputs, so there is no second
implementation to diverge (``tests/test_filtration_distance.py`` checks
equality bitwise anyway).  ``jnp.sort`` inside a kernel is the same
Mosaic caveat the phase-A/C scatters document: CI pins
``interpret=True`` (the dispatcher does this automatically off-TPU) and
the XLA reference remains the production CPU backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ph_distance import ref


def _dist_kernel(pts_a_ref, diag_a_ref, prof_a_ref,
                 pts_b_ref, diag_b_ref, prof_b_ref, sw_ref, bn_ref):
    sw, bn = ref.pair_distances(
        pts_a_ref[0], diag_a_ref[0], prof_a_ref[0],
        pts_b_ref[0], diag_b_ref[0], prof_b_ref[0])
    sw_ref[0, 0] = sw
    bn_ref[0, 0] = bn


def distance_matrix(pts, diag, prof, *, interpret: bool = False):
    """Blocked Pallas twin of ``ref.distance_matrix`` (same signature
    plus ``interpret``).  ``pts``/``diag`` are (B, K, F) projection
    tables, ``prof`` the (B, F) descending persistence profiles — all
    three from the shared preparation stages in ``ref``."""
    b, k, f = pts.shape
    tbl_i = pl.BlockSpec((1, k, f), lambda i, j: (i, 0, 0))
    tbl_j = pl.BlockSpec((1, k, f), lambda i, j: (j, 0, 0))
    prof_i = pl.BlockSpec((1, f), lambda i, j: (i, 0))
    prof_j = pl.BlockSpec((1, f), lambda i, j: (j, 0))
    cell = pl.BlockSpec((1, 1), lambda i, j: (i, j))

    sw, bn = pl.pallas_call(
        _dist_kernel,
        grid=(b, b),
        in_specs=[tbl_i, tbl_i, prof_i, tbl_j, tbl_j, prof_j],
        out_specs=[cell, cell],
        out_shape=[jax.ShapeDtypeStruct((b, b), pts.dtype),
                   jax.ShapeDtypeStruct((b, b), prof.dtype)],
        interpret=interpret,
    )(pts, diag, prof, pts, diag, prof)
    return sw, bn
