"""Batched diagram-distance kernels (sliced-Wasserstein + bottleneck bound).

``ops.pairwise_distances`` dispatches the pair-grid distance matrix
between the Pallas kernel (``kernel.py``) and the pure-XLA oracle
(``ref.py``); the projection / persistence-profile *preparation* stages
are shared XLA code in ``ref.py`` so both backends consume literally the
same arrays.  See ``src/repro/ph/DESIGN.md`` §12 for the capacity-pad
inertness argument this package relies on.
"""
from repro.kernels.ph_distance.ops import (  # noqa: F401
    diagram_distances,
    pairwise_distances,
)
from repro.kernels.ph_distance.ref import (  # noqa: F401
    diagram_projections,
    pair_distances,
    persistence_profiles,
)
