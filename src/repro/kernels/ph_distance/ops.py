"""Dispatch + whole-batch driver for the diagram-distance kernels.

Two public entry points:

* :func:`pairwise_distances` — the (B, B) matrix pair reduction over
  pre-built projection/profile tables, routed to the Pallas kernel
  (TPU, or ``interpret=True`` anywhere) or the bit-identical XLA
  reference.

* :func:`diagram_distances` — the whole-batch driver: capacity-padded
  diagram arrays in, ``(sw, bn)`` matrices out.  The preparation stages
  (projection tables, persistence profiles) are shared XLA code from
  ``ref`` whichever backend reduces the pairs, so backend choice cannot
  perturb a single input bit of the reduction.

NaN policy matches the engine boundary: diagram values are checked
host-side by :func:`repro.core.packed_keys.check_finite` with
``allow_inf=True`` — pad rows legitimately carry the ±inf sentinels of
their filtration, but a NaN birth/death cannot be ordered, projected,
or profiled, and fails fast here instead of silently poisoning a row of
the matrix.  Inside a jit trace the check is a no-op (tracers pass
through); ``PHEngine.distance_matrix`` re-checks its host inputs.
"""
from __future__ import annotations

import jax

from repro.core.packed_keys import check_finite
from repro.kernels.ph_distance import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pairwise_distances(pts, diag, prof, *, use_pallas: bool | None = None,
                       interpret: bool = False):
    """Pair-grid ``(sw, bn)`` matrices, Pallas or XLA backend.

    ``use_pallas=None`` auto-selects: the Pallas kernel on TPU, the XLA
    reference elsewhere (on CPU the vmapped reference compiles to the
    same sorts without the pair-grid bookkeeping).  Forcing
    ``use_pallas=True`` off-TPU runs the kernel in interpret mode (CI's
    parity path).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.distance_matrix(pts, diag, prof)
    return kernel.distance_matrix(pts, diag, prof,
                                  interpret=interpret or not _on_tpu())


def diagram_distances(birth, death, p_birth, *, n_dirs: int = 16,
                      merge_keys: str = "rank", width: int = 2,
                      use_pallas: bool | None = None,
                      interpret: bool = False):
    """Distance matrices of a batch of capacity-padded diagrams.

    ``birth``/``death``: (B, F) float arrays; ``p_birth``: (B, F) int32
    with -1 on pad rows (the :class:`repro.core.pixhomology.Diagram`
    layout, stacked).  Returns ``(sw, bn)``, both (B, B): sliced
    Wasserstein and the bottleneck lower bound — see ``ref`` for the
    definitions and the capacity-pad inertness argument.
    """
    if birth.ndim != 2:
        raise ValueError(
            f"diagram_distances expects stacked (B, F) diagrams, got "
            f"shape {tuple(birth.shape)}")
    check_finite(birth, where="diagram births", allow_inf=True)
    check_finite(death, where="diagram deaths", allow_inf=True)
    pts, diag = ref.diagram_projections(birth, death, p_birth,
                                        n_dirs=n_dirs)
    prof = ref.persistence_profiles(birth, death, p_birth,
                                    merge_keys=merge_keys, width=width)
    return pairwise_distances(pts, diag, prof, use_pallas=use_pallas,
                              interpret=interpret)
