"""Pure-jnp oracle for the 3x3 max/argmax pooling ops (paper Alg. 1 lines 1, 6).

These are the reference semantics the Pallas kernel (kernel.py) must match
bit-exactly.  All ops use a 3x3 window, stride 1, padding 1 (same-size output),
matching the paper's ``maxpool2d`` / ``arg-maxpool2d`` with kernel=3, stride=1,
pad=1.

Argmax tie-breaking uses the *total order* (value, flat_index): among equal
values the neighbor with the LARGEST flat index wins.  This makes every
operation deterministic even when the paper's strict-local-max precondition is
violated, and the union-find oracle uses the same total order.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.grid import neg_inf as _neg_inf  # noqa: F401  (re-export)
from repro.core.grid import pos_inf as _pos_inf  # noqa: F401  (re-export)
from repro.core.grid import shift2d

# (dr, dc) offsets of the 3x3 window, self included.
OFFSETS = [(-1, -1), (-1, 0), (-1, 1),
           (0, -1), (0, 0), (0, 1),
           (1, -1), (1, 0), (1, 1)]

# Deprecated alias kept for one release; the shared util lives in
# repro.core.grid so PixHomology and the pooling oracle use one shift.
_shift = shift2d


def maxpool3x3(x: jnp.ndarray) -> jnp.ndarray:
    """3x3/stride-1/pad-1 max pool; works for float and int dtypes."""
    fill = _neg_inf(x.dtype)
    out = x
    for dr, dc in OFFSETS:
        if (dr, dc) == (0, 0):
            continue
        out = jnp.maximum(out, shift2d(x, dr, dc, fill))
    return out


def minpool3x3(x: jnp.ndarray) -> jnp.ndarray:
    """3x3/stride-1/pad-1 min pool (= -maxpool2d(-x) in the paper)."""
    fill = _pos_inf(x.dtype)
    out = x
    for dr, dc in OFFSETS:
        if (dr, dc) == (0, 0):
            continue
        out = jnp.minimum(out, shift2d(x, dr, dc, fill))
    return out


def argmaxpool3x3(x: jnp.ndarray) -> jnp.ndarray:
    """Flat index (int32) of the 3x3-window max under (value, index) order.

    out[r, c] = flat index of the neighbor (self included) with the largest
    (value, flat_index) key.  Border windows are truncated (out-of-image
    candidates never win).
    """
    h, w = x.shape
    rows = jnp.arange(h, dtype=jnp.int32)[:, None]
    cols = jnp.arange(w, dtype=jnp.int32)[None, :]
    flat = rows * w + cols

    fill = _neg_inf(x.dtype)
    best_val = x
    best_idx = flat
    for dr, dc in OFFSETS:
        if (dr, dc) == (0, 0):
            continue
        v = shift2d(x, dr, dc, fill)
        i = shift2d(flat, dr, dc, jnp.int32(-1))
        better = (v > best_val) | ((v == best_val) & (i > best_idx))
        best_val = jnp.where(better, v, best_val)
        best_idx = jnp.where(better, i, best_idx)
    return best_idx


def maxargmaxpool3x3(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused (maxpool3x3, argmaxpool3x3) — what the Pallas kernel computes."""
    return maxpool3x3(x), argmaxpool3x3(x)
