"""Pallas TPU kernel for fused 3x3 max / argmax pooling (paper's hot spot).

The paper's PixHomology spends its array time in ``maxpool2d`` /
``arg-maxpool2d`` (Algorithm 1 lines 1 and 6).  On TPU we fuse the two into a
single VMEM-resident pass and make the reduction *separable* (vertical then
horizontal), so each output tile does 4 comparisons/pixel instead of 8.

TPU adaptation (src/repro/ph/DESIGN.md §2): Pallas BlockSpecs cannot express overlapping
(haloed) windows, so the host wrapper materializes three row-shifted views of
the (-inf)-padded image (rows r-1, r, r+1).  The kernel then:

  1. loads the three (block_rows, W+2) row planes into VMEM (BlockSpec-tiled,
     double-buffered by the Pallas pipeline);
  2. reduces vertically with (value, row) tie-breaking;
  3. reduces horizontally across three static column shifts with full
     (value, row, col) total-order tie-breaking — identical to ref.py;
  4. emits the pooled value plane and the int32 flat-index argmax plane.

Cost: 3 HBM reads of the image instead of 1 (the shifted views) — the
separable VMEM reduction and the fusion of max+argmax into one pass more than
pay for it versus four independent XLA reduce_window calls (see
DESIGN.md §Perf).  Row-block tiling keeps the VMEM working set to
~6 * block_rows * W * 4 bytes; W up to ~64k columns fits comfortably in 16 MB
VMEM with block_rows=8.

Tie-breaking note: within a 3x3 window, flat index order == (row, col)
lexicographic order (rows differ by at most 1, cols by at most 1), so the
kernel's (value, row, col) key equals ref.py's (value, flat_index) key.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.maxpool.ref import _neg_inf, _pos_inf

_LANES = 128


def _pad_rows(x: jnp.ndarray, rows: int, fill) -> jnp.ndarray:
    if rows == 0:
        return x
    return jnp.pad(x, ((0, rows), (0, 0)), constant_values=fill)


def _row_shifted_planes(x: jnp.ndarray, fill):
    """Three (H, W+2) planes holding rows r-1, r, r+1 of the padded image."""
    h, w = x.shape
    padded = jnp.pad(x, 1, constant_values=fill)  # (H+2, W+2)
    return padded[0:h, :], padded[1:h + 1, :], padded[2:h + 2, :]


def _maxarg_kernel(r0_ref, r1_ref, r2_ref, val_ref, arg_ref, *, width: int,
                   block_rows: int, want_arg: bool, minimum: bool):
    i = pl.program_id(0)
    planes = [r0_ref[...], r1_ref[...], r2_ref[...]]  # (TH, W+2) each

    def better(v, bv):
        return (v < bv) if minimum else (v > bv)

    # --- vertical reduction with (value, row) tie-break (larger row wins) ---
    best_v = planes[0]
    best_dr = jnp.zeros_like(planes[0], dtype=jnp.int32)
    for dr in (1, 2):
        v = planes[dr]
        take = better(v, best_v) | (v == best_v)  # larger dr wins ties
        best_v = jnp.where(take, v, best_v)
        best_dr = jnp.where(take, jnp.int32(dr), best_dr)

    # --- horizontal reduction with (value, row, col) tie-break ---
    out_v = best_v[:, 0:width]
    out_dr = best_dr[:, 0:width]
    out_dc = jnp.zeros((block_rows, width), jnp.int32)
    for dc in (1, 2):
        v = best_v[:, dc:dc + width]
        r = best_dr[:, dc:dc + width]
        take = (better(v, out_v)
                | ((v == out_v) & (r > out_dr))
                | ((v == out_v) & (r == out_dr)))  # larger dc wins ties
        out_v = jnp.where(take, v, out_v)
        out_dr = jnp.where(take, r, out_dr)
        out_dc = jnp.where(take, jnp.int32(dc), out_dc)

    val_ref[...] = out_v
    if want_arg:
        rows = (i * block_rows - 1
                + jax.lax.broadcasted_iota(jnp.int32, (block_rows, width), 0)
                + out_dr)
        cols = (jax.lax.broadcasted_iota(jnp.int32, (block_rows, width), 1)
                - 1 + out_dc)
        arg_ref[...] = rows * jnp.int32(width) + cols


def _pool_call(x: jnp.ndarray, *, want_arg: bool, minimum: bool,
               interpret: bool, block_rows: int):
    h, w = x.shape
    fill = _pos_inf(x.dtype) if minimum else _neg_inf(x.dtype)
    th = max(1, min(block_rows, h))
    hp = -(-h // th) * th  # ceil to a multiple of the row block

    r0, r1, r2 = _row_shifted_planes(x, fill)
    r0, r1, r2 = (_pad_rows(p, hp - h, fill) for p in (r0, r1, r2))

    kernel = functools.partial(_maxarg_kernel, width=w, block_rows=th,
                               want_arg=want_arg, minimum=minimum)
    in_spec = pl.BlockSpec((th, w + 2), lambda i: (i, 0))
    out_spec = pl.BlockSpec((th, w), lambda i: (i, 0))
    out_val, out_arg = pl.pallas_call(
        kernel,
        grid=(hp // th,),
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((hp, w), x.dtype),
                   jax.ShapeDtypeStruct((hp, w), jnp.int32)],
        interpret=interpret,
    )(r0, r1, r2)
    return out_val[:h], out_arg[:h]


def maxargmaxpool3x3(x: jnp.ndarray, *, interpret: bool = False,
                     block_rows: int = 8):
    """Fused (maxpool3x3, argmaxpool3x3); bit-identical to ref.py."""
    return _pool_call(x, want_arg=True, minimum=False, interpret=interpret,
                      block_rows=block_rows)


def maxpool3x3(x: jnp.ndarray, *, interpret: bool = False,
               block_rows: int = 8) -> jnp.ndarray:
    return _pool_call(x, want_arg=False, minimum=False, interpret=interpret,
                      block_rows=block_rows)[0]


def minpool3x3(x: jnp.ndarray, *, interpret: bool = False,
               block_rows: int = 8) -> jnp.ndarray:
    return _pool_call(x, want_arg=False, minimum=True, interpret=interpret,
                      block_rows=block_rows)[0]
