"""Jit'd public wrappers for the 3x3 pooling ops with backend dispatch.

`use_pallas=None` (default) auto-selects: the Pallas TPU kernel on TPU
backends, the pure-jnp reference elsewhere (this container is CPU-only, so CI
exercises the kernel via interpret mode in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.maxpool import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def maxargmaxpool3x3(x: jnp.ndarray, *, use_pallas: bool | None = None,
                     interpret: bool = False):
    """Fused 3x3 (maxpool, argmaxpool), stride 1, pad 1.

    Returns (max: x.dtype, argmax: int32 flat index), shapes == x.shape.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        from repro.kernels.maxpool import kernel
        return kernel.maxargmaxpool3x3(x, interpret=interpret or not _on_tpu())
    return ref.maxargmaxpool3x3(x)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def maxpool3x3(x: jnp.ndarray, *, use_pallas: bool | None = None,
               interpret: bool = False):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        from repro.kernels.maxpool import kernel
        return kernel.maxpool3x3(x, interpret=interpret or not _on_tpu())
    return ref.maxpool3x3(x)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def minpool3x3(x: jnp.ndarray, *, use_pallas: bool | None = None,
               interpret: bool = False):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        from repro.kernels.maxpool import kernel
        return kernel.minpool3x3(x, interpret=interpret or not _on_tpu())
    return ref.minpool3x3(x)
