"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern
(arXiv:2402.19427; hf).

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, lru width 2560,
local window 2048.  Pattern (rec, rec, lattn) cycled.  Sub-quadratic:
runs long_500k (constant-size recurrent state + bounded window cache).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma_2b", family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256000,
        block_pattern=("rec", "rec", "lattn"), mlp_type="geglu",
        local_window=2048, rnn_width=2560, conv_width=4,
        embed_scale_sqrt_dim=True, tie_embeddings=True,
        scan_layers=False, supports_long_context=True, seq_shard=False)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=3, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=192, vocab_size=512, rnn_width=64, local_window=16,
        dtype="float32")
