"""rwkv6-3b [ssm] — Finch, data-dependent decay (arXiv:2404.05892; hf).

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.  head size 64 =>
40 WKV heads.  Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6_3b", family="ssm",
        num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
        head_dim=64, d_ff=8960, vocab_size=65536,
        block_pattern=("rwkv",), norm_type="layernorm",
        rope_theta=None, tie_embeddings=False,
        wkv_impl="chunked", supports_long_context=True, seq_shard=False)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
        d_ff=448, vocab_size=512, dtype="float32")
