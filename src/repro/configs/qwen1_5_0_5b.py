"""qwen1.5-0.5b [dense] — QKV bias (hf:Qwen/Qwen1.5-0.5B; hf).

24L d_model=1024 16H (MHA kv=16) d_ff=2816 vocab=151936.
Full-attention: long_500k skipped (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1_5_0_5b", family="dense",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        head_dim=64, d_ff=2816, vocab_size=151936,
        block_pattern=("attn",), qkv_bias=True, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=176, vocab_size=512, dtype="float32")
