"""whisper-small [audio] — enc-dec, conv frontend STUB (arXiv:2212.04356).

12L (encoder) + 12L (decoder), d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865.  The mel/conv frontend is stubbed: input_specs() provides
precomputed frame embeddings (B, 1500, 768) per the brief.  Encoder-decoder
(not encoder-only) so decode shapes run.  Full attention: long_500k skipped.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper_small", family="audio",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        head_dim=64, d_ff=3072, vocab_size=51865,
        block_pattern=("attn",), mlp_type="gelu", norm_type="layernorm",
        rope_theta=None, encoder_layers=12, encoder_seq=1500,
        tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        encoder_seq=32, dtype="float32")
