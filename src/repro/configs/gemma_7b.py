"""gemma-7b [dense] — GeGLU, head_dim=256 (arXiv:2403.08295; hf).

28L d_model=3072 16H (MHA kv=16) d_ff=24576 vocab=256000.  Embeddings scaled
by sqrt(d_model); tied unembedding; RMSNorm with (1+scale).
Full-attention: long_500k skipped (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma_7b", family="dense",
        num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
        head_dim=256, d_ff=24576, vocab_size=256000,
        block_pattern=("attn",), mlp_type="geglu",
        embed_scale_sqrt_dim=True, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=256, vocab_size=512, dtype="float32")
