"""chameleon-34b [vlm] — early-fusion, VQ image tokens (arXiv:2405.09818;
unverified).  48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.

VQ image tokens share the text vocabulary (early fusion), so the backbone is
a plain decoder; the VQ tokenizer frontend is a stub (tokens arrive
pre-quantized).  Chameleon uses qk-norm for training stability.
Full-attention: long_500k skipped (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon_34b", family="vlm",
        num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=22016, vocab_size=65536,
        block_pattern=("attn",), qk_norm=True, tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=160, vocab_size=512, dtype="float32")
