"""dbrx-132b [moe] — 16 experts top-4, fine-grained
(hf:databricks/dbrx-base; unverified).

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
Full-attention: long_500k skipped (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx_132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=10752, vocab_size=100352,
        block_pattern=("moe",), norm_type="layernorm",
        rope_theta=500000.0, num_experts=16, top_k=4,
        tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=512, num_experts=4, top_k=2,
        capacity_factor=8.0, dtype="float32")
