"""mistral-nemo-12b [dense] — 128k ctx (hf:mistralai/Mistral-Nemo-Base-2407).

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128
(explicit: 32*128=4096 != d_model).  rope theta 1e6 for long context.
Full-attention: long_500k skipped (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral_nemo_12b", family="dense",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=131072,
        block_pattern=("attn",), rope_theta=1e6, tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=512, dtype="float32")
