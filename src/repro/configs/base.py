"""Config schema + registry for architectures and input shapes."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

VOCAB_PAD = 256  # vocabs padded up so `model`-axis sharding divides evenly


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # block construction; cycled over layers
    block_pattern: tuple[str, ...] = ("attn",)   # attn|moe|rwkv|rec|lattn
    mlp_type: str = "swiglu"                     # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"                   # rmsnorm | layernorm
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float | None = 10000.0
    local_window: int | None = None              # for "lattn" blocks
    embed_scale_sqrt_dim: bool = False
    tie_embeddings: bool = True
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_type: str = "softmax"
    moe_shared_expert: bool = False
    # recurrent (rglru)
    rnn_width: int = 0
    conv_width: int = 4
    # encoder-decoder (whisper): encoder layers + stub frontend length
    encoder_layers: int = 0
    encoder_seq: int = 0
    # implementation knobs
    wkv_impl: str = "chunked"                    # scan | chunked
    scan_layers: bool = True
    remat: str = "full"                          # none | full
    seq_shard: bool = True                       # SP: layer-boundary seq/TP
    dtype: str = "bfloat16"
    # long-context capability: sub-quadratic archs only (DESIGN.md §4)
    supports_long_context: bool = False

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // VOCAB_PAD) * VOCAB_PAD

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    @property
    def homogeneous(self) -> bool:
        return len(self.block_pattern) == 1

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                                    # train | prefill | decode


# The four assigned LM shapes (brief): decode/long lower serve_step.
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "rwkv6_3b",
    "llama4_scout_17b_a16e",
    "dbrx_132b",
    "chameleon_34b",
    "gemma_7b",
    "mistral_nemo_12b",
    "qwen1_5_0_5b",
    "phi3_mini_3_8b",
    "recurrentgemma_2b",
    "whisper_small",
]


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.config()


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.smoke_config()


def cells(archs=None, shapes=None):
    """All (arch, shape) dry-run cells incl. sanctioned skips -> (id, reason)."""
    out = []
    for a in archs or ARCH_IDS:
        cfg = get_config(a)
        for s in shapes or SHAPES:
            shape = SHAPES[s]
            skip = None
            if shape.name == "long_500k" and not cfg.supports_long_context:
                skip = ("full-attention arch: 500k dense KV pass is "
                        "quadratic; skipped per brief (DESIGN.md §4)")
            out.append((a, s, skip))
    return out
