"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert, early
fusion (hf:meta-llama/Llama-4-Scout-17B-16E; unverified).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.
Full-attention: long_500k skipped (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4_scout_17b_a16e", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=202048,
        block_pattern=("moe",), rope_theta=500000.0,
        num_experts=16, top_k=1, router_type="sigmoid",
        moe_shared_expert=True, tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, num_experts=4, capacity_factor=8.0,
        dtype="float32")
