"""Sharding rules: params / optimizer states / caches -> PartitionSpec trees.

Strategy (DESIGN.md §5):
* TP over `model`: column-parallel in-projections, row-parallel
  out-projections (Megatron); vocab over `model`.
* EP over `model`: MoE expert dim (E == 16 == axis size on the target mesh).
* ZeRO/FSDP over `data`: optimizer moments always; parameters too for archs
  whose model-sharded weights alone exceed the per-chip budget
  (``fsdp_params`` — dbrx, llama4-scout, chameleon).
* `pod` is pure data parallelism: params replicated across pods, one gradient
  all-reduce per step (DCN-friendly).
* Every rule is divisibility-guarded: an axis is applied to a dim only when
  it divides evenly (uneven sharding is rejected by jit) — e.g. llama4's 40
  heads don't split 16 ways, so its attention shards on the flattened feature
  dim instead; whisper's 12-head attention stays replicated while its MLP
  shards.

Caches (decode): KV caches shard batch over `data` and the *sequence* dim
over `model` (flash-decoding style: XLA turns the masked softmax over the
sharded dim into partial reductions + a small combine), so 32k x 128 caches
fit; recurrent states shard their channel dims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# Archs whose bf16 params exceed ~4 GB/chip with model-only sharding.
FSDP_PARAM_ARCHS = {"dbrx_132b", "llama4_scout_17b_a16e", "chameleon_34b"}

# trailing-dims rules: name -> ("col" | "row" | special)
_COL = {"wq", "wk", "wv", "wg", "wr", "w_gate", "w_up", "w_in", "wa", "wx",
        "tm_w1", "wd1", "conv_w"}
_ROW = {"wo", "w_down", "w_out", "wv_cm", "wd2"}


def _fits(dim: int, mesh, axis) -> bool:
    if axis is None or dim is None:
        return False
    size = mesh.shape[axis] if isinstance(axis, str) else \
        int(jnp.prod(jnp.array([mesh.shape[a] for a in axis])))
    return dim % size == 0 and dim >= size


def _axis_if(dim, mesh, axis):
    return axis if _fits(dim, mesh, axis) else None


def param_spec(path: tuple[str, ...], shape: tuple[int, ...], mesh,
               *, fsdp: bool, tp: str | None = "model",
               dp: str | None = "data") -> P:
    """PartitionSpec for one parameter leaf (leading stack dims -> None)."""
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    nd = len(shape)
    fs = dp if fsdp else None

    def pad(trailing):  # fill leading (layer-stack) dims with None
        return P(*([None] * (nd - len(trailing)) + list(trailing)))

    if name == "embedding":                      # (V, D)
        return pad([_axis_if(shape[-2], mesh, tp),
                    _axis_if(shape[-1], mesh, fs)])
    if name == "lm_head":                        # (D, V)
        return pad([_axis_if(shape[-2], mesh, fs),
                    _axis_if(shape[-1], mesh, tp)])
    if name == "router":                         # (D, E) tiny, replicated
        return pad([None, None])
    if parent == "moe" or (name in ("w_gate", "w_up", "w_down")
                           and nd >= 3 and path[-2] != "shared"):
        if name in ("w_gate", "w_up"):           # (E, D, F)
            return pad([_axis_if(shape[-3], mesh, tp), None,
                        _axis_if(shape[-1], mesh, dp)])
        if name == "w_down":                     # (E, F, D)
            return pad([_axis_if(shape[-3], mesh, tp),
                        _axis_if(shape[-2], mesh, dp), None])
    if parent == "cm" and name == "wv":          # channelmix (F, D): row
        return pad([_axis_if(shape[-2], mesh, tp),
                    _axis_if(shape[-1], mesh, fs)])
    if name in _COL and nd >= 2:                 # (.., in, out): col-parallel
        return pad([_axis_if(shape[-2], mesh, fs),
                    _axis_if(shape[-1], mesh, tp)])
    if name in _ROW and nd >= 2:                 # (.., in, out): row-parallel
        return pad([_axis_if(shape[-2], mesh, tp),
                    _axis_if(shape[-1], mesh, fs)])
    if name == "tm_w2":                          # (5, LORA, D)
        return pad([None, _axis_if(shape[-1], mesh, tp)] if nd == 2 else
                   [None, None, _axis_if(shape[-1], mesh, tp)])
    # norms, biases, gates, u, lam, maa*: replicated
    return P(*([None] * nd))


def param_specs(shapes_tree, mesh, arch_name: str, *, tp="model", dp="data",
                fsdp: bool | None = None):
    """PartitionSpec tree matching a params pytree (of ShapeDtypeStructs)."""
    if fsdp is None:
        fsdp = arch_name in FSDP_PARAM_ARCHS
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes_tree)

    def keyname(k):
        if isinstance(k, jax.tree_util.DictKey):
            return str(k.key)
        if isinstance(k, jax.tree_util.GetAttrKey):
            return k.name
        if isinstance(k, jax.tree_util.SequenceKey):
            return str(k.idx)
        return str(k)

    specs = []
    for kp, leaf in flat:
        path = tuple(keyname(k) for k in kp)
        specs.append(param_spec(path, tuple(leaf.shape), mesh,
                                fsdp=fsdp, tp=tp, dp=dp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_specs(pspec_tree, shapes_tree, mesh, *, dp="data"):
    """Moments: param spec + `data` on the largest still-replicated dim."""
    def one(spec: P, leaf):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        has_dp = any(p == dp or (isinstance(p, tuple) and dp in p)
                     for p in parts)
        if has_dp:
            return P(*parts)
        # find largest unsharded dim divisible by |data|
        cands = [(leaf.shape[i], i) for i in range(len(parts))
                 if parts[i] is None and _fits(leaf.shape[i], mesh, dp)]
        if cands:
            _, i = max(cands)
            parts[i] = dp
        return P(*parts)

    return jax.tree.map(one, pspec_tree, shapes_tree)


def batch_specs(batch_tree, mesh, dp_axes=("data",)):
    """Input batches: dim 0 (global batch) over the dp axes when divisible."""
    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        ax = dp_axes if all(m in mesh.shape for m in dp_axes) else None
        size = 1
        for a in dp_axes:
            size *= mesh.shape[a]
        if leaf.shape[0] % size == 0 and leaf.shape[0] >= size:
            return P(dp_axes, *([None] * (nd - 1)))
        return P(*([None] * nd))

    return jax.tree.map(one, batch_tree)


def cache_specs(cache_tree, mesh, *, tp="model", dp_axes=("data",)):
    """Decode caches: named-dim rules (see module docstring)."""
    dp = dp_axes
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)

    def keyname(k):
        if isinstance(k, jax.tree_util.DictKey):
            return str(k.key)
        if isinstance(k, jax.tree_util.GetAttrKey):
            return k.name
        if isinstance(k, jax.tree_util.SequenceKey):
            return str(k.idx)
        return str(k)

    dpsize = 1
    for a in dp_axes:
        dpsize *= mesh.shape[a]

    def dp_if(dim):
        return dp if dim % dpsize == 0 and dim >= dpsize else None

    specs = []
    for kp, leaf in flat:
        name = keyname(kp[-1]) if kp else ""
        shape = tuple(leaf.shape)
        nd = len(shape)
        if name in ("k", "v") and nd >= 4:
            # (..., B, S, KV, hd): B -> data, S -> model (flash-decoding)
            lead = [None] * (nd - 4)
            specs.append(P(*lead, dp_if(shape[-4]),
                           _axis_if(shape[-3], mesh, tp), None, None))
        elif name == "wkv" and nd >= 4:
            # (..., B, H, K, K): B -> data, K -> model
            lead = [None] * (nd - 4)
            specs.append(P(*lead, dp_if(shape[-4]), None,
                           _axis_if(shape[-2], mesh, tp), None))
        elif name in ("tm_x", "cm_x", "h") and nd >= 2:
            lead = [None] * (nd - 2)
            specs.append(P(*lead, dp_if(shape[-2]),
                           _axis_if(shape[-1], mesh, tp)))
        elif name == "conv" and nd >= 3:
            lead = [None] * (nd - 3)
            specs.append(P(*lead, dp_if(shape[-3]), None,
                           _axis_if(shape[-1], mesh, tp)))
        elif name == "length" or nd <= 1:
            specs.append(P(*([None] * nd)))
        else:
            specs.append(P(*([None] * nd)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def tile_partition_spec(n_tiles: int, mesh, dp_axes=("data",)) -> P:
    """Tile-axis spec for halo-tiled PH: the leading (row-major) tile axis
    over the data axes, so consecutive tile rows land on consecutive mesh
    devices.  Divisibility-guarded like every rule here: replicated (``P()``)
    when the dp size does not divide the tile count."""
    if not all(a in mesh.shape for a in dp_axes):
        return P()
    size = 1
    for a in dp_axes:
        size *= mesh.shape[a]
    if n_tiles % size == 0 and n_tiles >= size:
        return P(tuple(dp_axes))
    return P()


def to_named(tree_of_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, ctx, parts):
    """``with_sharding_constraint`` with divisibility guards.

    ``parts``: one entry per dim of x — an axis name, a tuple of axis
    names, or None.  Axes that don't divide the dim are dropped (uneven
    sharding is rejected by XLA).  ``ctx=None`` is a no-op so model code
    stays runnable without a mesh.
    """
    if ctx is None or ctx.mesh is None:
        return x
    mesh = ctx.mesh
    fixed = []
    for dim, p in zip(x.shape, tuple(parts) + (None,) * x.ndim):
        if p is None:
            fixed.append(None)
            continue
        axes = p if isinstance(p, tuple) else (p,)
        size = 1
        ok = True
        for a in axes:
            if a not in mesh.shape:
                ok = False
                break
            size *= mesh.shape[a]
        fixed.append(p if ok and dim % size == 0 and dim >= size else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
