"""Distribution context threaded through model code (mesh + axis roles)."""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

# The replication-check kwarg was renamed check_rep -> check_vma; probe the
# signature once so genuine caller TypeErrors are never masked by a retry.
try:
    import inspect
    _CHECK_KW = ("check_vma" if "check_vma" in
                 inspect.signature(_shard_map).parameters else "check_rep")
except (ValueError, TypeError):  # pragma: no cover - unintrospectable
    _CHECK_KW = "check_rep"


def shard_map_compat(fn, **kwargs):
    """``shard_map`` with replication checking off, across jax versions."""
    kwargs.setdefault(_CHECK_KW, False)
    return _shard_map(fn, **kwargs)


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Mesh
    dp_axes: tuple[str, ...] = ("data",)   # batch axes (pod + data)
    tp_axis: str | None = "model"          # tensor/expert-parallel axis

    def axis_size(self, name: str | None) -> int:
        if name is None:
            return 1
        return self.mesh.shape[name]

    @property
    def dp_size(self) -> int:
        return int(
            __import__("math").prod(self.mesh.shape[a] for a in self.dp_axes))

    def batch_spec(self, ndim: int) -> P:
        """(B, ...) activations: batch over dp axes, rest replicated."""
        return P(self.dp_axes, *([None] * (ndim - 1)))


def single_device_ctx() -> DistContext:
    """1x1 ("data","model") mesh for smoke tests and CPU examples."""
    dev = jax.devices()[0]
    import numpy as np
    mesh = Mesh(np.array([dev]).reshape(1, 1), ("data", "model"))
    return DistContext(mesh=mesh, dp_axes=("data",), tp_axis="model")
