"""Mixture-of-Experts layer with expert parallelism over the `model` axis.

Distribution design (DESIGN.md §5):

* Experts are sharded over the `model` mesh axis (EP).  Inside the layer the
  *sequence* dim is first split across the same axis, so each model-rank
  dispatches only S/ep of the tokens (router math is divided by ep instead of
  replicated) — then a capacity-bounded sort-based dispatch builds per-peer
  buffers and a single ``all_to_all`` delivers tokens to their experts; the
  reverse ``all_to_all`` + an ``all_gather`` over the sequence split restore
  the replicated activation layout.  XLA overlaps the (a2a -> expert GEMM ->
  a2a) chain across the grid automatically; buffer sizes are bounded by
  ``capacity_factor`` (dropped tokens fall back to the residual path, the
  standard Switch behaviour).

* Decode (S == 1) cannot split the sequence; the layer switches to a
  psum-combine path: every rank computes its local experts' contribution for
  all tokens and the partial outputs are summed over the `model` axis.

Both paths are exact for the same routing decisions and run unchanged on a
(1, 1) mesh (all_to_all/psum degenerate), which is how smoke tests cover them.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.context import shard_map_compat
from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_type: str = "softmax"    # softmax (renormalized top-k) | sigmoid
    aux_loss_weight: float = 0.01


def init_moe(key, spec: MoESpec, *, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    e, d, f = spec.num_experts, spec.d_model, spec.d_ff
    return {
        "router": layers.dense_init(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": layers.dense_init(ks[1], (e, d, f), dtype=dtype),
        "w_up": layers.dense_init(ks[2], (e, d, f), dtype=dtype),
        "w_down": layers.dense_init(ks[3], (e, f, d), dtype=dtype),
    }


def _route(x_tokens, router, spec: MoESpec):
    """x_tokens: (T, D) -> (gates (T, k), idx (T, k) int32, aux_probs (T, E))."""
    logits = jnp.einsum("td,de->te", x_tokens.astype(jnp.float32), router)
    if spec.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        gates, idx = jax.lax.top_k(scores, spec.top_k)
        probs = scores / jnp.maximum(
            jnp.sum(scores, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, spec.top_k)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, idx.astype(jnp.int32), probs


def _dispatch_indices(idx, spec: MoESpec, capacity: int):
    """Sort-based capacity assignment.

    idx: (T, k) expert ids.  Returns (token_sorted, e_sorted, pos, keep):
    flattened (T*k,) arrays; position of each kept (token, slot) within its
    expert's capacity buffer, first-come-first-served by token order.
    """
    t, k = idx.shape
    e_flat = idx.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)           # group by expert
    e_sorted = e_flat[order]
    counts = jnp.zeros(spec.num_experts, jnp.int32).at[e_flat].add(1)
    offsets = jnp.cumsum(counts) - counts              # exclusive
    pos = jnp.arange(t * k, dtype=jnp.int32) - offsets[e_sorted]
    keep = pos < capacity
    token_sorted = order // k
    slot_sorted = order % k
    return token_sorted, slot_sorted, e_sorted, pos, keep


def _expert_ffn(tokens, w_gate, w_up, w_down):
    """tokens: (E_local, C', D); weights (E_local, D, F)/(E_local, F, D)."""
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", tokens, w_gate,
                                  preferred_element_type=jnp.float32))
    up = jnp.einsum("ecd,edf->ecf", tokens, w_up,
                    preferred_element_type=jnp.float32)
    h = (gate * up).astype(tokens.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down,
                      preferred_element_type=jnp.float32).astype(tokens.dtype)


def _aux_loss(probs, idx, spec: MoESpec, axes):
    """Switch-style load-balance loss, averaged over all participating axes."""
    e = spec.num_experts
    top1 = idx[:, 0]
    f = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=0)
    p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * p)
    if axes:
        aux = jax.lax.pmean(aux, axes)
    return aux * spec.aux_loss_weight


def _named_axis_size(axis) -> int:
    """Size of a named mesh axis inside shard_map, across jax versions
    (``jax.lax.axis_size`` is new; ``psum(1, axis)`` is the classic idiom)."""
    if not axis:
        return 1
    try:
        return jax.lax.axis_size(axis)
    except AttributeError:
        return jax.lax.psum(1, axis)


def moe_apply(params, x, spec: MoESpec, ctx, *, decode: bool = False):
    """x: (B, S, D) with batch sharded over ctx.dp_axes. Returns (y, aux)."""
    ep_axis = ctx.tp_axis
    ep = ctx.axis_size(ep_axis)
    all_axes = tuple(ctx.dp_axes) + ((ep_axis,) if ep_axis else ())
    b, s, d = x.shape

    if decode or s % max(ep, 1) or s < ep:
        in_specs = (P(*[ctx.dp_axes, None, None]),
                    P(), P(ep_axis), P(ep_axis), P(ep_axis))
        out_specs = (P(*[ctx.dp_axes, None, None]), P())
        fn = lambda xx, router, wg, wu, wd: _moe_psum_path(
            xx, router, wg, wu, wd, spec, ep_axis, all_axes)
    else:
        # Sequence-split EP: the shard_map consumes the activation already
        # sequence-sharded over the EP axis (free under SP boundaries) and
        # returns it the same way — no gather on either side.
        in_specs = (P(*[ctx.dp_axes, ep_axis, None]),
                    P(), P(ep_axis), P(ep_axis), P(ep_axis))
        out_specs = (P(*[ctx.dp_axes, ep_axis, None]), P())
        fn = lambda xx, router, wg, wu, wd: _moe_a2a_path(
            xx, router, wg, wu, wd, spec, ep_axis, all_axes)

    y, aux = shard_map_compat(
        fn, mesh=ctx.mesh, in_specs=in_specs, out_specs=out_specs,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return y, aux


def _moe_a2a_path(x, router, w_gate, w_up, w_down, spec, ep_axis, all_axes):
    """Sequence-split + all_to_all expert parallelism (train / prefill).

    x arrives already sequence-sharded over the EP axis: (b, s_local, d)."""
    b, s, d = x.shape
    ep = _named_axis_size(ep_axis)
    e_local = spec.num_experts // max(ep, 1)

    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    capacity = max(1, int(t * spec.top_k * spec.capacity_factor
                          / spec.num_experts))

    gates, idx, probs = _route(tokens, router, spec)
    aux = _aux_loss(probs, idx, spec, all_axes)
    tok_s, slot_s, e_s, pos, keep = _dispatch_indices(idx, spec, capacity)

    # Scatter kept tokens into per-expert capacity buffers.
    buf = jnp.zeros((spec.num_experts * capacity, d), tokens.dtype)
    dest = jnp.where(keep, e_s * capacity + pos,
                     spec.num_experts * capacity)
    buf = buf.at[dest].set(tokens[tok_s], mode="drop")
    buf = buf.reshape(ep, e_local, capacity, d)

    if ep > 1:
        recv = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                                  tiled=False)
    else:
        recv = buf
    # recv[p, e, c, :] = peer p's tokens for my local expert e.
    expert_in = recv.transpose(1, 0, 2, 3).reshape(e_local, ep * capacity, d)
    expert_out = _expert_ffn(expert_in, w_gate, w_up, w_down)
    send = expert_out.reshape(e_local, ep, capacity, d).transpose(1, 0, 2, 3)
    if ep > 1:
        back = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0,
                                  tiled=False)
    else:
        back = send
    outs = back.reshape(spec.num_experts * capacity, d)

    # Combine: gather each kept (token, slot) output, weight, scatter-add.
    src = jnp.where(keep, e_s * capacity + pos, 0)
    contrib = outs[src] * jnp.where(keep, gates[tok_s, slot_s],
                                    0.0)[:, None].astype(outs.dtype)
    y_tokens = jnp.zeros((t, d), x.dtype).at[tok_s].add(
        contrib.astype(x.dtype))
    return y_tokens.reshape(b, s, d), aux


def _moe_psum_path(x, router, w_gate, w_up, w_down, spec, ep_axis, all_axes):
    """Local-expert + psum combine (decode / non-divisible sequences)."""
    b, s, d = x.shape
    ep = _named_axis_size(ep_axis)
    rank = jax.lax.axis_index(ep_axis) if ep_axis else 0
    e_local = spec.num_experts // max(ep, 1)

    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    capacity = max(1, int(-(-t * spec.top_k * spec.capacity_factor
                            // spec.num_experts)))

    gates, idx, probs = _route(tokens, router, spec)
    aux = _aux_loss(probs, idx, spec, all_axes)
    tok_s, slot_s, e_s, pos, keep = _dispatch_indices(idx, spec, capacity)

    # Keep only (token, slot) pairs owned by this rank's experts.
    mine = keep & (e_s // e_local == rank)
    e_rel = e_s - rank * e_local
    buf = jnp.zeros((e_local * capacity, d), tokens.dtype)
    dest = jnp.where(mine, e_rel * capacity + pos, e_local * capacity)
    buf = buf.at[dest].set(tokens[tok_s], mode="drop")
    expert_out = _expert_ffn(buf.reshape(e_local, capacity, d),
                             w_gate, w_up, w_down)
    outs = expert_out.reshape(e_local * capacity, d)

    src = jnp.where(mine, e_rel * capacity + pos, 0)
    contrib = outs[src] * jnp.where(mine, gates[tok_s, slot_s],
                                    0.0)[:, None].astype(outs.dtype)
    y_tokens = jnp.zeros((t, d), x.dtype).at[tok_s].add(
        contrib.astype(x.dtype))
    if ep_axis:
        y_tokens = jax.lax.psum(y_tokens, ep_axis)
    return y_tokens.reshape(b, s, d), aux
