"""Attention: GQA/MQA/MHA with RoPE, qk-norm, local windows, KV-cache decode.

Full-sequence attention uses a blockwise online-softmax formulation (flash
attention re-expressed in pure lax: vmap over query blocks, scan over KV
blocks, f32 running max/sum) so 32k-token sequences never materialize the
(S, S) score matrix.  The Pallas TPU kernel in ``kernels/flash_attention``
implements the same contraction for the hot path; this XLA path is the
reference and the dry-run/compile path.

Layout conventions: activations (B, S, D); q/k/v (B, S, H, hd); KV caches
(B, S_max, Hkv, hd) written at ``pos`` via dynamic_update_slice.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float | None = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    causal: bool = True
    window: int | None = None           # local attention window (None = full)
    q_block: int = 512
    kv_block: int = 1024


class KVCache(NamedTuple):
    k: jnp.ndarray       # (B, S_max, Hkv, hd)
    v: jnp.ndarray
    length: jnp.ndarray  # () int32 — tokens currently valid


def init_attention(key, spec: AttnSpec, *, dtype=jnp.float32):
    d, h, hk, hd = spec.d_model, spec.num_heads, spec.num_kv_heads, spec.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], (d, h * hd), dtype=dtype),
        "wk": layers.dense_init(ks[1], (d, hk * hd), dtype=dtype),
        "wv": layers.dense_init(ks[2], (d, hk * hd), dtype=dtype),
        "wo": layers.dense_init(ks[3], (h * hd, d), dtype=dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hk * hd,), dtype)
        p["bv"] = jnp.zeros((hk * hd,), dtype)
    if spec.qk_norm:
        p["q_norm"] = layers.rmsnorm_init(hd, dtype=dtype)
        p["k_norm"] = layers.rmsnorm_init(hd, dtype=dtype)
    return p


def _project_qkv(params, x, kv_src, positions, kv_positions, spec: AttnSpec):
    b = x.shape[0]
    kv_in = x if kv_src is None else kv_src
    q = layers.matmul(x, params["wq"])
    k = layers.matmul(kv_in, params["wk"])
    v = layers.matmul(kv_in, params["wv"])
    if spec.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, -1, spec.num_heads, spec.head_dim)
    k = k.reshape(b, -1, spec.num_kv_heads, spec.head_dim)
    v = v.reshape(b, -1, spec.num_kv_heads, spec.head_dim)
    if spec.qk_norm:
        q = layers.rmsnorm(params["q_norm"], q)
        k = layers.rmsnorm(params["k_norm"], k)
    if spec.rope_theta is not None:
        q = layers.rope(q, positions, theta=spec.rope_theta)
        k = layers.rope(k, kv_positions, theta=spec.rope_theta)
    return q, k, v


def blockwise_attention(q, k, v, *, q_positions, kv_positions, causal, window,
                        kv_valid_len=None, q_block=512, kv_block=1024):
    """Flash attention in pure XLA (custom_vjp): never materializes the
    (Sq, Skv) matrix in either direction.

    q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd).  Forward: vmap over query
    blocks (parallel => the query-sequence dim stays shardable for
    context-parallel attention, §Perf LM-4) x scan over KV blocks with an
    online softmax.  Backward: custom_vjp recomputes probabilities per block
    from the saved (q, k, v, out, lse) — O(S) residuals, no per-step scan
    carries (a vmap-of-scans autodiff pins O(S^2/kb) f32 carries: measured
    295 GB/device for a 0.5B model at 4k — §Perf LM-2 log).

    Positions must be 0..S-1 (standard full-sequence layout; offsets are
    handled by the decode path, which doesn't use this function).
    """
    del q_positions, kv_positions  # global arange layout (see docstring)
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    nq = -(-sq // qb)
    nk = -(-skv // kb)

    def pad_to(x, m, axis):
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, m - x.shape[axis])
        return jnp.pad(x, pad) if m != x.shape[axis] else x

    qp = pad_to(q, nq * qb, 1)
    kp = pad_to(k, nk * kb, 1)
    vp = pad_to(v, nk * kb, 1)
    skv_valid = int(skv if kv_valid_len is None else kv_valid_len) \
        if not hasattr(kv_valid_len, "dtype") else skv
    out = _flash(qp, kp, vp, causal, window, qb, kb, skv_valid)
    return out[:, :sq].astype(v.dtype)


def _mask_for(iq, ik, qb, kb, causal, window, skv_valid):
    qpos = iq * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    kpos = ik * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = kpos < skv_valid
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask[None, None, None]          # (1, 1, 1, qb, kb)


def _flash_fwd_impl(q, k, v, causal, window, qb, kb, skv_valid):
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    nq, nk = sq // qb, skv // kb
    scale = hd ** -0.5

    q_blocks = q.reshape(b, nq, qb, h, hd).transpose(1, 0, 2, 3, 4)
    k_blocks = k.reshape(b, nk, kb, hkv, hd).transpose(1, 0, 2, 3, 4)
    v_blocks = v.reshape(b, nk, kb, hkv, hd).transpose(1, 0, 2, 3, 4)

    def one_q_block(iq, qi):
        # GQA-grouped: q (B, qb, KV, G, hd) against (B, kb, KV, hd) — K/V
        # never repeated to H heads (§Perf LM-3); bf16 MXU, f32 accum.
        q5 = qi.reshape(b, qb, hkv, g, hd)

        def kv_step(carry, xs):
            m, l, acc = carry                        # (B, KV, G, qb[, hd])
            ik, kj, vj = xs
            s = jnp.einsum("bqngd,bknd->bngqk", q5, kj,
                           preferred_element_type=jnp.float32) * scale
            mask = _mask_for(iq, ik, qb, kb, causal, window, skv_valid)
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bngqk,bknd->bngqd", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qb), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk, dtype=jnp.int32), k_blocks, v_blocks))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = jnp.where(l > 0, jnp.where(jnp.isfinite(m), m, 0.0)
                        + jnp.log(jnp.maximum(l, 1e-30)), jnp.inf)
        return (out.transpose(0, 3, 1, 2, 4).reshape(b, qb, h, hd),
                lse)                                  # lse: (B, KV, G, qb)

    outs, lses = jax.vmap(one_q_block)(
        jnp.arange(nq, dtype=jnp.int32), q_blocks)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)
    return out.astype(v.dtype), lses                  # lses: (nq, B, KV, G, qb)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, qb, kb, skv_valid):
    return _flash_fwd_impl(q, k, v, causal, window, qb, kb, skv_valid)[0]


def _flash_fwd(q, k, v, causal, window, qb, kb, skv_valid):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, qb, kb, skv_valid)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, qb, kb, skv_valid, res, dout):
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    nq, nk = sq // qb, skv // kb
    scale = hd ** -0.5

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                          # (B, S, H)
    delta = delta.reshape(b, sq, hkv, g).transpose(1, 0, 2, 3) \
        .reshape(nq, qb, b, hkv, g).transpose(0, 2, 3, 4, 1)  # (nq,B,KV,G,qb)

    def blocks(x, n, blk, heads):
        return x.reshape(b, n, blk, heads, hd).transpose(1, 0, 2, 3, 4)

    q_blocks = blocks(q, nq, qb, h)
    k_blocks = blocks(k, nk, kb, hkv)
    v_blocks = blocks(v, nk, kb, hkv)
    do_blocks = blocks(dout, nq, qb, h)

    def p_of(iq, ik, q5, kj, lse_i):
        s = jnp.einsum("bqngd,bknd->bngqk", q5, kj,
                       preferred_element_type=jnp.float32) * scale
        mask = _mask_for(iq, ik, qb, kb, causal, window, skv_valid)
        lse_safe = jnp.where(jnp.isfinite(lse_i), lse_i, 0.0)
        p = jnp.exp(s - lse_safe[..., None])
        p = jnp.where(mask & jnp.isfinite(lse_i)[..., None], p, 0.0)
        return p                                      # (B, KV, G, qb, kb)

    # dq: for each q block, scan kv blocks.
    def dq_block(iq, qi, doi, lse_i, delta_i):
        q5 = qi.reshape(b, qb, hkv, g, hd)
        do5 = doi.reshape(b, qb, hkv, g, hd)

        def step(acc, xs):
            ik, kj, vj = xs
            p = p_of(iq, ik, q5, kj, lse_i)
            dvp = jnp.einsum("bqngd,bknd->bngqk", do5, vj,
                             preferred_element_type=jnp.float32)
            ds = p * (dvp - delta_i[..., None])
            acc = acc + jnp.einsum("bngqk,bknd->bqngd", ds.astype(kj.dtype),
                                   kj, preferred_element_type=jnp.float32)
            return acc, None

        acc0 = jnp.zeros((b, qb, hkv, g, hd), jnp.float32)
        acc, _ = jax.lax.scan(step, acc0, (jnp.arange(nk, dtype=jnp.int32),
                                           k_blocks, v_blocks))
        return (acc * scale).reshape(b, qb, h, hd)

    lse_blocks = _flash_lse_reshape(lse, nq)
    dq_blocks = jax.vmap(dq_block)(jnp.arange(nq, dtype=jnp.int32),
                                   q_blocks, do_blocks, lse_blocks, delta)
    dq = dq_blocks.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)

    # dk/dv: for each kv block, scan q blocks.
    def dkv_block(ik, kj, vj):
        def step(carry, xs):
            dk_acc, dv_acc = carry
            iq, qi, doi, lse_i, delta_i = xs
            q5 = qi.reshape(b, qb, hkv, g, hd)
            do5 = doi.reshape(b, qb, hkv, g, hd)
            p = p_of(iq, ik, q5, kj, lse_i)
            dv_acc = dv_acc + jnp.einsum(
                "bngqk,bqngd->bknd", p.astype(do5.dtype), do5,
                preferred_element_type=jnp.float32)
            dvp = jnp.einsum("bqngd,bknd->bngqk", do5, vj,
                             preferred_element_type=jnp.float32)
            ds = p * (dvp - delta_i[..., None])
            dk_acc = dk_acc + jnp.einsum(
                "bngqk,bqngd->bknd", ds.astype(q5.dtype), q5,
                preferred_element_type=jnp.float32)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, kb, hkv, hd), jnp.float32)
        (dk_acc, dv_acc), _ = jax.lax.scan(
            step, (z, z),
            (jnp.arange(nq, dtype=jnp.int32), q_blocks, do_blocks,
             lse_blocks, delta))
        return dk_acc * scale, dv_acc

    dk_blocks, dv_blocks = jax.vmap(dkv_block)(
        jnp.arange(nk, dtype=jnp.int32), k_blocks, v_blocks)
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(b, skv, hkv, hd)
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(b, skv, hkv, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _flash_lse_reshape(lse, nq):
    return lse                                        # already (nq, B, KV, G, qb)


_flash.defvjp(_flash_fwd, _flash_bwd)


def _constrain_qkv(q, k, v, ctx):
    """Pin the attention-core layout: batch over the data axes plus either

    * heads over `model` (Megatron) when the head count divides the TP
      degree, or
    * the *query sequence* over `model` (context-parallel: each TP rank
      computes its query rows against replicated K/V) when it does not —
      llama4's 40 heads and whisper's 12 heads vs 16-way TP would otherwise
      run the whole attention rectangle replicated on every rank (measured
      16x compute overhead on llama4 prefill_32k — EXPERIMENTS §Perf LM-4).

    Without any constraint, XLA's propagation loses head sharding across
    the q/k/v reshapes and emits per-layer all-to-all storms (LM-1)."""
    if ctx is None:
        return q, k, v
    from repro.distributed.sharding import constrain
    dp, tp = ctx.dp_axes, ctx.tp_axis
    tp_size = ctx.axis_size(tp)
    if q.shape[2] % max(tp_size, 1) == 0:
        q = constrain(q, ctx, (dp, None, tp, None))
        k = constrain(k, ctx, (dp, None, tp, None))
        v = constrain(v, ctx, (dp, None, tp, None))
    else:
        q = constrain(q, ctx, (dp, tp, None, None))
        k = constrain(k, ctx, (dp, None, None, None))
        v = constrain(v, ctx, (dp, None, None, None))
    return q, k, v


def _flash_kernel_ok(q, k, spec: AttnSpec) -> bool:
    """Use the Pallas kernel on TPU when the shapes tile into its blocks."""
    if jax.default_backend() != "tpu":
        return False
    sq, skv = q.shape[1], k.shape[1]
    return (sq % 128 == 0 and skv % 128 == 0
            and spec.head_dim in (64, 128, 256))


def apply_attention(params, x, *, spec: AttnSpec, positions=None,
                    kv_src=None, kv_positions=None, ctx=None):
    """Full-sequence attention (train / prefill without cache)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = (positions if kv_src is None
                        else jnp.arange(kv_src.shape[1], dtype=jnp.int32))
    q, k, v = _project_qkv(params, x, kv_src, positions, kv_positions, spec)
    q, k, v = _constrain_qkv(q, k, v, ctx)
    if _flash_kernel_ok(q, k, spec):
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), spec.causal and kv_src is None,
            spec.window).transpose(0, 2, 1, 3)
    else:
        out = blockwise_attention(
            q, k, v, q_positions=positions, kv_positions=kv_positions,
            causal=spec.causal and kv_src is None, window=spec.window,
            q_block=spec.q_block, kv_block=spec.kv_block)
    out = out.reshape(b, s, spec.num_heads * spec.head_dim)
    if ctx is not None:
        from repro.distributed.sharding import constrain
        out = constrain(out, ctx, (ctx.dp_axes, None, ctx.tp_axis))
    return layers.matmul(out, params["wo"])


def cache_len(max_len: int, spec: AttnSpec) -> int:
    """Physical cache length: local-window layers keep a ring of `window`."""
    return min(max_len, spec.window) if spec.window is not None else max_len


def init_cache(batch, max_len, spec: AttnSpec, *, dtype) -> KVCache:
    shape = (batch, cache_len(max_len, spec), spec.num_kv_heads, spec.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def prefill_attention(params, x, cache: KVCache, *, spec: AttnSpec,
                      ctx=None):
    """Full attention over a prompt, writing (the tail of) K/V to the cache.

    Ring caches (local-window layers) keep the last `cache_len` tokens, each
    stored at slot ``abs_pos % cache_len`` so decode writes stay aligned.
    """
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, None, positions, positions, spec)
    q, k, v = _constrain_qkv(q, k, v, ctx)
    c = cache.k.shape[1]
    ktail = k[:, -c:].astype(cache.k.dtype)
    vtail = v[:, -c:].astype(cache.v.dtype)
    if s >= c and s % c:
        ktail = jnp.roll(ktail, s % c, axis=1)
        vtail = jnp.roll(vtail, s % c, axis=1)
    knew = jax.lax.dynamic_update_slice(cache.k, ktail, (0, 0, 0, 0))
    vnew = jax.lax.dynamic_update_slice(cache.v, vtail, (0, 0, 0, 0))
    out = blockwise_attention(
        q, k, v, q_positions=positions, kv_positions=positions,
        causal=spec.causal, window=spec.window,
        q_block=spec.q_block, kv_block=spec.kv_block)
    out = out.reshape(b, s, spec.num_heads * spec.head_dim)
    out = layers.matmul(out, params["wo"])
    return out, KVCache(knew, vnew, jnp.asarray(s, jnp.int32))


def decode_attention(params, x, cache: KVCache, *, spec: AttnSpec,
                     kv_src_cache: KVCache | None = None):
    """One-token decode against the cache. x: (B, 1, D)."""
    b = x.shape[0]
    pos = jnp.asarray(cache.length, jnp.int32)
    positions = pos[None]

    if kv_src_cache is None:
        q, k, v = _project_qkv(params, x, None, positions, positions, spec)
        c = cache.k.shape[1]
        slot = pos % c
        ck = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
        new_cache = KVCache(ck, cv, cache.length + 1)
        keys, vals = ck, cv
        valid = jnp.minimum(pos + 1, c)
    else:
        # Cross attention: keys/values fixed (encoder outputs), no rope.
        q = layers.matmul(x, params["wq"])
        if spec.qkv_bias:
            q = q + params["bq"]
        q = q.reshape(b, 1, spec.num_heads, spec.head_dim)
        if spec.qk_norm:
            q = layers.rmsnorm(params["q_norm"], q)
        if spec.rope_theta is not None:
            q = layers.rope(q, positions, theta=spec.rope_theta)
        new_cache = cache
        keys, vals = kv_src_cache.k, kv_src_cache.v
        valid = kv_src_cache.length

    g = spec.num_heads // spec.num_kv_heads
    # GQA-grouped: contract against the cache without repeating K/V to H
    # heads (LM-3; the repeat materialized (B, S_cache, H, hd) f32).
    q5 = q.reshape(b, spec.num_kv_heads, g, spec.head_dim)
    s = jnp.einsum("bngd,bknd->bngk", q5, keys,
                   preferred_element_type=jnp.float32)
    s = s * spec.head_dim ** -0.5                    # (B, KV, G, S_cache)
    idx = jnp.arange(keys.shape[1], dtype=jnp.int32)
    mask = idx[None, None, None, :] < valid
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngk,bknd->bngd", p.astype(vals.dtype), vals,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, spec.num_heads * spec.head_dim).astype(x.dtype)
    return layers.matmul(out, params["wo"]), new_cache
