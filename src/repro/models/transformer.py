"""Decoder-only LM assembly: block registry, layer scan, loss, prefill/decode.

Blocks (cfg.block_pattern, cycled over layers):
  attn  — GQA attention + dense MLP            (dense / vlm archs)
  moe   — GQA attention + mixture-of-experts   (llama4, dbrx)
  rwkv  — RWKV6 TimeMix + ChannelMix           (rwkv6)
  rec   — RG-LRU recurrent block + MLP         (recurrentgemma)
  lattn — local-window attention + MLP         (recurrentgemma 1:2 pattern)

Homogeneous stacks are scanned (`lax.scan` over stacked params: compact HLO,
O(1) compile cost in depth) with per-layer remat; heterogeneous stacks are
python loops.  Decode threads a per-layer cache (KV cache or recurrent state)
through the same machinery.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, moe as moe_lib, rglru, rwkv6


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def attn_spec(cfg: ModelConfig, *, local: bool = False) -> attention.AttnSpec:
    return attention.AttnSpec(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
        qkv_bias=cfg.qkv_bias, causal=True,
        window=cfg.local_window if local else None)


def moe_spec(cfg: ModelConfig) -> moe_lib.MoESpec:
    return moe_lib.MoESpec(
        d_model=cfg.d_model, d_ff=cfg.d_ff, num_experts=cfg.num_experts,
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        router_type=cfg.router_type)


# ---------------------------------------------------------------------------
# Block init / apply / decode, dispatched on kind
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str):
    dt = _dtype(cfg)
    norm_init, _ = layers.make_norm(cfg.norm_type)
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("attn", "lattn", "moe"):
        p = {"norm1": norm_init(d, dtype=dt),
             "attn": attention.init_attention(
                 ks[0], attn_spec(cfg, local=kind == "lattn"), dtype=dt),
             "norm2": norm_init(d, dtype=dt)}
        if kind == "moe":
            p["moe"] = moe_lib.init_moe(ks[1], moe_spec(cfg), dtype=dt)
            if cfg.moe_shared_expert:
                p["shared"] = layers.mlp_init(ks[2], d, cfg.d_ff,
                                              cfg.mlp_type, dtype=dt)
        else:
            p["mlp"] = layers.mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_type,
                                       dtype=dt)
        return p
    if kind == "rwkv":
        return {"norm1": norm_init(d, dtype=dt),
                "tm": rwkv6.init_timemix(ks[0], d, dtype=dt),
                "norm2": norm_init(d, dtype=dt),
                "cm": rwkv6.init_channelmix(ks[1], d, cfg.d_ff, dtype=dt)}
    if kind == "rec":
        return {"norm1": norm_init(d, dtype=dt),
                "rec": rglru.init_recurrent_block(
                    ks[0], d, cfg.rnn_width, cfg.conv_width, dtype=dt),
                "norm2": norm_init(d, dtype=dt),
                "mlp": layers.mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_type,
                                       dtype=dt)}
    raise ValueError(kind)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    dt = _dtype(cfg)
    if kind in ("attn", "moe", "lattn"):
        return attention.init_cache(
            batch, max_len, attn_spec(cfg, local=kind == "lattn"), dtype=dt)
    if kind == "rwkv":
        return rwkv6.init_rwkv_state(batch, cfg.d_model, dtype=dt)
    if kind == "rec":
        state = rglru.init_recurrent_state(batch, cfg.rnn_width,
                                           cfg.conv_width, dtype=dt)
        return state
    raise ValueError(kind)


def block_apply(p, x, cfg: ModelConfig, kind: str, ctx, *, cache=None,
                decode: bool = False):
    """Full-seq (cache=None), prefill (cache given, decode=False) or
    one-token decode.  Returns (x, aux, new_cache)."""
    _, norm = layers.make_norm(cfg.norm_type)
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    if kind in ("attn", "moe", "lattn"):
        spec = attn_spec(cfg, local=kind == "lattn")
        h = norm(p["norm1"], x)
        if cache is None:
            a = attention.apply_attention(p["attn"], h, spec=spec, ctx=ctx)
        elif decode:
            a, new_cache = attention.decode_attention(p["attn"], h, cache,
                                                      spec=spec)
        else:
            a, new_cache = attention.prefill_attention(p["attn"], h, cache,
                                                       spec=spec, ctx=ctx)
        x = x + a
        h = norm(p["norm2"], x)
        if kind == "moe":
            m, aux = moe_lib.moe_apply(p["moe"], h, moe_spec(cfg), ctx,
                                       decode=decode)
            if cfg.moe_shared_expert:
                m = m + layers.mlp_apply(p["shared"], h, cfg.mlp_type)
        else:
            m = layers.mlp_apply(p["mlp"], h, cfg.mlp_type)
        return x + m, aux, new_cache

    if kind == "rwkv":
        st = cache or rwkv6.init_rwkv_state(x.shape[0], cfg.d_model,
                                            dtype=x.dtype)
        h = norm(p["norm1"], x)
        tm_out, tm_x, wkv = rwkv6.timemix_apply(
            p["tm"], h, st["tm_x"], st["wkv"], wkv_impl=cfg.wkv_impl)
        x = x + tm_out
        h = norm(p["norm2"], x)
        cm_out, cm_x = rwkv6.channelmix_apply(p["cm"], h, st["cm_x"])
        x = x + cm_out
        return x, aux, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}

    if kind == "rec":
        st = cache or rglru.init_recurrent_state(
            x.shape[0], cfg.rnn_width, cfg.conv_width, dtype=x.dtype)
        h = norm(p["norm1"], x)
        r, new_st = rglru.recurrent_block_apply(p["rec"], h, st,
                                                decode=decode)
        x = x + r
        h = norm(p["norm2"], x)
        x = x + layers.mlp_apply(p["mlp"], h, cfg.mlp_type)
        return x, aux, new_st

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    norm_init, _ = layers.make_norm(cfg.norm_type)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params: dict[str, Any] = {
        "embed": layers.embed_init(k_embed, cfg.padded_vocab, cfg.d_model,
                                   dtype=dt),
        "final_norm": norm_init(cfg.d_model, dtype=dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            k_head, (cfg.d_model, cfg.padded_vocab), dtype=dt)

    keys = jax.random.split(k_blocks, cfg.num_layers)
    if cfg.homogeneous and cfg.scan_layers:
        kind = cfg.block_pattern[0]
        params["blocks"] = jax.vmap(
            lambda k: init_block(k, cfg, kind))(keys)
    else:
        params["blocks"] = [init_block(keys[i], cfg, cfg.block_kind(i))
                            for i in range(cfg.num_layers)]
    return params


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def backbone(params, x, cfg: ModelConfig, ctx, *, caches=None,
             decode: bool = False):
    """Run all blocks. Returns (x, aux_total, new_caches)."""
    aux_total = jnp.zeros((), jnp.float32)

    def bnd(h):
        """Sequence-parallel layer boundary: activations (B: dp, S: tp, D).
        Converts the per-layer TP all-reduce into reduce-scatter/all-gather
        and divides saved layer-boundary activations (the backward-pass
        residency) by |model| — EXPERIMENTS.md §Perf iteration LM-2."""
        if cfg.seq_shard and ctx is not None and not decode:
            from repro.distributed.sharding import constrain
            return constrain(h, ctx, (ctx.dp_axes, ctx.tp_axis, None))
        return h

    x = bnd(x)

    if cfg.homogeneous and cfg.scan_layers:
        kind = cfg.block_pattern[0]

        if caches is None:
            def body(carry, p_l):
                h, aux = carry
                h, a, _ = block_apply(p_l, h, cfg, kind, ctx)
                return (bnd(h), aux + a), None

            (x, aux_total), _ = jax.lax.scan(
                _maybe_remat(body, cfg), (x, aux_total), params["blocks"])
            return x, aux_total, None

        def body(carry, xs):
            h, aux = carry
            p_l, cache_l = xs
            h, a, new_cache = block_apply(p_l, h, cfg, kind, ctx,
                                          cache=cache_l, decode=decode)
            return (bnd(h), aux + a), new_cache

        (x, aux_total), new_caches = jax.lax.scan(
            _maybe_remat(body, cfg), (x, aux_total),
            (params["blocks"], caches))
        return x, aux_total, new_caches

    new_caches = []
    for i, p_l in enumerate(params["blocks"]):
        kind = cfg.block_kind(i)
        cache_l = None if caches is None else caches[i]
        fn = _maybe_remat(
            functools.partial(block_apply, cfg=cfg, kind=kind, ctx=ctx,
                              decode=decode), cfg)
        x, a, new_cache = fn(p_l, x, cache=cache_l)
        x = bnd(x)
        aux_total = aux_total + a
        new_caches.append(new_cache)
    return x, aux_total, (None if caches is None else new_caches)


def logits_from_hidden(params, x, cfg: ModelConfig):
    _, norm = layers.make_norm(cfg.norm_type)
    h = norm(params["final_norm"], x)
    head = params.get("lm_head")
    logits = layers.unembed(params["embed"], h, head=head)  # f32
    # Mask padded vocab rows out of the softmax.
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


def embed_tokens(params, tokens, cfg: ModelConfig):
    return layers.embed_apply(params["embed"], tokens,
                              scale_by_sqrt_dim=cfg.embed_scale_sqrt_dim)


def loss_fn(params, batch, cfg: ModelConfig, ctx):
    """batch: dict(inputs (B,S) int32, targets (B,S) int32, mask (B,S))."""
    x = embed_tokens(params, batch["inputs"], cfg)
    x, aux, _ = backbone(params, x, cfg, ctx)
    _, norm = layers.make_norm(cfg.norm_type)
    h = norm(params["final_norm"], x)
    w = params.get("lm_head")
    if w is None:
        w = params["embed"]["embedding"].T
    ce = layers.chunked_softmax_xent(h, w, batch["targets"], batch["mask"],
                                     valid_vocab=cfg.vocab_size)
    return ce + aux, {"ce": ce, "aux": aux}


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.homogeneous and cfg.scan_layers:
        kind = cfg.block_pattern[0]
        one = init_block_cache(cfg, kind, batch, max_len)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape),
            one)
    return [init_block_cache(cfg, cfg.block_kind(i), batch, max_len)
            for i in range(cfg.num_layers)]


def prefill(params, tokens, cfg: ModelConfig, ctx, *, max_len: int):
    """Prompt pass; returns (last-token logits, caches)."""
    caches = init_caches(cfg, tokens.shape[0], max_len)
    x = embed_tokens(params, tokens, cfg)
    x, _, caches = backbone(params, x, cfg, ctx, caches=caches, decode=False)
    logits = logits_from_hidden(params, x[:, -1:, :], cfg)
    return logits, caches


def decode_step(params, token, caches, cfg: ModelConfig, ctx):
    """token: (B, 1) int32. Returns (logits (B,1,V), new caches)."""
    x = embed_tokens(params, token, cfg)
    x, _, caches = backbone(params, x, cfg, ctx, caches=caches, decode=True)
    return logits_from_hidden(params, x, cfg), caches
