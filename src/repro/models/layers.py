"""Shared neural-net layers (pure functions over param pytrees, no flax).

Params are nested dicts of jnp arrays.  Initializers take an explicit PRNG
key.  All matmuls accumulate in f32 (``preferred_element_type``) and cast
back to the activation dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, *, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (stddev 1/sqrt(fan_in) by default)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def matmul(x, w, *, out_dtype=None):
    out = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


def einsum(spec, *args, out_dtype=None):
    out = jnp.einsum(spec, *args, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or args[0].dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d, *, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params, x, *, eps=1e-6):
    """RMSNorm with (1 + scale) parameterization (gemma convention; scale
    init 0 == identity, matching scale-init-1 of the usual convention)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d, *, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, *, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * (1.0 + params["scale"].astype(jnp.float32))
    out = out + params["bias"].astype(jnp.float32)
    return out.astype(dt)


def make_norm(norm_type: str):
    if norm_type == "rmsnorm":
        return rmsnorm_init, rmsnorm
    if norm_type == "layernorm":
        return layernorm_init, layernorm
    raise ValueError(norm_type)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, *, theta: float = 10000.0):
    """Apply RoPE. x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    angles = angles[..., None, :]                             # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, mlp_type, *, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if mlp_type in ("swiglu", "geglu"):
        return {"w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
                "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
                "w_down": dense_init(k3, (d_ff, d_model), dtype=dtype)}
    if mlp_type == "gelu":
        return {"w_in": dense_init(k1, (d_model, d_ff), dtype=dtype),
                "b_in": jnp.zeros((d_ff,), dtype),
                "w_out": dense_init(k2, (d_ff, d_model), dtype=dtype),
                "b_out": jnp.zeros((d_model,), dtype)}
    raise ValueError(mlp_type)


def mlp_apply(params, x, mlp_type):
    if mlp_type == "swiglu":
        gate = jax.nn.silu(matmul(x, params["w_gate"]))
        return matmul(gate * matmul(x, params["w_up"]), params["w_down"])
    if mlp_type == "geglu":
        gate = jax.nn.gelu(matmul(x, params["w_gate"]), approximate=True)
        return matmul(gate * matmul(x, params["w_up"]), params["w_down"])
    if mlp_type == "gelu":
        h = jax.nn.gelu(matmul(x, params["w_in"]) + params["b_in"],
                        approximate=True)
        return matmul(h, params["w_out"]) + params["b_out"]
    raise ValueError(mlp_type)


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d_model, *, dtype=jnp.float32):
    # std d^-0.5: unit-variance activations after gemma's sqrt(d) embed
    # scaling AND ~unit-std logits under tied unembedding.
    return {"embedding": dense_init(key, (vocab, d_model),
                                    scale=d_model ** -0.5, dtype=dtype)}


def embed_apply(params, tokens, *, scale_by_sqrt_dim=False):
    emb = params["embedding"][tokens]
    if scale_by_sqrt_dim:
        emb = emb * jnp.asarray(emb.shape[-1] ** 0.5, emb.dtype)
    return emb


def unembed(params, x, *, head=None):
    """Logits: tied (embedding.T) unless a separate head matrix is given."""
    w = head if head is not None else params["embedding"].T
    return jnp.matmul(x, w.astype(x.dtype),
                      preferred_element_type=jnp.float32)


def cross_entropy_loss(logits_f32, targets, mask, *, z_loss: float = 1e-4):
    """Mean masked token cross-entropy (+ z-loss for logit drift control)."""
    lse = jax.nn.logsumexp(logits_f32, axis=-1)
    gold = jnp.take_along_axis(logits_f32, targets[..., None],
                               axis=-1).squeeze(-1)
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse ** 2
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_softmax_xent(h, w, targets, mask, *, valid_vocab: int,
                         chunk: int = 4096, z_loss: float = 1e-4):
    """Cross-entropy without materializing full (tokens, V) f32 logits.

    h: (B, S, D) final hidden states; w: (D, V) unembedding; the token dim is
    scanned in chunks with per-chunk remat, so peak memory is
    O(chunk x V / shards) instead of O(B x S x V) — the full-logit form costs
    ~300 GB/device at (B=128, S=4k, V=152k) f32 (EXPERIMENTS.md §Perf).
    """
    b, s, d = h.shape
    n = b * s
    v = w.shape[-1]
    hf = h.reshape(n, d)
    tf = targets.reshape(n)
    mf = mask.reshape(n).astype(jnp.float32)
    c = min(chunk, n)
    if n % c:
        pad = c - n % c
        hf = jnp.pad(hf, ((0, pad), (0, 0)))
        tf = jnp.pad(tf, (0, pad))
        mf = jnp.pad(mf, (0, pad))
        n += pad
    nchunks = n // c
    vocab_ok = jnp.arange(v) < valid_vocab

    def step(acc, xs):
        h_c, t_c, m_c = xs
        logits = jnp.matmul(h_c, w.astype(h_c.dtype),
                            preferred_element_type=jnp.float32)
        logits = jnp.where(vocab_ok, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_c[:, None], axis=-1)[:, 0]
        nll = lse - gold
        if z_loss:
            nll = nll + z_loss * lse ** 2
        return acc + jnp.sum(nll * m_c), None

    step = jax.checkpoint(step,
                          policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = jax.lax.scan(
        step, jnp.zeros((), jnp.float32),
        (hf.reshape(nchunks, c, d), tf.reshape(nchunks, c),
         mf.reshape(nchunks, c)))
    return total / jnp.maximum(jnp.sum(mf), 1.0)
