"""RWKV-6 "Finch" blocks (arXiv:2404.05892): data-dependent decay linear
attention (TimeMix) + squared-relu channel mixing (ChannelMix).

Two WKV evaluation paths:

* ``wkv_scan`` — faithful per-token recurrence ``S_t = diag(w_t) S_{t-1} +
  k_t v_t^T`` via ``lax.scan`` (O(T) sequential outer products).  Baseline.
* ``wkv_chunked`` — chunk-parallel form (beyond-paper optimization, see
  EXPERIMENTS.md §Perf): within a chunk of C tokens the recurrence unrolls to
  MXU-friendly matmuls with cumulative decay products; chunks are combined by
  a short scan carrying the (H, K, V) state.  Exact same math (f32 accum).

State layout per layer (decode): dict(tm_x (B,D), cm_x (B,D),
wkv (B,H,K,K) f32).  head size K = 64 (RWKV convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

HEAD_K = 64
LORA_MIX = 32
LORA_DECAY = 64


def init_timemix(key, d, *, dtype=jnp.float32):
    h = d // HEAD_K
    ks = jax.random.split(key, 12)
    return {
        "maa_base": jnp.zeros((d,), dtype),
        "maa": jnp.zeros((5, d), dtype),           # r,k,v,w,g token-shift mixes
        "tm_w1": layers.dense_init(ks[0], (d, 5 * LORA_MIX), dtype=dtype),
        "tm_w2": layers.dense_init(ks[1], (5, LORA_MIX, d),
                                   scale=LORA_MIX ** -0.5, dtype=dtype),
        "w0": jnp.zeros((d,), dtype),
        "wd1": layers.dense_init(ks[2], (d, LORA_DECAY), dtype=dtype),
        "wd2": layers.dense_init(ks[3], (LORA_DECAY, d),
                                 scale=LORA_DECAY ** -0.5, dtype=dtype),
        "u": jnp.zeros((h, HEAD_K), dtype),
        "wr": layers.dense_init(ks[4], (d, d), dtype=dtype),
        "wk": layers.dense_init(ks[5], (d, d), dtype=dtype),
        "wv": layers.dense_init(ks[6], (d, d), dtype=dtype),
        "wg": layers.dense_init(ks[7], (d, d), dtype=dtype),
        "wo": layers.dense_init(ks[8], (d, d), dtype=dtype),
        "ln_x": {"scale": jnp.zeros((d,), dtype),
                 "bias": jnp.zeros((d,), dtype)},
    }


def init_channelmix(key, d, d_ff, *, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "maa_k": jnp.zeros((d,), dtype),
        "maa_r": jnp.zeros((d,), dtype),
        "wk": layers.dense_init(ks[0], (d, d_ff), dtype=dtype),
        "wv": layers.dense_init(ks[1], (d_ff, d), dtype=dtype),
        "wr": layers.dense_init(ks[2], (d, d), dtype=dtype),
    }


def _group_norm(p, x, h):
    """Per-head groupnorm on (B, T, D) reshaped to (B, T, H, K)."""
    b, t, d = x.shape
    xs = x.reshape(b, t, h, HEAD_K).astype(jnp.float32)
    mu = jnp.mean(xs, axis=-1, keepdims=True)
    var = jnp.var(xs, axis=-1, keepdims=True)
    xs = (xs - mu) * jax.lax.rsqrt(var + 1e-5)
    xs = xs.reshape(b, t, d)
    out = xs * (1.0 + p["scale"].astype(jnp.float32)) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def _token_shift(x, prev):
    """shifted[t] = x[t-1]; slot 0 <- prev (zeros at sequence start)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def wkv_scan(r, k, v, w, u, state):
    """Faithful recurrence. r/k/v/w: (B, T, H, K); state: (B, H, K, K) f32.

    Returns (out (B, T, H, K), new_state).
    """
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs  # (B, H, K)
        kv = kt[..., :, None] * vt[..., None, :]          # (B, H, K, K)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         s + uf[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, wf))
    state, out = jax.lax.scan(step, state, xs)
    return out.transpose(1, 0, 2, 3).astype(r.dtype), state


def wkv_chunked(r, k, v, w, u, state, *, chunk: int = 32):
    """Chunk-parallel WKV (exact).  Within each chunk of C tokens:

      decay_prod[t] = prod_{s<=t} w_s      (cumulative, exclusive of s=t? see below)
      S_in contribution:   out_t += r_t (prod_{s<t} w_s) . S_in
      intra-chunk:         out_t += sum_{j<t} r_t (prod_{j<s<t} w_s) k_j v_j^T
                                  + r_t (u*k_t) v_t^T
      state update:        S_out = (prod_all w) S_in + sum_j (prod_{s>j} w_s) k_j v_j^T
    """
    b, t, h, kk = r.shape
    c = min(chunk, t)
    if t % c:
        pad = c - t % c
        zeros = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        t_pad = t + pad
    else:
        t_pad = t
    n = t_pad // c

    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def reshape(a):
        return a.reshape(b, n, c, h, kk).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = (reshape(a) for a in (rf, kf, vf, wf))

    logw = jnp.log(jnp.clip(wc, 1e-30, 1.0))          # (n, B, C, H, K)
    cum = jnp.cumsum(logw, axis=2)                    # inclusive prefix sums

    tri = jnp.tril(jnp.ones((c, c), bool), -1)        # strict lower triangle

    def chunk_step(s, xs):
        rj, kj, vj, cum_j, logw_j = xs                # (B, C, H, K) each
        # All decay factors are exp(non-positive) — never overflow; the
        # factored w_excl/w_incl form does (EXPERIMENTS.md §Perf).
        ce = cum_j - logw_j                           # log prod_{s<t} w_s
        we = jnp.exp(ce)
        wt_ = jnp.exp(cum_j[:, -1:] - cum_j)          # prod_{s>t} w_s
        w_all = jnp.exp(cum_j[:, -1])                 # prod over whole chunk
        # Inter-chunk: r_t decayed against the carried state.
        inter = jnp.einsum("bchk,bhkv->bchv", rj * we, s)
        # Intra-chunk: pairwise decay in log space, masked BEFORE exp.
        delta = ce[:, :, None, :, :] - cum_j[:, None, :, :, :]  # (B,i,j,H,K)
        delta = jnp.where(tri[None, :, :, None, None], delta, -jnp.inf)
        decay = jnp.exp(delta)
        scores = jnp.einsum("bihk,bijhk,bjhk->bhij", rj, decay, kj)
        intra = jnp.einsum("bhcd,bdhv->bchv", scores, vj)
        diag = jnp.einsum("bchk,bchk,bchv->bchv",
                          rj, uf[None, None] * kj, vj)
        out = inter + intra + diag
        # State: S_out = (prod_all w) S_in + sum_j (prod_{s>j} w) k_j v_j^T
        s_new = w_all[..., :, None] * s + jnp.einsum(
            "bchk,bchv->bhkv", kj * wt_, vj)
        return s_new, out

    state, out = jax.lax.scan(
        chunk_step, state, (rc, kc, vc, cum, logw))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, t_pad, h, kk)
    return out[:, :t].astype(r.dtype), state


def timemix_apply(p, x, state_x, state_wkv, *, wkv_impl: str = "scan",
                  chunk: int = 32):
    """x: (B, T, D). state_x: (B, D) prev token; state_wkv: (B, H, K, K)."""
    b, t, d = x.shape
    h = d // HEAD_K
    sx = _token_shift(x, state_x) - x

    xw = x + sx * p["maa_base"]
    lora = jnp.tanh(layers.matmul(xw, p["tm_w1"]))            # (B,T,5*32)
    lora = lora.reshape(b, t, 5, LORA_MIX).transpose(2, 0, 1, 3)
    deltas = jnp.einsum("sbtl,sld->sbtd", lora.astype(jnp.float32),
                        p["tm_w2"].astype(jnp.float32)).astype(x.dtype)
    mixed = x[None] + sx[None] * (p["maa"][:, None, None, :] + deltas)
    xr, xk, xv, xw_, xg = mixed[0], mixed[1], mixed[2], mixed[3], mixed[4]

    r = layers.matmul(xr, p["wr"]).reshape(b, t, h, HEAD_K)
    k = layers.matmul(xk, p["wk"]).reshape(b, t, h, HEAD_K)
    v = layers.matmul(xv, p["wv"]).reshape(b, t, h, HEAD_K)
    g = jax.nn.silu(layers.matmul(xg, p["wg"]))

    dec = (p["w0"].astype(jnp.float32)
           + jnp.tanh(layers.matmul(xw_, p["wd1"])).astype(jnp.float32)
           @ p["wd2"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(dec)).reshape(b, t, h, HEAD_K)       # (0, 1)

    if wkv_impl == "scan":
        out, new_wkv = wkv_scan(r, k, v, w.astype(r.dtype), p["u"], state_wkv)
    elif wkv_impl == "chunked":
        out, new_wkv = wkv_chunked(r, k, v, w.astype(r.dtype), p["u"],
                                   state_wkv, chunk=chunk)
    else:
        raise ValueError(wkv_impl)

    out = _group_norm(p["ln_x"], out.reshape(b, t, d), h)
    out = layers.matmul(out * g, p["wo"])
    return out, x[:, -1, :], new_wkv


def channelmix_apply(p, x, state_x):
    sx = _token_shift(x, state_x) - x
    xk = x + sx * p["maa_k"]
    xr = x + sx * p["maa_r"]
    kk = jnp.square(jax.nn.relu(layers.matmul(xk, p["wk"])))
    kv = layers.matmul(kk, p["wv"])
    return jax.nn.sigmoid(layers.matmul(xr, p["wr"])) * kv, x[:, -1, :]


def init_rwkv_state(batch, d, *, dtype=jnp.float32):
    h = d // HEAD_K
    return {"tm_x": jnp.zeros((batch, d), dtype),
            "cm_x": jnp.zeros((batch, d), dtype),
            "wkv": jnp.zeros((batch, h, HEAD_K, HEAD_K), jnp.float32)}
