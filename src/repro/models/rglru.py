"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> [Wx -> conv1d(width 4) -> RG-LRU]  *  gelu(Wgate x) -> Wout.

RG-LRU (diagonal gated linear recurrence):
    r_t = sigmoid(x_t W_a + b_a)            recurrence gate
    i_t = sigmoid(x_t W_x + b_x)            input gate
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Because the recurrence is diagonal it is evaluated with
``jax.lax.associative_scan`` (log-depth, fully parallel) for sequences and a
single fused step for decode — this is the TPU-native adaptation (DESIGN.md
§2): the GPU reference implementation uses a sequential CUDA scan kernel.

Decode state per layer: dict(conv (B, W-1, rnn), h (B, rnn) f32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

RGLRU_C = 8.0


def init_recurrent_block(key, d_model, rnn_width, conv_width,
                         *, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(Lambda)^c is in (0.9, 0.999) — standard.
    u = jax.random.uniform(ks[0], (rnn_width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (-1.0 / RGLRU_C) - 1.0) * -1.0  # sigmoid^-1(u^(1/c))
    return {
        "w_in": layers.dense_init(ks[1], (d_model, rnn_width), dtype=dtype),
        "w_gate": layers.dense_init(ks[2], (d_model, rnn_width), dtype=dtype),
        "w_out": layers.dense_init(ks[3], (rnn_width, d_model), dtype=dtype),
        "conv_w": layers.dense_init(ks[4], (conv_width, rnn_width),
                                    scale=conv_width ** -0.5, dtype=dtype),
        "conv_b": jnp.zeros((rnn_width,), dtype),
        "wa": layers.dense_init(ks[5], (rnn_width, rnn_width), dtype=dtype),
        "ba": jnp.zeros((rnn_width,), dtype),
        "wx": layers.dense_init(jax.random.fold_in(key, 7),
                                (rnn_width, rnn_width), dtype=dtype),
        "bx": jnp.zeros((rnn_width,), dtype),
        "lam": lam.astype(jnp.float32),
    }


def _causal_conv1d(p, x, state):
    """Depthwise-ish causal conv (width W): y_t = sum_w x_{t-W+1+w} * conv_w[w].

    x: (B, T, R); state: (B, W-1, R) history (zeros at start).
    Returns (y, new_state).
    """
    wlen = p["conv_w"].shape[0]
    full = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, T+W-1, R)
    y = jnp.zeros_like(x)
    t = x.shape[1]
    for i in range(wlen):
        y = y + full[:, i:i + t, :] * p["conv_w"][i]
    y = y + p["conv_b"]
    new_state = full[:, -(wlen - 1):, :] if wlen > 1 else state
    return y, new_state


def rglru(p, x, h0):
    """x: (B, T, R); h0: (B, R) f32. Parallel associative scan over T."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(layers.matmul(xf, p["wa"].astype(jnp.float32))
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(layers.matmul(xf, p["wx"].astype(jnp.float32))
                       + p["bx"].astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r       # (B, T, R)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12, None)) * (i * xf)

    # h_t = a_t h_{t-1} + b_t with h_{-1} = h0: fold h0 into b_0.
    b = gated.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    del a_sc
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(p, x, h0):
    """Single decode step. x: (B, 1, R); h0: (B, R) f32."""
    xf = x[:, 0, :].astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32)
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["wx"].astype(jnp.float32)
                       + p["bx"].astype(jnp.float32))
    a = jnp.exp(-RGLRU_C * jax.nn.softplus(p["lam"]) * r)
    h = a * h0 + jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12, None)) * (i * xf)
    return h.astype(x.dtype)[:, None, :], h


def recurrent_block_apply(p, x, state, *, decode: bool = False):
    """x: (B, T, D) -> (B, T, D).  state: dict(conv, h)."""
    gate = jax.nn.gelu(layers.matmul(x, p["w_gate"]), approximate=True)
    xin = layers.matmul(x, p["w_in"])
    conv, conv_state = _causal_conv1d(p, xin, state["conv"])
    if decode:
        y, h = rglru_step(p, conv, state["h"])
    else:
        y, h = rglru(p, conv, state["h"])
    out = layers.matmul(y * gate, p["w_out"])
    return out, {"conv": conv_state, "h": h}


def init_recurrent_state(batch, rnn_width, conv_width, *, dtype=jnp.float32):
    return {"conv": jnp.zeros((batch, conv_width - 1, rnn_width), dtype),
            "h": jnp.zeros((batch, rnn_width), jnp.float32)}
