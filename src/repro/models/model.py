"""Model facade: uniform init/loss/prefill/decode_step/input_specs interface
over decoder-only and encoder-decoder families (selected by config)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._mod = encdec if cfg.is_encdec else transformer

    # -- parameters --------------------------------------------------------
    def init(self, rng):
        return self._mod.init_params(rng, self.cfg)

    def param_shapes(self):
        return jax.eval_shape(lambda k: self.init(k),
                              jax.random.PRNGKey(0))

    # -- steps --------------------------------------------------------------
    def loss_fn(self, params, batch, ctx):
        return self._mod.loss_fn(params, batch, self.cfg, ctx)

    def prefill(self, params, batch, ctx, *, max_len: int):
        if self.cfg.is_encdec:
            return encdec.prefill(params, batch["frames"], batch["tokens"],
                                  self.cfg, ctx, max_len=max_len)
        return transformer.prefill(params, batch["tokens"], self.cfg, ctx,
                                   max_len=max_len)

    def decode_step(self, params, token, caches, ctx):
        return self._mod.decode_step(params, token, caches, self.cfg, ctx)

    def init_caches(self, batch: int, max_len: int):
        if self.cfg.is_encdec:
            # (self-attn caches, cross caches) — shapes via eval_shape users.
            raise NotImplementedError(
                "enc-dec caches come from prefill(); see decode_specs()")
        return transformer.init_caches(self.cfg, batch, max_len)

    # -- dry-run input specs (ShapeDtypeStruct stand-ins, no allocation) ----
    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        dt = jnp.dtype(cfg.dtype)

        if shape.kind == "train":
            batch = {"inputs": sds((b, s), i32), "targets": sds((b, s), i32),
                     "mask": sds((b, s), jnp.float32)}
            if cfg.is_encdec:
                batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), dt)
            return batch

        if shape.kind == "prefill":
            batch = {"tokens": sds((b, s), i32)}
            if cfg.is_encdec:
                batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), dt)
            return batch

        if shape.kind == "decode":
            # One new token against a cache of seq_len tokens.
            if cfg.is_encdec:
                caches = _encdec_cache_specs(cfg, b, s)
            else:
                caches = jax.eval_shape(
                    lambda: transformer.init_caches(cfg, b, s))
            return {"token": sds((b, 1), i32), "caches": caches}

        raise ValueError(shape.kind)


def _encdec_cache_specs(cfg: ModelConfig, b: int, max_len: int):
    from repro.models import attention
    sds = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.dtype)
    ld = cfg.num_layers
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    self_caches = attention.KVCache(
        sds((ld, b, max_len, hk, hd), dt), sds((ld, b, max_len, hk, hd), dt),
        sds((ld,), jnp.int32))
    cross = attention.KVCache(
        sds((ld, b, cfg.encoder_seq, hk, hd), dt),
        sds((ld, b, cfg.encoder_seq, hk, hd), dt),
        sds((ld,), jnp.int32))
    return (self_caches, cross)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
