"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the brief, the conv/mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, D).  Positions are sinusoidal on both
sides (whisper's decoder uses learned positions up to 448; we substitute
sinusoidal so assigned shapes up to 32k decode positions need no parameter
resizing — noted in DESIGN.md §4).

Encoder: bidirectional attention + GELU MLP.  Decoder: causal self-attention
(+KV cache) + cross-attention against cached encoder K/V + GELU MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers


def _spec(cfg: ModelConfig, *, causal: bool) -> attention.AttnSpec:
    return attention.AttnSpec(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        rope_theta=None, qkv_bias=cfg.qkv_bias, causal=causal)


def sinusoidal(positions, d):
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_block(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2)
    return {"norm1": layers.layernorm_init(cfg.d_model, dtype=dt),
            "attn": attention.init_attention(ks[0], _spec(cfg, causal=False),
                                             dtype=dt),
            "norm2": layers.layernorm_init(cfg.d_model, dtype=dt),
            "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu",
                                   dtype=dt)}


def _init_dec_block(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {"norm1": layers.layernorm_init(cfg.d_model, dtype=dt),
            "self_attn": attention.init_attention(
                ks[0], _spec(cfg, causal=True), dtype=dt),
            "norm2": layers.layernorm_init(cfg.d_model, dtype=dt),
            "cross_attn": attention.init_attention(
                ks[1], _spec(cfg, causal=False), dtype=dt),
            "norm3": layers.layernorm_init(cfg.d_model, dtype=dt),
            "mlp": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, "gelu",
                                   dtype=dt)}


def init_params(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_enc, k_dec = jax.random.split(key, 3)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": layers.embed_init(k_embed, cfg.padded_vocab, cfg.d_model,
                                   dtype=dt),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "enc_final_norm": layers.layernorm_init(cfg.d_model, dtype=dt),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "final_norm": layers.layernorm_init(cfg.d_model, dtype=dt),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, S_enc, D) stub embeddings -> encoder output."""
    s = frames.shape[1]
    x = frames + sinusoidal(jnp.arange(s), cfg.d_model).astype(frames.dtype)
    spec = _spec(cfg, causal=False)

    def body(h, p):
        a = attention.apply_attention(
            p["attn"], layers.layernorm(p["norm1"], h), spec=spec)
        h = h + a
        h = h + layers.mlp_apply(p["mlp"],
                                 layers.layernorm(p["norm2"], h), "gelu")
        return h, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.layernorm(params["enc_final_norm"], x)


def _dec_block(p, x, cfg, enc_out=None, *, self_cache=None, cross_cache=None,
               decode=False):
    spec_self = _spec(cfg, causal=True)
    spec_cross = _spec(cfg, causal=False)
    h = layers.layernorm(p["norm1"], x)
    if self_cache is None:
        a = attention.apply_attention(p["self_attn"], h, spec=spec_self)
        new_self = None
    elif decode:
        a, new_self = attention.decode_attention(p["self_attn"], h,
                                                 self_cache, spec=spec_self)
    else:
        a, new_self = attention.prefill_attention(p["self_attn"], h,
                                                  self_cache, spec=spec_self)
    x = x + a
    h = layers.layernorm(p["norm2"], x)
    if decode:
        c, _ = attention.decode_attention(p["cross_attn"], h, self_cache,
                                          spec=spec_cross,
                                          kv_src_cache=cross_cache)
    else:
        c = attention.apply_attention(p["cross_attn"], h, kv_src=enc_out,
                                      spec=spec_cross)
    x = x + c
    h = layers.layernorm(p["norm3"], x)
    x = x + layers.mlp_apply(p["mlp"], h, "gelu")
    return x, new_self


def loss_fn(params, batch, cfg: ModelConfig, ctx):
    """batch: frames (B,S_enc,D), inputs/targets/mask (B,S_dec)."""
    enc_out = encode(params, batch["frames"], cfg)
    s = batch["inputs"].shape[1]
    x = layers.embed_apply(params["embed"], batch["inputs"])
    x = x + sinusoidal(jnp.arange(s), cfg.d_model).astype(x.dtype)

    def body(h, p):
        h, _ = _dec_block(p, h, cfg, enc_out)
        return h, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = layers.layernorm(params["final_norm"], x)
    ce = layers.chunked_softmax_xent(
        x, params["embed"]["embedding"].T, batch["targets"], batch["mask"],
        valid_vocab=cfg.vocab_size)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def make_cross_caches(params, enc_out, cfg: ModelConfig):
    """Precompute per-layer cross-attention K/V from encoder output."""
    spec = _spec(cfg, causal=False)
    b, s, _ = enc_out.shape

    def one(p):
        k = layers.matmul(enc_out, p["cross_attn"]["wk"])
        v = layers.matmul(enc_out, p["cross_attn"]["wv"])
        if cfg.qkv_bias:
            k = k + p["cross_attn"]["bk"]
            v = v + p["cross_attn"]["bv"]
        k = k.reshape(b, s, spec.num_kv_heads, spec.head_dim)
        v = v.reshape(b, s, spec.num_kv_heads, spec.head_dim)
        return attention.KVCache(k, v, jnp.asarray(s, jnp.int32))

    return jax.vmap(one)(params["dec_blocks"])


def prefill(params, frames, tokens, cfg: ModelConfig, ctx, *, max_len: int):
    enc_out = encode(params, frames, cfg)
    cross = make_cross_caches(params, enc_out, cfg)
    b, s = tokens.shape
    x = layers.embed_apply(params["embed"], tokens)
    x = x + sinusoidal(jnp.arange(s), cfg.d_model).astype(x.dtype)
    self0 = jax.vmap(
        lambda _: attention.init_cache(b, max_len, _spec(cfg, causal=True),
                                       dtype=jnp.dtype(cfg.dtype))
    )(jnp.arange(cfg.num_layers))

    def body(h, xs):
        p, sc = xs
        h, new_sc = _dec_block(p, h, cfg, enc_out, self_cache=sc,
                               decode=False)
        return h, new_sc

    x, self_caches = jax.lax.scan(body, x, (params["dec_blocks"], self0))
    x = layers.layernorm(params["final_norm"], x[:, -1:, :])
    logits = layers.unembed(params["embed"], x)
    return logits, (self_caches, cross)


def decode_step(params, token, caches, cfg: ModelConfig, ctx):
    self_caches, cross = caches
    b = token.shape[0]
    pos = self_caches.length[0]
    x = layers.embed_apply(params["embed"], token)
    x = x + sinusoidal(pos[None].astype(jnp.int32),
                       cfg.d_model).astype(x.dtype)

    def body(h, xs):
        p, sc, cc = xs
        h, new_sc = _dec_block(p, h, cfg, self_cache=sc, cross_cache=cc,
                               decode=True)
        return h, new_sc

    x, self_caches = jax.lax.scan(body, x,
                                  (params["dec_blocks"], self_caches, cross))
    x = layers.layernorm(params["final_norm"], x)
    logits = layers.unembed(params["embed"], x)
    return logits, (self_caches, cross)
