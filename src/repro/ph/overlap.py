"""Overlap-engine primitives: async D2H streaming, transfer counters,
deferred results.

The synchronous transfer path serializes four things per dispatch: host
staging, H2D transfer, device compute, and the D2H readback that the
overflow check (``bool(np.any(np.asarray(d.overflow)))``) forces.  The
overlap engine (``PHConfig.overlap`` / :class:`repro.ph.OverlapSpec`)
breaks that chain; this module holds the pieces every layer shares:

* :func:`start_d2h` — begin asynchronous device->host copies on every
  ``jax.Array`` leaf of a pytree (``copy_to_host_async``), so a later
  ``np.asarray`` drains an in-flight copy instead of starting a blocking
  one.  Results and their packed overflow scalar start streaming the
  moment the dispatch returns.
* :class:`OverlapCounters` — thread-safe counters the benchmarks and the
  perf gate read: H2D transfer calls, D2H streams started, blocking
  syncs on the **dispatch** path (must be zero in steady state with
  overlap on — the PR 6 ``steady_state_traces == 0`` pattern), blocking
  syncs on the harvest path (where they belong), and donation replays
  (re-staging after the rare overflow consumed a donated buffer).
* :class:`PendingResult` — a deferred computation handle whose
  ``resolve()`` is memoized and thread-safe (the dispatch thread and a
  harvest thread may race the first resolve).

Nothing here changes numerics: every overlapped path resolves to exactly
the bytes the synchronous path produces — overflow/regrow semantics are
deferred, not altered.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

import jax

__all__ = ["OverlapCounters", "PendingResult", "start_d2h"]


class OverlapCounters:
    """Thread-safe transfer/sync counters for the overlap engine.

    ``h2d_transfers``
        ``jax.device_put`` calls issued by staging (a fused batch +
        thresholds put counts once — the point of fusing them).
    ``d2h_streams``
        async device->host copy groups started (one per dispatch whose
        results were streamed).
    ``dispatch_syncs``
        blocking device readbacks performed on the *dispatch* thread
        (the pipeline driver loop / serving tick).  The overlap engine's
        contract is that this stays **zero** in steady state; the bench
        records it per round and the perf gate asserts it.
    ``harvest_syncs``
        blocking readbacks performed where they are free — on a harvest
        thread (or inside an explicit ``resolve()``).
    ``donation_replays``
        regrow replays that had to re-stage a consumed (donated) input
        buffer from its retained host copy.
    """

    FIELDS = ("h2d_transfers", "d2h_streams", "dispatch_syncs",
              "harvest_syncs", "donation_replays")

    def __init__(self):
        self._lock = threading.Lock()
        for f in self.FIELDS:
            setattr(self, f, 0)

    def bump(self, field: str, k: int = 1) -> None:
        if field not in self.FIELDS:
            raise ValueError(f"unknown overlap counter {field!r}")
        with self._lock:
            setattr(self, field, getattr(self, field) + k)

    def snapshot(self) -> dict:
        with self._lock:
            return {f: getattr(self, f) for f in self.FIELDS}


def start_d2h(tree: Any, counters: OverlapCounters | None = None) -> Any:
    """Begin async device->host copies for every ``jax.Array`` leaf.

    Host (numpy) leaves are untouched; the tree is returned as-is so the
    call composes inline: ``start_d2h(plan(x))``.  A later
    ``np.asarray`` on a leaf then waits only for its in-flight copy —
    never for a newly scheduled one — which is what lets the overflow
    check and the diagram fetch ride the same stream.
    """
    started = False
    for leaf in jax.tree.leaves(tree):
        begin = getattr(leaf, "copy_to_host_async", None)
        if begin is not None:
            begin()
            started = True
    if started and counters is not None:
        counters.bump("d2h_streams")
    return tree


class PendingResult:
    """A deferred result: ``resolve()`` runs ``finish`` exactly once
    (memoized, thread-safe) and returns its value thereafter.

    ``finish`` performs whatever blocking work the dispatch path
    deferred — the overflow check, the regrow-and-replay loop, host
    materialization/repair — so callers choose *where* that blocking
    happens (inline for the synchronous API, a harvest thread for the
    overlapped one).  An exception raised by ``finish`` is re-raised on
    every subsequent ``resolve()``.
    """

    __slots__ = ("_finish", "_lock", "_done", "_value", "_exc")

    def __init__(self, finish: Callable[[], Any]):
        self._finish = finish
        self._lock = threading.Lock()
        self._done = False
        self._value = None
        self._exc: BaseException | None = None

    def resolve(self) -> Any:
        with self._lock:
            if not self._done:
                try:
                    self._value = self._finish()
                except BaseException as exc:
                    self._exc = exc
                finally:
                    self._done = True
                    self._finish = None     # drop closed-over buffers
            if self._exc is not None:
                raise self._exc
            return self._value
