"""Typed, frozen configuration for the PH engine (the single public knob set).

Every capacity, mode string, and backend toggle that used to travel as raw
kwargs through ``pixhomology`` and the pre-engine pipeline entry points
lives here exactly once.  ``PHConfig`` is hashable, so it can key compiled-plan
caches directly, and JSON round-trippable, so launch scripts and work logs
can persist the exact configuration of a run.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Any

from repro.core.packed_keys import (  # noqa: F401  (single source)
    FILTRATIONS,
    MERGE_KEYS,
)

CANDIDATE_MODES = ("exact", "paper")
HASH_ALGOS = ("blake2b", "sha1", "md5")
MERGE_IMPLS = ("scan", "boruvka")
PHASE_A_IMPLS = ("fused", "pooled")
PHASE_C_IMPLS = ("fused", "xla")
DTYPES = (None, "float32", "float64", "int32", "bfloat16")
BUCKET_ROUNDINGS = ("exact", "pow2")
ADMISSION_POLICIES = ("reject", "block")


def parse_grid(value) -> tuple[int, int]:
    """Parse a tile grid from its CLI form (``"2x4"``) or a pair."""
    if isinstance(value, str):
        parts = value.lower().split("x")
        if len(parts) != 2:
            raise ValueError(f"grid must look like 'RxC', got {value!r}")
        return tuple(int(x) for x in parts)
    return tuple(value)


@dataclasses.dataclass(frozen=True)
class TileSpec:
    """Tile-decomposition policy for oversized images (halo-tiled PH).

    ``grid=None`` lets the engine pick the smallest dividing grid whose
    tiles hold at most ``max_tile_pixels`` pixels; ``max_tile_pixels`` also
    doubles as the routing threshold — ``run_distributed``/the pipeline send
    images larger than it through :meth:`repro.ph.PHEngine.run_tiled`
    instead of the whole-image path.  Per-tile capacities regrow on tile
    overflow (ceiling: the tile pixel count); the global diagram capacity
    (``PHConfig.max_features``) regrows separately on seam-merge overflow.
    """

    grid: tuple[int, int] | None = None    # (gr, gc); None = auto
    halo: int = 1                          # only 1 is supported (3x3 stencil)
    max_features_per_tile: int = 2048
    max_candidates_per_tile: int = 8192
    max_tile_pixels: int = 1 << 20         # auto-grid budget + routing bound

    def __post_init__(self):
        if isinstance(self.grid, list):
            object.__setattr__(self, "grid", tuple(self.grid))
        if self.grid is not None:
            g = self.grid
            if (len(g) != 2 or not all(isinstance(x, int) and x >= 1
                                       for x in g)):
                raise ValueError(f"grid must be (gr, gc) of ints >= 1, "
                                 f"got {self.grid!r}")
        if self.halo != 1:
            raise ValueError(f"only halo=1 is supported (3x3 stencil), "
                             f"got {self.halo}")
        for field in ("max_features_per_tile", "max_candidates_per_tile",
                      "max_tile_pixels"):
            v = getattr(self, field)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{field} must be a positive int, got {v!r}")

    def replace(self, **changes) -> "TileSpec":
        return dataclasses.replace(self, **changes)

    def plan_fields(self) -> tuple:
        """The fields that affect compiled tiled executables (capacities
        are keyed separately by the engine, like max_features)."""
        return (self.grid, self.halo)


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Serving-daemon policy for :class:`repro.serving.PHServer`.

    ``buckets`` is the fixed bucket set the daemon batches into (each
    entry a square size or an ``(H, W)`` pair); ``None`` derives a bucket
    per request shape via ``PHConfig.bucket_rounding`` (plans then trace
    on first use instead of at :meth:`repro.ph.PHEngine.warmup`).  Every
    dispatch runs at the fixed batch shape ``(batch_cap, H, W)`` — short
    ticks pad free rows by repeating a real request — so one warmed plan
    per bucket serves every steady-state tick.

    ``max_queue`` bounds the *per-bucket* pending-request depth; at the
    bound, admission follows ``admission``: ``"reject"`` raises
    :class:`repro.serving.AdmissionError` (carrying a ``retry_after_s``
    hint), ``"block"`` makes ``submit`` wait for a slot (backpressure
    propagates to the caller).  ``tick_interval_s`` is the coalescing
    window: a dispatch leaves once its bucket reaches ``batch_cap``
    requests or the oldest pending request has waited one tick.
    """

    buckets: tuple[tuple[int, int], ...] | None = None
    batch_cap: int = 4
    max_queue: int = 64
    tick_interval_s: float = 0.002
    admission: str = "reject"

    def __post_init__(self):
        if self.buckets is not None:
            norm = []
            for b in self.buckets:
                if isinstance(b, (int,)):
                    b = (b, b)
                b = tuple(int(x) for x in b)
                if len(b) != 2 or not all(x >= 1 for x in b):
                    raise ValueError(f"bucket must be a size or (H, W) of "
                                     f"ints >= 1, got {b!r}")
                norm.append(b)
            if len(set(norm)) != len(norm):
                raise ValueError(f"duplicate serve buckets in {norm}")
            # Smallest-first, so bucket assignment picks the tightest fit.
            object.__setattr__(self, "buckets",
                               tuple(sorted(norm,
                                            key=lambda s: (s[0] * s[1], s))))
        for field in ("batch_cap", "max_queue"):
            v = getattr(self, field)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{field} must be a positive int, got {v!r}")
        if not (isinstance(self.tick_interval_s, (int, float))
                and self.tick_interval_s >= 0):
            raise ValueError(f"tick_interval_s must be >= 0, "
                             f"got {self.tick_interval_s!r}")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(f"admission must be one of "
                             f"{ADMISSION_POLICIES}, got {self.admission!r}")

    def replace(self, **changes) -> "ServeSpec":
        return dataclasses.replace(self, **changes)

    def plan_fields(self) -> tuple:
        """The fields that decide compiled batch shapes: the bucket set
        and the fixed dispatch batch size.  Queue depth, tick interval,
        and admission policy are host-side scheduling and excluded (like
        ``prefetch_rounds``)."""
        return (self.buckets, self.batch_cap)


@dataclasses.dataclass(frozen=True)
class OverlapSpec:
    """Host<->device overlap policy (the PR 9 overlap engine).

    With a spec, the transfer/compute/fetch path pipelines instead of
    serializing:

    * **staging ring** — the pipeline's loader thread stages round r+1's
      batch *and* thresholds in one fused ``jax.device_put`` while round
      r computes; ``staging_depth`` bounds how many device-staged rounds
      (and unresolved result rounds) may be in flight at once;
    * **donation** (``donate``) — staged bucket batches are donated to
      the compiled program (``donate_argnums``), so XLA reuses the input
      buffer for outputs instead of allocating a fresh one per round.
      Donated inputs are consumed; the rare regrow replay re-stages from
      the retained host copy (bit-identical, just a second transfer);
    * **async overflow** (``async_overflow``) — dispatch starts an async
      device->host copy of the packed overflow scalar (and the diagram)
      instead of blocking on it, so the next round can be staged and
      dispatched speculatively; the overflow check happens at harvest
      time and fires the existing regrow-and-replay only when true;
    * **async harvest** (``async_harvest``) — result materialization
      (``np.asarray`` of the diagram) is drained by a harvest thread, so
      the dispatch thread (pipeline driver / serving tick) never blocks
      on device results.

    Every overlapped path is bit-identical to the synchronous one —
    overflow semantics are unchanged, only deferred.
    """

    enabled: bool = True
    staging_depth: int = 2
    donate: bool = True
    async_overflow: bool = True
    async_harvest: bool = True

    def __post_init__(self):
        if not isinstance(self.staging_depth, int) or self.staging_depth < 1:
            raise ValueError(f"staging_depth must be a positive int, "
                             f"got {self.staging_depth!r}")
        for field in ("enabled", "donate", "async_overflow", "async_harvest"):
            v = getattr(self, field)
            if not isinstance(v, bool):
                raise ValueError(f"{field} must be a bool, got {v!r}")

    def replace(self, **changes) -> "OverlapSpec":
        return dataclasses.replace(self, **changes)

    def plan_fields(self) -> tuple:
        """``donate`` selects compiled executables (input/output buffer
        aliasing); ring depth and the async toggles are host-side
        scheduling, like ``prefetch_rounds``."""
        return (self.enabled, self.donate)


@dataclasses.dataclass(frozen=True)
class DeltaSpec:
    """Delta-recompute / frame-cache policy (:meth:`PHEngine.run_delta`).

    Consecutive survey frames differ in a few regions; with a delta spec
    the engine keeps a bounded LRU of per-frame tiled state
    (:class:`repro.cache.DiagramCache`), classifies tiles clean/dirty by a
    per-tile content hash over the halo-padded tile bytes (``hash_algo``),
    and recomputes phase A/B only for dirty tiles before replaying the
    O(boundary) seam merge — bit-identical to a cold
    :meth:`PHEngine.run_tiled`.  An identical frame short-circuits to the
    cached diagram without touching the device.

    ``cache_entries`` bounds the number of retained frame entries (each
    holds device-resident :class:`repro.core.tiling.TileBoundaryState`,
    so the budget is real memory).  ``verify`` is the paranoid mode:
    entries additionally keep the raw tile bytes and every clean
    classification is byte-compared, so a hash collision is *detected*
    (the tile is reclassified dirty and counted) instead of trusted.
    """

    enabled: bool = True
    cache_entries: int = 4
    hash_algo: str = "blake2b"
    verify: bool = False

    def __post_init__(self):
        if not isinstance(self.cache_entries, int) or self.cache_entries < 1:
            raise ValueError(f"cache_entries must be a positive int, "
                             f"got {self.cache_entries!r}")
        if self.hash_algo not in HASH_ALGOS:
            raise ValueError(f"hash_algo must be one of {HASH_ALGOS}, "
                             f"got {self.hash_algo!r}")

    def replace(self, **changes) -> "DeltaSpec":
        return dataclasses.replace(self, **changes)

    def plan_fields(self) -> tuple:
        """Only ``enabled`` selects compiled programs (the split
        phase-AB / scatter-merge pair vs the fused cold plan); cache
        depth, hash algorithm, and verify are host-side policy."""
        return (self.enabled,)


class FilterLevel(str, enum.Enum):
    """Variant-2 background filtering level (paper Table 1)."""

    VANILLA = "vanilla"            # no filtering
    LIGHT = "filter_light"         # 0.3 x (median + 2 MAD-sigma)
    STD = "filter_std"             # 1.0 x
    HEAVY = "filter_heavy"         # 1.3 x

    def __str__(self) -> str:  # argparse/json friendliness
        return self.value


@dataclasses.dataclass(frozen=True)
class PHConfig:
    """Frozen configuration of one PH computation family.

    Capacity fields (``max_features``, ``max_candidates``) are *initial*
    capacities: with ``auto_regrow`` on, the engine doubles them on overflow
    up to ``regrow_*_ceiling`` (``None`` = the image pixel count, at which
    overflow is impossible) at most ``max_regrows`` times.
    """

    # Diagram / merge-sweep capacities (static shapes; padded).
    max_features: int = 8192
    max_candidates: int = 32768
    # Filtration direction: "superlevel" (births at maxima — the paper's
    # astronomical-source workload) or "sublevel" (births at minima;
    # floating dtypes only).  Implemented as an exact boundary negation,
    # so sublevel(x) is bit-identical to superlevel(-x) with flipped
    # signs; part of stage_signature()/plan_key — plans and delta-cache
    # entries never cross filtrations.
    filtration: str = "superlevel"         # "superlevel" | "sublevel"
    # Algorithm variants / stage implementations (the stage graph: phase A
    # pointers+flags, phase B label resolution, phase C merge — every
    # combination is bit-identical, only the compiled program changes).
    candidate_mode: str = "exact"          # "exact" | "paper"
    merge_impl: str = "scan"               # "scan" | "boruvka"
    # Phase-C total-order keys: "packed" bit-casts (value, index) into
    # monotone int64 keys (no full-image argsort anywhere; needs a <= 32-bit
    # dtype and an int64 scope, else it resolves to the fallback), "rank"
    # materializes dense argsort ranks.  Bit-identical either way.
    merge_keys: str = "packed"             # "packed" | "rank"
    # phase_a_impl "fused": the repro.kernels.ph_phase_a kernel (Pallas on
    # TPU per use_pallas, its XLA reference elsewhere) + compacted-frontier
    # phase B.  "pooled": the unfused three-pooled-pass baseline + dense
    # whole-image doubling.
    phase_a_impl: str = "fused"            # "fused" | "pooled"
    # Strip height of the fused phase-A kernel (= its Pallas block rows and
    # the frontier compaction factor: the frontier is ~2/strip_rows of n).
    strip_rows: int = 8
    # phase_c_impl "fused": the repro.kernels.ph_phase_c compact merge —
    # Boruvka over the top-max_features root instance with the blocked
    # per-basin reduction (Pallas on TPU per use_pallas, its XLA reference
    # elsewhere).  "xla": the plain full-image Boruvka / scan merge.  Only
    # consulted when merge_impl="boruvka" (the scan merge has no phase-C
    # kernel); bit-identical either way.
    phase_c_impl: str = "fused"            # "fused" | "xla"
    # Edge-block size of the fused phase-C reduction (edges streamed per
    # Pallas grid step; the per-basin accumulator stays in VMEM).
    phase_c_block: int = 1024
    # Blockwise tournament width of the phase-C top-k selections (each
    # round keeps top-k of width*k candidates; any width >= 2 is
    # bit-identical — the autotuner picks it per shape).
    tournament_width: int = 2
    # Autotuning: look up (strip_rows, phase_c_block, tournament_width)
    # per (shape, dtype, backend) from the roofline autotuner's disk cache
    # (repro.roofline.autotune); missing entries fall back to the fields
    # above.  autotune_cache=None uses the default cache path.
    autotune: bool = False
    autotune_cache: str | None = None
    filter_level: FilterLevel = FilterLevel.VANILLA
    # Dtype policy: cast inputs before compute (None = keep input dtype).
    dtype: str | None = None
    # Backend toggles (forwarded to the maxpool kernels).
    use_pallas: bool | None = None
    interpret: bool = False
    # Overflow auto-regrow policy.
    auto_regrow: bool = True
    regrow_factor: int = 2
    max_regrows: int = 8
    regrow_features_ceiling: int | None = None
    regrow_candidates_ceiling: int | None = None
    # Tile decomposition for oversized images (None = whole-image only).
    tile: TileSpec | None = None
    # Streaming heterogeneous-batch pipeline knobs.
    # bucket_rounding: how per-round shape buckets are formed from a mixed
    # dataset — "pow2" pads each dim up to the next power of two (few
    # compiled plans, images padded with -inf below the Variant-2
    # threshold), "exact" gives every distinct shape its own bucket (no
    # padding; what VANILLA rounds always use, since padding is only exact
    # under a finite threshold).
    bucket_rounding: str = "pow2"
    # prefetch_rounds: rounds the driver's background loader may stage
    # ahead of the computing round (0 = fully serial load->compute).
    prefetch_rounds: int = 1
    # Serving-daemon policy (None = engine not used for serving).  The
    # bucket set and batch cap decide which padded batch shapes compile
    # (and which plans PHEngine.warmup pre-traces); queue depth / tick /
    # admission are host-side.
    serve: ServeSpec | None = None
    # Delta-recompute policy for frame sequences (None = every run cold).
    # With a spec, run_delta/run_sequence hash tiles against a bounded LRU
    # frame cache and recompute only dirty tiles; the serving daemon adds
    # its exact-hash / near-duplicate cache tier on top.
    delta: DeltaSpec | None = None
    # Host<->device overlap policy (None = fully synchronous transfers).
    # With a spec, staging/compute/fetch pipeline: fused H2D staging with
    # buffer donation, deferred (async) overflow checks with speculative
    # dispatch, and a harvest thread draining async D2H result copies.
    overlap: OverlapSpec | None = None

    def __post_init__(self):
        if isinstance(self.filter_level, str) and \
                not isinstance(self.filter_level, FilterLevel):
            object.__setattr__(self, "filter_level",
                               FilterLevel(self.filter_level))
        if isinstance(self.tile, dict):
            object.__setattr__(self, "tile", TileSpec(**self.tile))
        if self.tile is not None and not isinstance(self.tile, TileSpec):
            raise ValueError(f"tile must be a TileSpec or None, "
                             f"got {type(self.tile).__name__}")
        if isinstance(self.serve, dict):
            object.__setattr__(self, "serve", ServeSpec(**self.serve))
        if self.serve is not None and not isinstance(self.serve, ServeSpec):
            raise ValueError(f"serve must be a ServeSpec or None, "
                             f"got {type(self.serve).__name__}")
        if isinstance(self.delta, dict):
            object.__setattr__(self, "delta", DeltaSpec(**self.delta))
        if self.delta is not None and not isinstance(self.delta, DeltaSpec):
            raise ValueError(f"delta must be a DeltaSpec or None, "
                             f"got {type(self.delta).__name__}")
        if isinstance(self.overlap, dict):
            object.__setattr__(self, "overlap", OverlapSpec(**self.overlap))
        if self.overlap is not None and \
                not isinstance(self.overlap, OverlapSpec):
            raise ValueError(f"overlap must be an OverlapSpec or None, "
                             f"got {type(self.overlap).__name__}")
        if self.filtration not in FILTRATIONS:
            raise ValueError(f"filtration must be one of {FILTRATIONS}, "
                             f"got {self.filtration!r}")
        if self.filtration == "sublevel" and self.dtype in ("int32",):
            raise ValueError(
                "filtration='sublevel' requires a floating dtype "
                "(integer negation overflows at the minimum); pick a "
                "float dtype or leave dtype=None with float inputs")
        if self.candidate_mode not in CANDIDATE_MODES:
            raise ValueError(f"candidate_mode must be one of "
                             f"{CANDIDATE_MODES}, got {self.candidate_mode!r}")
        if self.merge_impl not in MERGE_IMPLS:
            raise ValueError(f"merge_impl must be one of {MERGE_IMPLS}, "
                             f"got {self.merge_impl!r}")
        if self.merge_keys not in MERGE_KEYS:
            raise ValueError(f"merge_keys must be one of {MERGE_KEYS}, "
                             f"got {self.merge_keys!r}")
        if self.phase_a_impl not in PHASE_A_IMPLS:
            raise ValueError(f"phase_a_impl must be one of {PHASE_A_IMPLS}, "
                             f"got {self.phase_a_impl!r}")
        if not isinstance(self.strip_rows, int) or self.strip_rows < 1:
            raise ValueError(f"strip_rows must be a positive int, "
                             f"got {self.strip_rows!r}")
        if self.phase_c_impl not in PHASE_C_IMPLS:
            raise ValueError(f"phase_c_impl must be one of {PHASE_C_IMPLS}, "
                             f"got {self.phase_c_impl!r}")
        if not isinstance(self.phase_c_block, int) or self.phase_c_block < 1:
            raise ValueError(f"phase_c_block must be a positive int, "
                             f"got {self.phase_c_block!r}")
        if not isinstance(self.tournament_width, int) or \
                self.tournament_width < 2:
            raise ValueError(f"tournament_width must be an int >= 2, "
                             f"got {self.tournament_width!r}")
        if self.dtype not in DTYPES:
            raise ValueError(f"dtype must be one of {DTYPES}, "
                             f"got {self.dtype!r}")
        if self.bucket_rounding not in BUCKET_ROUNDINGS:
            raise ValueError(f"bucket_rounding must be one of "
                             f"{BUCKET_ROUNDINGS}, "
                             f"got {self.bucket_rounding!r}")
        if not isinstance(self.prefetch_rounds, int) or \
                self.prefetch_rounds < 0:
            raise ValueError(f"prefetch_rounds must be an int >= 0, "
                             f"got {self.prefetch_rounds!r}")
        for field in ("max_features", "max_candidates", "regrow_factor"):
            v = getattr(self, field)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{field} must be a positive int, got {v!r}")
        if self.regrow_factor < 2:
            raise ValueError("regrow_factor must be >= 2")
        if self.max_regrows < 0:
            raise ValueError("max_regrows must be >= 0")
        if self.regrow_features_ceiling is not None and \
                self.regrow_features_ceiling < self.max_features:
            raise ValueError("regrow_features_ceiling < max_features")
        if self.regrow_candidates_ceiling is not None and \
                self.regrow_candidates_ceiling < self.max_candidates:
            raise ValueError("regrow_candidates_ceiling < max_candidates")

    # -- derived ----------------------------------------------------------

    def replace(self, **changes) -> "PHConfig":
        return dataclasses.replace(self, **changes)

    def stage_signature(self) -> tuple:
        """The stage-graph implementation choice, one tuple per stage.

        Phase A (pointer/flag generation + its strip height and backend),
        phase B (label resolution follows phase A: compacted frontier for
        "fused", dense doubling for "pooled"), phase C (merge reduction).
        Every signature computes bit-identical diagrams; the signature
        keys *compiled programs*, so it is embedded in :meth:`plan_key`.
        """
        return (("a", self.phase_a_impl, self.strip_rows, self.use_pallas,
                 self.interpret, self.filtration),
                ("b", "frontier" if self.phase_a_impl == "fused"
                 else "dense", self.candidate_mode),
                ("c", self.merge_impl, self.merge_keys, self.phase_c_impl,
                 self.phase_c_block, self.tournament_width))

    def plan_key(self) -> tuple:
        """The config fields that affect *compiled executables*.

        Regrow policy, filter level, and ``prefetch_rounds`` are host-side
        decisions and are deliberately excluded (plan caches are
        per-:class:`PHEngine`, so share one engine to reuse plans across
        those knobs).  The :meth:`stage_signature` is included — it selects
        the compiled stage programs; ``bucket_rounding`` is included — it
        decides which padded batch shapes get compiled.  Capacities are
        passed separately by the engine (regrow re-dispatches at larger
        capacities under the same config).
        """
        return (self.stage_signature(), self.dtype, self.bucket_rounding,
                self.tile.plan_fields() if self.tile is not None else None,
                self.serve.plan_fields() if self.serve is not None else None,
                self.delta.plan_fields() if self.delta is not None else None,
                self.overlap.plan_fields() if self.overlap is not None
                else None)

    # -- construction / serialization -------------------------------------

    @classmethod
    def from_flags(cls, args: Any, **overrides) -> "PHConfig":
        """Build from an argparse ``Namespace`` (or any attribute bag).

        Recognized attributes (all optional): ``max_features``,
        ``max_candidates``, ``candidate_mode``, ``filtration``,
        ``merge_impl``,
        ``merge_keys``, ``phase_a_impl``, ``strip_rows``,
        ``filter`` or ``filter_level``,
        ``dtype``, ``use_pallas``, ``interpret``,
        ``no_regrow``/``auto_regrow``, ``max_regrows``,
        ``bucket_rounding``, ``prefetch_rounds``/``no_prefetch``; serving:
        ``serve`` (bool), ``serve_buckets`` (sizes or ``"HxW"`` strings),
        ``serve_batch_cap``, ``serve_max_queue``, ``serve_tick_ms``,
        ``serve_admission``; overlap: ``overlap`` (bool),
        ``overlap_depth``, ``no_donate``, ``no_async_overflow``,
        ``no_async_harvest``.
        """
        kw: dict[str, Any] = {}
        for name in ("max_features", "max_candidates", "candidate_mode",
                     "filtration", "merge_impl", "merge_keys", "phase_a_impl",
                     "strip_rows", "phase_c_impl", "phase_c_block",
                     "tournament_width", "autotune", "autotune_cache",
                     "dtype", "use_pallas", "interpret",
                     "max_regrows", "auto_regrow", "regrow_factor",
                     "regrow_features_ceiling", "regrow_candidates_ceiling",
                     "bucket_rounding", "prefetch_rounds"):
            v = getattr(args, name, None)
            if v is not None:
                kw[name] = v
        level = getattr(args, "filter_level", None) or getattr(
            args, "filter", None)
        if level is not None:
            kw["filter_level"] = FilterLevel(level)
        if getattr(args, "no_regrow", False):
            kw["auto_regrow"] = False
        if getattr(args, "no_prefetch", False):
            kw["prefetch_rounds"] = 0
        tile_kw: dict[str, Any] = {}
        for attr, field in (("tile_grid", "grid"),
                            ("tile_max_features", "max_features_per_tile"),
                            ("tile_max_candidates",
                             "max_candidates_per_tile"),
                            ("max_tile_pixels", "max_tile_pixels")):
            v = getattr(args, attr, None)
            if v is not None:
                tile_kw[field] = v
        if tile_kw.get("grid") is not None:
            tile_kw["grid"] = parse_grid(tile_kw["grid"])
        if tile_kw or getattr(args, "tile", False):
            kw["tile"] = TileSpec(**tile_kw)
        serve_kw: dict[str, Any] = {}
        for attr, field in (("serve_buckets", "buckets"),
                            ("serve_batch_cap", "batch_cap"),
                            ("serve_max_queue", "max_queue"),
                            ("serve_admission", "admission")):
            v = getattr(args, attr, None)
            if v is not None:
                serve_kw[field] = v
        tick_ms = getattr(args, "serve_tick_ms", None)
        if tick_ms is not None:
            serve_kw["tick_interval_s"] = float(tick_ms) / 1e3
        if serve_kw.get("buckets") is not None:
            serve_kw["buckets"] = tuple(
                parse_grid(b) if isinstance(b, str) and "x" in b.lower()
                else int(b) for b in serve_kw["buckets"])
        if serve_kw or getattr(args, "serve", False):
            kw["serve"] = ServeSpec(**serve_kw)
        delta_kw: dict[str, Any] = {}
        for attr, field in (("delta_cache_entries", "cache_entries"),
                            ("delta_hash", "hash_algo"),
                            ("delta_verify", "verify")):
            v = getattr(args, attr, None)
            if v is not None:
                delta_kw[field] = v
        if delta_kw or getattr(args, "delta", False):
            kw["delta"] = DeltaSpec(**delta_kw)
        overlap_kw: dict[str, Any] = {}
        v = getattr(args, "overlap_depth", None)
        if v is not None:
            overlap_kw["staging_depth"] = int(v)
        if getattr(args, "no_donate", False):
            overlap_kw["donate"] = False
        if getattr(args, "no_async_overflow", False):
            overlap_kw["async_overflow"] = False
        if getattr(args, "no_async_harvest", False):
            overlap_kw["async_harvest"] = False
        if overlap_kw or getattr(args, "overlap", False):
            kw["overlap"] = OverlapSpec(**overlap_kw)
        kw.update(overrides)
        return cls(**kw)

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["filter_level"] = self.filter_level.value
        return json.dumps(d, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "PHConfig":
        d = json.loads(s)
        d["filter_level"] = FilterLevel(d.get("filter_level", "vanilla"))
        return cls(**d)
