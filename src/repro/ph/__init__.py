"""Public facade for PixHomology computation (the only supported entry).

    from repro.ph import PHConfig, PHEngine, FilterLevel

    engine = PHEngine(PHConfig(filter_level=FilterLevel.STD))
    result = engine.run(image)                  # single image, auto-regrow
    batch = engine.run_batch(images)            # vmap'd (B, H, W)
    job = engine.run_distributed(range(64))     # sharded pipeline
    tiled = engine.run_tiled(huge_image)        # halo-tiled + seam merge

Lower layers (``repro.core``, ``repro.pipeline``) remain importable for
tests and internals, but applications, examples, launch scripts, and
benchmarks go through this package.
"""
from repro.ph.config import (  # noqa: F401
    ADMISSION_POLICIES,
    CANDIDATE_MODES,
    DTYPES,
    HASH_ALGOS,
    MERGE_IMPLS,
    DeltaSpec,
    FilterLevel,
    OverlapSpec,
    PHConfig,
    ServeSpec,
    TileSpec,
    parse_grid,
)
from repro.ph.engine import (  # noqa: F401
    PHEngine,
    PHResult,
    Plan,
    RegrowStats,
    threshold_dtype,
)
