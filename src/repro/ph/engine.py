"""PHEngine: the single entry point for PixHomology computation.

The engine owns three things the call sites used to re-implement:

* a **compiled-plan cache** keyed by ``(kind, shape, dtype, capacities,
  config.plan_key())`` — repeated single-image, ``vmap``-batched, and
  ``shard_map``-sharded calls reuse one jitted executable instead of
  re-tracing (every plan carries a trace counter, so tests and benchmarks
  can assert reuse);

* **overflow auto-regrow** — the ``Diagram.overflow`` flag triggers
  re-dispatch at doubled ``max_features``/``max_candidates`` up to a
  configurable ceiling (default: the image pixel count, at which overflow
  is impossible), with per-call :class:`RegrowStats`;

* the **distributed pipeline** — ``run_distributed`` owns the end-to-end
  job: shape-bucketed scheduling of heterogeneous datasets, prefetch
  overlap, work-log fault tolerance, and failure injection all hang off
  the engine.

See ``src/repro/ph/README.md`` for the cache-keying and regrow policy.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Diagram, batched_pixhomology, diagram_to_array, \
    num_candidates as core_num_candidates, pixhomology
from repro.core.packed_keys import check_finite, key_scope, \
    resolve_merge_keys
from repro.distributed.context import shard_map_compat
from repro.ph.config import FilterLevel, OverlapSpec, PHConfig, TileSpec
from repro.ph.overlap import OverlapCounters, PendingResult, start_d2h

# The engine's behavior when the config carries no overlap spec:
# synchronous transfers, no donation — the pre-overlap code path.
_OVERLAP_OFF = OverlapSpec(enabled=False)

# Donating an image batch whose buffer no diagram output can alias is
# intentional (XLA still owns — and may reuse/free early — the donated
# space); the per-compile advisory would otherwise spam every round.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


def threshold_dtype(image_dtype):
    """Dtype for Variant-2 thresholds: the image dtype for floats, float32
    for integer images (so fractional thresholds and the -inf "no
    truncation" sentinel are not destroyed by an integer cast; comparisons
    in the core promote)."""
    return image_dtype if jnp.issubdtype(image_dtype, jnp.floating) \
        else jnp.float32


class Plan:
    """One cached compiled executable plus its trace/call counters.

    ``merge_keys`` records the *resolved* phase-C key encoding; packed
    plans trace, lower, and execute inside the int64
    :func:`repro.core.packed_keys.key_scope` — the scope must wrap the
    outermost jit call, which is exactly what ``__call__``/:meth:`lower`
    are.

    Thread safety: concurrent submitters (the serving daemon, the hammer
    regression test) may race into one plan.  The *first* call — the one
    that traces — is serialized under the plan lock so two threads cannot
    both pay (and double-count) the trace; once ``traces > 0`` the
    compiled executable is reached without the lock, so steady-state
    calls run concurrently.
    """

    __slots__ = ("fn", "key", "traces", "calls", "merge_keys", "_lock")

    def __init__(self, fn: Callable, key: tuple, merge_keys: str = "rank"):
        self.fn = fn
        self.key = key
        self.traces = 0
        self.calls = 0
        self.merge_keys = merge_keys
        self._lock = threading.Lock()

    def __call__(self, *args):
        with self._lock:
            self.calls += 1
            cold = self.traces == 0
        if cold:
            with self._lock:
                with key_scope(self.merge_keys):
                    return self.fn(*args)
        with key_scope(self.merge_keys):
            return self.fn(*args)

    def lower(self, *args):
        """``fn.lower(*args)`` under the plan's key scope (dryrun path)."""
        with key_scope(self.merge_keys):
            return self.fn.lower(*args)


@dataclasses.dataclass(frozen=True)
class RegrowStats:
    """What the overflow auto-regrow loop did for one run."""

    attempts: int                  # re-dispatches performed (0 = first try fit)
    final_max_features: int
    final_max_candidates: int
    overflow: bool                 # residual overflow after the final attempt

    @property
    def regrown(self) -> bool:
        return self.attempts > 0


@dataclasses.dataclass(frozen=True)
class PHResult:
    """Diagram plus the effective configuration that produced it."""

    diagram: Diagram
    config: PHConfig               # capacities reflect any regrow
    regrow: RegrowStats
    # Variant-2 threshold(s) actually applied: a scalar for run(), a (B,)
    # array for run_batch(), None when no filtering was in effect.
    threshold: Any = None
    # Delta-recompute accounting (repro.core.delta.DeltaStats) when the
    # result came through run_delta / run_sequence; None otherwise.
    delta: Any = None

    def to_array(self) -> np.ndarray:
        return diagram_to_array(self.diagram)


class PHEngine:
    """Config-driven PH computation with plan caching and auto-regrow.

    One engine per configuration family; engines are cheap to construct but
    the plan cache only pays off when reused, so share an engine across
    calls of the same workload.
    """

    def __init__(self, config: PHConfig | None = None):
        self.config = config if config is not None else PHConfig()
        if not isinstance(self.config, PHConfig):
            raise TypeError(f"config must be a PHConfig, "
                            f"got {type(self.config).__name__}")
        self._plans: dict[tuple, Plan] = {}
        # Largest regrown capacities seen per (kind, shape, dtype): later
        # calls start there instead of re-walking the doubling chain.
        self._grown: dict[tuple, tuple[int, int]] = {}
        # Autotune memo: effective (tuned) config per (shape, dtype), so
        # the disk-cache lookup happens once per shape family.
        self._tuned: dict[tuple, PHConfig] = {}
        # Delta frame store (repro.cache.DiagramCache), built lazily from
        # config.delta.cache_entries on the first run_delta call.
        self._delta_cache = None
        # Autotuned tile-grid memo per (shape, dtype) — like _tuned, one
        # disk-cache lookup per shape family.
        self._tuned_grids: dict[tuple, tuple[int, int] | None] = {}
        self._hits = 0
        self._misses = 0
        self.regrow_log: list[dict] = []
        # Overlap-engine accounting (H2D/D2H transfers, blocking syncs by
        # thread role, donation replays) — bumped by the engine, executor,
        # driver, and server; read by the bench and the perf gate.
        self.overlap_counters = OverlapCounters()
        # Guards the plan cache, the regrow memo, and every counter:
        # concurrent submitters (the serving daemon's clients, N threads
        # hammering run()) share one engine, and an unguarded cache miss
        # would let two threads build — and trace — the same plan twice.
        # Tracing/compute happen *outside* this lock (Plan serializes its
        # own first call), so the engine lock is never held across XLA.
        self._lock = threading.RLock()

    # -- plan cache --------------------------------------------------------

    def get_plan(self, key: tuple, builder: Callable[[Plan], Callable],
                 merge_keys: str = "rank") -> Plan:
        """Fetch or build the compiled plan for ``key`` (thread-safe: one
        plan object per key, however many threads race the miss).

        ``builder(plan)`` returns the callable; it receives the plan object
        so traced wrappers can bump ``plan.traces`` at trace time.
        ``merge_keys`` is the *resolved* key encoding — packed plans run
        their trace/lower/execute under the int64 key scope.
        """
        with self._lock:
            plan = self._plans.get(key)
            if plan is None:
                plan = Plan(None, key, merge_keys)
                plan.fn = builder(plan)
                self._plans[key] = plan
                self._misses += 1
            else:
                self._hits += 1
            return plan

    def plan_stats(self) -> dict:
        with self._lock:
            plans = list(self._plans.values())
            return {
                "plans": len(plans),
                "traces": sum(p.traces for p in plans),
                "calls": sum(p.calls for p in plans),
                "hits": self._hits,
                "misses": self._misses,
                "regrows": len(self.regrow_log),
            }

    # -- overlap policy ----------------------------------------------------

    def overlap_spec(self) -> OverlapSpec:
        """Effective overlap policy — a disabled spec when the config
        carries none (synchronous transfers, the pre-overlap behavior)."""
        o = self.config.overlap
        return o if o is not None else _OVERLAP_OFF

    def donate_batched(self) -> bool:
        """Whether engine-owned padded batches dispatch through donating
        plans.  Only batches the engine (or executor/server) built from
        host arrays are ever donated — user-supplied device arrays may be
        aliased by the caller, and donation invalidates the buffer."""
        o = self.overlap_spec()
        return o.enabled and o.donate

    def _stream_results(self) -> bool:
        """Whether dispatches start async D2H copies on their results
        (overflow scalar included) instead of leaving the first
        ``np.asarray`` to schedule a blocking copy."""
        o = self.overlap_spec()
        return o.enabled and o.async_overflow

    def _merge_keys_for(self, dtype) -> str:
        """The resolved phase-C key encoding for ``dtype`` under this
        config (packed falls back to rank on > 32-bit dtypes or when the
        int64 scope is unavailable — bit-identical either way)."""
        return resolve_merge_keys(self.config.merge_keys, dtype)

    def _effective_config(self, shape2d, dtype) -> PHConfig:
        """The config with autotuned ``(strip_rows, phase_c_block,
        tournament_width)`` folded in for this image shape family,
        memoized per (shape, dtype).

        With ``config.autotune`` on this is a pure **disk-cache lookup**
        (:func:`repro.roofline.autotune.lookup`) — the engine never
        measures; a missing cache entry keeps the config's own fields.
        The effective config's :meth:`PHConfig.plan_key` keys the plan
        cache, so tuned parameters deterministically select compiled
        programs.
        """
        cfg = self.config
        if not cfg.autotune:
            return cfg
        key = (tuple(shape2d), str(dtype))
        with self._lock:
            got = self._tuned.get(key)
        if got is not None:
            return got
        from repro.roofline import autotune
        tp = autotune.lookup(tuple(shape2d), str(dtype),
                             path=cfg.autotune_cache)
        eff = cfg if tp.source == "default" else cfg.replace(
            strip_rows=tp.strip_rows,
            phase_c_block=tp.phase_c_block,
            tournament_width=tp.tournament_width)
        with self._lock:
            self._tuned[key] = eff
        return eff

    def _tuned_grid(self, shape2d, dtype) -> tuple[int, int] | None:
        """Autotuned tile grid for this shape family — a pure disk-cache
        lookup (:func:`repro.roofline.autotune.lookup`), memoized per
        (shape, dtype); ``None`` when autotune is off or the cache has no
        ``tile_grid`` for the family."""
        cfg = self.config
        if not cfg.autotune:
            return None
        key = (tuple(shape2d), str(dtype))
        with self._lock:
            if key in self._tuned_grids:
                return self._tuned_grids[key]
        from repro.roofline import autotune
        tg = autotune.lookup(tuple(shape2d), str(dtype),
                             path=cfg.autotune_cache).tile_grid
        with self._lock:
            self._tuned_grids[key] = tg
        return tg

    def _resolve_grid(self, shape2d, dtype, spec: TileSpec
                      ) -> tuple[int, int]:
        """Tile grid for one image: the spec's explicit grid, else the
        autotuned grid (validated — a stale cache entry that no longer
        divides the shape is ignored), else ``choose_grid`` from the
        tile-pixel budget.  The winner lands in every tiled/delta plan
        key, so tuning deterministically selects compiled programs."""
        from repro.core import tiling
        if spec.grid is not None:
            return tuple(spec.grid)
        tg = self._tuned_grid(shape2d, dtype)
        if tg is not None:
            try:
                tiling.validate_grid(tuple(shape2d), tg)
                return tg
            except ValueError:
                pass
        return tiling.choose_grid(tuple(shape2d), spec.max_tile_pixels)

    def _ph_kwargs(self, mf: int, mc: int, merge_keys: str,
                   cfg: PHConfig | None = None) -> dict:
        """Static kwargs of one compiled stage-graph program: capacities
        plus the config's stage signature knobs (phase A impl/strip rows,
        candidate mode, merge impl/keys, phase C impl/block/width, backend
        toggles).  ``merge_keys`` arrives resolved — the plan's key scope
        matches it.  ``cfg`` (default: the engine config) lets autotuned
        effective configs supply the tuned fields."""
        cfg = self.config if cfg is None else cfg
        return dict(max_features=mf, max_candidates=mc,
                    candidate_mode=cfg.candidate_mode,
                    merge_impl=cfg.merge_impl,
                    merge_keys=merge_keys,
                    phase_a_impl=cfg.phase_a_impl,
                    strip_rows=cfg.strip_rows,
                    phase_c_impl=cfg.phase_c_impl,
                    phase_c_block=cfg.phase_c_block,
                    tournament_width=cfg.tournament_width,
                    use_pallas=cfg.use_pallas, interpret=cfg.interpret,
                    filtration=cfg.filtration)

    def _local_plan(self, kind: str, shape, dtype, mf: int, mc: int,
                    truncated: bool, donate: bool = False) -> Plan:
        """Plan for the non-sharded entry points: ``kind`` selects the
        callee ("single" -> pixhomology, "batched" -> its vmap).

        ``donate`` compiles with ``donate_argnums=(0,)`` so the image
        batch's device buffer is reused for an output instead of being
        re-allocated per round.  Donation changes the executable's
        input/output aliasing, so it is part of the plan key; callers
        must own the donated buffer (the bucketed/serving paths build
        their padded batches from host arrays) and must re-stage it
        before any replay — the regrow dispatchers do.
        """
        callee = pixhomology if kind == "single" else batched_pixhomology
        mk = self._merge_keys_for(dtype)
        eff = self._effective_config(tuple(shape)[-2:], dtype)
        key = (kind, shape, str(dtype), mf, mc, truncated, donate,
               eff.plan_key())

        def build(plan: Plan):
            kw = self._ph_kwargs(mf, mc, mk, eff)

            def compute(x, tv=None):
                plan.traces += 1   # python side effect: runs per (re)trace
                return callee(x, tv, **kw)

            dn = (0,) if donate else ()
            if truncated:
                return jax.jit(lambda im, tv: compute(im, tv),
                               donate_argnums=dn)
            return jax.jit(lambda im: compute(im), donate_argnums=dn)

        return self.get_plan(key, build, mk)

    def sharded_plan(self, ctx, shape, dtype, mf: int, mc: int,
                     donate: bool = False) -> Plan:
        """shard_map'd batched PH over ``ctx.dp_axes`` (always thresholded:
        vanilla rounds pass -inf, which is a no-op for float images).

        Per-image work is embarrassingly parallel, so it is pinned inside
        shard_map — XLA's sharding propagation otherwise replicates the
        merge-scan carries and emits ~70 TB of all-gathers per batch
        (src/repro/ph/DESIGN.md §Perf PH-1: collective 1407 s -> ~0).

        ``donate`` as in :meth:`_local_plan`: the round's padded image
        batch buffer is donated to the executable (the staging ring owns
        it and retains the host copy for the rare regrow replay).
        """
        mk = self._merge_keys_for(dtype)
        eff = self._effective_config(tuple(shape)[-2:], dtype)
        key = ("sharded", ctx, shape, str(dtype), mf, mc, donate,
               eff.plan_key())

        def build(plan: Plan):
            from jax.sharding import PartitionSpec as P
            kw = self._ph_kwargs(mf, mc, mk, eff)
            dp = ctx.dp_axes
            out_specs = Diagram(P(dp, None), P(dp, None), P(dp, None),
                                P(dp, None), P(dp), P(dp), P(dp))

            def compute(images, tvals):
                plan.traces += 1
                if images.shape[0] == 1:
                    # Per-device batch of one (the pipeline's M == dp_size
                    # rounds): vmap lowers the merge scan ~2.5x worse than
                    # the single-image program, so bypass it.
                    diag = pixhomology(images[0], tvals[0], **kw)
                    return jax.tree.map(lambda x: jnp.expand_dims(x, 0),
                                        diag)
                return batched_pixhomology(images, tvals, **kw)

            return jax.jit(shard_map_compat(
                compute, mesh=ctx.mesh,
                in_specs=(P(dp, None, None), P(dp)),
                out_specs=out_specs),
                donate_argnums=(0,) if donate else ())

        return self.get_plan(key, build, mk)

    def tiled_plan(self, shape, dtype, grid, mf: int, tf: int, tk: int,
                   truncated: bool, ctx=None) -> Plan:
        """Halo-tiled PH plan (``repro.core.tiling.tiled_pixhomology``).

        ``mf`` is the global diagram capacity, ``tf``/``tk`` the per-tile
        root/candidate capacities; ``ctx`` (optional) shards the per-tile
        phases over the mesh's data axes via ``shard_map``.
        """
        from repro.core.tiling import tiled_pixhomology
        mk = self._merge_keys_for(dtype)
        key = ("tiled", ctx, shape, str(dtype), grid, mf, tf, tk, truncated,
               self.config.plan_key())

        cfg = self.config

        def build(plan: Plan):
            def compute(x, tv=None):
                plan.traces += 1
                return tiled_pixhomology(
                    x, tv, grid=grid, max_features=mf,
                    tile_max_features=tf, tile_max_candidates=tk,
                    shard_ctx=ctx, merge_keys=mk,
                    phase_c_impl=cfg.phase_c_impl,
                    phase_c_block=cfg.phase_c_block,
                    filtration=cfg.filtration)

            if truncated:
                return jax.jit(lambda im, tv: compute(im, tv))
            return jax.jit(lambda im: compute(im))

        return self.get_plan(key, build, mk)

    def tiled_stacks_plan(self, shape, dtype, grid, mf: int, tf: int,
                          tk: int, truncated: bool, ctx=None) -> Plan:
        """Tiled PH plan over pre-staged tile stacks
        (``repro.core.tiling.tiled_pixhomology_stacks``) — the streaming
        path where no host-resident image exists."""
        from repro.core.tiling import tiled_pixhomology_stacks
        mk = self._merge_keys_for(dtype)
        key = ("tiled_stacks", ctx, shape, str(dtype), grid, mf, tf, tk,
               truncated, self.config.plan_key())

        cfg = self.config

        def build(plan: Plan):
            def compute(pv, pg, tv=None):
                plan.traces += 1
                return tiled_pixhomology_stacks(
                    pv, pg, tv, shape=shape, grid=grid, max_features=mf,
                    tile_max_features=tf, tile_max_candidates=tk,
                    shard_ctx=ctx, merge_keys=mk,
                    phase_c_impl=cfg.phase_c_impl,
                    phase_c_block=cfg.phase_c_block,
                    filtration=cfg.filtration)

            if truncated:
                return jax.jit(lambda pv, pg, tv: compute(pv, pg, tv))
            return jax.jit(lambda pv, pg: compute(pv, pg))

        return self.get_plan(key, build, mk)

    def delta_ab_plan(self, tile_shape, dtype, n_stack: int, tf: int,
                      tk: int, truncated: bool) -> Plan:
        """Batched per-tile phases A+B over a dirty-tile stack
        (:func:`repro.core.delta.phase_ab_stack`).  ``n_stack`` is the
        power-of-two dirty bucket, so the set of compiled batch shapes is
        logarithmic in the tile count."""
        from repro.core.delta import phase_ab_stack
        mk = self._merge_keys_for(dtype)
        cfg = self.config
        key = ("delta_ab", tuple(tile_shape), str(dtype), n_stack, tf, tk,
               truncated, cfg.plan_key())

        def build(plan: Plan):
            def compute(pv, pg, tv=None):
                plan.traces += 1
                return phase_ab_stack(pv, pg, tv, tile_max_features=tf,
                                      tile_max_candidates=tk, merge_keys=mk,
                                      filtration=cfg.filtration)

            if truncated:
                return jax.jit(lambda pv, pg, tv: compute(pv, pg, tv))
            return jax.jit(lambda pv, pg: compute(pv, pg))

        return self.get_plan(key, build, mk)

    def delta_merge_plan(self, shape, dtype, grid, n_stack: int, mf: int,
                         tf: int, tk: int, truncated: bool) -> Plan:
        """Scatter fresh dirty rows into the cached tile state and replay
        the seam merge (:func:`repro.core.delta.scatter_merge`); returns
        ``(new_state, TiledDiagram)``."""
        from repro.core.delta import scatter_merge
        mk = self._merge_keys_for(dtype)
        cfg = self.config
        key = ("delta_merge", tuple(shape), str(dtype), grid, n_stack, mf,
               tf, tk, truncated, cfg.plan_key())

        def build(plan: Plan):
            def compute(state, fresh, slots, tv=None):
                plan.traces += 1
                return scatter_merge(
                    state, fresh, slots, tv, shape=tuple(shape), grid=grid,
                    max_features=mf, tile_max_features=tf,
                    tile_max_candidates=tk, merge_keys=mk,
                    phase_c_impl=cfg.phase_c_impl,
                    phase_c_block=cfg.phase_c_block,
                    filtration=cfg.filtration)

            if truncated:
                return jax.jit(lambda s, f, sl, tv: compute(s, f, sl, tv))
            return jax.jit(lambda s, f, sl: compute(s, f, sl))

        return self.get_plan(key, build, mk)

    # -- capacity regrow ---------------------------------------------------

    def _ceilings(self, n: int) -> tuple[int, int]:
        cfg = self.config
        ceil_f = min(cfg.regrow_features_ceiling or n, n)
        ceil_c = min(cfg.regrow_candidates_ceiling or n, n)
        return ceil_f, ceil_c

    def initial_capacities(self, n: int) -> tuple[int, int]:
        """Effective first-attempt capacities for an n-pixel image (clamped
        to n so equivalent over-sized configs share one plan)."""
        return min(self.config.max_features, n), \
            min(self.config.max_candidates, n)

    def grow_capacities(self, mf: int, mc: int, n: int) -> tuple[int, int]:
        """One regrow step: double both capacities up to their ceilings.

        ``Diagram.overflow`` is a single flag, so both capacities grow
        together (padding is cheap relative to a second re-dispatch).
        Returns unchanged values when both ceilings are reached.
        """
        ceil_f, ceil_c = self._ceilings(n)
        return min(mf * self.config.regrow_factor, ceil_f), \
            min(mc * self.config.regrow_factor, ceil_c)

    def begin_regrow(self, dispatch: Callable[[int, int], Any],
                     overflowed: Callable[[Any], bool],
                     n: int, kind: str,
                     memo_key: tuple | None = None,
                     stream: bool = False
                     ) -> tuple[Any, Callable[[], tuple[Any, "RegrowStats"]]]:
        """Dispatch once at the memoized capacities and return
        ``(out, finish)`` with **no blocking device readback**.

        ``finish()`` performs the deferred overflow check and, on the
        rare overflow, the regrow-and-replay loop — returning the same
        ``(out, RegrowStats)`` the synchronous :meth:`run_with_regrow`
        produces (which is literally ``begin_regrow(...)`` followed by
        an immediate ``finish()``, so the two are bit-identical by
        construction; overflow semantics are deferred, never altered).

        With ``stream=True`` the dispatched output starts async
        device->host copies immediately (``copy_to_host_async``), so
        the overflow scalar — and usually the diagram itself — is
        already on the host by the time ``finish()`` looks at it.  The
        caller may dispatch further work between ``begin`` and
        ``finish`` (the speculative next round of the overlap engine);
        a dispatch that donated its input must rebuild it on replay,
        which the engine's own dispatch closures do.

        ``memo_key`` makes grown capacities sticky: a later call for the
        same (kind, shape, dtype) starts at the largest capacity already
        discovered instead of re-walking the doubling chain."""
        cfg = self.config
        mf0, mc0 = self.initial_capacities(n)
        if cfg.auto_regrow and memo_key is not None:
            with self._lock:
                got = self._grown.get(memo_key)
            if got:
                mf0 = max(mf0, min(got[0], n))
                mc0 = max(mc0, min(got[1], n))
        out0 = dispatch(mf0, mc0)
        if stream:
            start_d2h(out0, self.overlap_counters)

        def finish(out=out0, mf=mf0, mc=mc0):
            attempts = 0
            over = overflowed(out)  # drains the in-flight copy if streamed
            while over and cfg.auto_regrow and attempts < cfg.max_regrows:
                nmf, nmc = self.grow_capacities(mf, mc, n)
                if (nmf, nmc) == (mf, mc):
                    break   # at the ceiling: residual overflow is reported
                with self._lock:
                    self.regrow_log.append({"kind": kind, "from": (mf, mc),
                                            "to": (nmf, nmc)})
                mf, mc = nmf, nmc
                attempts += 1
                out = dispatch(mf, mc)
                over = overflowed(out)
            if attempts and memo_key is not None:
                with self._lock:
                    got = self._grown.get(memo_key)
                    if got is None or got < (mf, mc):
                        self._grown[memo_key] = (mf, mc)
            return out, RegrowStats(attempts, mf, mc, bool(over))

        return out0, finish

    def run_with_regrow(self, dispatch: Callable[[int, int], Any],
                        overflowed: Callable[[Any], bool],
                        n: int, kind: str,
                        memo_key: tuple | None = None
                        ) -> tuple[Any, RegrowStats]:
        """Shared synchronous driver: dispatch, then regrow while overflow
        persists — :meth:`begin_regrow` plus an immediate ``finish()``."""
        _, finish = self.begin_regrow(dispatch, overflowed, n, kind,
                                      memo_key=memo_key)
        return finish()

    # -- data prep ---------------------------------------------------------

    def cast_input(self, image) -> jnp.ndarray:
        """Apply the config's dtype policy (None = keep the input dtype).

        The engine boundary rejects non-finite pixels: NaN cannot be
        ordered by any filtration (the packed bit-cast keys would silently
        scatter it through the key order), and ±inf collides with the
        inert pad/halo sentinels the padded dispatch paths rely on."""
        check_finite(image)
        x = jnp.asarray(image)
        if self.config.dtype is not None:
            x = x.astype(self.config.dtype)
        return x

    def cast_input_host(self, image) -> np.ndarray:
        """Host-side twin of :meth:`cast_input`: the same dtype policy
        (canonicalization included, so ``float64`` inputs land on the
        dtype the device dispatch will actually use) applied with numpy.
        Staging paths use this so building a padded round never bounces
        host -> device -> host — no device allocation happens until the
        round's one fused ``device_put``.  Rejects non-finite pixels
        exactly like :meth:`cast_input`."""
        x = np.asarray(image)
        check_finite(x)
        dt = self.config.dtype if self.config.dtype is not None else x.dtype
        np_dt = np.dtype(jax.dtypes.canonicalize_dtype(dt))
        if x.dtype != np_dt:
            x = x.astype(np_dt)
        return x

    def _auto_threshold(self, image) -> float | None:
        # The host conversion happens only past the VANILLA check: it is a
        # full device-to-host readback, pure waste when no filter applies.
        if self.config.filter_level is FilterLevel.VANILLA:
            return None
        from repro.data import astro
        host = np.asarray(image)
        if self.config.filtration == "sublevel":
            # The astro statistic keeps the brightest pixels of a
            # superlevel analysis; its exact sublevel mirror is the
            # negation on both sides (keep <= -t of -image == keep >= t).
            t, _ = astro.filter_threshold(-host, self.config.filter_level)
            return None if t is None else -t
        t, _ = astro.filter_threshold(host, self.config.filter_level)
        return t

    def auto_threshold(self, image) -> float | None:
        """The Variant-2 threshold ``config.filter_level`` implies for
        ``image`` (``None`` under VANILLA).  The serving daemon calls
        this on the submitter's thread so the coalescing tick never pays
        the host-side statistic."""
        return self._auto_threshold(image)

    # -- warm plan pool ----------------------------------------------------

    def warmup(self, bucket_shapes=None, *, batch_sizes=None, dtype=None,
               truncated: bool = True) -> dict:
        """Pre-trace and compile the plans a steady-state request stream
        will hit, so no request ever pays a trace (serving p50 latency
        becomes compute-only).

        ``bucket_shapes``: square sizes or ``(H, W)`` pairs; defaults to
        the config's ``serve.buckets``.  For every bucket this pushes a
        **worst-case dummy** (a checkerboard — the maximal
        feature/candidate load a bucket can produce) through the normal
        dispatch-with-regrow path, for the **single**-image plan plus one
        **batched** plan per entry of ``batch_sizes`` (default: the
        config's ``serve.batch_cap``, the fixed dispatch batch the daemon
        pads every tick to).  Trace, lowering, compile, *and* the
        overflow regrow chain all happen here: the sticky regrow memo
        records the grown capacity tier, so steady-state requests start
        at a tier whose plan already exists.  ``truncated`` warms the
        thresholded program variants (what padded serving batches always
        run; ``-inf`` thresholds make them exact no-ops for unfiltered
        images).

        Returns ``{"plans": ..., "traces": ..., "seconds": ...}`` — the
        *new* plans/traces this warmup added.  After it, the existing
        plan trace counters (:meth:`plan_stats`) let callers assert that
        steady state re-traces nothing; ``benchmarks/serve_bench.py``
        gates on exactly that.
        """
        spec = self.config.serve
        if bucket_shapes is None:
            if spec is None or spec.buckets is None:
                raise ValueError("warmup needs bucket_shapes (or a config "
                                 "serve spec with a fixed bucket set)")
            bucket_shapes = spec.buckets
        if batch_sizes is None:
            batch_sizes = (spec.batch_cap,) if spec is not None else ()
        before = self.plan_stats()
        t0 = time.perf_counter()
        for shape in bucket_shapes:
            shape = (int(shape), int(shape)) if isinstance(shape, int) \
                else tuple(shape)
            h, w = shape
            n = h * w
            # Stride-2 peak grid: under 8-connectivity the local maxima
            # of an image form an independent set of the king graph,
            # whose maximum size is ceil(h/2)*ceil(w/2) — exactly the
            # peaks planted here (distinct heights, so no plateaus merge
            # them).  No real image of this bucket produces more
            # features, so the regrow tier discovered here upper-bounds
            # the tier any steady-state dispatch will ask for.
            dummy = np.zeros(shape, np.dtype(dtype or "float32"))
            peaks = dummy[::2, ::2]
            peaks[...] = 1 + np.arange(peaks.size).reshape(peaks.shape)
            if self.config.filtration == "sublevel":
                # Same worst case, mirrored: the planted extrema must be
                # the filtration's feature points (local minima), and the
                # inert "no truncation" sentinel flips sign with it.
                dummy = -dummy
            inert = np.inf if self.config.filtration == "sublevel" \
                else -np.inf
            host = self.cast_input_host(dummy)
            x = self.cast_input(dummy)
            tv = jnp.asarray(inert, threshold_dtype(x.dtype))
            over = lambda d: bool(np.any(np.asarray(d.overflow)))  # noqa: E731
            for kind, b in [("single", None)] + [("batched", int(b))
                                                 for b in batch_sizes]:
                bshape = shape if b is None else (b, h, w)
                # Batched dispatches (what the serving tick runs) go
                # through donating plans when the overlap engine donates:
                # warming the non-donating twin would leave steady state
                # retracing.  Donated buffers are consumed per call, so
                # the donating warmup re-stages from the host dummy.
                donate = self.donate_batched() and kind == "batched"
                xb = x if b is None else (
                    None if donate else jnp.broadcast_to(x, bshape))
                tb = tv if b is None else jnp.broadcast_to(tv, (b,))

                def dispatch(mf, mc, kind=kind, bshape=bshape, xb=xb, tb=tb,
                             donate=donate):
                    plan = self._local_plan(kind, bshape, x.dtype, mf, mc,
                                            truncated, donate=donate)
                    if donate:
                        xb = jnp.asarray(np.broadcast_to(host, bshape))
                    return plan(xb, tb) if truncated else plan(xb)

                out, _ = self.run_with_regrow(
                    dispatch, over, n, kind,
                    memo_key=(kind, bshape, str(x.dtype)))
                jax.block_until_ready(out)
        after = self.plan_stats()
        return {"plans": after["plans"] - before["plans"],
                "traces": after["traces"] - before["traces"],
                "seconds": round(time.perf_counter() - t0, 4)}

    # -- public entry points ----------------------------------------------

    def run(self, image, truncate_value: float | None = None) -> PHResult:
        """0-dim PH of one 2D image (Algorithm 1) with auto-regrow.

        ``truncate_value`` overrides the config's ``filter_level`` (pass an
        explicit Variant-2 threshold); with the default ``None`` the
        threshold is derived from ``config.filter_level``.
        """
        x = self.cast_input(image)
        if x.ndim != 2:
            raise ValueError(f"expected 2D image, got shape {x.shape}")
        if truncate_value is None:
            truncate_value = self._auto_threshold(image)
        n = x.size
        truncated = truncate_value is not None
        shape, dtype = x.shape, x.dtype

        def dispatch(mf, mc):
            plan = self._local_plan("single", shape, dtype, mf, mc,
                                    truncated)
            if truncated:
                return plan(x, jnp.asarray(truncate_value,
                                           threshold_dtype(x.dtype)))
            return plan(x)

        diag, stats = self.run_with_regrow(
            dispatch, lambda d: bool(d.overflow), n, "single",
            memo_key=("single", shape, str(dtype)))
        return PHResult(diag, self.config.replace(
            max_features=stats.final_max_features,
            max_candidates=stats.final_max_candidates), stats,
            truncate_value)

    def _dedupe_batch(self, images, truncate_values):
        """Content-hash duplicate detection for :meth:`run_batch`.

        Returns ``None`` when dedupe cannot help (fewer than two images,
        non-2D rows, or no duplicates); otherwise ``(reps, inverse,
        rep_images, rep_tvs)`` where ``reps`` indexes the first occurrence
        of each distinct ``(bytes, shape, dtype, threshold)`` and
        ``inverse[i]`` maps row ``i`` to its representative's rank.
        """
        import hashlib
        arr = images if hasattr(images, "ndim") else None
        if arr is not None:
            if getattr(arr, "ndim", 0) != 3 or arr.shape[0] < 2:
                return None
            host = np.asarray(arr)
            seq = [host[i] for i in range(host.shape[0])]
        else:
            seq = [np.asarray(im) for im in images]
            if len(seq) < 2 or any(im.ndim != 2 for im in seq):
                return None
        if truncate_values is None:
            tvs = [None] * len(seq)
        elif np.isscalar(truncate_values):
            tvs = [float(truncate_values)] * len(seq)
        else:
            tvs = list(np.asarray(truncate_values, object))
            if len(tvs) != len(seq):
                return None   # let the dispatch path raise its own error
        keys = []
        for im, t in zip(seq, tvs):
            digest = hashlib.blake2b(
                np.ascontiguousarray(im).tobytes(), digest_size=16).digest()
            keys.append((im.shape, str(im.dtype), digest,
                         None if t is None else float(t)))
        first: dict = {}
        reps: list[int] = []
        inverse = np.empty(len(seq), np.int64)
        for i, k in enumerate(keys):
            got = first.get(k)
            if got is None:
                first[k] = got = len(reps)
                reps.append(i)
            inverse[i] = got
        if len(reps) == len(seq):
            return None
        rep_tvs = None if truncate_values is None \
            else [tvs[i] for i in reps]
        return reps, inverse, [seq[i] for i in reps], rep_tvs

    def run_batch(self, images, truncate_values=None, *,
                  bucket: tuple[int, int] | None = None,
                  dedupe: bool = True) -> PHResult:
        """vmap'd PH over an image batch, regrowing on *any* overflow.

        ``images``: a ``(B, H, W)`` array (one compiled batch — the fast
        path), or a sequence of 2D images whose shapes may be **mixed**.
        Mixed shapes are padded to one shape bucket — ``bucket``, or the
        elementwise maximum of each image's
        :func:`repro.pipeline.scheduler.bucket_shape` under
        ``config.bucket_rounding`` — with the inert fill, and the two pad
        artifacts are repaired host-side after compute
        (:mod:`repro.pipeline.padding`), so every row of the result is
        bit-identical to :meth:`run` on that image alone.  ``bucket``
        also forces uniform-shape batches into a fixed padded dispatch
        shape (what the serving daemon's warmed plans require).

        ``truncate_values``: optional per-image thresholds ((B,) array or
        sequence; ``None`` entries derive from ``config.filter_level``).
        Padded rows always run thresholded; when neither an explicit nor
        a filter-level threshold exists, the image minimum stands in
        (exact — it keeps every real pixel and excludes every pad pixel).

        ``dedupe`` (default on): exact content duplicates — same bytes,
        shape, dtype, and threshold — compute once and fan out to every
        requesting row host-side.  The dispatch batch shrinks to the
        distinct images, so callers that need a *fixed* dispatch shape
        (the serving daemon's warmed plans) must pass ``dedupe=False``.
        """
        return self.run_batch_async(images, truncate_values, bucket=bucket,
                                    dedupe=dedupe).resolve()

    def run_batch_async(self, images, truncate_values=None, *,
                        bucket: tuple[int, int] | None = None,
                        dedupe: bool = True) -> PendingResult:
        """Non-blocking :meth:`run_batch`: device compute is dispatched —
        and, with ``overlap.async_overflow``, result copies start
        streaming to the host — before this returns.  ``resolve()`` on
        the returned :class:`repro.ph.overlap.PendingResult` performs
        the deferred overflow check, the rare regrow-and-replay, and the
        host-side pad repair, producing exactly :meth:`run_batch`'s
        ``PHResult`` (the synchronous method literally calls this and
        resolves immediately, so bit-identity is by construction).  The
        serving daemon's tick thread dispatches through this and hands
        ``resolve()`` to its harvest thread.
        """
        if dedupe:
            plan = self._dedupe_batch(images, truncate_values)
            if plan is not None:
                reps, inverse, rep_images, rep_tvs = plan
                pending = self.run_batch_async(rep_images, rep_tvs,
                                               bucket=bucket, dedupe=False)

                def fanout():
                    res = pending.resolve()
                    host = jax.tree.map(np.asarray, res.diagram)
                    diag = jax.tree.map(lambda a: a[inverse], host)
                    thr = res.threshold
                    if thr is not None and not np.isscalar(thr):
                        thr = np.asarray(thr)[inverse]
                    return dataclasses.replace(res, diagram=diag,
                                               threshold=thr)

                return PendingResult(fanout)
        arr = images if hasattr(images, "ndim") else None
        if arr is not None and arr.ndim == 3 and (
                bucket is None or tuple(bucket) == tuple(arr.shape[1:])):
            return self._run_batch_uniform(arr, truncate_values)
        seq = [arr[i] for i in range(arr.shape[0])] if arr is not None \
            else list(images)
        if not seq:
            raise ValueError("run_batch needs at least one image")
        shapes = {tuple(np.shape(im)) for im in seq}
        if any(len(s) != 2 for s in shapes):
            raise ValueError(f"expected a (B, H, W) batch or a sequence of "
                             f"2D images, got shapes {sorted(shapes)}")
        if bucket is None and len(shapes) == 1:
            return self._run_batch_uniform(np.stack(
                [np.asarray(im) for im in seq]), truncate_values)
        return self._run_batch_bucketed(seq, truncate_values, bucket)

    def _run_batch_uniform(self, images, truncate_values=None
                           ) -> PendingResult:
        """One-compiled-shape (B, H, W) batch (the pre-serving path);
        dispatches and returns a :class:`PendingResult` whose
        ``resolve()`` finishes the deferred overflow/regrow work."""
        x = self.cast_input(images)
        if x.ndim != 3:
            raise ValueError(f"expected (B, H, W) batch, got shape {x.shape}")
        if truncate_values is None and \
                self.config.filter_level is not FilterLevel.VANILLA:
            host = np.asarray(images)
            truncate_values = np.asarray(
                [self._auto_threshold(host[i]) for i in range(host.shape[0])],
                np.float32)
        truncated = truncate_values is not None
        if truncated:
            tvals = jnp.asarray(truncate_values, threshold_dtype(x.dtype))
        n = x.shape[1] * x.shape[2]
        shape, dtype = x.shape, x.dtype

        def dispatch(mf, mc):
            plan = self._local_plan("batched", shape, dtype, mf, mc,
                                    truncated)
            if truncated:
                return plan(x, tvals)
            return plan(x)

        _, finish = self.begin_regrow(
            dispatch, lambda d: bool(np.any(np.asarray(d.overflow))),
            n, "batched", memo_key=("batched", shape, str(dtype)),
            stream=self._stream_results())

        def materialize(tvs=truncate_values):
            diag, stats = finish()
            return PHResult(diag, self.config.replace(
                max_features=stats.final_max_features,
                max_candidates=stats.final_max_candidates), stats, tvs)

        return PendingResult(materialize)

    def _run_batch_bucketed(self, seq, truncate_values,
                            bucket: tuple[int, int] | None) -> PendingResult:
        """Mixed-shape batch via one shape-bucketed padded dispatch;
        dispatches and returns a :class:`PendingResult` (the pad repair
        and row stacking happen at ``resolve()``)."""
        from repro.pipeline.padding import pad_fixup, pad_image, \
            pad_threshold, unpad_diagram
        from repro.pipeline.scheduler import bucket_shape
        # Host-side cast: no device allocation during batch building (the
        # one H2D transfer below stages the whole padded batch at once).
        imgs = [self.cast_input_host(im) for im in seq]
        if bucket is None:
            per = [bucket_shape(im.shape, self.config.bucket_rounding)
                   for im in imgs]
            bucket = (max(s[0] for s in per), max(s[1] for s in per))
        bucket = (int(bucket[0]), int(bucket[1]))
        if truncate_values is None:
            tvs: list = [None] * len(imgs)
        else:
            tvs = [None if t is None or not np.isfinite(t) else float(t)
                   for t in np.asarray(truncate_values, object).tolist()] \
                if not np.isscalar(truncate_values) \
                else [float(truncate_values)] * len(imgs)
        if len(tvs) != len(imgs):
            raise ValueError(f"{len(tvs)} thresholds for {len(imgs)} images")

        filt = self.config.filtration
        inert = np.inf if filt == "sublevel" else -np.inf
        batch = np.empty((len(imgs), *bucket), imgs[0].dtype)
        tvals = np.empty((len(imgs),), np.float64)
        fixups: list = [None] * len(imgs)
        for i, im in enumerate(imgs):
            if im.dtype != imgs[0].dtype:
                raise ValueError("mixed dtypes in one batch: "
                                 f"{im.dtype} vs {imgs[0].dtype}")
            t = tvs[i] if tvs[i] is not None else self._auto_threshold(im)
            if im.shape != bucket:
                t = pad_threshold(im, t, filt)
                fixups[i] = pad_fixup(im, filt)
            batch[i] = pad_image(im, bucket, filt)
            tvals[i] = inert if t is None else t

        dtype = batch.dtype
        shape = batch.shape
        n = bucket[0] * bucket[1]
        donate = self.donate_batched()
        xb = None if donate else jnp.asarray(batch)
        tvj = jnp.asarray(tvals, threshold_dtype(dtype))
        dispatched = [0]

        def dispatch(mf, mc):
            plan = self._local_plan("batched", shape, dtype, mf, mc, True,
                                    donate=donate)
            if donate:
                # A donated buffer is consumed by its dispatch: every
                # call (re)stages from the retained host batch.  Replays
                # after an overflow are the only second calls.
                if dispatched[0]:
                    self.overlap_counters.bump("donation_replays")
                dispatched[0] += 1
                return plan(jnp.asarray(batch), tvj)
            return plan(xb, tvj)

        _, finish = self.begin_regrow(
            dispatch, lambda d: bool(np.any(np.asarray(d.overflow))),
            n, "batched", memo_key=("batched", shape, str(dtype)),
            stream=self._stream_results())

        def materialize():
            diag, stats = finish()
            rows = []
            host = jax.tree.map(np.asarray, diag)
            for i in range(len(imgs)):
                d = Diagram(*(x[i] for x in host))
                if fixups[i] is not None:
                    d = unpad_diagram(d, fixups[i], bucket)
                rows.append(d)
            stacked = jax.tree.map(lambda *xs: np.stack(xs), *rows)
            return PHResult(stacked, self.config.replace(
                max_features=stats.final_max_features,
                max_candidates=stats.final_max_candidates), stats,
                tvals)

        return PendingResult(materialize)

    def num_candidates(self, image, truncate_value=None) -> int:
        """Count death-point candidates under this engine's config (for
        sizing ``max_candidates`` / ``max_candidates_per_tile`` before a
        run; forwards the config's candidate mode and backend toggles)."""
        cfg = self.config
        x = self.cast_input(image)
        if truncate_value is None:
            truncate_value = self._auto_threshold(image)
        return int(core_num_candidates(
            x, cfg.candidate_mode, truncate_value,
            use_pallas=cfg.use_pallas, interpret=cfg.interpret,
            phase_a_impl=cfg.phase_a_impl, strip_rows=cfg.strip_rows,
            merge_keys=cfg.merge_keys, filtration=cfg.filtration))

    # -- diagram distances -------------------------------------------------

    def _stack_diagrams(self, diagrams):
        """Normalize distance inputs to host ``(birth, death, p_birth)``
        stacks of one common capacity.

        Accepts a batched :class:`PHResult`/:class:`Diagram` (2D fields,
        straight from :meth:`run_batch`), a sequence of per-image
        results/diagrams (1D fields, possibly of *mixed* capacities —
        regrow makes these; shorter ones gain pad rows, which the
        distance kernels treat as diagonal points, i.e. exactly
        nothing), or a ready ``(birth, death, p_birth)`` array triple.
        NaN births/deaths are rejected here — the same boundary rule as
        image inputs; the ±inf pad sentinels are of course allowed.
        """
        if isinstance(diagrams, tuple) and len(diagrams) == 3 \
                and not isinstance(diagrams[0], (PHResult, Diagram)):
            birth, death, p_birth = (np.asarray(a) for a in diagrams)
        else:
            if isinstance(diagrams, (PHResult, Diagram)):
                diagrams = [diagrams]
            ds = [r.diagram if isinstance(r, PHResult) else r
                  for r in diagrams]
            if not ds:
                raise ValueError("distance_matrix needs at least one "
                                 "diagram")
            rows = []
            for d in ds:
                b = np.atleast_2d(np.asarray(d.birth))
                de = np.atleast_2d(np.asarray(d.death))
                pb = np.atleast_2d(np.asarray(d.p_birth))
                rows.extend((b[i], de[i], pb[i]) for i in range(b.shape[0]))
            f = max(r[0].shape[0] for r in rows)

            def _grow(a, fill, dt):
                out = np.full(f, fill, dt)
                out[:a.shape[0]] = a
                return out

            birth = np.stack([_grow(b, 0, b.dtype) for b, _, _ in rows])
            death = np.stack([_grow(d, 0, d.dtype) for _, d, _ in rows])
            p_birth = np.stack([_grow(p, -1, np.int32) for _, _, p in rows])
        if birth.ndim != 2:
            raise ValueError(f"expected stacked (B, F) diagrams, got "
                             f"shape {tuple(birth.shape)}")
        check_finite(birth, where="diagram births", allow_inf=True)
        check_finite(death, where="diagram deaths", allow_inf=True)
        return birth, death, p_birth.astype(np.int32)

    def distance_plan(self, b: int, f: int, dtype, n_dirs: int) -> Plan:
        """Plan for the ``(B, F)`` diagram-distance matrix — its own
        cached kind, so serving/bench loops over a fixed batch shape
        trace once.  The plan key carries the backend toggles (the
        Pallas/interpret choice changes the executable) and the resolved
        key encoding (the profile selection primitive differs)."""
        mk = self._merge_keys_for(dtype)
        cfg = self.config
        key = ("distance", b, f, str(dtype), n_dirs, mk,
               cfg.use_pallas, cfg.interpret)

        def build(plan: Plan):
            from repro.kernels.ph_distance import diagram_distances

            def compute(birth, death, p_birth):
                plan.traces += 1
                return diagram_distances(
                    birth, death, p_birth, n_dirs=n_dirs, merge_keys=mk,
                    width=cfg.tournament_width,
                    use_pallas=cfg.use_pallas, interpret=cfg.interpret)

            return jax.jit(compute)

        return self.get_plan(key, build, mk)

    def distance_matrix(self, diagrams, *, n_dirs: int = 16):
        """Pairwise distance matrices of a batch of diagrams.

        ``diagrams``: anything :meth:`_stack_diagrams` accepts — a
        batched result from :meth:`run_batch`, a list of :meth:`run`
        results (mixed capacities fine), raw :class:`Diagram` tuples, or
        a ``(birth, death, p_birth)`` array triple.  Returns
        ``(sw, bottleneck)``, both (B, B) jnp arrays: sliced-Wasserstein
        distance and the bottleneck lower bound — definitions and the
        capacity-pad inertness argument live in
        :mod:`repro.kernels.ph_distance.ref` and DESIGN.md §12.

        Diagrams are taken in this engine's ``config.filtration``
        convention.  Both distances are invariant under simultaneously
        negating every diagram (a point reflection: all projections
        negate, so per-direction sorted pairings — and the persistence
        profiles — are preserved), so sublevel diagrams are canonicalized
        to the internal superlevel space by exact negation before the
        kernels run; matrices of a sublevel run and of the superlevel
        run on the negated images then agree bit-for-bit (a tested
        invariant).
        """
        birth, death, p_birth = self._stack_diagrams(diagrams)
        if self.config.filtration == "sublevel":
            birth, death = -birth, -death
        dt = self.config.dtype if self.config.dtype is not None \
            else birth.dtype
        dt = np.dtype(jax.dtypes.canonicalize_dtype(dt))
        if not np.issubdtype(dt, np.floating):
            dt = np.dtype(np.float32)
        birth = birth.astype(dt, copy=False)
        death = death.astype(dt, copy=False)
        plan = self.distance_plan(birth.shape[0], birth.shape[1],
                                  dt, int(n_dirs))
        return plan(birth, death, p_birth)

    def should_tile(self, n_pixels: int) -> bool:
        """True when the config routes an ``n_pixels`` image through the
        tiled path (``tile`` configured and the image exceeds its
        ``max_tile_pixels`` budget)."""
        t = self.config.tile
        return t is not None and n_pixels > t.max_tile_pixels

    def provider_threshold(self, provider):
        """Variant-2 threshold for a tile provider, consistent across
        every streaming entry point: the provider's estimate with its
        sample budget tied to the tile budget (O(tile) residency), fixed
        by this engine's config.  ``None`` under VANILLA."""
        if self.config.filter_level is FilterLevel.VANILLA:
            return None
        if self.config.filtration == "sublevel":
            raise ValueError(
                "filter_level-derived thresholds for tile providers are "
                "superlevel statistics; under filtration='sublevel' pass "
                "an explicit truncate_value (or use FilterLevel.VANILLA)")
        if not hasattr(provider, "filter_threshold"):
            raise ValueError(
                f"filter_level={self.config.filter_level} needs a "
                f"threshold, but the tile provider has no "
                f"filter_threshold(); pass truncate_value")
        spec = self.config.tile if self.config.tile is not None \
            else TileSpec()
        try:
            return provider.filter_threshold(
                self.config.filter_level,
                sample=math.isqrt(spec.max_tile_pixels))
        except TypeError:   # provider without a sample knob
            return provider.filter_threshold(self.config.filter_level)

    def stage_tiles(self, provider, *, grid=None, ctx=None):
        """Stage a tile provider's halo-padded tiles on device (O(tile)
        host residency), choosing the grid from the config's
        :class:`TileSpec` when not given.  The returned
        ``repro.core.tiling.StagedTiles`` feeds :meth:`run_tiled` — this
        is the half the pipeline's prefetch thread runs ahead of time.
        """
        from repro.core import tiling
        spec = self.config.tile if self.config.tile is not None \
            else TileSpec()
        if grid is None:
            dt = self.config.dtype if self.config.dtype is not None \
                else getattr(provider, "dtype", np.float32)
            grid = self._resolve_grid(tuple(provider.shape),
                                      np.dtype(dt), spec)
        # Halo fill is the user-space inert extreme of the filtration
        # (the tiled core negates it to the internal -inf under sublevel).
        fill = np.inf if self.config.filtration == "sublevel" else None
        return tiling.load_tile_stacks(provider, tuple(grid), ctx=ctx,
                                       fill=fill)

    def run_tiled(self, image, truncate_value=None, *, grid=None,
                  ctx=None) -> PHResult:
        """Halo-tiled PH of one (possibly device-exceeding) 2D image.

        ``image`` is one of

        * a host-resident 2D array (convenience path),
        * a **tile provider** (``shape`` / ``dtype`` /
          ``halo_tile(t, grid, fill=...)``, e.g.
          :class:`repro.data.astro.AstroImage`) — tiles are generated and
          placed on device one at a time, so no host ever materializes the
          image (Variant-1 ``load_self`` for tiles), or
        * a ``repro.core.tiling.StagedTiles`` already staged by
          :meth:`stage_tiles` (the pipeline's prefetch path; pass the
          threshold explicitly, there is no image to derive it from).

        Bit-identical to :meth:`run` with ``candidate_mode="exact"`` while
        keeping per-tile working memory proportional to the tile size.
        ``grid`` overrides the config's :class:`TileSpec` grid (auto-chosen
        from ``max_tile_pixels`` when both are None); ``ctx`` places tile
        rows on the mesh's data axes via ``shard_map``.  Overflow regrows
        per level: tile capacities toward the tile pixel count on tile
        overflow, ``max_features`` toward the image pixel count on
        seam-merge overflow.
        """
        from repro.core import tiling
        cfg = self.config
        if cfg.candidate_mode != "exact":
            raise ValueError("run_tiled supports candidate_mode='exact' "
                             "only (the paper-literal distillation has no "
                             "tiled equivalence proof)")
        staged = image if isinstance(image, tiling.StagedTiles) else None
        provider = None
        if staged is None and hasattr(image, "halo_tile"):
            provider = image
            if truncate_value is None:
                truncate_value = self.provider_threshold(provider)
            staged = self.stage_tiles(provider, grid=grid, ctx=ctx)
        spec = cfg.tile if cfg.tile is not None else TileSpec()
        if staged is not None:
            if cfg.dtype is not None:       # apply the config dtype policy
                staged = dataclasses.replace(
                    staged, pvals=jnp.asarray(staged.pvals).astype(cfg.dtype))
            if grid is not None and tuple(grid) != tuple(staged.grid):
                raise ValueError(f"grid={tuple(grid)} does not match the "
                                 f"staged tiles' grid {staged.grid}")
            shape, grid = staged.shape, staged.grid
            dtype = jnp.asarray(staged.pvals).dtype
            x = None
        else:
            x = self.cast_input(image)
            if x.ndim != 2:
                raise ValueError(f"expected 2D image, got shape {x.shape}")
            if truncate_value is None:
                truncate_value = self._auto_threshold(image)
            if grid is None:
                grid = self._resolve_grid(x.shape, x.dtype, spec)
            shape, dtype = x.shape, x.dtype
        grid = tuple(grid)
        tiling.validate_grid(shape, grid)
        h, w = shape
        n = h * w
        tile_n = (h // grid[0]) * (w // grid[1])
        truncated = truncate_value is not None
        tvj = jnp.asarray(truncate_value, threshold_dtype(dtype)) \
            if truncated else None

        mf = min(cfg.max_features, n)
        tf = min(spec.max_features_per_tile, tile_n)
        tk = min(spec.max_candidates_per_tile, tile_n)
        # Regrow ceilings apply per level: the configured feature ceiling
        # bounds the global diagram (and per-tile roots), the candidate
        # ceiling bounds per-tile candidates — each clamped to the pixel
        # count it can never usefully exceed.
        ceil_mf, _ = self._ceilings(n)
        ceil_tf, ceil_tk = self._ceilings(tile_n)
        memo_key = ("tiled", tuple(shape), grid, str(dtype), ctx)
        if cfg.auto_regrow:
            with self._lock:
                got = self._grown.get(memo_key)
            if got:
                mf = max(mf, min(got[0], n))
                tf = max(tf, min(got[1], tile_n))
                tk = max(tk, min(got[2], tile_n))

        attempts = 0
        while True:
            if staged is not None:
                plan = self.tiled_stacks_plan(tuple(shape), dtype, grid,
                                              mf, tf, tk, truncated, ctx)
                out = plan(staged.pvals, staged.pgidx, tvj) if truncated \
                    else plan(staged.pvals, staged.pgidx)
            else:
                plan = self.tiled_plan(shape, dtype, grid, mf, tf, tk,
                                       truncated, ctx)
                out = plan(x, tvj) if truncated else plan(x)
            if self._stream_results():
                start_d2h(out, self.overlap_counters)
            tile_of = bool(out.tile_overflow)
            merge_of = bool(out.merge_overflow)
            if not (tile_of or merge_of) or not cfg.auto_regrow \
                    or attempts >= cfg.max_regrows:
                break
            nmf = min(mf * cfg.regrow_factor, ceil_mf) if merge_of else mf
            ntf, ntk = tf, tk
            if tile_of:
                ntf = min(tf * cfg.regrow_factor, ceil_tf)
                ntk = min(tk * cfg.regrow_factor, ceil_tk)
            if (nmf, ntf, ntk) == (mf, tf, tk):
                break   # at the ceilings: residual overflow is reported
            with self._lock:
                self.regrow_log.append({"kind": "tiled",
                                        "from": (mf, tf, tk),
                                        "to": (nmf, ntf, ntk)})
            mf, tf, tk = nmf, ntf, ntk
            attempts += 1
        if attempts:
            with self._lock:
                self._grown[memo_key] = (mf, tf, tk)

        # final_max_candidates reports the per-tile candidate capacity (the
        # knob that actually regrows on the tiled path).
        stats = RegrowStats(attempts, mf, tk, bool(tile_of or merge_of))
        eff = cfg.replace(
            max_features=mf,
            tile=spec.replace(grid=grid, max_features_per_tile=tf,
                              max_candidates_per_tile=tk))
        return PHResult(out.diagram, eff, stats, truncate_value)

    def run_delta(self, image, truncate_value=None, *, grid=None
                  ) -> PHResult:
        """Delta-recompute tiled PH of one frame against the engine's
        frame store — **bit-identical** to :meth:`run_tiled` on the same
        frame, at O(changed area) compute for near-duplicate frames.

        ``image`` accepts the same forms as :meth:`run_tiled` (host 2D
        array, tile provider, or ``StagedTiles``).  The frame's per-tile
        content-hash grid (:func:`repro.core.delta.frame_digests`) is
        classified against the :class:`repro.cache.DiagramCache`:

        * **full hit** — the cached :class:`PHResult` is returned without
          touching the device;
        * **partial hit** — phases A+B re-run for the dirty tiles only
          (padded to a power-of-two bucket), the fresh rows are scattered
          into the cached :class:`TileBoundaryState`, and the O(boundary)
          seam merge replays;
        * **miss** (or ``config.delta`` disabled/absent) — every tile is
          dirty; the same scatter program runs against an all-zeros base,
          so cold and warm paths share compiled programs bit for bit.

        ``PHResult.delta`` carries a :class:`repro.core.delta.DeltaStats`
        (tiles recomputed, hit kind).  Regrow mirrors :meth:`run_tiled`
        and shares its sticky capacity memo; a tile-capacity regrow
        invalidates the cached state (its arrays are shape-static), a
        merge-only regrow keeps the fresh phase-AB rows and re-runs just
        the merge program.
        """
        from repro.cache import DiagramCache, FrameCacheEntry
        from repro.core import delta as delta_mod, tiling
        cfg = self.config
        dspec = cfg.delta
        if dspec is None or not dspec.enabled:
            res = self.run_tiled(image, truncate_value, grid=grid)
            n_t = np.prod(res.config.tile.grid)
            return dataclasses.replace(res, delta=delta_mod.DeltaStats(
                int(n_t), int(n_t), "cold"))
        if cfg.candidate_mode != "exact":
            raise ValueError("run_delta supports candidate_mode='exact' "
                             "only (it rides the tiled path)")
        staged = image if isinstance(image, tiling.StagedTiles) else None
        if staged is None and hasattr(image, "halo_tile"):
            provider = image
            if truncate_value is None:
                truncate_value = self.provider_threshold(provider)
            staged = self.stage_tiles(provider, grid=grid)
        spec = cfg.tile if cfg.tile is not None else TileSpec()
        if staged is not None:
            if cfg.dtype is not None:
                staged = dataclasses.replace(
                    staged, pvals=jnp.asarray(staged.pvals).astype(cfg.dtype))
            if grid is not None and tuple(grid) != tuple(staged.grid):
                raise ValueError(f"grid={tuple(grid)} does not match the "
                                 f"staged tiles' grid {staged.grid}")
            shape, grid = staged.shape, staged.grid
            dtype = jnp.asarray(staged.pvals).dtype
            source = staged
        else:
            x = self.cast_input_host(image)   # host-side: hashing + dirty
            if x.ndim != 2:                   # stacks never bounce via HBM
                raise ValueError(f"expected 2D image, got shape {x.shape}")
            if truncate_value is None:
                truncate_value = self._auto_threshold(image)
            if grid is None:
                grid = self._resolve_grid(x.shape, x.dtype, spec)
            shape, dtype = x.shape, x.dtype
            source = x
        grid = tuple(grid)
        tiling.validate_grid(shape, grid)
        h, w = shape
        n = h * w
        n_tiles = grid[0] * grid[1]
        tile_n = (h // grid[0]) * (w // grid[1])
        tile_shape = (h // grid[0] + 2, w // grid[1] + 2)
        truncated = truncate_value is not None
        tvj = jnp.asarray(truncate_value, threshold_dtype(dtype)) \
            if truncated else None
        tv_key = float(truncate_value) if truncated else None

        digests, raw = delta_mod.frame_digests(
            source, grid, algo=dspec.hash_algo, with_bytes=dspec.verify,
            filtration=cfg.filtration)
        # Everything that must match for a cached state row to be
        # bit-reusable (threshold included: it filters inside phase B).
        context = (tuple(shape), grid, str(dtype), dspec.hash_algo, tv_key,
                   cfg.plan_key())
        with self._lock:
            if self._delta_cache is None:
                self._delta_cache = DiagramCache(dspec.cache_entries)
            cache = self._delta_cache

        mf = min(cfg.max_features, n)
        tf = min(spec.max_features_per_tile, tile_n)
        tk = min(spec.max_candidates_per_tile, tile_n)
        ceil_mf, _ = self._ceilings(n)
        ceil_tf, ceil_tk = self._ceilings(tile_n)
        # Shared with run_tiled so cold and delta runs of one frame family
        # agree on regrown capacities (equal capacities => equal plans).
        memo_key = ("tiled", tuple(shape), grid, str(dtype), None)
        if cfg.auto_regrow:
            with self._lock:
                got = self._grown.get(memo_key)
            if got:
                mf = max(mf, min(got[0], n))
                tf = max(tf, min(got[1], tile_n))
                tk = max(tk, min(got[2], tile_n))

        kind, entry, dirty_mask = cache.lookup(
            context, digests, capacities=(mf, tf, tk), tile_bytes=raw)
        if kind == "hit":
            return dataclasses.replace(
                entry.result,
                delta=delta_mod.DeltaStats(n_tiles, 0, "full"))
        if kind == "partial":
            dirty = np.flatnonzero(dirty_mask)
            base = entry.state
        else:
            dirty = np.arange(n_tiles)
            base = None

        attempts = 0
        while True:
            if base is None:
                base = delta_mod.empty_state(shape, grid, dtype, tf, tk)
            bucket = delta_mod.dirty_bucket(len(dirty), n_tiles)
            pv, pg, slots = delta_mod.dirty_stacks(source, grid, dirty,
                                                   bucket, cfg.filtration)
            ab = self.delta_ab_plan(tile_shape, dtype, bucket, tf, tk,
                                    truncated)
            fresh = ab(pv, pg, tvj) if truncated else ab(pv, pg)
            mg = self.delta_merge_plan(shape, dtype, grid, bucket, mf, tf,
                                       tk, truncated)
            new_state, out = mg(base, fresh, slots, tvj) if truncated \
                else mg(base, fresh, slots)
            if self._stream_results():
                start_d2h(out, self.overlap_counters)
            tile_of = bool(out.tile_overflow)
            merge_of = bool(out.merge_overflow)
            if not (tile_of or merge_of) or not cfg.auto_regrow \
                    or attempts >= cfg.max_regrows:
                break
            nmf = min(mf * cfg.regrow_factor, ceil_mf) if merge_of else mf
            ntf, ntk = tf, tk
            if tile_of:
                ntf = min(tf * cfg.regrow_factor, ceil_tf)
                ntk = min(tk * cfg.regrow_factor, ceil_tk)
            if (nmf, ntf, ntk) == (mf, tf, tk):
                break   # at the ceilings: residual overflow is reported
            with self._lock:
                self.regrow_log.append({"kind": "delta",
                                        "from": (mf, tf, tk),
                                        "to": (nmf, ntf, ntk)})
            if (ntf, ntk) != (tf, tk):
                # Tile capacities grew: the cached/base state arrays are
                # the wrong shape — recompute every tile from scratch.
                dirty = np.arange(n_tiles)
                base = None
                kind = "miss"
            mf, tf, tk = nmf, ntf, ntk
            attempts += 1
        if attempts:
            with self._lock:
                got = self._grown.get(memo_key)
                if got is None or got < (mf, tf, tk):
                    self._grown[memo_key] = (mf, tf, tk)

        stats = RegrowStats(attempts, mf, tk, bool(tile_of or merge_of))
        eff = cfg.replace(
            max_features=mf,
            tile=spec.replace(grid=grid, max_features_per_tile=tf,
                              max_candidates_per_tile=tk))
        hit = "partial" if kind == "partial" else "miss"
        dstats = delta_mod.DeltaStats(n_tiles, int(len(np.unique(dirty))),
                                      hit)
        result = PHResult(out.diagram, eff, stats, truncate_value, dstats)
        # put() on an existing (context, digests) key replaces in place, so
        # pipeline retries / resumed rounds never double-insert.
        cache.put(context, FrameCacheEntry(
            digests=digests, state=new_state, result=result,
            capacities=(mf, tf, tk), tile_bytes=raw))
        return result

    def run_sequence(self, frames, truncate_values=None, *, grid=None):
        """Generator: :meth:`run_delta` over an iterable of frames (the
        survey-stream entry point).  ``truncate_values`` is a scalar
        applied to every frame or a per-frame sequence; yields one
        :class:`PHResult` per frame as it completes, so a consumer can
        stream diagrams while later frames hash."""
        for i, frame in enumerate(frames):
            if truncate_values is None:
                tv = None
            elif np.isscalar(truncate_values):
                tv = truncate_values
            else:
                tv = truncate_values[i]
            yield self.run_delta(frame, tv, grid=grid)

    def delta_cache_stats(self) -> dict:
        """Snapshot of the delta frame store's counters (zeros before the
        first ``run_delta`` call)."""
        with self._lock:
            cache = self._delta_cache
        if cache is None:
            from repro.cache import CacheStats
            return CacheStats().snapshot()
        return cache.stats.snapshot()

    def run_distributed(self, images, *, ctx=None, image_size: int = 512,
                        strategy: str = "part_LPT",
                        work_log=None, failure_injector=None,
                        max_retries: int = 3, verbose: bool = False):
        """The paper's end-to-end distributed job, engine-owned.

        Builds a sharded executor over ``ctx`` (default: one data axis over
        every local device), schedules ``images`` with the Variant-3
        ``strategy`` into shape-bucketed rounds, applies the config's
        Variant-2 filter level, records completed work in ``work_log``,
        and auto-regrows capacities on overflow (grown capacities stick
        for subsequent rounds).

        ``images``: a heterogeneous dataset — each element is an image id
        (``int``, at ``image_size``), an ``(id, size)`` / ``(id, (H, W))``
        pair, or a :class:`repro.pipeline.scheduler.ImageMeta` (the
        synthetic astro loader renders square frames only; rectangular
        specs are rejected at schedule time).  Same-shape
        images share padded shape buckets (one cached sharded plan per
        bucket); images larger than the config's
        ``TileSpec.max_tile_pixels`` schedule as tile-grid rounds through
        :meth:`run_tiled`, loaded tile-by-tile so no host materializes
        them; the driver's loader thread stages round r+1 while round r
        computes (``config.prefetch_rounds``).

        Returns :class:`repro.pipeline.driver.PipelineResult`.
        """
        from repro.launch.mesh import auto_context
        from repro.pipeline.driver import run_pipeline
        from repro.pipeline.executor import ShardedPHExecutor
        executor = ShardedPHExecutor(self, ctx or auto_context(),
                                     image_size=image_size)
        return run_pipeline(executor, images, strategy=strategy,
                            work_log=work_log,
                            failure_injector=failure_injector,
                            max_retries=max_retries, verbose=verbose)
