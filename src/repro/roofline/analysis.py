"""Roofline analysis from compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically — a scan of 8 matmuls reports 1 matmul of flops), which would
undercount scan-over-layers models by ~num_layers.  This module therefore
re-derives the three roofline terms from the per-device optimized HLO text
with explicit loop expansion:

* per computation: dot flops (exact, from contracting dims), per-op memory
  traffic (fusion boundaries = real HBM traffic; fused interiors are free),
  and collective bytes by op type;
* a call graph walk multiplies while bodies by their trip count (parsed from
  the loop condition's comparison constant) and fusions/calls by 1.

Hardware model (TPU v5e, per brief): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  All terms are per-chip seconds (HLO here is the
per-device SPMD program, so per-device quantities over per-chip rates equal
the brief's global/(chips x rate)).
"""
from __future__ import annotations

import dataclasses
import math
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s*"
                     r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{")
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples by summing)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_type: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)  # (name, kind)
    whiles: list = dataclasses.field(default_factory=list)  # (body, cond)
    max_const: int = 1          # largest small int constant (trip counts)
    compare_consts: list = dataclasses.field(default_factory=list)

    @property
    def trip_count(self) -> int:
        # Prefer constants actually used in compare ops (loop bounds); the
        # any-constant fallback can pick up unrelated literals.
        if self.compare_consts:
            return max(self.compare_consts)
        return self.max_const


def _parse_computations(text: str) -> dict[str, CompStats]:
    comps: dict[str, CompStats] = {}
    cur: CompStats | None = None
    symbols: dict[str, str] = {}
    const_vals: dict[str, int] = {}

    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = CompStats()
            comps[mc.group(1)] = cur
            symbols = {}
            # parameters in the signature: name: type
            for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|"
                                  r"(?:\w+\[[\d,]*\](?:\{[^}]*\})?))", line):
                symbols[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(line)
        if not md:
            continue
        name, type_str, op, rest = md.groups()
        symbols[name] = type_str
        result_b = shape_bytes(type_str)

        # small integer constants (trip-count candidates)
        if op == "constant":
            mi = re.match(r"\s*([\d]+)\s*\)", rest)
            if mi:
                v = int(mi.group(1))
                const_vals[name] = v
                if 1 < v < 10_000_000:
                    cur.max_const = max(cur.max_const, v)
        if op == "compare":
            for om in _OPERAND_RE.finditer(rest.split(")")[0]):
                v = const_vals.get(om.group(1))
                if v is not None and 1 < v < 10_000_000:
                    cur.compare_consts.append(v)

        is_coll = any(op.startswith(c) for c in COLLECTIVES)
        if is_coll and op.endswith("-done"):
            continue                     # counted at -start
        if is_coll:
            base = next(c for c in COLLECTIVES if op.startswith(c))
            factor = 2.0 if base == "all-reduce" else 1.0
            b = result_b * factor
            cur.coll_bytes += b
            cur.coll_by_type[base] = cur.coll_by_type.get(base, 0.0) + b
            cur.bytes += result_b
            continue

        if op == "while":
            body = _CALL_RE.search(rest)
            cond = _COND_RE.search(rest)
            if body and cond:
                cur.whiles.append((body.group(1), cond.group(1)))
            continue

        if op in ("fusion", "call", "custom-call", "conditional"):
            kind_m = re.search(r"kind=k(\w+)", rest)
            kind = kind_m.group(1) if kind_m else "Input"
            ops_bytes = 0
            paren = rest.split(")")[0]
            for om in _OPERAND_RE.finditer(paren):
                t = symbols.get(om.group(1))
                if t:
                    b = shape_bytes(t)
                    if kind == "Loop" and result_b:
                        # loop fusions stream element-wise: a much larger
                        # operand is being sliced/gathered inside, so its
                        # real traffic is bounded by the result size.
                        b = min(b, result_b)
                    ops_bytes += b
            cur.bytes += result_b + ops_bytes
            cm = _CALL_RE.search(rest)
            if cm and op != "custom-call":
                cur.calls.append((cm.group(1), op))
            continue

        if op in ("dot", "dot-general"):
            dims = shape_dims(type_str)
            out_elems = math.prod(dims) if dims else 1
            k = 1
            cm = _CONTRACT_RE.search(rest)
            lhs_name = _OPERAND_RE.search(rest)
            if cm and lhs_name:
                lt = symbols.get(lhs_name.group(1))
                if lt:
                    ldims = shape_dims(lt)
                    for ci in cm.group(1).split(","):
                        if ci:
                            k *= ldims[int(ci)]
            cur.flops += 2.0 * out_elems * k
            paren = rest.split(")")[0]
            ops_bytes = sum(shape_bytes(symbols.get(om.group(1), ""))
                            for om in _OPERAND_RE.finditer(paren))
            cur.bytes += result_b + ops_bytes
            continue

        if op in ("dynamic-update-slice", "scatter"):
            # XLA updates these in place inside loops: traffic = the update
            # operand (+ indices), not the whole result buffer.
            ops_list = _OPERAND_RE.findall(rest.split(")")[0])
            upd_b = 0
            for nm in ops_list[1:]:
                t = symbols.get(nm)
                if t:
                    upd_b += shape_bytes(t)
            cur.bytes += min(upd_b, result_b) or result_b
            continue

        # everything else: result bytes only (standalone elementwise/copy);
        # parameters/constants are free.
        if op not in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast"):
            cur.bytes += result_b
    return comps


@dataclasses.dataclass
class HloSummary:
    flops: float
    bytes: float
    coll_bytes: float
    coll_by_type: dict
    n_whiles: int
    unresolved_trip_counts: int
    flops_unexpanded: float = 0.0
    bytes_unexpanded: float = 0.0


def analyze_hlo(text: str) -> HloSummary:
    comps = _parse_computations(text)
    # Entry = computation not referenced as callee anywhere, or name 'main'.
    callees = set()
    for c in comps.values():
        callees.update(n for n, _ in c.calls)
        callees.update(b for b, _ in c.whiles)
        callees.update(cd for _, cd in c.whiles)
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None:
        roots = [n for n in comps if n not in callees]
        entry = roots[0] if roots else next(iter(comps))

    unresolved = 0
    n_whiles = 0

    def walk(name: str, seen: tuple = (),
             expand: bool = True) -> tuple[float, float, float, dict]:
        nonlocal unresolved, n_whiles
        if name not in comps or name in seen:
            return 0.0, 0.0, 0.0, {}
        c = comps[name]
        fl, by, cb = c.flops, c.bytes, c.coll_bytes
        cbt = dict(c.coll_by_type)
        for callee, kind in c.calls:
            f2, b2, c2, t2 = walk(callee, seen + (name,), expand)
            # Fusion interiors live in registers/VMEM: their flops are real
            # but their memory traffic is the call site's operands/result
            # (already counted) — adding b2 would double count (measured
            # 6x overstatement on the PH cell).
            fl, cb = fl + f2, cb + c2
            if kind != "fusion":
                by += b2
            for k, v in t2.items():
                cbt[k] = cbt.get(k, 0.0) + v
        for body, cond in c.whiles:
            if expand:
                n_whiles += 1
            trip = comps[cond].trip_count if cond in comps else 1
            if trip <= 1:
                if expand:
                    unresolved += 1
                trip = 1
            if not expand:
                trip = 1
            f2, b2, c2, t2 = walk(body, seen + (name,), expand)
            fc, bc, cc, _ = walk(cond, seen + (name,), expand)
            fl += trip * (f2 + fc)
            by += trip * (b2 + bc)
            cb += trip * c2
            for k, v in t2.items():
                cbt[k] = cbt.get(k, 0.0) + trip * v
        return fl, by, cb, cbt

    fl, by, cb, cbt = walk(entry)
    fl0, by0, _, _ = walk(entry, expand=False)
    return HloSummary(fl, by, cb, cbt, n_whiles, unresolved,
                      flops_unexpanded=fl0, bytes_unexpanded=by0)


def roofline_terms(flops: float, bytes_: float, coll_bytes: float) -> dict:
    """Per-chip seconds for the three roofline terms + the bottleneck."""
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    coll_s = coll_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, coll_s)
    return dict(terms, bottleneck=dom,
                roofline_fraction=(compute_s / bound if bound > 0 else 0.0))


def blended_totals(summary: HloSummary, ca_flops: float,
                   ca_bytes: float) -> tuple[float, float]:
    """Scale XLA's per-op cost analysis (while bodies counted once) by the
    loop-expansion factors from our own HLO walk — XLA's careful per-op
    accounting x our trip-count expansion."""
    ef = summary.flops / max(summary.flops_unexpanded, 1.0)
    eb = summary.bytes / max(summary.bytes_unexpanded, 1.0)
    flops = ca_flops * ef if ca_flops > 0 else summary.flops
    bytes_ = ca_bytes * eb if ca_bytes > 0 else summary.bytes
    return flops, bytes_


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE), D = tokens."""
    n = active_params(cfg)
    if shape.kind == "decode":
        tokens = shape.global_batch          # one new token per sequence
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n * tokens


def count_params(cfg, *, active: bool) -> float:
    d, f, l, v = cfg.d_model, cfg.d_ff, cfg.num_layers, cfg.padded_vocab
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    total = v * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0.0
    for i in range(l):
        kind = cfg.block_kind(i)
        if kind in ("attn", "lattn", "moe"):
            per_layer_attn = d * h * hd + 2 * d * kv * hd + h * hd * d
            per_layer += per_layer_attn
            if kind == "moe":
                e_frac = (cfg.top_k / cfg.num_experts) if active else 1.0
                per_layer += 3 * d * f * cfg.num_experts * e_frac
                if cfg.moe_shared_expert:
                    per_layer += 3 * d * f
            else:
                nmat = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
                per_layer += nmat * d * f
        elif kind == "rwkv":
            per_layer += 5 * d * d + 2 * d * f + d * d
        elif kind == "rec":
            r = cfg.rnn_width
            per_layer += 2 * d * r + r * d + 2 * r * r + 3 * d * f
    total += per_layer
    if cfg.is_encdec:
        per_enc = d * h * hd * 2 + 2 * d * kv * hd + 2 * d * f
        total += cfg.encoder_layers * per_enc
        total += cfg.num_layers * (d * h * hd + 2 * d * kv * hd + h * hd * d)
    return float(total)


def active_params(cfg) -> float:
    return count_params(cfg, active=True)


def total_params(cfg) -> float:
    return count_params(cfg, active=False)
