"""Roofline-driven autotuner for the PH stage-graph knobs.

Searches ``(strip_rows, phase_c_block, tournament_width)`` per
``(shape, dtype, backend)`` in two stages:

1. **Model ranking** — every candidate's whole-image PH program is
   lowered and compiled once; the optimized HLO is walked by
   :mod:`repro.roofline.analysis` and the candidate scored by its
   dominant roofline term (max of compute/memory/collective seconds).
   Compilation is cheap relative to trials, so the model prunes the
   search space before any device time is spent.
2. **Measured trials** — only the model's top ``measure_top`` candidates
   pay short wall-clock trials (best of ``trials`` steady-state calls);
   the fastest wins.

The winner persists in a JSON disk cache keyed by :func:`cache_key`.
``PHEngine`` consumes the cache through :func:`lookup` when
``PHConfig.autotune`` is set: ``lookup`` NEVER compiles or measures — a
cache miss returns :data:`DEFAULTS` (``source="default"``) and the
config's own fields stand — and the tuned fields are folded into the
engine's effective config, whose ``plan_key`` then selects compiled
programs deterministically.  :func:`autotune` is the offline entry point
(``benchmarks/core_bench.py --autotune``, the CI smoke).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import numpy as np

# Repo-root artifacts/ — next to the committed BENCH snapshots.
DEFAULT_CACHE_PATH = (Path(__file__).resolve().parents[3]
                      / "artifacts" / "autotune_cache.json")


@dataclasses.dataclass(frozen=True)
class TunedParams:
    """One tuned knob assignment.  ``source`` records provenance:
    ``"default"`` (no cache entry — the config's own fields stand),
    ``"cache"`` (disk hit), ``"model"`` (roofline rank, measurement
    failed or was skipped), ``"measured"`` (trial winner).

    ``tile_grid`` is the tuned tile decomposition for the *tiled* path
    (``None`` = not tuned — the engine falls back to
    ``repro.core.tiling.choose_grid``); searched separately by
    :func:`autotune_grid` because its programs (per-tile phases + seam
    merge) are disjoint from the whole-image stage graph the scalar
    knobs re-block."""

    strip_rows: int = 8
    phase_c_block: int = 1024
    tournament_width: int = 2
    source: str = "default"
    tile_grid: tuple[int, int] | None = None


DEFAULTS = TunedParams()


def cache_key(shape, dtype, backend: str | None = None) -> str:
    """``"HxW|dtype|backend"`` — the disk-cache key for one shape
    family (``backend=None`` resolves to the current JAX backend)."""
    if backend is None:
        import jax
        backend = jax.default_backend()
    h, w = (int(shape[0]), int(shape[1]))
    return f"{h}x{w}|{dtype}|{backend}"


def load_cache(path=None) -> dict:
    p = Path(path) if path is not None else DEFAULT_CACHE_PATH
    try:
        with open(p) as f:
            cache = json.load(f)
        return cache if isinstance(cache, dict) else {}
    except (OSError, ValueError):
        return {}


def save_cache(cache: dict, path=None) -> Path:
    p = Path(path) if path is not None else DEFAULT_CACHE_PATH
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_name(p.name + ".tmp")
    tmp.write_text(json.dumps(cache, indent=2, sort_keys=True) + "\n")
    tmp.replace(p)
    return p


def lookup(shape, dtype, *, path=None, backend: str | None = None
           ) -> TunedParams:
    """Tuned params for ``(shape, dtype, backend)`` — pure cache lookup.

    This is the engine-facing call: it never compiles, measures, or
    writes; a missing/corrupt entry returns :data:`DEFAULTS` so the
    caller's own config fields apply (graceful fallback).
    """
    entry = load_cache(path).get(cache_key(shape, str(dtype), backend))
    if not isinstance(entry, dict):
        return DEFAULTS
    tg = entry.get("tile_grid")
    try:
        grid = None if tg is None else (int(tg[0]), int(tg[1]))
    except (TypeError, ValueError, IndexError):
        grid = None
    try:
        return TunedParams(int(entry["strip_rows"]),
                           int(entry["phase_c_block"]),
                           int(entry["tournament_width"]), "cache", grid)
    except (KeyError, TypeError, ValueError):
        # Grid-only entry (autotune_grid ran, the scalar search did not):
        # keep source="default" so the caller's own scalar fields stand,
        # but still surface the tuned grid.
        return dataclasses.replace(DEFAULTS, tile_grid=grid)


def candidate_space(shape) -> list[TunedParams]:
    """The search grid: strip heights bounded by the image, phase-C edge
    blocks spanning ~VMEM-step sizes, tournament widths 2/4.  Every
    candidate computes bit-identical diagrams (the knobs only re-block
    compiled programs), so the search needs no correctness filter."""
    h = int(shape[0])
    rows = [r for r in (4, 8, 16, 32) if r <= h] or [h]
    return [TunedParams(r, b, t, "candidate")
            for r in rows
            for b in (256, 1024, 4096)
            for t in (2, 4)]


def _build(shape, dtype, params: TunedParams):
    """jit-wrapped whole-image PH program pinned to ``params`` (fused
    stage graph, packed keys where the dtype allows), plus a worst-case
    input: the stride-2 peak grid from the engine's warmup — the maximal
    feature/candidate load this bucket can produce, so scores and trials
    upper-bound real images."""
    import jax
    import jax.numpy as jnp

    from repro.core.packed_keys import resolve_merge_keys
    from repro.core.pixhomology import pixhomology

    h, w = (int(shape[0]), int(shape[1]))
    n = h * w
    mk = resolve_merge_keys("packed", jnp.dtype(dtype))
    kw = dict(max_features=min(8192, n), max_candidates=min(32768, n),
              merge_impl="boruvka", merge_keys=mk,
              phase_a_impl="fused", strip_rows=params.strip_rows,
              phase_c_impl="fused", phase_c_block=params.phase_c_block,
              tournament_width=params.tournament_width)
    fn = jax.jit(lambda im: pixhomology(im, None, **kw))
    img = np.zeros((h, w), np.dtype(dtype))
    peaks = img[::2, ::2]
    peaks[...] = 1 + np.arange(peaks.size).reshape(peaks.shape)
    return fn, jnp.asarray(img), mk


def model_score(shape, dtype, params: TunedParams) -> float:
    """Roofline seconds of the compiled program under ``params`` — the
    dominant term of :func:`repro.roofline.analysis.roofline_terms` on
    the optimized HLO.  Used for *relative* candidate ranking only (the
    constants are TPU-v5e; ordering, not magnitude, is what matters)."""
    from repro.core.packed_keys import key_scope
    from repro.roofline.analysis import analyze_hlo, roofline_terms

    fn, x, mk = _build(shape, dtype, params)
    with key_scope(mk):
        text = fn.lower(x).compile().as_text()
    s = analyze_hlo(text)
    terms = roofline_terms(s.flops, s.bytes, s.coll_bytes)
    return max(terms["compute_s"], terms["memory_s"],
               terms["collective_s"])


def measure(shape, dtype, params: TunedParams, *, trials: int = 3) -> float:
    """Best-of-``trials`` steady-state seconds of the program under
    ``params`` (first call compiles and is excluded)."""
    import jax

    from repro.core.packed_keys import key_scope
    fn, x, mk = _build(shape, dtype, params)
    with key_scope(mk):
        jax.block_until_ready(fn(x))        # compile + warm
        best = float("inf")
        for _ in range(max(1, trials)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            best = min(best, time.perf_counter() - t0)
    return best


def autotune(shape, dtype, *, path=None, backend: str | None = None,
             measure_top: int = 3, trials: int = 3,
             space: list[TunedParams] | None = None) -> TunedParams:
    """Search, persist, and return tuned params for one shape family.

    A pre-existing cache entry short-circuits to :func:`lookup` (re-tune
    by deleting the entry/file).  ``measure_top=0`` or ``trials=0`` is a
    zero measurement budget: the roofline model alone ranks (or, if every
    compile fails, :data:`DEFAULTS` comes back and nothing is persisted —
    the graceful-fallback contract ``tests/test_autotune.py`` pins).
    """
    shape = (int(shape[0]), int(shape[1]))
    dtype = str(dtype)
    key = cache_key(shape, dtype, backend)
    cache = load_cache(path)
    prior = cache.get(key)
    if isinstance(prior, dict) and "strip_rows" in prior:
        # Scalar knobs already tuned (a grid-only entry from
        # autotune_grid does not short-circuit the scalar search).
        return lookup(shape, dtype, path=path, backend=backend)

    cands = list(space) if space is not None else candidate_space(shape)
    scored = []
    for p in cands:
        try:
            scored.append((model_score(shape, dtype, p), p))
        except Exception:   # candidate failed to compile: skip it
            continue
    if not scored:
        return DEFAULTS
    scored.sort(key=lambda sp: sp[0])

    timed = []
    for _, p in scored[:max(0, measure_top)]:
        try:
            timed.append((measure(shape, dtype, p, trials=trials), p))
        except Exception:
            continue
    if timed and trials > 0:
        timed.sort(key=lambda sp: sp[0])
        best = dataclasses.replace(timed[0][1], source="measured")
    else:
        best = dataclasses.replace(scored[0][1], source="model")

    entry = cache.get(key)
    if not isinstance(entry, dict):
        entry = {}
    entry.update({"strip_rows": best.strip_rows,
                  "phase_c_block": best.phase_c_block,
                  "tournament_width": best.tournament_width,
                  "source": best.source})
    cache[key] = entry
    save_cache(cache, path)
    if "tile_grid" in entry:
        try:
            tg = entry["tile_grid"]
            best = dataclasses.replace(
                best, tile_grid=(int(tg[0]), int(tg[1])))
        except (TypeError, ValueError, IndexError):
            pass
    return best


# ---------------------------------------------------------------------------
# Tile-grid search (the tiled/delta path's decomposition knob)
# ---------------------------------------------------------------------------

def grid_candidates(shape, *, max_tile_pixels: int | None = None,
                    limit: int = 6) -> list[tuple[int, int]]:
    """Candidate tile grids for one image shape: dividing ``(gr, gc)``
    pairs with at least 2 and at most 1024 tiles, tiles no thinner than
    8 pixels, optionally bounded by ``max_tile_pixels``.  Pre-ranked by
    (square-ish tiles, fewer tiles) and truncated to ``limit`` — the
    per-tile cost model then ranks the survivors, so the heuristic only
    bounds compile work, never picks the winner."""
    h, w = (int(shape[0]), int(shape[1]))
    cands = []
    for gr in (d for d in range(1, h + 1) if h % d == 0):
        tr = h // gr
        if tr < 8:
            break
        for gc in (d for d in range(1, w + 1) if w % d == 0):
            tc = w // gc
            if tc < 8:
                break
            n_tiles = gr * gc
            if not 2 <= n_tiles <= 1024:
                continue
            if max_tile_pixels is not None and tr * tc > max_tile_pixels:
                continue
            cands.append((abs(tr - tc), n_tiles, (gr, gc)))
    cands.sort()
    return [g for _, _, g in cands[:max(1, limit)]]


def grid_model_score(shape, dtype, grid) -> float:
    """Byte-traffic model for one tile grid: total peak bytes of the
    per-tile phase programs across all tiles plus the O(boundary) seam
    table (:func:`repro.core.tiling.per_tile_cost` supplies the per-tile
    footprint).  A pure compile-time ranking — relative ordering is all
    that is used, mirroring :func:`model_score`."""
    from repro.core.tiling import _ring_coords, per_tile_cost

    h, w = (int(shape[0]), int(shape[1]))
    gr, gc = grid
    tr, tc = h // gr, w // gc
    n_tiles = gr * gc
    c = per_tile_cost((tr, tc), dtype, n_tiles)
    per_tile = (c["phase_a"]["peak_bytes_est"]
                + c["phase_b"]["peak_bytes_est"])
    table = n_tiles * len(_ring_coords(tr, tc)[0]) * 8
    return float(n_tiles * per_tile + table)


def _build_tiled(shape, dtype, grid):
    """jit-wrapped tiled PH program pinned to ``grid`` on the same
    worst-case stride-2 peak input :func:`_build` uses."""
    import jax
    import jax.numpy as jnp

    from repro.core.packed_keys import resolve_merge_keys
    from repro.core.tiling import tiled_pixhomology

    h, w = (int(shape[0]), int(shape[1]))
    n = h * w
    gr, gc = grid
    tile_n = (h // gr) * (w // gc)
    mk = resolve_merge_keys("packed", jnp.dtype(dtype))
    kw = dict(grid=(gr, gc), max_features=min(8192, n),
              tile_max_features=min(2048, tile_n),
              tile_max_candidates=min(8192, tile_n), merge_keys=mk)
    fn = jax.jit(lambda im: tiled_pixhomology(im, None, **kw))
    img = np.zeros((h, w), np.dtype(dtype))
    peaks = img[::2, ::2]
    peaks[...] = 1 + np.arange(peaks.size).reshape(peaks.shape)
    return fn, jnp.asarray(img), mk


def measure_grid(shape, dtype, grid, *, trials: int = 3) -> float:
    """Best-of-``trials`` steady-state seconds of the tiled program under
    ``grid`` (first call compiles and is excluded)."""
    import jax

    from repro.core.packed_keys import key_scope
    fn, x, mk = _build_tiled(shape, dtype, grid)
    with key_scope(mk):
        jax.block_until_ready(fn(x))        # compile + warm
        best = float("inf")
        for _ in range(max(1, trials)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            best = min(best, time.perf_counter() - t0)
    return best


def autotune_grid(shape, dtype, *, path=None, backend: str | None = None,
                  max_tile_pixels: int | None = None, measure_top: int = 2,
                  trials: int = 2,
                  space: list[tuple[int, int]] | None = None
                  ) -> tuple[int, int] | None:
    """Search, persist, and return the tile grid for one shape family.

    Rides the same disk cache entry as :func:`autotune` (the
    ``tile_grid`` field of :func:`cache_key`'s entry), so the engine
    recovers both through one :func:`lookup` and both fold into plan
    keys.  A pre-existing ``tile_grid`` short-circuits; if every
    candidate fails, ``None`` comes back and nothing is persisted (the
    engine then falls through to ``choose_grid`` — graceful fallback,
    same contract as :func:`lookup`).
    """
    shape = (int(shape[0]), int(shape[1]))
    dtype = str(dtype)
    key = cache_key(shape, dtype, backend)
    cache = load_cache(path)
    entry = cache.get(key)
    if isinstance(entry, dict) and entry.get("tile_grid") is not None:
        return lookup(shape, dtype, path=path, backend=backend).tile_grid

    cands = list(space) if space is not None else \
        grid_candidates(shape, max_tile_pixels=max_tile_pixels)
    scored = []
    for g in cands:
        try:
            scored.append((grid_model_score(shape, dtype, g), g))
        except Exception:   # candidate failed to compile: skip it
            continue
    if not scored:
        return None
    scored.sort()

    timed = []
    for _, g in scored[:max(0, measure_top)]:
        try:
            timed.append((measure_grid(shape, dtype, g, trials=trials), g))
        except Exception:
            continue
    if timed and trials > 0:
        timed.sort()
        best, src = timed[0][1], "measured"
    else:
        best, src = scored[0][1], "model"

    if not isinstance(entry, dict):
        entry = {}
    entry.update({"tile_grid": [int(best[0]), int(best[1])],
                  "tile_grid_source": src})
    cache[key] = entry
    save_cache(cache, path)
    return (int(best[0]), int(best[1]))
