"""Deterministic synthetic LM token pipeline.

Learnable structure (not uniform noise): a per-document order-2 Markov chain
over the vocabulary derived from a hashed transition rule, so models show a
decreasing loss curve.  Sharded "self-loading" (paper Variant 1): each call
materializes only the requested global batch; per-device slices are
deterministic in (step, position), so any host can regenerate any shard —
this is also what makes data-pipeline restore trivial (state = step count).

LPT note (DESIGN.md §4): for LM training the paper's Variant-3 scheduling
maps to length-bucketed batch packing; documents here are fixed-length so
packing is exact, but ``pack_documents`` shows the LPT path used for
variable-length corpora.
"""
from __future__ import annotations

import numpy as np

from repro.pipeline.scheduler import part_lpt


class TokenStream:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, s, v = self.batch, self.seq, self.vocab
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        toks[:, 1] = rng.integers(0, v, b)
        mult = rng.integers(1, v, b)[:, None]
        for t in range(2, s + 1):
            # order-2 hashed markov chain + occasional random jumps
            a = toks[:, t - 1].astype(np.int64)
            c = toks[:, t - 2].astype(np.int64)
            nxt = ((a * 1103515245 + c * 12345 + 6364136) % 2147483647) % v
            jump = rng.random(b) < 0.05
            nxt = np.where(jump, rng.integers(0, v, b), nxt)
            toks[:, t] = nxt.astype(np.int32)
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:],
                "mask": np.ones((b, s), np.float32)}


def pack_documents(lengths, budget: int, m_bins: int):
    """LPT-pack variable-length documents into m token-budget bins."""
    ids = list(range(len(lengths)))
    costs = {i: float(lengths[i]) for i in ids}
    sched = part_lpt(ids, m_bins, costs)
    return sched.queues
