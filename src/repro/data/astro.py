"""Synthetic astronomical images (paper §6.2) with windowed loading.

The paper builds its 90-image dataset with astropy/photutils: a zeroed
array, Gaussian readout noise + sky background, then ~340k Gaussian stars
per 10k x 10k frame (≈3.4 objects / kilopixel²).  Astropy is not available
offline, so this module reimplements the same recipe in NumPy:

  image = sky + N(0, read_noise) + sum_i A_i * G(sigma_i, x_i, y_i)

Star amplitudes follow a power law (faint objects dominate, as in real
frames), PSF sigmas ~ U(1, 2.5) px.  Every image is deterministic in
``image_id`` (the pipeline's executors re-generate rather than transfer —
the paper's Variant-1 ``load_self``).

Windowed loading (the streaming-pipeline residency story): the read noise
is seeded *per row*, so :func:`generate_window` can materialize any
``(h, w)`` window of an image bit-identically to the corresponding slice
of :func:`generate_image` while holding only O(h * w) pixels (plus one
O(size) row buffer) — no host ever renders the frames it does not own.
(The per-row streams changed every image's noise realization relative to
the pre-windowing single-stream recipe; work logs and benchmark trend
lines recorded before that change describe different pixel data.)
:class:`AstroImage` wraps this as the tile provider the halo-tiled
distributed path loads through (Variant-1 ``load_self`` for tiles).
"""
from __future__ import annotations

import numpy as np

DENSITY_PER_KPX2 = 3.4 / 1000.0    # paper: ~340k objects on 10k x 10k


def star_params(image_id: int, size: int,
                *, density: float = DENSITY_PER_KPX2,
                amp_min: float = 10.0, amp_max: float = 5000.0):
    """Star draws for an image id (separate stream from the noise so the
    Variant-3 cost model can evaluate them without rendering the frame).

    The per-image star count is itself random (Poisson-like via a +-40%
    uniform factor) — this is what makes the workload skewed and the
    paper's straggler discussion meaningful."""
    rng = np.random.default_rng(np.random.SeedSequence([77, image_id, 1]))
    base = max(1, int(density * size * size))
    n_stars = max(1, int(base * rng.uniform(0.6, 1.4)))
    u = rng.random(n_stars)
    # Power-law amplitudes (faint objects dominate, like real number counts).
    a = amp_min * (1 - u * (1 - (amp_max / amp_min) ** -0.8)) ** (-1 / 0.8)
    xy = rng.random((n_stars, 2)) * size
    sig = rng.uniform(1.0, 2.5, n_stars)
    return a, xy, sig


def generate_window(image_id: int, row0: int, col0: int, h: int, w: int,
                    *, size: int = 1024,
                    density: float = DENSITY_PER_KPX2,
                    sky: float = 100.0, read_noise: float = 5.0,
                    amp_min: float = 10.0, amp_max: float = 5000.0,
                    stamp: int = 15) -> np.ndarray:
    """The ``[row0:row0+h, col0:col0+w]`` window of image ``image_id``,
    bit-identical to the same slice of :func:`generate_image` while only
    ever materializing the window itself (noise is drawn row by row from a
    per-row stream; only stars whose stamp intersects the window are
    rendered, and skipping the rest cannot change any in-window pixel).
    """
    if not (0 <= row0 and row0 + h <= size and 0 <= col0
            and col0 + w <= size and h >= 1 and w >= 1):
        raise ValueError(f"window [{row0}:{row0 + h}, {col0}:{col0 + w}] "
                         f"out of bounds for size {size}")
    img = np.empty((h, w), np.float32)
    for k in range(h):
        rng = np.random.default_rng(
            np.random.SeedSequence([77, image_id, 0, row0 + k]))
        row = rng.normal(sky, read_noise, size=size).astype(np.float32)
        img[k] = row[col0:col0 + w]

    a, xy, sig = star_params(image_id, size, density=density,
                             amp_min=amp_min, amp_max=amp_max)
    half = stamp // 2
    yy, xx = np.mgrid[-half:half + 1, -half:half + 1].astype(np.float32)
    iy_all = xy[:, 0].astype(np.int64)
    ix_all = xy[:, 1].astype(np.int64)
    hit = ((iy_all + half >= row0) & (iy_all - half < row0 + h)
           & (ix_all + half >= col0) & (ix_all - half < col0 + w))
    for i in np.flatnonzero(hit):
        cy, cx = xy[i]
        iy, ix = int(cy), int(cx)
        dy, dx = cy - iy, cx - ix
        g = a[i] * np.exp(-(((yy - dy) ** 2 + (xx - dx) ** 2)
                            / (2.0 * sig[i] ** 2)))
        y0 = max(row0, max(0, iy - half))
        y1 = min(row0 + h, min(size, iy + half + 1))
        x0 = max(col0, max(0, ix - half))
        x1 = min(col0 + w, min(size, ix + half + 1))
        if y0 >= y1 or x0 >= x1:
            continue
        gy0, gx0 = y0 - (iy - half), x0 - (ix - half)
        img[y0 - row0:y1 - row0, x0 - col0:x1 - col0] += \
            g[gy0:gy0 + (y1 - y0), gx0:gx0 + (x1 - x0)]
    return img


def generate_image(image_id: int, size: int = 1024, **kwargs) -> np.ndarray:
    """Deterministic synthetic star field, float32 (size, size) — the
    full-frame special case of :func:`generate_window`."""
    return generate_window(image_id, 0, 0, size, size, size=size, **kwargs)


def estimate_threshold(img: np.ndarray, n_sigma: float = 2.0) -> float:
    """Per-image background threshold (median + n_sigma * MAD-sigma), the
    paper's Variant-2 'threshold acquired with each image'."""
    med = float(np.median(img))
    mad = float(np.median(np.abs(img - med)))
    return med + n_sigma * 1.4826 * mad


FILTER_FACTORS = {"vanilla": None, "filter_light": 0.3, "filter_std": 1.0,
                  "filter_heavy": 1.3}


def _level_name(level) -> str:
    """Accept a plain string or a ``repro.ph.FilterLevel`` enum member."""
    name = getattr(level, "value", level)
    if name not in FILTER_FACTORS:
        raise ValueError(f"unknown filter level {level!r}; expected one of "
                         f"{sorted(FILTER_FACTORS)}")
    return name


def filter_threshold(img: np.ndarray, level) -> tuple[float | None,
                                                       float]:
    """Variant 2: per-image exclusion threshold.

    Returns (truncate_value or None, dropped pixel fraction).  The threshold
    is passed to ``pixhomology(..., truncate_value=t)`` which *excludes*
    sub-threshold pixels from the analysis algorithmically (births dropped,
    merges skipped, survivors truncated at t) — closer to the paper's
    "background pixels excluded from the subsequent analysis" than mutating
    the image would be, and it shortens the sequential merge sweep, which is
    the actual Variant-2 win on TPU (src/repro/ph/DESIGN.md §Perf).
    """
    factor = FILTER_FACTORS[_level_name(level)]
    if factor is None:
        return None, 0.0
    t = estimate_threshold(img) * factor
    return float(t), float((img < t).mean())


def estimate_cost(img: np.ndarray, level="filter_std") -> float:
    """Variant 3 LPT cost proxy: number of non-background pixels."""
    factor = FILTER_FACTORS[_level_name(level)] or 1.0
    t = estimate_threshold(img) * factor
    return float((img >= t).sum())


def estimate_cost_from_id(image_id: int, size: int) -> float:
    """Schedule-time cost estimate without rendering the frame: the number
    of above-background pixels scales with sum_i sigma_i^2 log(A_i / noise)
    (area of each Gaussian above the ~5-sigma noise floor)."""
    a, _, sig = star_params(image_id, size)
    visible = a > 25.0
    return float(np.sum(2 * np.pi * sig[visible] ** 2
                        * np.log(np.maximum(a[visible] / 25.0, 1.0 + 1e-6))))


class FrameSequence:
    """Deterministic survey stream over one base star field: frame 0 is
    the base frame, each later frame adds localized Gaussian transients
    confined to a chosen subset of tiles — the workload
    :meth:`repro.ph.PHEngine.run_delta` exists for.

    ``dirty_frac`` controls how many of the ``grid`` tiles each frame
    touches (at least one).  Transient stamps are placed at least
    ``stamp // 2 + 2`` pixels inside their tile, so with halo-padded tile
    hashing *exactly* the chosen tiles change (the stamp never reaches a
    neighbor's halo window); :meth:`dirty_tiles` returns the intended set
    for a frame so tests and benchmarks can assert the delta layer's
    classification against ground truth.  Everything is deterministic in
    ``(image_id, frame index)``.
    """

    def __init__(self, image_id: int, size: int = 1024, *,
                 grid: tuple[int, int] = (4, 4), dirty_frac: float = 0.1,
                 amp: float = 2000.0, stamp: int = 15, **gen_kwargs):
        gr, gc = int(grid[0]), int(grid[1])
        if size % gr or size % gc:
            raise ValueError(f"grid {grid} does not divide size {size}")
        margin = stamp // 2 + 2
        if size // gr <= 2 * margin or size // gc <= 2 * margin:
            raise ValueError(f"tiles {size // gr}x{size // gc} too small "
                             f"for stamp {stamp} with a 2px halo margin")
        if not 0.0 <= dirty_frac <= 1.0:
            raise ValueError(f"dirty_frac must be in [0, 1], "
                             f"got {dirty_frac}")
        self.image_id = int(image_id)
        self.size = int(size)
        self.grid = (gr, gc)
        self.dirty_frac = float(dirty_frac)
        self.amp = float(amp)
        self.stamp = int(stamp)
        self.gen_kwargs = gen_kwargs
        self._base: np.ndarray | None = None

    @property
    def shape(self) -> tuple[int, int]:
        return (self.size, self.size)

    def base(self) -> np.ndarray:
        """The shared frame-0 star field (rendered once, then reused)."""
        if self._base is None:
            self._base = generate_image(self.image_id, self.size,
                                        **self.gen_kwargs)
        return self._base

    def dirty_tiles(self, i: int) -> np.ndarray:
        """Row-major tile indices frame ``i`` perturbs (empty for frame
        0); ``ceil(dirty_frac * n_tiles)`` of them, at least one."""
        if i == 0:
            return np.empty(0, np.int64)
        gr, gc = self.grid
        n_tiles = gr * gc
        n_dirty = max(1, int(np.ceil(self.dirty_frac * n_tiles)))
        rng = np.random.default_rng(
            np.random.SeedSequence([77, self.image_id, 5, i]))
        return np.sort(rng.choice(n_tiles, size=min(n_dirty, n_tiles),
                                  replace=False))

    def frame(self, i: int) -> np.ndarray:
        """Frame ``i``: the base field plus one transient per dirty tile,
        each strictly interior to its tile (see class docstring)."""
        img = self.base().copy()
        if i == 0:
            return img
        gr, gc = self.grid
        tr, tc = self.size // gr, self.size // gc
        half = self.stamp // 2
        margin = half + 2
        yy, xx = np.mgrid[-half:half + 1, -half:half + 1].astype(np.float32)
        rng = np.random.default_rng(
            np.random.SeedSequence([77, self.image_id, 6, i]))
        for t in self.dirty_tiles(i):
            r0, c0 = (int(t) // gc) * tr, (int(t) % gc) * tc
            cy = r0 + rng.integers(margin, tr - margin)
            cx = c0 + rng.integers(margin, tc - margin)
            sig = rng.uniform(1.0, 2.5)
            a = self.amp * rng.uniform(0.5, 1.5)
            g = a * np.exp(-((yy ** 2 + xx ** 2) / (2.0 * sig ** 2)))
            img[cy - half:cy + half + 1, cx - half:cx + half + 1] += g
        return img

    def frames(self, n: int):
        """Generator of the first ``n`` frames (feeds
        ``PHEngine.run_sequence``)."""
        for i in range(n):
            yield self.frame(i)


class AstroImage:
    """Windowed Variant-1 loader for one synthetic frame (a tile provider).

    Nothing is rendered at construction; each :meth:`window` /
    :meth:`halo_tile` call materializes only the pixels it returns, so an
    executor that owns a few tiles of an oversized image never holds the
    frame — the streaming pipeline's residency guarantee.  Satisfies the
    tile-provider protocol of :func:`repro.core.tiling.load_tile_stacks`
    (``shape`` / ``dtype`` / ``halo_tile``).
    """

    dtype = np.float32

    def __init__(self, image_id: int, size: int = 1024, **gen_kwargs):
        self.image_id = int(image_id)
        self.size = int(size)
        self.gen_kwargs = gen_kwargs

    @property
    def shape(self) -> tuple[int, int]:
        return (self.size, self.size)

    def window(self, row0: int, col0: int, h: int, w: int) -> np.ndarray:
        return generate_window(self.image_id, row0, col0, h, w,
                               size=self.size, **self.gen_kwargs)

    def halo_tile(self, t: int, grid: tuple[int, int], *,
                  fill: float = -np.inf) -> np.ndarray:
        """Tile ``t`` (row-major) of the ``(gr, gc)`` grid with its 1-pixel
        halo; halo pixels outside the frame are ``fill`` (matching
        ``repro.core.tiling.split_tiles``)."""
        gr, gc = grid
        th, tw = self.size // gr, self.size // gc
        r0, c0 = (t // gc) * th, (t % gc) * tw
        out = np.full((th + 2, tw + 2), fill, np.float32)
        ry0, ry1 = max(0, r0 - 1), min(self.size, r0 + th + 1)
        rx0, rx1 = max(0, c0 - 1), min(self.size, c0 + tw + 1)
        win = self.window(ry0, rx0, ry1 - ry0, rx1 - rx0)
        out[ry0 - (r0 - 1):ry1 - (r0 - 1),
            rx0 - (c0 - 1):rx1 - (c0 - 1)] = win
        return out

    def filter_threshold(self, level, *, sample: int = 256) -> float | None:
        """Variant-2 threshold estimated on a centered ``sample``-square
        window (O(sample²) resident, deterministic) — the whole-frame
        statistic would defeat windowed loading for oversized images."""
        factor = FILTER_FACTORS[_level_name(level)]
        if factor is None:
            return None
        s = min(self.size, sample)
        off = (self.size - s) // 2
        return float(estimate_threshold(self.window(off, off, s, s))
                     * factor)
