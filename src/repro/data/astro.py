"""Synthetic astronomical images (paper §6.2).

The paper builds its 90-image dataset with astropy/photutils: a zeroed
array, Gaussian readout noise + sky background, then ~340k Gaussian stars
per 10k x 10k frame (≈3.4 objects / kilopixel²).  Astropy is not available
offline, so this module reimplements the same recipe in NumPy:

  image = sky + N(0, read_noise) + sum_i A_i * G(sigma_i, x_i, y_i)

Star amplitudes follow a power law (faint objects dominate, as in real
frames), PSF sigmas ~ U(1, 2.5) px.  Every image is deterministic in
``image_id`` (the pipeline's executors re-generate rather than transfer —
the paper's Variant-1 ``load_self``).
"""
from __future__ import annotations

import numpy as np

DENSITY_PER_KPX2 = 3.4 / 1000.0    # paper: ~340k objects on 10k x 10k


def star_params(image_id: int, size: int,
                *, density: float = DENSITY_PER_KPX2,
                amp_min: float = 10.0, amp_max: float = 5000.0):
    """Star draws for an image id (separate stream from the noise so the
    Variant-3 cost model can evaluate them without rendering the frame).

    The per-image star count is itself random (Poisson-like via a +-40%
    uniform factor) — this is what makes the workload skewed and the
    paper's straggler discussion meaningful."""
    rng = np.random.default_rng(np.random.SeedSequence([77, image_id, 1]))
    base = max(1, int(density * size * size))
    n_stars = max(1, int(base * rng.uniform(0.6, 1.4)))
    u = rng.random(n_stars)
    # Power-law amplitudes (faint objects dominate, like real number counts).
    a = amp_min * (1 - u * (1 - (amp_max / amp_min) ** -0.8)) ** (-1 / 0.8)
    xy = rng.random((n_stars, 2)) * size
    sig = rng.uniform(1.0, 2.5, n_stars)
    return a, xy, sig


def generate_image(image_id: int, size: int = 1024, *,
                   density: float = DENSITY_PER_KPX2,
                   sky: float = 100.0, read_noise: float = 5.0,
                   amp_min: float = 10.0, amp_max: float = 5000.0,
                   stamp: int = 15) -> np.ndarray:
    """Deterministic synthetic star field, float32 (size, size)."""
    rng = np.random.default_rng(np.random.SeedSequence([77, image_id, 0]))
    img = rng.normal(sky, read_noise, size=(size, size)).astype(np.float32)
    a, xy, sig = star_params(image_id, size, density=density,
                             amp_min=amp_min, amp_max=amp_max)
    n_stars = a.shape[0]

    half = stamp // 2
    yy, xx = np.mgrid[-half:half + 1, -half:half + 1].astype(np.float32)
    for i in range(n_stars):
        cy, cx = xy[i]
        iy, ix = int(cy), int(cx)
        dy, dx = cy - iy, cx - ix
        g = a[i] * np.exp(-(((yy - dy) ** 2 + (xx - dx) ** 2)
                            / (2.0 * sig[i] ** 2)))
        y0, y1 = max(0, iy - half), min(size, iy + half + 1)
        x0, x1 = max(0, ix - half), min(size, ix + half + 1)
        gy0, gx0 = y0 - (iy - half), x0 - (ix - half)
        img[y0:y1, x0:x1] += g[gy0:gy0 + (y1 - y0), gx0:gx0 + (x1 - x0)]
    return img


def estimate_threshold(img: np.ndarray, n_sigma: float = 2.0) -> float:
    """Per-image background threshold (median + n_sigma * MAD-sigma), the
    paper's Variant-2 'threshold acquired with each image'."""
    med = float(np.median(img))
    mad = float(np.median(np.abs(img - med)))
    return med + n_sigma * 1.4826 * mad


FILTER_FACTORS = {"vanilla": None, "filter_light": 0.3, "filter_std": 1.0,
                  "filter_heavy": 1.3}


def _level_name(level) -> str:
    """Accept a plain string or a ``repro.ph.FilterLevel`` enum member."""
    name = getattr(level, "value", level)
    if name not in FILTER_FACTORS:
        raise ValueError(f"unknown filter level {level!r}; expected one of "
                         f"{sorted(FILTER_FACTORS)}")
    return name


def filter_threshold(img: np.ndarray, level) -> tuple[float | None,
                                                       float]:
    """Variant 2: per-image exclusion threshold.

    Returns (truncate_value or None, dropped pixel fraction).  The threshold
    is passed to ``pixhomology(..., truncate_value=t)`` which *excludes*
    sub-threshold pixels from the analysis algorithmically (births dropped,
    merges skipped, survivors truncated at t) — closer to the paper's
    "background pixels excluded from the subsequent analysis" than mutating
    the image would be, and it shortens the sequential merge sweep, which is
    the actual Variant-2 win on TPU (EXPERIMENTS.md table 1).
    """
    factor = FILTER_FACTORS[_level_name(level)]
    if factor is None:
        return None, 0.0
    t = estimate_threshold(img) * factor
    return float(t), float((img < t).mean())


def estimate_cost(img: np.ndarray, level="filter_std") -> float:
    """Variant 3 LPT cost proxy: number of non-background pixels."""
    factor = FILTER_FACTORS[_level_name(level)] or 1.0
    t = estimate_threshold(img) * factor
    return float((img >= t).sum())


def estimate_cost_from_id(image_id: int, size: int) -> float:
    """Schedule-time cost estimate without rendering the frame: the number
    of above-background pixels scales with sum_i sigma_i^2 log(A_i / noise)
    (area of each Gaussian above the ~5-sigma noise floor)."""
    a, _, sig = star_params(image_id, size)
    visible = a > 25.0
    return float(np.sum(2 * np.pi * sig[visible] ** 2
                        * np.log(np.maximum(a[visible] / 25.0, 1.0 + 1e-6))))
