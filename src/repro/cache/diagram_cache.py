"""Bounded LRU stores behind delta recompute and the serving cache tier.

Two layers share this module:

* :class:`DiagramCache` — the **frame store** for
  :meth:`repro.ph.PHEngine.run_delta`.  One entry per cached frame:
  the per-tile content-hash grid, the device-resident
  :class:`repro.core.tiling.TileBoundaryState`, the finished
  :class:`repro.ph.PHResult`, and the capacities the state was built at.
  ``lookup`` classifies an incoming frame against the store (full hit /
  partial hit with a dirty mask / miss) in one call, so the engine's
  delta path is a straight line.  Entries are keyed by ``(context,
  digests)`` where ``context`` pins everything that must match for a
  cached state row to be *bit-reusable*: image shape, grid, dtype,
  threshold, hash algorithm, and the config plan key.  The threshold is
  part of the context on purpose — a Variant-2 threshold filters
  candidates and roots *inside* phase B, so state computed under a
  different threshold is not reusable (a changed threshold is a full
  miss, never a wrong answer).

* :class:`LRUCache` — a generic bounded mapping with hit/miss/evict
  counters; the serving daemon keys finished results by the exact
  request hash so repeated requests bypass the queue entirely.

Eviction policy (both layers): least-recently-*used* — every full or
partial hit refreshes the entry; inserting past ``capacity`` evicts the
stalest entry and counts it.  Collision policy: by default a 128-bit
content hash is trusted (the engineering-standard birthday bound); with
``DeltaSpec.verify`` the caller passes the raw tile bytes and every
clean classification is byte-compared — a collision is then *detected*:
the tile is reclassified dirty (harmless, just recomputed) and counted
in ``stats.collisions``.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any

import numpy as np


@dataclasses.dataclass
class CacheStats:
    """Counters for one cache instance (snapshot-friendly)."""

    hits: int = 0            # full hits: identical frame / exact request
    partial_hits: int = 0    # near-duplicate: subset of tiles dirty
    misses: int = 0
    inserts: int = 0
    evictions: int = 0
    collisions: int = 0      # verify-mode digest collisions caught

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class FrameCacheEntry:
    """One cached frame of the delta store.

    ``state`` is the stacked per-tile :class:`TileBoundaryState` exactly
    as the scatter-merge program produced it (device-resident — reusing
    it costs no host round-trip).  ``capacities`` records the
    ``(max_features, tile_max_features, tile_max_candidates)`` the state
    was built at: a partial hit requires equal capacities (state arrays
    are shape-static), while a full hit does not (the finished result is
    returned as-is).  ``tile_bytes`` is populated only in verify mode.
    """

    digests: tuple[bytes, ...]
    state: Any
    result: Any
    capacities: tuple[int, int, int]
    tile_bytes: tuple[bytes, ...] | None = None


class LRUCache:
    """Thread-safe bounded mapping with LRU eviction and counters."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self.stats = CacheStats()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key):
        with self._lock:
            got = self._entries.get(key)
            if got is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return got

    def put(self, key, value) -> None:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
            self._entries[key] = value
            self.stats.inserts += 1
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1


class DiagramCache:
    """Bounded LRU of :class:`FrameCacheEntry` keyed by (context, digests).

    ``lookup`` is the single classification entry point; ``put`` inserts
    or refreshes.  Near-duplicate matching scans same-context entries and
    picks the one with the most clean tiles — the store is small by
    design (``DeltaSpec.cache_entries``), so the scan is O(entries).
    """

    def __init__(self, capacity: int = 4):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._entries: collections.OrderedDict[tuple, FrameCacheEntry] = \
            collections.OrderedDict()
        self.stats = CacheStats()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def _verified_clean(self, entry: FrameCacheEntry, clean: np.ndarray,
                        tile_bytes) -> np.ndarray:
        """Byte-compare verify pass: demote hash-clean tiles whose bytes
        actually differ (a detected collision) to dirty."""
        if tile_bytes is None or entry.tile_bytes is None:
            return clean
        out = clean.copy()
        for t in np.flatnonzero(clean):
            if entry.tile_bytes[t] != tile_bytes[t]:
                out[t] = False
                self.stats.collisions += 1
        return out

    def lookup(self, context: tuple, digests: tuple[bytes, ...],
               capacities: tuple[int, int, int] | None = None,
               tile_bytes: tuple[bytes, ...] | None = None
               ) -> tuple[str, FrameCacheEntry | None, np.ndarray | None]:
        """Classify a frame: ``("hit", entry, None)`` for an identical
        frame, ``("partial", entry, dirty_mask)`` for the best
        same-context near-duplicate (fewest dirty tiles; requires
        matching ``capacities``), else ``("miss", None, None)``.

        ``tile_bytes`` (verify mode) demotes colliding tiles to dirty
        before classification — a full-grid collision therefore degrades
        to a partial/miss instead of returning a stale diagram.
        """
        with self._lock:
            exact = self._entries.get((context, digests))
            if exact is not None:
                clean = np.ones(len(digests), bool)
                clean = self._verified_clean(exact, clean, tile_bytes)
                if clean.all():
                    self._entries.move_to_end((context, digests))
                    self.stats.hits += 1
                    return "hit", exact, None
                # collision inside an exact-digest match: fall through to
                # the partial path with the demoted mask
                if capacities is None or exact.capacities == capacities:
                    self._entries.move_to_end((context, digests))
                    self.stats.partial_hits += 1
                    return "partial", exact, ~clean
            best_key, best_clean = None, None
            for key, entry in self._entries.items():
                if key[0] != context or len(key[1]) != len(digests):
                    continue
                if capacities is not None and \
                        entry.capacities != capacities:
                    continue
                clean = np.array([a == b for a, b in
                                  zip(key[1], digests)], bool)
                clean = self._verified_clean(entry, clean, tile_bytes)
                if best_clean is None or clean.sum() > best_clean.sum():
                    best_key, best_clean = key, clean
            if best_key is not None and best_clean.any():
                self._entries.move_to_end(best_key)
                self.stats.partial_hits += 1
                return "partial", self._entries[best_key], ~best_clean
            self.stats.misses += 1
            return "miss", None, None

    def put(self, context: tuple, entry: FrameCacheEntry) -> None:
        with self._lock:
            key = (context, entry.digests)
            if key in self._entries:
                self._entries.move_to_end(key)
            self._entries[key] = entry
            self.stats.inserts += 1
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
