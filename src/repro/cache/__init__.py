"""Bounded caches for PH results: the delta-recompute frame store and the
serving daemon's exact-result tier.

:class:`DiagramCache` keys device-resident per-frame tiled state
(:class:`repro.core.tiling.TileBoundaryState`) by ``(context, tile-hash
grid)`` and answers three questions in one lookup: identical frame (full
hit — the cached diagram is returned without touching the device),
near-duplicate frame (partial hit — the clean-tile subset of the state is
reusable), or miss.  :class:`LRUCache` is the generic bounded mapping the
serving cache tier uses for exact request-hash results.
"""
from repro.cache.diagram_cache import (  # noqa: F401
    CacheStats,
    DiagramCache,
    FrameCacheEntry,
    LRUCache,
)
