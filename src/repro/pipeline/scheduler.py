"""Workload partitioning strategies (paper §5.2.1 Variant 3), shape-aware.

Spark semantics -> SPMD adaptation (src/repro/ph/DESIGN.md §5): executors are mesh
devices and work proceeds in synchronized *rounds* (one image per executor
per round).  A strategy turns (image ids, cost estimates, m executors) into
per-executor queues; the driver zips queues into rounds.  Makespan under
this model is sum over rounds of the max per-round cost, which the
schedulers below minimize the same way they do in the paper:

* part_executors — shuffle, one contiguous chunk per executor (static).
* part_images   — one partition per image, round-robin over executors as
  they free up (Spark's default dynamic assignment; simulated greedily).
* part_LPT      — Longest-Processing-Time over estimated costs (Graham):
  sort descending, repeatedly assign to the least-loaded executor.

Heterogeneous datasets (:func:`make_bucketed_schedule`): image ids carry
``(H, W)`` metadata (:class:`ImageMeta`), and rounds are built from *shape
buckets* — every image in a round shares one padded bucket shape, so one
cached sharded plan serves the whole round.  Cost balancing is LPT within
each bucket and across buckets: buckets are processed largest-shape first,
and free executor slots in a bucket's rounds are back-filled with images
from smaller buckets whenever their pad-inflated cost does not raise the
round maximum (so padding is only ever "free").  Images above the tiled
routing bound schedule as per-image tile-grid rounds (the tiles span the
mesh) instead of competing for whole-image slots.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np


@dataclasses.dataclass
class Schedule:
    strategy: str
    queues: list[list[int]]          # per-executor ordered image ids

    @property
    def num_rounds(self) -> int:
        return max((len(q) for q in self.queues), default=0)

    def rounds(self):
        """Yield per-round lists of (executor, image_id)."""
        for r in range(self.num_rounds):
            yield [(e, q[r]) for e, q in enumerate(self.queues)
                   if r < len(q)]

    def makespan(self, costs: dict[int, float]) -> float:
        """Lockstep-round makespan: sum of per-round maxima."""
        total = 0.0
        for rnd in self.rounds():
            total += max(costs[i] for _, i in rnd)
        return total

    def queue_makespan(self, costs: dict[int, float]) -> float:
        """Classic (asynchronous-executor) makespan: max queue sum."""
        return max((sum(costs[i] for i in q) for q in self.queues),
                   default=0.0)

    def padded_makespan(self, costs: dict[int, float],
                        metas_by_id: dict[int, "ImageMeta"],
                        pad_shape: tuple[int, int]) -> float:
        """Lockstep makespan of this shape-agnostic schedule on a
        heterogeneous dataset: every round runs one program at
        ``pad_shape`` (the global maximum bucket), so each image pays the
        :func:`effective_cost` pad inflation — the baseline
        :func:`make_bucketed_schedule` is measured against."""
        total = 0.0
        for rnd in self.rounds():
            total += max(effective_cost(costs[i], metas_by_id[i], pad_shape)
                         for _, i in rnd)
        return total


def part_executors(ids, m: int, *, seed: int = 0) -> Schedule:
    ids = list(ids)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(ids))
    chunks = np.array_split(perm, m)
    return Schedule("part_executors",
                    [[ids[i] for i in c] for c in chunks])


def part_images(ids, m: int, costs=None) -> Schedule:
    """Greedy dynamic assignment: next image goes to the executor whose
    queue finishes first (equal costs -> round robin, like Spark default)."""
    ids = list(ids)
    loads = [0.0] * m
    queues: list[list[int]] = [[] for _ in range(m)]
    for i in ids:
        e = int(np.argmin(loads))
        queues[e].append(i)
        loads[e] += 1.0 if costs is None else costs[i]
    return Schedule("part_images", queues)


def part_lpt(ids, m: int, costs) -> Schedule:
    """Graham's LPT rule on estimated processing times."""
    order = sorted(ids, key=lambda i: -costs[i])
    loads = [0.0] * m
    queues: list[list[int]] = [[] for _ in range(m)]
    for i in order:
        e = int(np.argmin(loads))
        queues[e].append(i)
        loads[e] += costs[i]
    return Schedule("part_LPT", queues)


STRATEGIES = {"part_executors": part_executors, "part_images": part_images,
              "part_LPT": part_lpt}


def make_schedule(strategy: str, ids, m: int, costs=None, seed: int = 0):
    if strategy == "part_executors":
        return part_executors(ids, m, seed=seed)
    if strategy == "part_images":
        return part_images(ids, m, costs)
    if strategy == "part_LPT":
        if costs is None:
            raise ValueError("part_LPT needs cost estimates (Variant 3)")
        return part_lpt(ids, m, costs)
    raise ValueError(strategy)


# ---------------------------------------------------------------------------
# Shape-aware scheduling: buckets, tile-grid rounds, pad-aware makespan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ImageMeta:
    """An image id plus the ``(H, W)`` shape the scheduler plans with."""

    image_id: int
    shape: tuple[int, int]

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(self.shape))
        h, w = self.shape
        if h < 1 or w < 1:
            raise ValueError(f"bad image shape {self.shape}")

    @property
    def pixels(self) -> int:
        return self.shape[0] * self.shape[1]


def normalize_images(images: Iterable, default_size: int = 512
                     ) -> list[ImageMeta]:
    """Coerce a heterogeneous dataset spec into :class:`ImageMeta` rows.

    Accepted elements: ``ImageMeta``; a bare ``int`` id (shape
    ``(default_size, default_size)``); an ``(id, size)`` pair; an
    ``(id, (H, W))`` pair.
    """
    metas = []
    for item in images:
        if isinstance(item, ImageMeta):
            metas.append(item)
        elif isinstance(item, (int, np.integer)):
            metas.append(ImageMeta(int(item), (default_size, default_size)))
        else:
            img_id, shape = item
            if isinstance(shape, (int, np.integer)):
                shape = (int(shape), int(shape))
            metas.append(ImageMeta(int(img_id), tuple(shape)))
    ids = [m.image_id for m in metas]
    if len(set(ids)) != len(ids):
        raise ValueError("duplicate image ids in dataset")
    return metas


def _next_pow2(x: int) -> int:
    return 1 << (int(x) - 1).bit_length()


def bucket_shape(shape: tuple[int, int], rounding: str = "pow2"
                 ) -> tuple[int, int]:
    """The padded bucket an image shape schedules under."""
    if rounding == "exact":
        return tuple(shape)
    if rounding == "pow2":
        return (_next_pow2(shape[0]), _next_pow2(shape[1]))
    raise ValueError(f"unknown bucket rounding {rounding!r}")


def assign_bucket(shape: tuple[int, int],
                  buckets: tuple[tuple[int, int], ...] | None = None,
                  rounding: str = "pow2") -> tuple[int, int] | None:
    """The serving bucket a request ``shape`` dispatches under.

    With a fixed ``buckets`` set (``ServeSpec.buckets``, sorted
    smallest-first) the tightest bucket containing the shape wins —
    ``None`` when it fits none (the caller rejects the request; a
    too-large image must go through the tiled path, not a padded batch).
    Without one, the shape derives its own bucket via
    :func:`bucket_shape`, exactly like the batch pipeline's rounds.
    """
    if buckets is None:
        return bucket_shape(tuple(shape), rounding)
    h, w = shape
    for hb, wb in buckets:
        if h <= hb and w <= wb:
            return (hb, wb)
    return None


def effective_cost(cost: float, meta: ImageMeta,
                   shape: tuple[int, int]) -> float:
    """Pad-aware cost: running ``meta`` inside a ``shape``-padded program
    scales the estimate by the padded/own pixel ratio (phases 1-2 of the
    algorithm sweep every padded pixel)."""
    return cost * (shape[0] * shape[1]) / meta.pixels


@dataclasses.dataclass(frozen=True)
class BucketRound:
    """One lockstep dispatch: a shape bucket's round, or one tiled image.

    ``kind="whole"``: ``entries`` are ``(executor_slot, meta)`` pairs, every
    image padded to ``shape``.  ``kind="tiled"``: a single oversized image
    whose tile grid spans the mesh; ``entries`` holds its one meta.
    """

    kind: str
    shape: tuple[int, int]
    entries: tuple[tuple[int, ImageMeta], ...]

    @property
    def image_ids(self) -> list[int]:
        return [meta.image_id for _, meta in self.entries]

    def cost(self, costs: dict[int, float]) -> float:
        if self.kind == "tiled":
            return sum(costs[meta.image_id] for _, meta in self.entries)
        return max(effective_cost(costs[meta.image_id], meta, self.shape)
                   for _, meta in self.entries)


@dataclasses.dataclass
class BucketedSchedule:
    strategy: str
    round_list: list[BucketRound]

    @property
    def num_rounds(self) -> int:
        return len(self.round_list)

    def rounds(self):
        yield from self.round_list

    def makespan(self, costs: dict[int, float]) -> float:
        """Lockstep pad-aware makespan: sum of per-round maxima of
        :func:`effective_cost` (tiled rounds cost their whole image)."""
        return sum(r.cost(costs) for r in self.round_list)


def _bucket_rounds(strategy: str, buckets: dict, m: int, costs, *,
                   pad: bool, rounding: str,
                   seed: int = 0) -> list[BucketRound]:
    """Rounds for a bucket partition, largest bucket shape first.

    ``part_LPT`` builds each bucket's rounds by *sorted banding* —
    descending (pad-aware) cost, groups of m — which is optimal for the
    lockstep sum-of-round-maxima makespan (the j-th round's max is the
    (jm+1)-th largest cost, the universal lower bound); other strategies
    keep their queue-zip semantics.  When padding is allowed and costs are
    known, free executor slots are back-filled with the most expensive
    smaller-bucket images whose pad-inflated cost does not raise the round
    maximum (padding only ever "free").
    """
    buckets = {shape: list(pool) for shape, pool in buckets.items()}
    rounds: list[BucketRound] = []
    order = sorted(buckets, key=lambda s: (-s[0] * s[1], s))
    for bi, shape in enumerate(order):
        pool = buckets[shape]
        if not pool:
            continue
        if strategy == "part_LPT":
            ordered = sorted(
                pool, key=lambda meta: (-effective_cost(
                    costs[meta.image_id], meta, shape), meta.image_id))
            raw = [[(k % m, meta.image_id) for k, meta in
                    enumerate(ordered[r:r + m])]
                   for r in range(0, len(ordered), m)]
        else:
            sched = make_schedule(strategy, [meta.image_id for meta in pool],
                                  m, costs, seed=seed)
            raw = list(sched.rounds())
        by_id = {meta.image_id: meta for meta in pool}
        smaller = [meta for s in order[bi + 1:] for meta in buckets[s]]
        for rnd in raw:
            entries = [(slot, by_id[i]) for slot, i in rnd]
            if pad and costs is not None and smaller and len(entries) < m:
                used = {slot for slot, _ in entries}
                free = [s for s in range(m) if s not in used]
                rmax = max(effective_cost(costs[meta.image_id], meta, shape)
                           for _, meta in entries)
                smaller.sort(key=lambda meta: -costs[meta.image_id])
                for slot in free:
                    pick = next(
                        (meta for meta in smaller
                         if effective_cost(costs[meta.image_id], meta,
                                           shape) <= rmax), None)
                    if pick is None:
                        break
                    smaller.remove(pick)
                    buckets[bucket_shape(pick.shape, rounding)].remove(pick)
                    entries.append((slot, pick))
            rounds.append(BucketRound("whole", shape, tuple(entries)))
    return rounds


def make_bucketed_schedule(strategy: str, metas, m: int, costs=None, *,
                           rounding: str = "pow2", pad: bool = True,
                           max_tile_pixels: int | None = None,
                           seed: int = 0) -> BucketedSchedule:
    """Schedule a heterogeneous dataset into shape-bucketed rounds.

    ``pad=False`` forces exact-shape buckets and disables cross-bucket
    back-fill (required when no finite Variant-2 threshold exists: padded
    pixels are only provably inert below a threshold).  Back-fill also
    needs ``costs``; without them buckets stay self-contained.

    For ``part_LPT`` with costs and padding allowed, two candidates are
    evaluated under the pad-aware lockstep makespan and the cheaper wins:
    per-shape buckets (no pad waste, but buckets serialize), and one
    global bucket at the maximum shape (everything padded, but maximal
    slot utilization — this candidate's banding alone already lower-bounds
    any shape-agnostic schedule at that pad shape, so bucketed-LPT never
    loses to ``part_images``-on-padded-images).
    """
    if strategy == "part_LPT" and costs is None:
        raise ValueError("part_LPT needs cost estimates (Variant 3)")
    metas = list(metas)
    tiled = [meta for meta in metas
             if max_tile_pixels is not None and meta.pixels > max_tile_pixels]
    tiled_ids = {meta.image_id for meta in tiled}
    regular = [meta for meta in metas if meta.image_id not in tiled_ids]
    if not pad:
        rounding = "exact"

    buckets: dict[tuple[int, int], list[ImageMeta]] = {}
    for meta in regular:
        buckets.setdefault(bucket_shape(meta.shape, rounding),
                           []).append(meta)

    rounds = _bucket_rounds(strategy, buckets, m, costs, pad=pad,
                            rounding=rounding, seed=seed)
    if (strategy == "part_LPT" and pad and costs is not None
            and len(buckets) > 1):
        top = max(buckets, key=lambda s: s[0] * s[1])
        merged = _bucket_rounds(strategy, {top: regular}, m, costs,
                                pad=pad, rounding=rounding, seed=seed)
        def span(rs):
            return sum(r.cost(costs) for r in rs)
        if span(merged) < span(rounds):
            rounds = merged

    if costs is not None:
        tiled.sort(key=lambda meta: -costs[meta.image_id])
    for meta in tiled:
        rounds.append(BucketRound("tiled", meta.shape, ((0, meta),)))
    return BucketedSchedule(strategy, rounds)
