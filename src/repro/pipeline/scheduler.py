"""Workload partitioning strategies (paper §5.2.1 Variant 3).

Spark semantics -> SPMD adaptation (DESIGN.md §2): executors are mesh
devices and work proceeds in synchronized *rounds* (one image per executor
per round).  A strategy turns (image ids, cost estimates, m executors) into
per-executor queues; the driver zips queues into rounds.  Makespan under
this model is sum over rounds of the max per-round cost, which the
schedulers below minimize the same way they do in the paper:

* part_executors — shuffle, one contiguous chunk per executor (static).
* part_images   — one partition per image, round-robin over executors as
  they free up (Spark's default dynamic assignment; simulated greedily).
* part_LPT      — Longest-Processing-Time over estimated costs (Graham):
  sort descending, repeatedly assign to the least-loaded executor.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Schedule:
    strategy: str
    queues: list[list[int]]          # per-executor ordered image ids

    @property
    def num_rounds(self) -> int:
        return max((len(q) for q in self.queues), default=0)

    def rounds(self):
        """Yield per-round lists of (executor, image_id)."""
        for r in range(self.num_rounds):
            yield [(e, q[r]) for e, q in enumerate(self.queues)
                   if r < len(q)]

    def makespan(self, costs: dict[int, float]) -> float:
        """Lockstep-round makespan: sum of per-round maxima."""
        total = 0.0
        for rnd in self.rounds():
            total += max(costs[i] for _, i in rnd)
        return total

    def queue_makespan(self, costs: dict[int, float]) -> float:
        """Classic (asynchronous-executor) makespan: max queue sum."""
        return max((sum(costs[i] for i in q) for q in self.queues),
                   default=0.0)


def part_executors(ids, m: int, *, seed: int = 0) -> Schedule:
    ids = list(ids)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(ids))
    chunks = np.array_split(perm, m)
    return Schedule("part_executors",
                    [[ids[i] for i in c] for c in chunks])


def part_images(ids, m: int, costs=None) -> Schedule:
    """Greedy dynamic assignment: next image goes to the executor whose
    queue finishes first (equal costs -> round robin, like Spark default)."""
    ids = list(ids)
    loads = [0.0] * m
    queues: list[list[int]] = [[] for _ in range(m)]
    for i in ids:
        e = int(np.argmin(loads))
        queues[e].append(i)
        loads[e] += 1.0 if costs is None else costs[i]
    return Schedule("part_images", queues)


def part_lpt(ids, m: int, costs) -> Schedule:
    """Graham's LPT rule on estimated processing times."""
    order = sorted(ids, key=lambda i: -costs[i])
    loads = [0.0] * m
    queues: list[list[int]] = [[] for _ in range(m)]
    for i in order:
        e = int(np.argmin(loads))
        queues[e].append(i)
        loads[e] += costs[i]
    return Schedule("part_LPT", queues)


STRATEGIES = {"part_executors": part_executors, "part_images": part_images,
              "part_LPT": part_lpt}


def make_schedule(strategy: str, ids, m: int, costs=None, seed: int = 0):
    if strategy == "part_executors":
        return part_executors(ids, m, seed=seed)
    if strategy == "part_images":
        return part_images(ids, m, costs)
    if strategy == "part_LPT":
        if costs is None:
            raise ValueError("part_LPT needs cost estimates (Variant 3)")
        return part_lpt(ids, m, costs)
    raise ValueError(strategy)
