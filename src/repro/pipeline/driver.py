"""Pipeline driver: scheduling rounds, work-log fault tolerance, elasticity.

Spark-equivalents (paper §4.2, §5.2): the driver only moves image *ids*
(negligible traffic, paper Variant 1); completed work is recorded in an
append-only JSONL work-log so a crashed/restarted run (or an injected
executor failure) re-schedules only the incomplete images — the Spark
lineage/checkpoint story.  Changing the executor count between rounds
re-schedules the remaining work (elastic scaling).

``run_pipeline`` is the engine's distributed workhorse: call it through
:meth:`repro.ph.PHEngine.run_distributed`.  ``pool`` is any executor with
``num_executors`` / ``image_size`` / ``load_self`` / ``run_round``
(normally :class:`repro.pipeline.executor.ShardedPHExecutor`).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.pipeline.scheduler import make_schedule


@dataclasses.dataclass
class PipelineResult:
    diagrams: dict          # image_id -> dict summary
    rounds: int
    failures: int
    elapsed_s: float


class FailureInjector:
    """Deterministically fail chosen rounds once each (for tests/benchmarks)."""

    def __init__(self, fail_rounds=()):
        self.fail_rounds = set(fail_rounds)
        self.seen = set()

    def __call__(self, round_idx: int):
        if round_idx in self.fail_rounds and round_idx not in self.seen:
            self.seen.add(round_idx)
            raise RuntimeError(f"injected executor failure in round "
                               f"{round_idx}")


def _summarize(diag, idx: int) -> dict:
    count = int(diag.count[idx])
    return {
        "count": count,
        "overflow": bool(diag.overflow[idx]),
        "top_births": np.asarray(diag.birth[idx][:5], np.float64).tolist(),
        "top_deaths": np.asarray(diag.death[idx][:5], np.float64).tolist(),
        "persistence_sum": float(np.sum(
            np.clip(np.asarray(diag.birth[idx][:count], np.float64)
                    - np.asarray(diag.death[idx][:count], np.float64),
                    0, None))),
    }


def run_pipeline(pool, image_ids, *, strategy: str = "part_LPT",
                 work_log: str | Path | None = None,
                 failure_injector=None, max_retries: int = 3,
                 verbose: bool = False) -> PipelineResult:
    t0 = time.time()
    log_path = Path(work_log) if work_log else None
    done: dict[int, dict] = {}

    # Resume from the work log (fault tolerance across driver restarts).
    if log_path and log_path.exists():
        for line in log_path.read_text().splitlines():
            rec = json.loads(line)
            done[rec["image_id"]] = rec["summary"]

    pending = [i for i in image_ids if i not in done]
    failures = 0
    rounds = 0
    attempt = 0

    while pending and attempt <= max_retries:
        attempt += 1
        m = pool.num_executors
        # Variant 2 costs come from the executors' own load pass; for
        # scheduling we use the cheap deterministic estimate.
        costs = {i: _cheap_cost(pool, i) for i in pending}
        sched = make_schedule(strategy, pending, m, costs)
        try:
            for rnd in sched.rounds():
                ids = [i for _, i in rnd]
                if failure_injector:
                    failure_injector(rounds)
                imgs, thresholds, _ = pool.load_self(ids)
                if imgs.shape[0] < m:          # pad the last round
                    padn = m - imgs.shape[0]
                    imgs = np.concatenate(
                        [imgs, np.repeat(imgs[-1:], padn, 0)], axis=0)
                    thresholds = np.concatenate(
                        [thresholds, np.repeat(thresholds[-1:], padn)])
                diags = pool.run_round(imgs, thresholds)
                for slot, img_id in enumerate(ids):
                    summary = _summarize(diags, slot)
                    done[img_id] = summary
                    if log_path:
                        with log_path.open("a") as f:
                            f.write(json.dumps(
                                {"image_id": img_id,
                                 "summary": summary}) + "\n")
                rounds += 1
                if verbose:
                    print(f"round {rounds}: {len(ids)} images "
                          f"({len(done)}/{len(image_ids)})", flush=True)
            pending = [i for i in image_ids if i not in done]
        except RuntimeError as e:
            failures += 1
            pending = [i for i in image_ids if i not in done]
            if verbose:
                print(f"FAILURE (attempt {attempt}): {e}; "
                      f"{len(pending)} images re-scheduled", flush=True)

    if pending:
        raise RuntimeError(f"pipeline could not finish {len(pending)} images "
                           f"after {max_retries} retries")
    return PipelineResult(done, rounds, failures, time.time() - t0)


def _cheap_cost(pool, image_id: int) -> float:
    from repro.data.astro import estimate_cost_from_id
    return estimate_cost_from_id(image_id, pool.image_size)
