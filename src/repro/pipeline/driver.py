"""Pipeline driver: bucketed rounds, prefetch overlap, work-log tolerance.

Spark-equivalents (paper §4.2, §5.2): the driver only moves image *ids and
shapes* (negligible traffic, paper Variant 1); completed work is recorded
in an append-only JSONL work-log so a crashed/restarted run (or an injected
executor failure) re-schedules only the incomplete images — the Spark
lineage/checkpoint story.  Changing the executor count between rounds
re-schedules the remaining work (elastic scaling).

Streaming heterogeneous batches: the schedule is shape-bucketed
(:func:`repro.pipeline.scheduler.make_bucketed_schedule` — one padded
bucket shape per round, oversized images as tile-grid rounds), and a
background loader thread stages round r+1's shards on device while round r
computes (double buffering; ``PHConfig.prefetch_rounds``).  Failures keep
their semantics: a staged-but-unconsumed round is simply discarded and its
images re-scheduled from the work log.

Overlap engine (``PHConfig.overlap`` with ``async_harvest``): instead of
blocking on each round's results, the driver dispatches through the
pool's ``begin_staged`` and hands the deferred resolution to a harvest
thread, keeping up to ``OverlapSpec.staging_depth`` rounds in flight —
so in steady state the dispatch loop performs **zero** blocking device
readbacks (counter-verified: ``OverlapCounters.dispatch_syncs``).  The
failure injector now observes *dispatch sequence numbers* (identical to
completed-round indices in synchronous mode); on a failure, rounds whose
harvest already completed are recorded — they are real results — while
unresolved in-flight rounds are discarded and their images re-schedule
from the work log, exactly like a discarded prefetch slot.

``run_pipeline`` is the engine's distributed workhorse: call it through
:meth:`repro.ph.PHEngine.run_distributed`.  ``pool`` is any executor with
``num_executors`` / ``estimate_costs`` / ``load_round`` / ``run_staged``
plus the scheduling knobs ``bucket_rounding`` / ``pad_ok`` /
``prefetch_rounds`` / ``max_tile_pixels`` (normally
:class:`repro.pipeline.executor.ShardedPHExecutor`).
"""
from __future__ import annotations

import dataclasses
import json
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np

from repro.pipeline.scheduler import make_bucketed_schedule, normalize_images


@dataclasses.dataclass
class PipelineResult:
    diagrams: dict          # image_id -> dict summary
    rounds: int
    failures: int
    elapsed_s: float


class FailureInjector:
    """Deterministically fail chosen rounds once each (for tests/benchmarks)."""

    def __init__(self, fail_rounds=()):
        self.fail_rounds = set(fail_rounds)
        self.seen = set()

    def __call__(self, round_idx: int):
        if round_idx in self.fail_rounds and round_idx not in self.seen:
            self.seen.add(round_idx)
            raise RuntimeError(f"injected executor failure in round "
                               f"{round_idx}")


def _summarize(diag) -> dict:
    count = int(diag.count)
    return {
        "count": count,
        "overflow": bool(diag.overflow),
        "top_births": np.asarray(diag.birth[:5], np.float64).tolist(),
        "top_deaths": np.asarray(diag.death[:5], np.float64).tolist(),
        "persistence_sum": float(np.sum(
            np.clip(np.asarray(diag.birth[:count], np.float64)
                    - np.asarray(diag.death[:count], np.float64),
                    0, None))),
    }


def run_pipeline(pool, images, *, strategy: str = "part_LPT",
                 work_log: str | Path | None = None,
                 failure_injector=None, max_retries: int = 3,
                 verbose: bool = False) -> PipelineResult:
    t0 = time.time()
    metas = normalize_images(images,
                             default_size=getattr(pool, "image_size", 512))
    log_path = Path(work_log) if work_log else None
    done: dict[int, dict] = {}

    # Resume from the work log (fault tolerance across driver restarts).
    if log_path and log_path.exists():
        for line in log_path.read_text().splitlines():
            rec = json.loads(line)
            done[rec["image_id"]] = rec["summary"]

    pending = [m for m in metas if m.image_id not in done]
    failures = 0
    rounds = 0
    attempt = 0
    prefetch = max(0, int(getattr(pool, "prefetch_rounds", 0)))
    ospec = getattr(pool, "overlap", None)
    overlapped = (ospec is not None and ospec.enabled
                  and ospec.async_harvest
                  and hasattr(pool, "begin_staged"))
    depth = ospec.staging_depth if overlapped else 0
    counters = getattr(getattr(pool, "engine", None),
                       "overlap_counters", None)

    def record(rnd, per_image):
        nonlocal rounds
        for img_id, diag in per_image.items():
            summary = _summarize(diag)
            done[img_id] = summary
            if log_path:
                with log_path.open("a") as f:
                    f.write(json.dumps(
                        {"image_id": img_id,
                         "summary": summary}) + "\n")
        rounds += 1
        if verbose:
            print(f"round {rounds}: {rnd.kind} {rnd.shape} "
                  f"{len(per_image)} images "
                  f"({len(done)}/{len(metas)})", flush=True)

    def resolve_on_harvest(pending_round):
        # Runs on the harvest thread: blocking readbacks are free here.
        if counters is not None:
            counters.bump("harvest_syncs")
        return pending_round.resolve()

    while pending and attempt <= max_retries:
        attempt += 1
        m = pool.num_executors
        # Variant-3 costs come from the executor (measured where a load
        # already ran, the render-free estimate otherwise).
        costs = pool.estimate_costs(pending)
        sched = make_bucketed_schedule(
            strategy, pending, m, costs,
            rounding=getattr(pool, "bucket_rounding", "exact"),
            pad=getattr(pool, "pad_ok", False),
            max_tile_pixels=getattr(pool, "max_tile_pixels", None))
        round_list = list(sched.rounds())
        loader = ThreadPoolExecutor(max_workers=1, thread_name_prefix="ph-load") \
            if prefetch and len(round_list) > 1 else None
        harvest = ThreadPoolExecutor(max_workers=1,
                                     thread_name_prefix="ph-harvest") \
            if overlapped else None
        staged_q: list = []     # FIFO of in-flight load futures
        harvest_q: list = []    # FIFO of (harvest future, round)
        next_load = 0
        # Dispatch sequence for the failure injector: in synchronous mode
        # it equals the completed-round counter at injection time, so
        # injector semantics are unchanged; under overlap it indexes
        # dispatch order (rounds ahead of the harvested count).
        seq = rounds

        def top_up():
            # The front future is the round about to be consumed; while a
            # round computes, at most `prefetch` later rounds stay staged.
            nonlocal next_load
            while (loader is not None and len(staged_q) < prefetch
                   and next_load < len(round_list)):
                staged_q.append(loader.submit(pool.load_round,
                                              round_list[next_load]))
                next_load += 1

        try:
            for rnd in round_list:
                # Double buffering: the loader thread stages ahead while
                # this thread computes; with prefetch off, load inline.
                top_up()
                if staged_q:
                    staged = staged_q.pop(0).result()
                else:
                    staged = pool.load_round(rnd)
                    next_load += 1
                top_up()
                if failure_injector:
                    failure_injector(seq)
                seq += 1
                if harvest is not None:
                    # Overlapped: dispatch now, resolve on the harvest
                    # thread; block only when the in-flight window would
                    # exceed the staging-ring depth.
                    harvest_q.append((harvest.submit(
                        resolve_on_harvest, pool.begin_staged(staged)),
                        rnd))
                    while len(harvest_q) > depth:
                        fut, rnd_done = harvest_q.pop(0)
                        record(rnd_done, fut.result())
                else:
                    record(rnd, pool.run_staged(staged))
            while harvest_q:
                fut, rnd_done = harvest_q.pop(0)
                record(rnd_done, fut.result())
        except RuntimeError as e:
            failures += 1
            if verbose:
                print(f"FAILURE (attempt {attempt}): {e}; "
                      f"re-scheduling incomplete images", flush=True)
        finally:
            # Discard staged-but-unconsumed rounds (their images simply
            # re-schedule); surface nothing from the loader here.
            for fut in staged_q:
                try:
                    fut.result()
                except Exception:
                    pass
            # Harvest rounds already in flight: a completed round is a
            # real result (record it — its images must not re-schedule);
            # a failed or poisoned one is discarded like a prefetch slot
            # and its images re-schedule from the work log.
            while harvest_q:
                fut, rnd_done = harvest_q.pop(0)
                try:
                    record(rnd_done, fut.result())
                except Exception:
                    pass
            if harvest is not None:
                harvest.shutdown(wait=True)
            if loader is not None:
                loader.shutdown(wait=True)
        pending = [mm for mm in metas if mm.image_id not in done]

    if pending:
        raise RuntimeError(f"pipeline could not finish {len(pending)} images "
                           f"after {max_retries} retries")
    return PipelineResult(done, rounds, failures, time.time() - t0)
