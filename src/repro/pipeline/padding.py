"""Bucket padding and pad-artifact repair, shared by every padded dispatch.

Three call sites stage mixed-shape images into one fixed compiled batch
shape: the sharded executor's bucketed rounds
(:meth:`repro.pipeline.executor.ShardedPHExecutor.load_round`), the
engine's mixed-shape :meth:`repro.ph.PHEngine.run_batch`, and the serving
daemon's coalescing tick (:class:`repro.serving.PHServer`).  They all rely
on the same exactness argument (src/repro/ph/README.md "Padding
correctness"):

* pad pixels are filled with the dtype minimum (``-inf`` for floats), so
  under a finite per-image Variant-2 threshold they are **provably
  inert** — below every threshold, they produce no births, no candidates,
  and no merges;
* when no filter level supplies a threshold, the **image minimum** is an
  exact substitute: ``pixhomology`` keeps pixels ``>= truncate_value``, so
  a threshold at the minimum excludes nothing real while still excluding
  every pad pixel (the essential death it clips is restored by the fixup
  below) — this is what lets VANILLA requests share padded buckets;
* the two residual artifacts are repaired host-side from load-time
  metadata: flat indices are strided by the bucket width instead of the
  image width (a pure remap, row order among real pixels is preserved by
  right/bottom padding), and the essential class dies at the pad minimum
  instead of the recorded image minimum.

:func:`pad_fixup` captures the metadata at staging time;
:func:`unpad_diagram` applies the repair, making padded diagrams
bit-identical to unpadded per-image runs (incl. ``p_birth``/``p_death``).
"""
from __future__ import annotations

import numpy as np

from repro.core import Diagram


def pad_fill_value(dtype):
    """The below-everything fill for pad pixels of ``dtype``."""
    dtype = np.dtype(dtype)
    return -np.inf if np.issubdtype(dtype, np.floating) \
        else np.iinfo(dtype).min


def pad_threshold(img: np.ndarray, threshold: float | None) -> float:
    """The finite threshold a padded dispatch of ``img`` runs under.

    An explicit finite ``threshold`` passes through; otherwise the image
    minimum stands in (exact — see the module docstring).  Raises when no
    finite threshold above the pad fill exists (an integer image whose
    minimum sits at the dtype minimum is indistinguishable from its own
    padding).
    """
    if threshold is not None and np.isfinite(threshold):
        return float(threshold)
    t = float(img.min())
    fill = pad_fill_value(img.dtype)
    if not np.isfinite(t) or t <= fill:
        raise ValueError(
            f"cannot pad image: no finite threshold above the pad fill "
            f"{fill!r} (image minimum {t!r}); pass an explicit "
            f"truncate_value or use exact-shape batches")
    return t


def pad_fixup(img: np.ndarray) -> tuple[int, int, float, int]:
    """Repair metadata of one to-be-padded image: ``(H, W, min_val,
    min_idx)`` with the index flat in the *unpadded* frame.  ``argmin``
    returns the first (lowest flat index) occurrence of the minimum —
    exactly the global minimum the essential class dies at."""
    h, w = img.shape
    mni = int(img.argmin())
    return (h, w, img.reshape(-1)[mni], mni)


def pad_image(img: np.ndarray, bucket: tuple[int, int]) -> np.ndarray:
    """Right/bottom-pad ``img`` to ``bucket`` with the inert fill (row
    order among real pixels is preserved, so :func:`unpad_diagram`'s
    stride remap is exact)."""
    h, w = img.shape
    hb, wb = bucket
    if (h, w) == (hb, wb):
        return img
    if h > hb or w > wb:
        raise ValueError(f"image {img.shape} exceeds bucket {bucket}")
    out = np.full((hb, wb), pad_fill_value(img.dtype), img.dtype)
    out[:h, :w] = img
    return out


def unpad_diagram(d: Diagram, fixup, bucket: tuple[int, int]) -> Diagram:
    """Undo the two pad artifacts of a bucket-padded image's diagram.

    ``fixup = (H, W, min_val, min_idx)`` from :func:`pad_fixup`.
    Remapping flat indices from stride ``Wb`` to stride ``W`` and
    restoring the essential death makes the diagram bit-identical to the
    unpadded whole-image run.
    """
    h, w, mnv, mni = fixup
    wb = bucket[1]

    def remap(p):
        p = p.copy()
        valid = p >= 0
        p[valid] = (p[valid] // wb) * w + (p[valid] % wb)
        return p

    p_birth = remap(d.p_birth)
    p_death = remap(d.p_death)
    death = d.death.copy()
    if int(d.count) > 0:        # row 0 is the essential class (max birth)
        death[0] = mnv
        p_death[0] = mni
    return Diagram(d.birth, death, p_birth, p_death,
                   d.count, d.n_unmerged, d.overflow)
