"""Bucket padding and pad-artifact repair, shared by every padded dispatch.

Three call sites stage mixed-shape images into one fixed compiled batch
shape: the sharded executor's bucketed rounds
(:meth:`repro.pipeline.executor.ShardedPHExecutor.load_round`), the
engine's mixed-shape :meth:`repro.ph.PHEngine.run_batch`, and the serving
daemon's coalescing tick (:class:`repro.serving.PHServer`).  They all rely
on the same exactness argument (src/repro/ph/README.md "Padding
correctness"), stated here for the superlevel filtration with the
sublevel dual in parentheses:

* pad pixels are filled with the *inert extreme* of the filtration — the
  dtype minimum / ``-inf`` under superlevel (``+inf`` under sublevel, where
  the analysis keeps *low* values) — so under a finite per-image Variant-2
  threshold they are **provably inert**: below (above) every threshold,
  they produce no births, no candidates, and no merges;
* when no filter level supplies a threshold, the **image minimum**
  (maximum) is an exact substitute: ``pixhomology`` keeps pixels
  ``>= truncate_value`` (``<= t``), so a threshold at the extreme excludes
  nothing real while still excluding every pad pixel (the essential death
  it clips is restored by the fixup below) — this is what lets VANILLA
  requests share padded buckets;
* the two residual artifacts are repaired host-side from load-time
  metadata: flat indices are strided by the bucket width instead of the
  image width (a pure remap, row order among real pixels is preserved by
  right/bottom padding — filtration-invariant), and the essential class
  dies at the pad fill instead of the recorded image minimum (maximum).

Historical bug this layout fixes: the fixup used to *assume* the pad fill
is the global minimum, so an image whose true minimum sat in a padded
margin row — or any sublevel request — silently restored the wrong death.
Every function now takes the filtration and records the matching extreme.

:func:`pad_fixup` captures the metadata at staging time;
:func:`unpad_diagram` applies the repair, making padded diagrams
bit-identical to unpadded per-image runs (incl. ``p_birth``/``p_death``).
"""
from __future__ import annotations

import numpy as np

from repro.core import Diagram
from repro.core.packed_keys import resolve_filtration


def pad_fill_value(dtype, filtration: str = "superlevel"):
    """The inert fill for pad pixels of ``dtype`` under ``filtration``:
    below everything for superlevel, above everything for sublevel."""
    dtype = np.dtype(dtype)
    resolve_filtration(filtration)
    if filtration == "sublevel":
        if not np.issubdtype(dtype, np.floating):
            raise ValueError(
                f"filtration='sublevel' requires a floating dtype, "
                f"got {dtype}")
        return np.inf
    return -np.inf if np.issubdtype(dtype, np.floating) \
        else np.iinfo(dtype).min


def pad_threshold(img: np.ndarray, threshold: float | None,
                  filtration: str = "superlevel") -> float:
    """The finite threshold a padded dispatch of ``img`` runs under.

    An explicit finite ``threshold`` passes through; otherwise the image
    extreme stands in — the minimum under superlevel, the maximum under
    sublevel (exact — see the module docstring).  Raises when no finite
    threshold separating the image from the pad fill exists (an integer
    image whose minimum sits at the dtype minimum is indistinguishable
    from its own padding).
    """
    if threshold is not None and np.isfinite(threshold):
        return float(threshold)
    fill = pad_fill_value(img.dtype, filtration)
    if filtration == "sublevel":
        t = float(img.max())
        bad = not np.isfinite(t) or t >= fill
    else:
        t = float(img.min())
        bad = not np.isfinite(t) or t <= fill
    if bad:
        raise ValueError(
            f"cannot pad image: no finite threshold separating the pad "
            f"fill {fill!r} from the image extreme {t!r}; pass an "
            f"explicit truncate_value or use exact-shape batches")
    return t


def pad_fixup(img: np.ndarray,
              filtration: str = "superlevel") -> tuple[int, int, float, int]:
    """Repair metadata of one to-be-padded image: ``(H, W, ext_val,
    ext_idx)`` with the index flat in the *unpadded* frame.  The extreme
    is the essential death point of the filtration — the global minimum
    under superlevel, the global maximum under sublevel; ``argmin`` /
    ``argmax`` return the first (lowest flat index) occurrence, exactly
    the pixel the elder rule's ``(value, index)`` total order picks."""
    resolve_filtration(filtration)
    h, w = img.shape
    ei = int(img.argmax() if filtration == "sublevel" else img.argmin())
    return (h, w, img.reshape(-1)[ei], ei)


def pad_image(img: np.ndarray, bucket: tuple[int, int],
              filtration: str = "superlevel") -> np.ndarray:
    """Right/bottom-pad ``img`` to ``bucket`` with the inert fill (row
    order among real pixels is preserved, so :func:`unpad_diagram`'s
    stride remap is exact)."""
    h, w = img.shape
    hb, wb = bucket
    if (h, w) == (hb, wb):
        return img
    if h > hb or w > wb:
        raise ValueError(f"image {img.shape} exceeds bucket {bucket}")
    out = np.full((hb, wb), pad_fill_value(img.dtype, filtration), img.dtype)
    out[:h, :w] = img
    return out


def unpad_diagram(d: Diagram, fixup, bucket: tuple[int, int]) -> Diagram:
    """Undo the two pad artifacts of a bucket-padded image's diagram.

    ``fixup = (H, W, ext_val, ext_idx)`` from :func:`pad_fixup` (already
    filtration-aware: the recorded extreme *is* the essential death point
    of whichever filtration staged it).  Remapping flat indices from
    stride ``Wb`` to stride ``W`` and restoring the essential death makes
    the diagram bit-identical to the unpadded whole-image run.  Row 0 is
    the essential class under both filtrations (the elder root sorts
    first in the internal key order).
    """
    h, w, env, eni = fixup
    wb = bucket[1]

    def remap(p):
        p = p.copy()
        valid = p >= 0
        p[valid] = (p[valid] // wb) * w + (p[valid] % wb)
        return p

    p_birth = remap(d.p_birth)
    p_death = remap(d.p_death)
    death = d.death.copy()
    if int(d.count) > 0:        # row 0 is the essential class
        death[0] = env
        p_death[0] = eni
    return Diagram(d.birth, death, p_birth, p_death,
                   d.count, d.n_unmerged, d.overflow)
