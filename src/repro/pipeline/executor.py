"""Executor layer: sharded batched PixHomology over the device mesh.

One SPMD program per round: a (M, Hb, Wb) image batch sharded over the data
axes, vmapped PixHomology per device (the paper's ``process_image`` map).
Images are *generated/loaded per executor* (Variant 1 ``load_self``): the
driver passes image metadata, each host materializes only its shard — and
for oversized images only its halo-padded *tiles*
(:meth:`ShardedPHExecutor.load_self_tiled`, windowed loading through
:class:`repro.data.astro.AstroImage`).

Heterogeneous rounds: a round's images share one padded bucket shape
``(Hb, Wb)``; smaller images are padded with ``-inf``.  Under the finite
per-image Variant-2 threshold the pipeline always supplies for padded
rounds, the pad pixels are provably inert — they are below every
threshold, so they produce no births, no candidates, and no merges —
leaving exactly two pad artifacts, both repaired host-side in
:meth:`ShardedPHExecutor.run_staged`:

* flat pixel indices are laid out with stride ``Wb`` instead of ``W``
  (row-order among real pixels is preserved, so a pure index remap
  suffices), and
* the essential class dies at the pad minimum (``-inf``) instead of the
  image minimum, which the loader records at generation time.

The padding/repair primitives live in :mod:`repro.pipeline.padding` and
are shared with ``PHEngine.run_batch``'s mixed-shape path and the serving
daemon's coalescing tick.

The compiled sharded program comes from the engine's plan cache
(:meth:`repro.ph.PHEngine.sharded_plan`); this module only moves data and
applies the engine's overflow auto-regrow policy round by round.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import Diagram
from repro.data import astro
from repro.ph.config import FilterLevel
from repro.ph.engine import PHEngine, threshold_dtype
from repro.ph.overlap import PendingResult
from repro.pipeline.padding import pad_fill_value, pad_fixup, unpad_diagram
from repro.pipeline.scheduler import BucketRound, ImageMeta


@dataclasses.dataclass
class StagedRound:
    """Device-staged inputs of one scheduled round (built by
    :meth:`ShardedPHExecutor.load_round`, possibly on the driver's
    prefetch thread while the previous round computes).

    The host copies are retained past staging: donated device buffers
    are consumed by their dispatch, so the rare overflow replay
    re-stages from ``host_batch`` instead of regenerating images."""

    rnd: BucketRound
    batch: Any = None           # whole rounds: (M, Hb, Wb) device array
    tvals: Any = None           # whole rounds: (M,) device thresholds
    fixups: list | None = None  # per entry: None | (H, W, min_val, min_idx)
    tiles: Any = None           # tiled rounds: repro.core.tiling.StagedTiles
    threshold: float | None = None  # tiled rounds: Variant-2 threshold
    host_batch: Any = None      # whole rounds: pinned host (M, Hb, Wb)
    host_tvals: Any = None      # whole rounds: host (M,) thresholds


class ShardedPHExecutor:
    """Engine-backed executor pool over a device mesh.

    Capacities start at the engine config's values and, with
    ``auto_regrow`` on, stick at any regrown size for subsequent rounds
    and runs (the engine's regrow memo: an overflow in round r means
    round r+1 likely overflows too).
    """

    def __init__(self, engine: PHEngine, ctx, *, image_size: int = 512):
        if not isinstance(engine, PHEngine):
            raise TypeError(f"engine must be a PHEngine, "
                            f"got {type(engine).__name__}")
        self.engine = engine
        self.ctx = ctx
        self.image_size = image_size
        self._spec = NamedSharding(ctx.mesh, P(ctx.dp_axes, None, None))
        self._tspec = NamedSharding(ctx.mesh, P(ctx.dp_axes))
        # Variant-3 costs measured from actually-loaded images, keyed by
        # (id, shape) — the same id can appear at different sizes across
        # runs of a reused pool; they override the schedule-time estimate
        # on re-scheduling (retries).
        self._measured_costs: dict[tuple, float] = {}

    @property
    def num_executors(self) -> int:
        return self.ctx.dp_size

    # -- scheduling knobs (read by the driver) -----------------------------

    @property
    def bucket_rounding(self) -> str:
        return self.engine.config.bucket_rounding

    @property
    def pad_ok(self) -> bool:
        """Padded (mixed-shape) rounds need a finite Variant-2 threshold
        to keep the pad pixels out of the analysis — VANILLA runs use
        exact-shape buckets instead."""
        return self.engine.config.filter_level is not FilterLevel.VANILLA

    @property
    def prefetch_rounds(self) -> int:
        return self.engine.config.prefetch_rounds

    @property
    def max_tile_pixels(self) -> int | None:
        t = self.engine.config.tile
        return t.max_tile_pixels if t is not None else None

    @property
    def overlap(self):
        """The engine's effective overlap policy (the driver reads
        ``enabled`` / ``staging_depth`` / ``async_harvest``)."""
        return self.engine.overlap_spec()

    # -- Variant-3 costs ---------------------------------------------------

    def estimate_costs(self, metas) -> dict[int, float]:
        """Schedule-time costs: the executor-measured cost where a load
        already happened (Variant 2/3's per-image pass), else the
        render-free star-stream estimate.  Also the earliest point every
        image spec reaches this executor, so shapes it cannot load are
        rejected here instead of mid-run on the prefetch thread."""
        out = {}
        for meta in metas:
            _require_square(meta.shape)
            got = self._measured_costs.get((meta.image_id, meta.shape))
            out[meta.image_id] = got if got is not None else \
                astro.estimate_cost_from_id(meta.image_id, meta.shape[0])
        return out

    # -- Variant-1 loading -------------------------------------------------

    def _load_one(self, meta: ImageMeta):
        """Generate one whole (sub-bucket-size) image + its threshold and
        measured cost.  (On a real cluster each process runs this only for
        its addressable slots.)"""
        h, _ = _require_square(meta.shape)
        img = astro.generate_image(meta.image_id, h)
        # Engine-derived so the threshold statistic mirrors correctly
        # under filtration='sublevel' (None under VANILLA either way).
        t = self.engine.auto_threshold(img)
        self._measured_costs[(meta.image_id, meta.shape)] = \
            astro.estimate_cost(img, self.engine.config.filter_level)
        return img, t

    def load_round(self, rnd: BucketRound) -> StagedRound:
        """Stage one scheduled round on device (thread-safe: the driver
        calls this on a background loader thread for round r+1 while round
        r computes)."""
        if rnd.kind == "tiled":
            assert len(rnd.entries) == 1
            return self.load_self_tiled(rnd, rnd.entries[0][1])
        return self._stage_round(self._build_host_round(rnd))

    def _build_host_round(self, rnd: BucketRound) -> StagedRound:
        """Host half of staging: generate, cast, and pad one round into a
        pinned (M, Hb, Wb) host batch plus its (M,) thresholds.

        Pure-CPU by construction: the dtype cast runs through
        ``cast_input_host`` (numpy), so building a round allocates **no**
        device buffer — a regression test monkeypatches ``device_put``
        to assert exactly that.  The one H2D transfer for the whole
        round happens in :meth:`_stage_round`."""
        m = self.num_executors
        hb, wb = rnd.shape
        filt = self.engine.config.filtration
        inert = np.inf if filt == "sublevel" else -np.inf
        bdt = self.engine.cast_input_host(np.zeros((), np.float32)).dtype
        batch = np.full((m, hb, wb), pad_fill_value(bdt, filt), bdt)
        tvals = np.full((m,), inert, np.dtype(threshold_dtype(bdt)))
        fixups: list = [None] * len(rnd.entries)
        for k, (slot, meta) in enumerate(rnd.entries):
            img, t = self._load_one(meta)
            # The config dtype cast happens here, per image, so the pad
            # fixup below observes exactly the values the compute sees
            # (a lossy cast can move the argmin between near-min pixels).
            img = self.engine.cast_input_host(img)
            h, w = img.shape
            if (h, w) != (hb, wb):
                if t is None:
                    raise ValueError(
                        "padded round without a finite threshold (the "
                        "scheduler must use exact buckets when pad_ok is "
                        "False)")
                batch[slot, :h, :w] = img
                tvals[slot] = t
                fixups[k] = pad_fixup(img, filt)
            else:
                batch[slot] = img
                tvals[slot] = inert if t is None else t
        filled = {slot for slot, _ in rnd.entries}
        src = rnd.entries[0][0]
        for s in range(m):          # pad free slots: repeat a staged image
            if s not in filled:
                batch[s] = batch[src]
                tvals[s] = tvals[src]
        return StagedRound(rnd, fixups=fixups, host_batch=batch,
                           host_tvals=tvals)

    def _stage_round(self, staged: StagedRound) -> StagedRound:
        """Device half of staging: the round's batch **and** thresholds
        go up in one fused ``device_put`` (a single transfer per round,
        not a second tiny put for the scalars — the bench counts
        ``h2d_transfers`` per round to hold this at one)."""
        staged.batch, staged.tvals = jax.device_put(
            (staged.host_batch, staged.host_tvals),
            (self._spec, self._tspec))
        self.engine.overlap_counters.bump("h2d_transfers")
        return staged

    def load_self_tiled(self, rnd: BucketRound,
                        meta: ImageMeta) -> StagedRound:
        """Variant-1 ``load_self`` for tiles: stage an oversized image as
        device-resident halo tiles through the windowed
        :class:`repro.data.astro.AstroImage` provider — no code path here
        (or below) materializes the full frame on any host."""
        h, _ = _require_square(meta.shape)
        provider = astro.AstroImage(meta.image_id, h)
        t = self.engine.provider_threshold(provider)
        tiles = self.engine.stage_tiles(provider, ctx=self.ctx)
        return StagedRound(rnd, tiles=tiles, threshold=t)

    def load_self(self, image_ids) -> tuple[np.ndarray, np.ndarray, dict]:
        """Variant 1 for a homogeneous id list (all at ``image_size``):
        executors materialize their own images; also computes the
        Variant-2 thresholds and Variant-3 costs.  The bucketed pipeline
        stages through :meth:`load_round`; this remains for direct
        ``run_round`` use."""
        size = self.image_size
        inert = np.inf if self.engine.config.filtration == "sublevel" \
            else -np.inf
        imgs, thresholds, costs = [], [], {}
        for i in image_ids:
            img, t = self._load_one(ImageMeta(int(i), (size, size)))
            imgs.append(img)
            thresholds.append(inert if t is None else t)
            costs[i] = self._measured_costs[(int(i), (size, size))]
        return np.stack(imgs), np.asarray(thresholds, np.float32), costs

    # -- round execution ---------------------------------------------------

    def run_staged(self, staged: StagedRound) -> dict[int, Diagram]:
        """Run one staged round; returns per-image host diagrams with the
        pad artifacts repaired (index remap + essential death).

        Synchronous: dispatch *and* the blocking result readback happen
        on the calling thread (one dispatch-path sync — counted).  The
        overlapped driver calls :meth:`begin_staged` instead and resolves
        on its harvest thread."""
        self.engine.overlap_counters.bump("dispatch_syncs")
        return self.begin_staged(staged).resolve()

    def begin_staged(self, staged: StagedRound) -> PendingResult:
        """Dispatch one staged round without blocking for its results.

        Whole rounds launch the sharded program now (with D2H streaming
        under ``overlap.async_overflow``) and defer the overflow check,
        the rare regrow replay, and the pad repair into the returned
        :class:`PendingResult`; tiled rounds defer the whole tiled/delta
        call (its dispatch runs wherever ``resolve()`` does — the
        driver's harvest thread — while the driver stages later rounds).
        ``resolve()`` returns exactly :meth:`run_staged`'s per-image
        dict, bit-identically — it is the same code on another thread."""
        rnd = staged.rnd
        if rnd.kind == "tiled":
            meta = rnd.entries[0][1]
            tiles, threshold = staged.tiles, staged.threshold

            def tiled_finish():
                res = self._tiled(tiles, threshold)
                return {meta.image_id: jax.tree.map(np.asarray,
                                                    res.diagram)}

            return PendingResult(tiled_finish)

        finish = self._begin_sharded(staged)

        def whole_finish():
            diags = finish()
            out: dict[int, Diagram] = {}
            for k, (slot, meta) in enumerate(rnd.entries):
                d = Diagram(*(np.asarray(x[slot]) for x in diags))
                if staged.fixups[k] is not None:
                    d = unpad_diagram(d, staged.fixups[k], rnd.shape)
                out[meta.image_id] = d
            return out

        return PendingResult(whole_finish)

    def _tiled(self, image, threshold):
        """One tiled-image dispatch: through the engine's delta path when
        ``config.delta`` is enabled (bit-identical; retried/resumed rounds
        of the same frame become cache hits instead of recomputes —
        ``DiagramCache.put`` replaces in place, so a retry never
        double-inserts), else the sharded ``run_tiled`` path."""
        eng = self.engine
        dspec = eng.config.delta
        if dspec is not None and dspec.enabled:
            return eng.run_delta(image, threshold)
        return eng.run_tiled(image, threshold, ctx=self.ctx)

    def _begin_sharded(self, staged: StagedRound):
        """Launch one sharded whole-image dispatch with the engine's
        regrow deferred: returns ``finish() -> host diagram tree``.

        Under donation the round's device batch buffer is consumed by
        its dispatch; the rare overflow replay re-stages the batch from
        the retained host copy (thresholds are not donated — attempt 0's
        device array is reused)."""
        eng = self.engine
        batch, tvals = staged.batch, staged.tvals
        shape, dtype = batch.shape, batch.dtype
        n = shape[1] * shape[2]
        donate = eng.donate_batched()
        calls = [0]

        def dispatch(mf, mc):
            plan = eng.sharded_plan(self.ctx, shape, dtype, mf, mc,
                                    donate=donate)
            xb = batch
            if donate and calls[0]:
                eng.overlap_counters.bump("donation_replays")
                eng.overlap_counters.bump("h2d_transfers")
                xb = jax.device_put(staged.host_batch, self._spec)
            calls[0] += 1
            with self.ctx.mesh:
                return plan(xb, tvals)

        _, finish = eng.begin_regrow(
            dispatch, lambda d: bool(np.any(np.asarray(d.overflow))),
            n, "sharded", memo_key=("sharded", shape, str(dtype)),
            stream=eng._stream_results())

        def finish_host():
            diags, _ = finish()
            return jax.tree.map(np.asarray, diags)

        return finish_host

    def run_round(self, images: np.ndarray, thresholds: np.ndarray):
        """images: (M, H, W) with M == num_executors (padded by caller).

        Images larger than the engine's ``TileSpec.max_tile_pixels`` budget
        are transparently routed through the halo-tiled path: instead of one
        whole image per executor, each image spans the mesh tile-by-tile
        (the scenario the whole-image design cannot serve).  The bucketed
        pipeline schedules such images as their own tile-grid rounds; this
        batch-shaped entry point remains for direct use.
        """
        eng = self.engine
        if eng.should_tile(images.shape[1] * images.shape[2]):
            return self._run_round_tiled(images, thresholds)
        host = eng.cast_input_host(images)
        staged = self._stage_round(StagedRound(
            None, host_batch=host,
            host_tvals=np.asarray(thresholds,
                                  np.dtype(threshold_dtype(host.dtype)))))
        eng.overlap_counters.bump("dispatch_syncs")
        return self._begin_sharded(staged)()

    def _run_round_tiled(self, images: np.ndarray, thresholds: np.ndarray):
        """Oversized-image round: one image at a time, tiles spanning the
        mesh's data axes (regrow and plan caching live in ``run_tiled``)."""
        # Rounds may repeat identical rows (short-round padding, duplicate
        # datasets); a full tiled run per duplicate would be pure waste, so
        # every (threshold, image) is computed once per round — any
        # identical row reuses the first result, wherever it appears.
        seen: dict[tuple, int] = {}
        diags: list[Diagram] = []
        for i in range(images.shape[0]):
            key = (float(thresholds[i]),
                   hashlib.sha1(np.ascontiguousarray(
                       images[i]).tobytes()).hexdigest())
            dup = seen.get(key)
            if dup is not None and np.array_equal(images[i], images[dup]):
                diags.append(diags[dup])
                continue
            seen[key] = i
            diags.append(jax.tree.map(
                np.asarray,
                self._tiled(images[i], float(thresholds[i])).diagram))
        # Per-image regrow can leave different diagram capacities; pad the
        # rows to the round maximum before stacking into the (M, F) layout
        # a batched consumer expects.
        f = max(d.birth.shape[0] for d in diags)

        sublevel = self.engine.config.filtration == "sublevel"

        def padded(d: Diagram) -> Diagram:
            extra = f - d.birth.shape[0]
            if extra == 0:
                return d
            # Match the core's own pad rows: -inf under superlevel,
            # +inf in sublevel user space (diagrams negate on the way out).
            fill = (-np.inf if np.issubdtype(d.birth.dtype, np.floating)
                    else np.iinfo(d.birth.dtype).min)
            if sublevel:
                fill = -fill
            return Diagram(
                np.concatenate([d.birth, np.full(extra, fill,
                                                 d.birth.dtype)]),
                np.concatenate([d.death, np.full(extra, fill,
                                                 d.death.dtype)]),
                np.concatenate([d.p_birth, np.full(extra, -1, np.int32)]),
                np.concatenate([d.p_death, np.full(extra, -1, np.int32)]),
                d.count, d.n_unmerged, d.overflow)

        return jax.tree.map(lambda *xs: np.stack(xs), *map(padded, diags))


def _require_square(shape) -> tuple[int, int]:
    """The synthetic astro loader only renders square frames; reject
    rectangles before they are scheduled (the scheduler itself is
    shape-generic — a different pool may well accept them)."""
    h, w = shape
    if h != w:
        raise ValueError(f"astro frames are square, got {tuple(shape)}")
    return h, w


