"""Executor layer: sharded batched PixHomology over the device mesh.

One SPMD program per round: a (M, H, W) image batch sharded over the data
axes, vmapped PixHomology per device (the paper's ``process_image`` map).
Images are *generated/loaded per executor* (Variant 1 ``load_self``): the
driver passes image ids, each host materializes only its shard.

The compiled sharded program comes from the engine's plan cache
(:meth:`repro.ph.PHEngine.sharded_plan`); this module only moves data and
applies the engine's overflow auto-regrow policy round by round.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data import astro
from repro.ph.config import PHConfig
from repro.ph.engine import PHEngine, threshold_dtype


class ShardedPHExecutor:
    """Engine-backed executor pool over a device mesh.

    Capacities start at the engine config's values and, with
    ``auto_regrow`` on, stick at any regrown size for subsequent rounds
    and runs (the engine's regrow memo: an overflow in round r means
    round r+1 likely overflows too).
    """

    def __init__(self, engine: PHEngine, ctx, *, image_size: int = 512):
        if not isinstance(engine, PHEngine):
            raise TypeError(f"engine must be a PHEngine, "
                            f"got {type(engine).__name__}")
        self.engine = engine
        self.ctx = ctx
        self.image_size = image_size
        self._spec = NamedSharding(ctx.mesh, P(ctx.dp_axes, None, None))
        self._tspec = NamedSharding(ctx.mesh, P(ctx.dp_axes))

    @property
    def num_executors(self) -> int:
        return self.ctx.dp_size

    def load_self(self, image_ids) -> tuple[np.ndarray, np.ndarray, dict]:
        """Variant 1: executors materialize their own images (here: the
        host generates shards deterministically from ids; on a real cluster
        each process generates/loads only its addressable shard).  Also
        computes the Variant-2 thresholds and Variant-3 costs."""
        level = self.engine.config.filter_level
        imgs, thresholds, costs = [], [], {}
        for i in image_ids:
            img = astro.generate_image(i, self.image_size)
            t, _ = astro.filter_threshold(img, level)
            imgs.append(img)
            thresholds.append(-np.inf if t is None else t)
            costs[i] = astro.estimate_cost(img, level)
        return np.stack(imgs), np.asarray(thresholds, np.float32), costs

    def run_round(self, images: np.ndarray, thresholds: np.ndarray):
        """images: (M, H, W) with M == num_executors (padded by driver).

        Images larger than the engine's ``TileSpec.max_tile_pixels`` budget
        are transparently routed through the halo-tiled path: instead of one
        whole image per executor, each image spans the mesh tile-by-tile
        (the scenario the whole-image design cannot serve).
        """
        eng = self.engine
        if eng.should_tile(images.shape[1] * images.shape[2]):
            return self._run_round_tiled(images, thresholds)
        batch = jax.device_put(eng.cast_input(images), self._spec)
        tvals = jax.device_put(
            jnp.asarray(thresholds, threshold_dtype(batch.dtype)),
            self._tspec)
        n = images.shape[1] * images.shape[2]

        def dispatch(mf, mc):
            plan = eng.sharded_plan(self.ctx, batch.shape, batch.dtype,
                                    mf, mc)
            with self.ctx.mesh:
                return jax.tree.map(np.asarray, plan(batch, tvals))

        diags, _ = eng.run_with_regrow(
            dispatch, lambda d: bool(np.any(d.overflow)), n, "sharded",
            memo_key=("sharded", batch.shape, str(batch.dtype)))
        return diags

    def _run_round_tiled(self, images: np.ndarray, thresholds: np.ndarray):
        """Oversized-image round: one image at a time, tiles spanning the
        mesh's data axes (regrow and plan caching live in ``run_tiled``)."""
        from repro.core import Diagram
        diags = []
        for i in range(images.shape[0]):
            # The driver pads short rounds by repeating the last image;
            # a full tiled run per duplicate would be pure waste, so reuse
            # the previous result for consecutive identical rows.
            if diags and thresholds[i] == thresholds[i - 1] \
                    and np.array_equal(images[i], images[i - 1]):
                diags.append(diags[-1])
                continue
            diags.append(jax.tree.map(
                np.asarray,
                self.engine.run_tiled(images[i], float(thresholds[i]),
                                      ctx=self.ctx).diagram))
        # Per-image regrow can leave different diagram capacities; pad the
        # rows to the round maximum before stacking into the (M, F) layout
        # the driver's summarizer expects.
        f = max(d.birth.shape[0] for d in diags)

        def padded(d: Diagram) -> Diagram:
            extra = f - d.birth.shape[0]
            if extra == 0:
                return d
            neg_inf = (-np.inf if np.issubdtype(d.birth.dtype, np.floating)
                       else np.iinfo(d.birth.dtype).min)
            return Diagram(
                np.concatenate([d.birth, np.full(extra, neg_inf,
                                                 d.birth.dtype)]),
                np.concatenate([d.death, np.full(extra, neg_inf,
                                                 d.death.dtype)]),
                np.concatenate([d.p_birth, np.full(extra, -1, np.int32)]),
                np.concatenate([d.p_death, np.full(extra, -1, np.int32)]),
                d.count, d.n_unmerged, d.overflow)

        return jax.tree.map(lambda *xs: np.stack(xs), *map(padded, diags))


def make_sharded_ph(ctx, **kw):
    """Deprecated: use ``PHEngine.sharded_plan`` (plan-cached) instead."""
    warnings.warn("make_sharded_ph is deprecated; use PHEngine.sharded_plan",
                  DeprecationWarning, stacklevel=2)
    engine = PHEngine(PHConfig(
        max_features=kw.pop("max_features", 256),      # pixhomology's old
        max_candidates=kw.pop("max_candidates", 4096),  # kwarg defaults
        auto_regrow=False, **kw))
    cfg = engine.config

    def fn(imgs, tvals):
        plan = engine.sharded_plan(ctx, imgs.shape, imgs.dtype,
                                   cfg.max_features, cfg.max_candidates)
        return plan(imgs, tvals)

    return fn


class ExecutorPool(ShardedPHExecutor):
    """Deprecated kwargs shim over :class:`ShardedPHExecutor`.

    Kept for one release: builds a private engine from the raw kwargs with
    auto-regrow off (the pre-engine behavior surfaced overflow as a flag
    only).  New code constructs a :class:`repro.ph.PHEngine` and calls
    ``run_distributed`` / ``ShardedPHExecutor`` directly.
    """

    def __init__(self, ctx, image_size: int = 512,
                 max_features: int = 8192, max_candidates: int = 32768,
                 filter_level="filter_std"):
        warnings.warn(
            "ExecutorPool(ctx, **kwargs) is deprecated; build a "
            "repro.ph.PHEngine(PHConfig(...)) and use engine.run_distributed"
            " (or ShardedPHExecutor) instead",
            DeprecationWarning, stacklevel=2)
        engine = PHEngine(PHConfig(
            max_features=max_features, max_candidates=max_candidates,
            filter_level=filter_level, auto_regrow=False))
        super().__init__(engine, ctx, image_size=image_size)
