"""Executor layer: sharded batched PixHomology over the device mesh.

One SPMD program per round: a (M, H, W) image batch sharded over the data
axes, vmapped PixHomology per device (the paper's ``process_image`` map).
Images are *generated/loaded per executor* (Variant 1 ``load_self``): the
driver passes image ids, each host materializes only its shard.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import Diagram, batched_pixhomology
from repro.data import astro

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


def make_sharded_ph(ctx, **kw):
    """shard_map'd batched PixHomology: per-image work is embarrassingly
    parallel, so we pin it inside shard_map over the data axes — XLA's
    sharding propagation otherwise replicates the merge-scan carries and
    emits ~70 TB of all-gathers per batch (EXPERIMENTS.md §Perf iteration
    PH-1: collective term 1407 s -> ~0)."""
    fn = functools.partial(batched_pixhomology, **kw)
    dp = ctx.dp_axes
    out_specs = Diagram(P(dp, None), P(dp, None), P(dp, None), P(dp, None),
                        P(dp), P(dp), P(dp))
    return shard_map(lambda imgs, t: fn(imgs, t), mesh=ctx.mesh,
                     in_specs=(P(dp, None, None), P(dp)),
                     out_specs=out_specs, check_vma=False)


@dataclasses.dataclass
class ExecutorPool:
    ctx: object                     # DistContext
    image_size: int = 512
    max_features: int = 8192
    max_candidates: int = 32768
    filter_level: str = "filter_std"

    def __post_init__(self):
        self._fn = jax.jit(make_sharded_ph(
            self.ctx, max_features=self.max_features,
            max_candidates=self.max_candidates))
        self._spec = NamedSharding(self.ctx.mesh,
                                   P(self.ctx.dp_axes, None, None))

    @property
    def num_executors(self) -> int:
        return self.ctx.dp_size

    def load_self(self, image_ids) -> tuple[np.ndarray, np.ndarray, dict]:
        """Variant 1: executors materialize their own images (here: the
        host generates shards deterministically from ids; on a real cluster
        each process generates/loads only its addressable shard).  Also
        computes the Variant-2 thresholds and Variant-3 costs."""
        imgs, thresholds, costs = [], [], {}
        for i in image_ids:
            img = astro.generate_image(i, self.image_size)
            t, _ = astro.filter_threshold(img, self.filter_level)
            imgs.append(img)
            thresholds.append(-np.inf if t is None else t)
            costs[i] = astro.estimate_cost(img)
        return np.stack(imgs), np.asarray(thresholds, np.float32), costs

    def run_round(self, images: np.ndarray, thresholds: np.ndarray):
        """images: (M, H, W) with M == num_executors (padded by driver)."""
        batch = jax.device_put(jnp.asarray(images), self._spec)
        tspec = NamedSharding(self.ctx.mesh, P(self.ctx.dp_axes))
        tvals = jax.device_put(jnp.asarray(thresholds), tspec)
        with self.ctx.mesh:
            return jax.tree.map(np.asarray, self._fn(batch, tvals))
