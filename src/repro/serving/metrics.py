"""SLO metrics for the PH serving daemon.

Everything here is host-side bookkeeping around the serving loop in
:mod:`repro.serving.server`: per-bucket latency distributions
(queue-wait and end-to-end), batch occupancy, and admission counters.
The recorders are called from two kinds of threads at once — client
threads inside ``submit()`` and the daemon's tick thread after each
dispatch — so every mutation goes through one lock per
:class:`ServeMetrics` instance.

Metric definitions (mirrored in ``DESIGN.md`` §8):

``queue_wait_s``
    Dispatch start minus submit time: how long a request sat in its
    bucket queue before the tick thread picked it up.  Pure scheduling
    latency — grows with load, shrinks with ``batch_cap``/tick rate.
``e2e_s``
    Result-ready minus submit time: what the client actually observes on
    the future (queue wait + padded-batch compute + host repair).
``occupancy``
    Real requests per dispatched batch divided by ``batch_cap``.  The
    daemon always dispatches the *fixed* shape ``(batch_cap, Hb, Wb)``
    (padding free rows by repeating a real request) so one warmed plan
    serves every tick; occupancy says how much of that fixed batch did
    useful work.
``rejected``
    Submissions refused at admission (queue at ``max_queue`` under the
    ``"reject"`` policy).  The saturation section of
    ``benchmarks/serve_bench.py`` exists to drive this above zero.
``cache_hits`` / ``cache_misses``
    Serving cache-tier outcomes: a hit is a submit whose exact request
    hash (image bytes + shape + dtype + threshold) matched a finished
    result — the future resolves on the submit thread and the request
    never enters a queue.  Eviction counts live on the
    :class:`repro.cache.LRUCache` itself and are merged into
    ``PHServer.stats()``'s ``cache`` section.

Percentiles come from a fixed-capacity ring buffer (:class:`Reservoir`)
— O(capacity) memory however long the daemon runs, exact percentiles
over the most recent ``capacity`` samples (a sliding window, which is
what an SLO dashboard wants anyway).
"""
from __future__ import annotations

import threading

import numpy as np

__all__ = ["Reservoir", "BucketMetrics", "ServeMetrics", "bucket_label"]


def bucket_label(bucket: tuple[int, int]) -> str:
    """``(H, W) -> "HxW"`` — JSON-friendly bucket key."""
    return f"{int(bucket[0])}x{int(bucket[1])}"


class Reservoir:
    """Fixed-capacity ring buffer of float samples with exact percentiles
    over the retained (most recent) window.  Thread-safe."""

    __slots__ = ("_buf", "_next", "_seen", "_lock")

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._buf = np.empty(capacity, np.float64)
        self._next = 0          # ring write position
        self._seen = 0          # total samples ever added
        self._lock = threading.Lock()

    def add(self, value: float) -> None:
        with self._lock:
            self._buf[self._next] = float(value)
            self._next = (self._next + 1) % self._buf.size
            self._seen += 1

    def extend(self, values) -> None:
        for v in values:
            self.add(v)

    def __len__(self) -> int:
        with self._lock:
            return self._seen

    def _window(self) -> np.ndarray:
        return self._buf[:min(self._seen, self._buf.size)]

    def percentile(self, q: float) -> float:
        """Exact percentile over the retained window; ``0.0`` when no
        sample has been recorded yet (a freshly started server must
        expose zeroed — not raising, not NaN — latency stats)."""
        with self._lock:
            w = self._window()
            if w.size == 0:
                return 0.0
            return float(np.percentile(w, q))

    def summary(self) -> dict:
        """``{count, mean, p50, p95, p99, max}`` (seconds in, seconds
        out); all-zero when empty, so dashboards and the perf gate can
        read every key of a fresh server without guards (single-sample
        windows are exact: every percentile is that sample)."""
        with self._lock:
            w = self._window()
            if w.size == 0:
                return {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                        "p99": 0.0, "max": 0.0}
            p50, p95, p99 = np.percentile(w, [50.0, 95.0, 99.0])
            return {"count": self._seen,
                    "mean": float(w.mean()),
                    "p50": float(p50),
                    "p95": float(p95),
                    "p99": float(p99),
                    "max": float(w.max())}


class BucketMetrics:
    """Latency/throughput accounting for one shape bucket."""

    __slots__ = ("queue_wait_s", "e2e_s", "batch_s", "requests", "batches",
                 "rows", "rejected", "failed")

    def __init__(self, window: int = 4096):
        self.queue_wait_s = Reservoir(window)
        self.e2e_s = Reservoir(window)
        self.batch_s = Reservoir(window)    # per-dispatch compute+repair
        self.requests = 0                   # requests resolved successfully
        self.batches = 0                    # dispatches (incl. padded rows)
        self.rows = 0                       # real rows across dispatches
        self.rejected = 0
        self.failed = 0

    def occupancy(self, batch_cap: int) -> float | None:
        if self.batches == 0:
            return None
        return self.rows / (self.batches * batch_cap)

    def snapshot(self, batch_cap: int) -> dict:
        occ = self.occupancy(batch_cap)
        return {"requests": self.requests,
                "batches": self.batches,
                "rows": self.rows,
                "rejected": self.rejected,
                "failed": self.failed,
                "occupancy": None if occ is None else round(occ, 4),
                "queue_wait_s": self.queue_wait_s.summary(),
                "e2e_s": self.e2e_s.summary(),
                "batch_s": self.batch_s.summary()}


class ServeMetrics:
    """All-buckets metrics hub; one per :class:`~repro.serving.PHServer`.

    The per-:class:`Reservoir` locks make individual samples safe; this
    object's own lock additionally keeps the counters and the bucket
    map consistent across the submit / tick threads.
    """

    def __init__(self, batch_cap: int, window: int = 4096):
        self.batch_cap = int(batch_cap)
        self._window = int(window)
        self._buckets: dict[tuple[int, int], BucketMetrics] = {}
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected = 0
        self.cache_hits = 0
        self.cache_misses = 0

    def bucket(self, bucket: tuple[int, int]) -> BucketMetrics:
        key = (int(bucket[0]), int(bucket[1]))
        with self._lock:
            m = self._buckets.get(key)
            if m is None:
                m = self._buckets[key] = BucketMetrics(self._window)
            return m

    # -- recorders ---------------------------------------------------------

    def record_submit(self, bucket) -> None:
        self.bucket(bucket)  # ensure the bucket shows up in snapshots
        with self._lock:
            self.submitted += 1

    def record_reject(self, bucket) -> None:
        m = self.bucket(bucket)
        with self._lock:
            m.rejected += 1
            self.rejected += 1

    def record_cache(self, *, hit: bool) -> None:
        """One serving cache-tier lookup outcome (hits also count as a
        submitted+completed request: the client got a result)."""
        with self._lock:
            if hit:
                self.cache_hits += 1
                self.submitted += 1
                self.completed += 1
            else:
                self.cache_misses += 1

    def record_batch(self, bucket, *, queue_waits, e2e, batch_s) -> None:
        """One successful dispatch: ``queue_waits``/``e2e`` carry one
        sample per *real* request in the batch."""
        m = self.bucket(bucket)
        m.queue_wait_s.extend(queue_waits)
        m.e2e_s.extend(e2e)
        m.batch_s.add(batch_s)
        with self._lock:
            m.requests += len(e2e)
            m.batches += 1
            m.rows += len(e2e)
            self.completed += len(e2e)

    def record_failure(self, bucket, n_requests: int) -> None:
        m = self.bucket(bucket)
        with self._lock:
            m.failed += n_requests
            self.failed += n_requests

    def mean_batch_seconds(self, bucket) -> float | None:
        m = self.bucket(bucket)
        s = m.batch_s.summary()
        return s["mean"] if s["count"] else None

    # -- export ------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-ready view: global counters + per-bucket summaries keyed
        ``"HxW"``."""
        with self._lock:
            buckets = dict(self._buckets)
            top = {"submitted": self.submitted,
                   "completed": self.completed,
                   "failed": self.failed,
                   "rejected": self.rejected,
                   "batch_cap": self.batch_cap,
                   "cache": {"hits": self.cache_hits,
                             "misses": self.cache_misses}}
        top["buckets"] = {bucket_label(k): m.snapshot(self.batch_cap)
                          for k, m in sorted(buckets.items())}
        return top
