"""PH-as-a-service: an async serving daemon over one shared PHEngine.

The batch entry points grew bottom-up (PR 2 ``run_batch``, PR 3's
prefetch-pipelined executor) but all assume the caller *has* a batch.
A service sees the opposite shape of traffic: many independent clients,
one image each, shapes mixed, arrival times arbitrary.  This module
closes that gap with a daemon that keeps the engine's compiled plans hot
and turns request streams into the fixed-shape batches those plans want:

``submit(image, truncate_value) -> concurrent.futures.Future[PHResult]``
    Clients enqueue and move on; the future resolves with exactly what
    ``PHEngine.run(image, truncate_value)`` would have returned
    (bit-identical — padding artifacts are repaired by
    :mod:`repro.pipeline.padding` inside ``engine.run_batch``).

**Coalescing tick** (modeled on the executor's prefetch loader thread):
one daemon thread blocks until work arrives, sleeps one
``tick_interval_s`` so concurrent submitters land in the same tick, then
drains every non-empty bucket queue, up to ``batch_cap`` requests per
bucket per pass.  Under sustained load the loop never sleeps —
continuous batching.

**Fixed dispatch shape**: a partially filled batch is padded to exactly
``(batch_cap, Hb, Wb)`` by repeating a real request, so every dispatch
of a bucket reuses the *one* plan ``warmup()`` traced for it.  Combined
with the warmup dummy that pre-walks the regrow chain
(:meth:`repro.ph.engine.PHEngine.warmup`), steady state re-traces
nothing; ``steady_state_traces()`` measures exactly that and
``benchmarks/serve_bench.py`` gates on it.

**Serving cache tier** (active when the engine's ``config.delta`` is
enabled): ``submit`` hashes the request — image bytes + shape + dtype +
threshold — and an exact match against a bounded
:class:`repro.cache.LRUCache` of finished results resolves the future on
the *submit thread*; the request never enters a queue, never pads a
batch, never touches the device.  Misses dispatch normally and insert on
completion.  Near-duplicate requests (same shape, few changed tiles)
ride the engine's delta path instead: dispatch routes them through
:meth:`repro.ph.PHEngine.run_delta`, so a survey stream hitting the
daemon re-computes only its changed tiles.  Hit/miss counters live in
:class:`repro.serving.metrics.ServeMetrics`; evictions on the LRU
itself; both surface in :meth:`PHServer.stats` under ``"cache"``.

**Admission control**: each bucket queue is bounded by ``max_queue``.
At the bound, the ``"reject"`` policy raises :class:`AdmissionError`
carrying a ``retry_after_s`` hint (estimated from the queue depth and
recent batch latency); the ``"block"`` policy parks the submitting
thread until space frees.  ``shutdown(drain=True)`` stops admission,
lets the tick thread finish every queued request, and joins it;
``drain=False`` fails undispatched futures instead.

Thread model: client threads run ``submit`` (queue + metrics, no XLA);
the single tick thread runs every dispatch.  The shared engine is
internally locked (plan cache / regrow memo), so hammering the *engine*
from more threads is also safe — the daemon just never needs to.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np

from repro.cache import LRUCache
from repro.ph.config import ServeSpec
from repro.ph.engine import PHEngine, PHResult
from repro.pipeline.scheduler import assign_bucket
from repro.serving.metrics import ServeMetrics

__all__ = ["AdmissionError", "PHServer"]

# Bound on the exact-result tier: entries are host-side diagram rows
# (KBs), so the tier can afford far more entries than the device-resident
# delta frame store (DeltaSpec.cache_entries).
CACHE_TIER_ENTRIES = 256


class AdmissionError(RuntimeError):
    """Raised by ``submit`` when a bucket queue is full under the
    ``"reject"`` admission policy.  ``retry_after_s`` estimates when the
    queue should have space (depth worth of batches at the recent
    per-batch latency)."""

    def __init__(self, message: str, retry_after_s: float):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


class _Request:
    __slots__ = ("image", "truncate_value", "bucket", "future", "t_submit",
                 "cache_key")

    def __init__(self, image, truncate_value, bucket, cache_key=None):
        self.image = image
        self.truncate_value = truncate_value
        self.bucket = bucket
        self.future: Future = Future()
        self.t_submit = time.perf_counter()
        self.cache_key = cache_key


class PHServer:
    """Async PH daemon: bucketed continuous batching over one engine.

    ``engine``: the shared :class:`PHEngine`; its ``config.serve``
    (:class:`ServeSpec`) supplies the bucket set and serving knobs (a
    default spec is used when absent — dynamic pow-2 buckets, which
    serve correctly but cannot be fully pre-warmed).

    Lifecycle: construct (``start=True`` spawns the tick thread
    immediately), optionally :meth:`warmup`, ``submit`` at will, then
    :meth:`shutdown` — or use it as a context manager, which shuts down
    with a full drain::

        with PHServer(engine) as srv:
            srv.warmup()
            futs = [srv.submit(img) for img in images]
            diagrams = [f.result().diagram for f in futs]
    """

    def __init__(self, engine: PHEngine, *, start: bool = True,
                 spec: ServeSpec | None = None):
        if not isinstance(engine, PHEngine):
            raise TypeError(f"engine must be a PHEngine, "
                            f"got {type(engine).__name__}")
        self.engine = engine
        # ``spec`` overrides the engine config's serve spec — legitimate
        # for the host-side knobs (max_queue / tick / admission), which
        # never enter plan_key; keep buckets/batch_cap matched to the
        # engine's warmed plans or warmup() again.
        if spec is None:
            spec = engine.config.serve \
                if engine.config.serve is not None else ServeSpec()
        self.spec: ServeSpec = spec
        self.metrics = ServeMetrics(self.spec.batch_cap)
        # Cache tier: active only when the engine opts into delta compute
        # (config.delta enabled) — exact request hashes short-circuit at
        # submit, near-duplicates dispatch through run_delta.
        dspec = engine.config.delta
        self._delta_serving = dspec is not None and dspec.enabled
        self._cache: LRUCache | None = \
            LRUCache(CACHE_TIER_ENTRIES) if self._delta_serving else None
        self._cond = threading.Condition()
        self._queues: dict[tuple[int, int], deque[_Request]] = {}
        if self.spec.buckets is not None:
            for b in self.spec.buckets:     # fixed set, smallest-first
                self._queues[b] = deque()
        # Accepting from construction: a not-yet-started server queues
        # submissions and dispatches them once start() spawns the tick
        # thread (handy for priming; tests fill queues deterministically
        # this way).  Only shutdown() stops admission.
        self._accepting = True
        self._stop = False
        self._inflight = 0
        self._thread: threading.Thread | None = None
        self._warm_traces: int | None = None
        # Overlap engine: with async_harvest on, the tick thread only
        # *dispatches* batches — futures resolve (and in-flight counts
        # drop) on this harvest thread, so the tick never blocks on
        # result materialization.  The delta path keeps its synchronous
        # per-request dispatch (the cache tier inserts on completion).
        ospec = engine.overlap_spec()
        self._harvest: ThreadPoolExecutor | None = None
        if ospec.enabled and ospec.async_harvest and not self._delta_serving:
            self._harvest = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="ph-serve-harvest")
        if start:
            self.start()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        with self._cond:
            if self._thread is not None:
                raise RuntimeError("PHServer already started")
            if not self._accepting:
                raise RuntimeError("PHServer was shut down")
            self._stop = False
            self._thread = threading.Thread(
                target=self._loop, name="ph-serve-tick", daemon=True)
            self._thread.start()

    def warmup(self, **kwargs) -> dict:
        """Pre-trace the serving plans (delegates to
        :meth:`PHEngine.warmup`) and snapshot the engine's trace counter;
        :meth:`steady_state_traces` counts from here."""
        info = self.engine.warmup(**kwargs)
        self._warm_traces = self.engine.plan_stats()["traces"]
        return info

    def steady_state_traces(self) -> int | None:
        """Plan traces since :meth:`warmup` (``None`` before warmup).
        Zero on a warmed server is the whole point of the warm pool —
        ``serve_bench`` asserts it over a sustained mixed-shape stream."""
        if self._warm_traces is None:
            return None
        return self.engine.plan_stats()["traces"] - self._warm_traces

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every queued and in-flight request has resolved
        (or ``timeout`` elapses).  Returns True when fully drained."""
        with self._cond:
            return self._cond.wait_for(
                lambda: self._inflight == 0
                and not any(self._queues.values()), timeout)

    def shutdown(self, *, drain: bool = True,
                 timeout: float | None = None) -> None:
        """Stop admission and the tick thread.  ``drain=True`` (default)
        lets every already-queued request run to completion first;
        ``drain=False`` fails undispatched futures with
        ``RuntimeError`` (an in-flight batch still completes)."""
        with self._cond:
            self._accepting = False
            if not drain or self._thread is None:
                # No tick thread -> nothing will ever drain the queues.
                for q in self._queues.values():
                    while q:
                        q.popleft().future.set_exception(RuntimeError(
                            "PHServer shut down before dispatch"))
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        if self._harvest is not None:
            # In-flight batches finish resolving on the harvest thread
            # before shutdown returns (their futures must not dangle).
            self._harvest.shutdown(wait=True)
            self._harvest = None

    def __enter__(self) -> "PHServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=True)

    # -- client API --------------------------------------------------------

    def submit(self, image, truncate_value: float | None = None) -> Future:
        """Enqueue one 2D image; returns a future resolving to the
        :class:`PHResult` of ``engine.run(image, truncate_value)``
        (computed inside a padded bucket batch, repaired bit-identical).

        Raises :class:`AdmissionError` when the bucket queue is full
        under the ``"reject"`` policy; blocks under ``"block"``;
        ``ValueError`` for non-2D images or shapes exceeding the largest
        configured bucket; ``RuntimeError`` once shut down.
        """
        img = np.asarray(image)
        if img.ndim != 2:
            raise ValueError(f"expected a 2D image, got shape {img.shape}")
        bucket = assign_bucket(img.shape, self.spec.buckets,
                               self.engine.config.bucket_rounding)
        if bucket is None:
            raise ValueError(
                f"image shape {img.shape} exceeds the largest serve "
                f"bucket {self.spec.buckets[-1]}")
        cache_key = None
        if self._cache is not None:
            cache_key = self._request_key(img, truncate_value)
            with self._cond:
                accepting = self._accepting
            if accepting:
                got = self._cache.get(cache_key)
                if got is not None:
                    # Exact-hash hit: the computation is deterministic, so
                    # the stored PHResult *is* this request's answer.  No
                    # queue, no batch, no device work.
                    self.metrics.record_cache(hit=True)
                    fut: Future = Future()
                    fut.set_result(got)
                    return fut
                self.metrics.record_cache(hit=False)
        req = _Request(img, truncate_value, bucket, cache_key)
        with self._cond:
            if not self._accepting:
                raise RuntimeError("PHServer is not accepting requests")
            q = self._queues.setdefault(bucket, deque())
            if len(q) >= self.spec.max_queue:
                if self.spec.admission == "reject":
                    self.metrics.record_reject(bucket)
                    retry = self._retry_after(bucket)
                    raise AdmissionError(
                        f"bucket {bucket} queue full "
                        f"({self.spec.max_queue}); retry in ~{retry:.3g}s",
                        retry)
                self._cond.wait_for(
                    lambda: len(q) < self.spec.max_queue
                    or not self._accepting)
                if not self._accepting:
                    raise RuntimeError(
                        "PHServer shut down while blocked on admission")
            q.append(req)
            self.metrics.record_submit(bucket)
            self._cond.notify_all()
        return req.future

    def stats(self) -> dict:
        """Serving metrics snapshot + engine plan stats +
        ``steady_state_traces`` + cache-tier counters."""
        snap = self.metrics.snapshot()
        snap["engine"] = self.engine.plan_stats()
        snap["steady_state_traces"] = self.steady_state_traces()
        snap["cache"] = self.cache_stats()
        snap["overlap"] = self.engine.overlap_counters.snapshot()
        return snap

    # -- cache tier --------------------------------------------------------

    @staticmethod
    def _request_key(img: np.ndarray, truncate_value) -> tuple:
        """Exact request identity: content digest + shape + dtype +
        threshold.  Equal keys imply bit-identical results (the engine is
        deterministic), so a cached result can stand in for compute."""
        digest = hashlib.blake2b(np.ascontiguousarray(img).tobytes(),
                                 digest_size=16).digest()
        return (img.shape, str(img.dtype), digest,
                None if truncate_value is None else float(truncate_value))

    def cache_stats(self) -> dict:
        """Cache-tier counters: submit-side hit/miss (from
        :class:`ServeMetrics`), the LRU's own insert/evict counters, and
        the engine's delta frame-store counters."""
        out = {"enabled": self._delta_serving,
               "hits": self.metrics.cache_hits,
               "misses": self.metrics.cache_misses}
        if self._cache is not None:
            lru = self._cache.stats
            out.update(entries=len(self._cache), inserts=lru.inserts,
                       evictions=lru.evictions)
        out["delta_store"] = self.engine.delta_cache_stats()
        return out

    # -- daemon ------------------------------------------------------------

    def _retry_after(self, bucket) -> float:
        """Full-queue backoff hint: batches needed to drain the queue
        times the recent per-batch latency (tick interval when no batch
        has completed yet)."""
        per_batch = self.metrics.mean_batch_seconds(bucket)
        if per_batch is None:
            per_batch = self.spec.tick_interval_s
        batches = max(1, -(-self.spec.max_queue // self.spec.batch_cap))
        return batches * max(per_batch, self.spec.tick_interval_s)

    def _loop(self) -> None:
        cond = self._cond
        while True:
            with cond:
                cond.wait_for(lambda: self._stop
                              or any(self._queues.values()))
                if self._stop and not any(self._queues.values()):
                    return
            # Coalescing window: submitters racing this tick get into it.
            if self.spec.tick_interval_s > 0 and not self._stop:
                time.sleep(self.spec.tick_interval_s)
            while True:
                with cond:
                    bucket = next(
                        (b for b, q in self._queues.items() if q), None)
                    if bucket is None:
                        break
                    q = self._queues[bucket]
                    reqs = [q.popleft() for _ in
                            range(min(len(q), self.spec.batch_cap))]
                    self._inflight += len(reqs)
                    cond.notify_all()   # blocked submitters: space freed
                deferred = False
                try:
                    deferred = self._dispatch(bucket, reqs)
                finally:
                    if not deferred:
                        with cond:
                            self._inflight -= len(reqs)
                            cond.notify_all()   # drain()/shutdown waiters

    def _dispatch(self, bucket, reqs) -> bool:
        """Run one bucket micro-batch and resolve its futures.  A raise
        anywhere in compute fails *this round's* futures only — the loop
        (and every other queued request) carries on.

        Returns True when resolution was handed to the harvest thread
        (async harvest): the futures resolve there, bit-identically to
        the synchronous path — same :meth:`_finish_batch` on another
        thread — and the in-flight accounting follows them."""
        if self._delta_serving:
            self._dispatch_delta(bucket, reqs)
            return False
        t0 = time.perf_counter()
        imgs = [r.image for r in reqs]
        tvs = [r.truncate_value for r in reqs]
        pad = self.spec.batch_cap - len(imgs)
        if pad > 0:
            # Fixed dispatch shape (batch_cap, Hb, Wb): repeat a real
            # request into the free rows so the warmed plan always fits.
            imgs = imgs + [imgs[0]] * pad
            tvs = tvs + [tvs[0]] * pad
        try:
            # dedupe=False: the warmed plans require the fixed dispatch
            # shape; exact duplicates are the cache tier's job anyway.
            # Dispatch-only: device compute launches (and, with
            # async_overflow, D2H copies start) without blocking here.
            pending = self.engine.run_batch_async(imgs, tvs, bucket=bucket,
                                                  dedupe=False)
        except Exception as exc:        # noqa: BLE001 — isolate the round
            for r in reqs:
                r.future.set_exception(exc)
            self.metrics.record_failure(bucket, len(reqs))
            return False
        if self._harvest is not None:
            self._harvest.submit(self._harvest_batch, bucket, reqs,
                                 pending, t0)
            return True
        self.engine.overlap_counters.bump("dispatch_syncs")
        self._finish_batch(bucket, reqs, pending, t0)
        return False

    def _harvest_batch(self, bucket, reqs, pending, t0) -> None:
        """Harvest-thread entry: resolve the batch, then release its
        in-flight slots (drain()/shutdown wait on exactly this)."""
        try:
            self.engine.overlap_counters.bump("harvest_syncs")
            self._finish_batch(bucket, reqs, pending, t0)
        finally:
            with self._cond:
                self._inflight -= len(reqs)
                self._cond.notify_all()

    def _finish_batch(self, bucket, reqs, pending, t0) -> None:
        """Materialize one dispatched batch and resolve its futures —
        the blocking half of :meth:`_dispatch`, runnable on either the
        tick thread (sync) or the harvest thread (async)."""
        try:
            out = pending.resolve()
        except Exception as exc:        # noqa: BLE001 — isolate the round
            for r in reqs:
                r.future.set_exception(exc)
            self.metrics.record_failure(bucket, len(reqs))
            return
        t1 = time.perf_counter()
        diag = out.diagram
        thr = None if out.threshold is None else np.asarray(out.threshold)
        for i, r in enumerate(reqs):
            row = type(diag)(*(np.asarray(f)[i] for f in diag))
            r.future.set_result(PHResult(
                row, out.config, out.regrow,
                None if thr is None else float(thr[i])))
        self.metrics.record_batch(
            bucket,
            queue_waits=[t0 - r.t_submit for r in reqs],
            e2e=[t1 - r.t_submit for r in reqs],
            batch_s=t1 - t0)

    def _dispatch_delta(self, bucket, reqs) -> None:
        """Delta-serving round: each request runs through
        :meth:`PHEngine.run_delta` — near-duplicates of recent frames
        recompute only their dirty tiles — and the finished result is
        inserted into the exact-hash tier so an identical future request
        never reaches dispatch at all.  A per-request raise fails that
        future only."""
        t0 = time.perf_counter()
        done: list[_Request] = []
        for r in reqs:
            try:
                res = self.engine.run_delta(r.image, r.truncate_value)
            except Exception as exc:    # noqa: BLE001 — isolate the request
                r.future.set_exception(exc)
                self.metrics.record_failure(bucket, 1)
                continue
            if self._cache is not None and r.cache_key is not None:
                self._cache.put(r.cache_key, res)
            r.future.set_result(res)
            done.append(r)
        t1 = time.perf_counter()
        if done:
            self.metrics.record_batch(
                bucket,
                queue_waits=[t0 - r.t_submit for r in done],
                e2e=[t1 - r.t_submit for r in done],
                batch_s=t1 - t0)
