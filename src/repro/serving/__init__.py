"""PH-as-a-service: async daemon, bucketed continuous batching, SLO metrics.

    from repro.ph import PHConfig, PHEngine, ServeSpec
    from repro.serving import PHServer

    engine = PHEngine(PHConfig(serve=ServeSpec(buckets=(64, 128))))
    with PHServer(engine) as srv:
        srv.warmup()                        # pre-trace the warm plan pool
        fut = srv.submit(image)             # Future[PHResult]
        diagram = fut.result().diagram
    print(srv.stats())                      # p50/p95/p99, occupancy, ...

See :mod:`repro.serving.server` for the daemon and
:mod:`repro.serving.metrics` for the SLO instrumentation;
``launch/ph_serve.py`` wires both into a CLI demo and
``benchmarks/serve_bench.py`` into the gated benchmark.
"""
from repro.serving.metrics import (  # noqa: F401
    BucketMetrics,
    Reservoir,
    ServeMetrics,
    bucket_label,
)
from repro.serving.server import (  # noqa: F401
    AdmissionError,
    PHServer,
)
